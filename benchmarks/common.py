"""Shared benchmark utilities: timing, tracing, and CSV emission.

Every benchmark prints ``name,us_per_call,derived`` CSV rows, where
`derived` carries the figure's headline quantity (error/iterations/
ratio), so `python -m benchmarks.run` is grep-able.

Timing is span-backed (obs, DESIGN.md Sec. 14): `timed` wraps its
measurement loop in an `obs.span`, and `stopwatch` is the span-based
replacement for ad-hoc `time.perf_counter()` pairs — so every
benchmark's timing shows up in the exported Chrome/Perfetto trace
(`export_trace` writes ``benchmarks/TRACE_<bench>[_quick].json``, the
artifact `python -m repro.obs.report` summarizes).
"""

from __future__ import annotations

import contextlib
import os
import time

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import WVConfig, WVMethod, program_columns

WEIGHT_LSB = 8.06  # sqrt(65): cell-domain rms -> B=6 two-slice weight rms

_BENCH_DIR = os.path.dirname(os.path.abspath(__file__))


def timed(fn, *args, reps: int = 1, name: str | None = None):
    """Compile once, then time `reps` calls; returns (out, us_per_call).

    The measurement loop (including the trailing block_until_ready) is
    recorded as one ``bench`` span named `name` (or the callable's name).
    """
    fn(*args)  # compile
    label = name or getattr(fn, "__name__", "timed") or "timed"
    with obs.span(f"bench.{label}", cat="bench", reps=reps) as sp:
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(*args)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / reps * 1e6
        sp["us_per_call"] = us
    return out, us


class _Stopwatch:
    seconds: float = 0.0

    @property
    def us(self) -> float:
        return self.seconds * 1e6


@contextlib.contextmanager
def stopwatch(name: str, cat: str = "bench", **args):
    """Span-backed wall timer: ``with stopwatch("x") as w: ...; w.seconds``."""
    w = _Stopwatch()
    with obs.span(f"bench.{name}", cat=cat, **args):
        t0 = time.perf_counter()
        try:
            yield w
        finally:
            w.seconds = time.perf_counter() - t0


def trace_path(bench: str, quick: bool = False) -> str:
    """Gitignored trace artifact path next to the BENCH_*.json outputs."""
    suffix = "_quick" if quick else ""
    return os.path.join(_BENCH_DIR, f"TRACE_{bench}{suffix}.json")


def export_trace(bench: str, quick: bool = False) -> str:
    """Export the run's trace events; returns the written path."""
    path = obs.tracer.export(trace_path(bench, quick))
    print(f"# trace: {path}")
    return path


def run_wv(cfg: WVConfig, n_columns: int = 512, seed: int = 0):
    """Program random targets; returns per-column means dict + us/call."""
    tkey, pkey = jax.random.split(jax.random.PRNGKey(seed))
    targets = jax.random.randint(
        tkey, (n_columns, cfg.n_cells), 0, cfg.device.levels
    ).astype(jnp.float32)
    fn = jax.jit(lambda k, t: program_columns(k, t, cfg))
    (g, stats), us = timed(fn, pkey, targets)
    return {
        "rms_cell": float(jnp.mean(stats.rms_error_lsb)),
        "rms_weight": float(jnp.mean(stats.rms_error_lsb)) * WEIGHT_LSB,
        "iterations": float(jnp.mean(stats.iterations)),
        "latency_us": float(jnp.mean(stats.latency_ns)) / 1e3,
        "energy_nj": float(jnp.mean(stats.energy_pj)) / 1e3,
        "reads": float(jnp.mean(stats.reads)),
    }, us


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


ALL_METHODS = [WVMethod.CW_SC, WVMethod.MRA, WVMethod.HD_PV, WVMethod.HARP]
