"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` CSV rows, where
`derived` carries the figure's headline quantity (error/iterations/
ratio), so `python -m benchmarks.run` is grep-able.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import WVConfig, WVMethod, program_columns

WEIGHT_LSB = 8.06  # sqrt(65): cell-domain rms -> B=6 two-slice weight rms


def timed(fn, *args, reps: int = 1):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / reps * 1e6


def run_wv(cfg: WVConfig, n_columns: int = 512, seed: int = 0):
    """Program random targets; returns per-column means dict + us/call."""
    tkey, pkey = jax.random.split(jax.random.PRNGKey(seed))
    targets = jax.random.randint(
        tkey, (n_columns, cfg.n_cells), 0, cfg.device.levels
    ).astype(jnp.float32)
    fn = jax.jit(lambda k, t: program_columns(k, t, cfg))
    (g, stats), us = timed(fn, pkey, targets)
    return {
        "rms_cell": float(jnp.mean(stats.rms_error_lsb)),
        "rms_weight": float(jnp.mean(stats.rms_error_lsb)) * WEIGHT_LSB,
        "iterations": float(jnp.mean(stats.iterations)),
        "latency_us": float(jnp.mean(stats.latency_ns)) / 1e3,
        "energy_nj": float(jnp.mean(stats.energy_pj)) / 1e3,
        "reads": float(jnp.mean(stats.reads)),
    }, us


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")


ALL_METHODS = [WVMethod.CW_SC, WVMethod.MRA, WVMethod.HD_PV, WVMethod.HARP]
