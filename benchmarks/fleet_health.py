"""Fleet health & SLO observability under degradation (BENCH_fleet).

A small serving FLEET — one continuous-batching replica per deployed
chip, each programmed on its own (heterogeneous) silicon via PR 7's
correlated FaultConfig fields — serves ONE global Poisson arrival
stream through a least-loaded router while staggered verify-triggered
scrubs run between decode steps.  Every replica accumulates streaming
latency digests in-jit and per-tile health maps on its existing syncs
(DESIGN.md Sec. 16); a declarative `SLOPolicy` is evaluated host-side
once per fixed window over `obs.fleet_status()`.

The degradation scenario is the point of the benchmark: the LAST
replica deploys on bad silicon (a stuck-cell population the healthy
chips lack), but its first verify-triggered scrub is deferred to a
known window — deferred maintenance.  Until that window the fleet is
green.  At the inject window the scrub discovers the bad tiles
(bounded-retry refresh gives up on the stuck cells), the give-up-rate
rule breaches, the router drains the sick replica, and the remaining
capacity is below the offered load — so the windowed p99 latency rule
breaches in a following window.  Both firing windows are
HARD-ASSERTED:

* no SLO rule breaches in any window before the inject window;
* the give-up-rate rule fires exactly AT the inject window;
* the p99 latency rule fires after the inject window (the recorded
  first-breach window), never before.

Scheduler contracts are asserted per replica as in BENCH_serving:
`host_syncs == decode_steps` (the digests ride the one per-step
fetch).  Full mode commits BENCH_fleet.json; `--quick` writes the
gitignored BENCH_fleet_quick.json plus TRACE_fleet_quick.json and
fleet_status_quick.json for the CI dashboard render step.
"""

from __future__ import annotations

import collections
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import WVConfig, WVMethod
from repro.core.programmer import deploy_arrays
from repro.core.types import FaultConfig
from repro.lifetime import LifetimeSimulator
from repro.lifetime.refresh import RefreshConfig, RefreshPolicy
from repro.models import ModelConfig, init_params
from repro.serving import ContinuousScheduler, ServeEngine, poisson_requests

from .common import emit, export_trace

OUT = os.path.join(os.path.dirname(__file__), "BENCH_fleet.json")
OUT_QUICK = os.path.join(os.path.dirname(__file__), "BENCH_fleet_quick.json")

GIVE_UP_PULSES = 80
WINDOW_DIGEST = ("fleet.window_latency_steps", 0.0, 512.0, 128)


def _model_cfg(quick: bool) -> ModelConfig:
    return ModelConfig(
        name="fleet-bench",
        n_layers=1 if quick else 2,
        d_model=32 if quick else 64,
        n_heads=2,
        n_kv_heads=2,
        head_dim=16,
        d_ff=64 if quick else 128,
        vocab_size=64,
        dtype=jnp.float32,
        attn_chunk_q=16,
        attn_chunk_kv=16,
        remat=False,
        tie_embeddings=False,
    )


def _fault_cfg(sick: bool) -> FaultConfig:
    """Heterogeneous silicon: every chip carries correlated per-tile /
    per-chip variation (distinct per-replica deploy keys draw distinct
    maps); the sick chip additionally has a stuck-cell population."""
    base = FaultConfig(
        columns_per_tile=32,
        tiles_per_chip=8,
        sigma_tile_fault_dec=0.3,
        sigma_tile_eff_frac=0.05,
        sigma_chip_eff_frac=0.05,
    )
    if sick:
        base = base.replace(p_stuck_hrs=0.02, p_stuck_lrs=0.01)
    return base


def _free_slots(sched: ContinuousScheduler) -> int:
    return int(np.sum(np.asarray(sched._rid) < 0))


def main(quick: bool = False) -> dict:
    cfg = _model_cfg(quick)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_replicas = 2
    sick = n_replicas - 1
    n_slots = 4
    max_len = 64
    prompt_lens = (3, 14)
    max_new = (4, 8)
    window_steps = 16 if quick else 24
    n_windows = 8
    inject_window = 2
    rate = 1.2  # > post-drain capacity, < the fleet's
    n_requests = int(rate * window_steps * n_windows)
    scrub_dt_s = 30.0

    # Default (converging) fine budget: healthy cells program to target,
    # so give-ups are the signature of genuinely bad silicon — the
    # stuck-cell population on the sick chip — not of a starved sweep.
    wv = WVConfig(method=WVMethod.HARP, give_up_pulses=GIVE_UP_PULSES)

    # ------------------------------------------------- deploy the fleet
    replicas = []
    for r in range(n_replicas):
        fc = _fault_cfg(sick=(r == sick))
        deployed, report = deploy_arrays(
            jax.random.PRNGKey(100 + r), params, wv, fault_cfg=fc
        )
        engine = ServeEngine(cfg, deployed.materialize(), temperature=0.7)
        sched = ContinuousScheduler(
            engine, n_slots=n_slots, max_len=max_len,
            key=jax.random.PRNGKey(200 + r), name=f"rep{r}",
        )
        sched.warmup(prompt_range=prompt_lens)
        warm = dict(sched.trace_counts)
        sched.reset(keep_traces=True)
        sim = LifetimeSimulator(
            jax.random.PRNGKey(300 + r), deployed,
            refresh_cfg=RefreshConfig(policy=RefreshPolicy.VERIFY_TRIGGERED),
            on_refresh=engine.swap_params,
            columns_per_tile=fc.columns_per_tile,
        )
        replicas.append(
            {
                "r": r,
                "sick": r == sick,
                "fault_cfg": fc,
                "deployed": deployed,
                "sched": sched,
                "sim": sim,
                "warm": warm,
                "rms_cell_error_lsb": round(float(report.rms_cell_error_lsb), 4),
                "deploy_gave_up_cells": float(report.total_gave_up_cells),
                "completed_seen": 0,
            }
        )
    n_cells_fleet = sum(
        int(np.prod(arr.g.shape))
        for rep in replicas
        for arr in rep["deployed"].arrays.values()
    )

    # --------------------------------------------------- SLO policy
    p99_ceiling = 15.0
    give_up_ceiling = 1e-4
    policy = obs.SLOPolicy(
        rules=(
            obs.SLORule(
                "p99_latency", "digests.fleet.window_latency_steps.p99",
                p99_ceiling,
            ),
            obs.SLORule(
                "give_up_rate", "health.gauges.fleet.give_up_rate",
                give_up_ceiling,
            ),
            obs.SLORule(
                "scrub_backlog", "health.gauges.lifetime.refresh_debt_epochs",
                float(n_windows + 1),
            ),
        )
    )

    # ------------------------------------------------- global serve loop
    reqs = poisson_requests(
        23, n_requests, rate=rate, vocab=cfg.vocab_size,
        prompt_lens=prompt_lens, max_new=max_new,
    )
    pending = collections.deque(sorted(reqs, key=lambda q: (q.arrival, q.rid)))
    drained: set[int] = set()
    windows = []
    first_breach: dict[str, int | None] = {ru.name: None for ru in policy.rules}
    t = 0
    for w in range(n_windows):
        # window-scoped latency digest: completions THIS window only
        obs.digests.reset(WINDOW_DIGEST[0])
        for _ in range(window_steps):
            # least-loaded router over the healthy replicas
            while pending and pending[0].arrival <= t:
                live = [rep for rep in replicas if rep["r"] not in drained]
                live = [rep for rep in live if _free_slots(rep["sched"]) > 0]
                if not live:
                    break
                rep = max(live, key=lambda q: _free_slots(q["sched"]))
                rep["sched"].now = float(t)
                rep["sched"].admit(pending.popleft())
            for rep in replicas:
                if rep["sched"].active_slots():
                    rep["sched"].now = float(t)
                    rep["sched"].step()
            t += 1
            # staggered verify-triggered scrubs: replica r's slot within
            # the window is offset by 4r steps; the sick replica's
            # maintenance is DEFERRED until the inject window (this is
            # the injected degradation crossing into view).
            for rep in replicas:
                r = rep["r"]
                if t % window_steps != (4 * (r + 1)) % window_steps:
                    continue
                if rep["sick"] and w < inject_window:
                    continue
                # Deferred maintenance catches up with a FULL scrub the
                # first time it runs (every leaf is overdue), which is
                # exactly when the bad tiles surface; steady-state
                # scrubs stay incremental (O(max_leaves) per epoch).
                catch_up = rep["sick"] and rep["sim"].epoch == 0
                rep["sim"].step_epoch(
                    scrub_dt_s, max_leaves=None if catch_up else 2
                )
        # ---- end of window: harvest completions + evaluate the policy
        arrivals = sum(1 for q in reqs if w * window_steps <= q.arrival < t)
        completed_w = 0
        for rep in replicas:
            done = rep["sched"].completed
            for rec in done[rep["completed_seen"]:]:
                name, lo, hi, nb = WINDOW_DIGEST
                obs.digests.observe(
                    name, rec.latency_steps, lo=lo, hi=hi, n_buckets=nb
                )
                completed_w += 1
            rep["completed_seen"] = len(done)
        gave_up = obs.registry.snapshot().get("lifetime.gave_up_cells", 0.0)
        obs.health_registry.set_gauge(
            "fleet.give_up_rate", gave_up / n_cells_fleet
        )
        results = policy.evaluate(obs.fleet_status(), window=w)
        breaches = {res["name"]: bool(res["breached"]) for res in results}
        for res in results:
            if res["breached"] and first_breach[res["name"]] is None:
                first_breach[res["name"]] = w
        # health-driven routing: a give-up breach drains the sick replica
        if breaches.get("give_up_rate") and sick not in drained:
            drained.add(sick)
        wd = obs.digests.get(WINDOW_DIGEST[0])
        windows.append(
            {
                "window": w,
                "arrivals": arrivals,
                "completed": completed_w,
                "queue_len": len(pending),
                "p99_window_latency_steps": (
                    wd.quantile(0.99) if wd is not None else None
                ),
                "give_up_rate": gave_up / n_cells_fleet,
                "drained": sorted(drained),
                "breaches": breaches,
            }
        )
        emit(
            f"fleet.window{w}",
            0.0,
            f"p99={windows[-1]['p99_window_latency_steps']};"
            f"give_up_rate={windows[-1]['give_up_rate']:.2e};"
            f"breaches={sum(breaches.values())}",
        )

    # -------------------------------------------------- hard assertions
    for rep in replicas:
        s = rep["sched"]
        assert s.host_syncs == s.decode_steps, (
            rep["r"], s.host_syncs, s.decode_steps,
        )
        retraces = {
            k: s.trace_counts[k] - rep["warm"][k] for k in rep["warm"]
        }
        assert all(v == 0 for v in retraces.values()), (rep["r"], retraces)
    pre = [wd for wd in windows if wd["window"] < inject_window]
    assert all(not any(wd["breaches"].values()) for wd in pre), (
        f"SLO breach before the inject window: {pre}"
    )
    assert first_breach["give_up_rate"] == inject_window, (
        f"give-up-rate rule fired at {first_breach['give_up_rate']}, "
        f"expected inject window {inject_window}"
    )
    assert (
        first_breach["p99_latency"] is not None
        and first_breach["p99_latency"] >= inject_window
    ), f"p99 rule fired at {first_breach['p99_latency']}"

    # ------------------------------------------------------- artifacts
    per_replica = {}
    for rep in replicas:
        s = rep["sched"]
        per_replica[f"rep{rep['r']}"] = {
            "sick": rep["sick"],
            "rms_cell_error_lsb": rep["rms_cell_error_lsb"],
            "deploy_gave_up_cells": rep["deploy_gave_up_cells"],
            "decode_steps": s.decode_steps,
            "host_syncs": s.host_syncs,
            "completed": len(s.completed),
            "scrub_epochs": rep["sim"].epoch,
            "digests": s.digest_stats(),
        }
    status = obs.fleet_status(
        extra={
            "fleet": {
                "windows": windows,
                "first_breach_window": first_breach,
                "inject_window": inject_window,
                "drained": sorted(drained),
            }
        }
    )
    out = {
        "config": {
            "quick": quick,
            "model": cfg.name,
            "n_replicas": n_replicas,
            "sick_replica": sick,
            "n_slots": n_slots,
            "max_len": max_len,
            "rate_req_per_step": rate,
            "n_requests": n_requests,
            "window_steps": window_steps,
            "n_windows": n_windows,
            "inject_window": inject_window,
            "give_up_pulses": GIVE_UP_PULSES,
            "slo": {
                "p99_latency_steps_ceiling": p99_ceiling,
                "give_up_rate_ceiling": give_up_ceiling,
            },
        },
        "replicas": per_replica,
        "windows": windows,
        "contracts": {
            "host_syncs_per_step": 1.0,
            "retraces_after_warmup": 0,
            "no_breach_before_inject": True,
            "give_up_first_breach_window": first_breach["give_up_rate"],
            "p99_first_breach_window": first_breach["p99_latency"],
        },
    }
    path = OUT_QUICK if quick else OUT
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)

    # dashboard inputs: digest/health/SLO instants + fleet status JSON
    obs.digests.emit()
    obs.health_registry.emit()
    export_trace("fleet", quick)
    status_path = os.path.join(
        os.path.dirname(__file__),
        f"fleet_status{'_quick' if quick else ''}.json",
    )
    with open(status_path, "w") as f:
        json.dump(status, f, indent=1, sort_keys=True, default=str)
    print(f"# fleet status: {status_path}")
    emit(
        "fleet.health",
        0.0,
        f"give_up@{first_breach['give_up_rate']};"
        f"p99@{first_breach['p99_latency']};json={os.path.basename(path)}",
    )
    return out


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
