"""Faulty-silicon tolerance sweep (BENCH_faults): fault rate x WV x remap.

Sweeps per-cell fault probability (stuck-at-HRS / stuck-at-LRS / weak
cells with collapsed step efficiency, plus a spatially correlated
per-tile rate field) across WV methods and three deployment arms:

* ``none``  — faults injected, no mitigation: stuck cells land wherever
  the weight matrix put them and the WV loop burns its full retry
  budget before giving up;
* ``remap`` — bounded-retry WV with give-up + spare-column remapping:
  columns whose give-up count crosses the threshold are re-programmed
  onto spare columns and served through the `RemapTable` permutation;
* ``remap`` additionally uses fault-aware placement (`plan_placement`):
  leaves are allocated to the cleanest physical tiles first, so the
  correlated per-tile fault field is dodged rather than just repaired.

Three contracts are HARD-ASSERTED on every run (CI quick smoke):

* zero-fault bit-identity — a deployment with the entire fault/give-up
  machinery enabled but all fault rates zero materializes bit-identical
  weights to a plain deployment (the robustness layer is provably free
  when unused);
* exactly one device->host sync per deploy, in every arm — give-up and
  remap accounting ride the existing `DeployReport` fetch
  (DESIGN.md Sec. 15);
* graceful degradation — at the highest fault rate the remapped arm's
  materialized-weight error stays below the unmitigated arm's, and the
  report carries non-zero give-up/remap counts to prove the path ran.

Full mode commits BENCH_faults.json; ``--quick`` writes the
(gitignored) BENCH_faults_quick.json and shrinks the sweep for CI.
"""

from __future__ import annotations

import json
import os
import sys

import jax
import jax.numpy as jnp

from repro.core import WVMethod, default_config_for_array
from repro.core import pipeline, remap
from repro.core.programmer import deploy_arrays
from repro.core.types import FaultConfig

from .common import emit, export_trace, stopwatch
from .fig10_robustness import _train_tiny_lm

OUT = os.path.join(os.path.dirname(__file__), "BENCH_faults.json")
OUT_QUICK = os.path.join(os.path.dirname(__file__), "BENCH_faults_quick.json")

# Above the worst healthy cell's fine-pulse usage for every method at
# the default 50-iteration cap (measured ~40-79 for HARP), so the
# zero-fault deploy is bit-identical; weak cells (5% step efficiency)
# and stuck cells exhaust it and give up.
GIVE_UP_PULSES = 80


def _fault_cfg(rate: float) -> FaultConfig:
    """Per-cell fault mix at total probability `rate` (before the
    correlated per-tile multiplier): half stuck-at-HRS, a quarter
    stuck-at-LRS, a quarter weak cells."""
    return FaultConfig(
        p_stuck_hrs=0.50 * rate,
        p_stuck_lrs=0.25 * rate,
        p_weak=0.25 * rate,
        sigma_tile_fault_dec=0.5,
        columns_per_tile=64,
        tiles_per_chip=16,
    )


def _wmse(a, b) -> float:
    """Mean squared error between two materialized parameter trees."""
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    num = sum(float(jnp.sum((x - y) ** 2)) for x, y in zip(la, lb))
    den = sum(x.size for x in la)
    return num / max(den, 1)


def _deploy_one_sync(key, params, wv, **kw):
    """deploy_arrays wrapped in the single-host-sync contract assert."""
    before = pipeline.host_sync_count()
    dep, rep = deploy_arrays(key, params, wv, **kw)
    syncs = pipeline.host_sync_count() - before
    assert syncs == 1, f"deploy performed {syncs} host syncs, contract is 1"
    return dep, rep


def main(quick: bool = False) -> dict:
    methods = [WVMethod.HARP] if quick else [WVMethod.CW_SC, WVMethod.HARP]
    rates = (0.02,) if quick else (0.002, 0.008, 0.02)
    with stopwatch("faults.train"):
        cfg, params, eval_fn, eval_batch = _train_tiny_lm(
            steps=40 if quick else 220
        )
    clean = float(eval_fn(params, eval_batch))
    emit("faults.clean", 0.0, f"eval_loss={clean:.4f}")

    remap_cfg = remap.RemapConfig(spare_frac=0.25, placement=True)
    rows = []
    out = {}
    for m in methods:
        wv_plain = default_config_for_array(32).replace(method=m)
        wv_guard = wv_plain.replace(give_up_pulses=GIVE_UP_PULSES)

        # ---- zero-fault reference + bit-identity contract -----------
        dep0, rep0 = _deploy_one_sync(jax.random.PRNGKey(42), params, wv_plain)
        ref = dep0.materialize()
        dep0g, _ = _deploy_one_sync(
            jax.random.PRNGKey(42), params, wv_guard, fault_cfg=FaultConfig()
        )
        refg = dep0g.materialize()
        assert all(
            bool(jnp.all(a == b))
            for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(refg))
        ), (
            "zero-fault deploy with give-up/fault machinery enabled is "
            "not bit-identical to the plain deploy"
        )
        loss0 = float(eval_fn(ref, eval_batch))
        emit(
            f"faults.{m.value}.rate0",
            0.0,
            f"dloss={loss0 - clean:+.4f} bit_identical=1",
        )

        for rate in rates:
            fc = _fault_cfg(rate)
            for arm, rc in (("none", None), ("remap", remap_cfg)):
                with stopwatch(f"faults.{m.value}.{rate:g}.{arm}") as w:
                    dep, rep = _deploy_one_sync(
                        jax.random.PRNGKey(42), params, wv_guard,
                        fault_cfg=fc, remap_cfg=rc,
                    )
                    mat = dep.materialize()
                loss = float(eval_fn(mat, eval_batch))
                wmse = _wmse(mat, ref)
                row = {
                    "method": m.value,
                    "fault_rate": rate,
                    "arm": arm,
                    "dloss": round(loss - clean, 5),
                    "wmse_vs_clean": wmse,
                    "gave_up_cells": rep.total_gave_up_cells,
                    "retry_pulses": rep.total_retry_pulses,
                    "remapped_columns": rep.remapped_columns,
                    "deploy_s": round(w.seconds, 3),
                    "host_syncs": 1,
                }
                rows.append(row)
                out[(m.value, rate, arm)] = row
                emit(
                    f"faults.{m.value}.rate{rate:g}.{arm}",
                    w.seconds * 1e6,
                    f"dloss={loss - clean:+.4f} wmse={wmse:.2e} "
                    f"gave_up={rep.total_gave_up_cells:.0f} "
                    f"remapped={rep.remapped_columns}",
                )

    # ---- graceful-degradation contracts at the highest fault rate ----
    hi = max(rates)
    for m in methods:
        norem = out[(m.value, hi, "none")]
        remapd = out[(m.value, hi, "remap")]
        assert norem["gave_up_cells"] > 0, (
            "give-up path never fired at the highest fault rate"
        )
        assert remapd["remapped_columns"] > 0, (
            "remap path never fired at the highest fault rate"
        )
        assert remapd["wmse_vs_clean"] < norem["wmse_vs_clean"], (
            f"{m.value}: remap did not reduce weight error "
            f"({remapd['wmse_vs_clean']:.3e} vs {norem['wmse_vs_clean']:.3e})"
        )
        # End-task deltas on the tiny bench LM are noise-level, so they
        # get a tolerance band (as in fig10/test_system).
        assert remapd["dloss"] < norem["dloss"] + 0.01

    result = {
        "config": {
            "quick": quick,
            "model": cfg.name,
            "methods": [m.value for m in methods],
            "fault_rates": list(rates),
            "give_up_pulses": GIVE_UP_PULSES,
            "spare_frac": remap_cfg.spare_frac,
            "placement": remap_cfg.placement,
            "clean_eval_loss": round(clean, 5),
        },
        "rows": rows,
        "contracts": {
            "zero_fault_bit_identical": True,
            "host_syncs_per_deploy": 1,
        },
    }
    path = OUT_QUICK if quick else OUT
    with open(path, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
    export_trace("faults", quick)
    emit(
        "fault.tolerance",
        0.0,
        f"rates={len(rates)};methods={len(methods)};"
        f"json={os.path.basename(path)}",
    )
    return result


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
