"""Retention under drift + refresh policies: accuracy vs time vs energy.

The lifetime scenario (ISSUE 1 / DESIGN.md Sec. 9): program columns,
age them through wall-clock epochs (relaxation, log-time drift, read
disturb), and scrub with each policy:

  none             - drift baseline: error grows epoch over epoch.
  periodic         - full re-program of every column every epoch:
                     retention ceiling, maximum maintenance energy.
  verify_triggered - voted verify sweeps flag drifted columns; only
                     those re-enter the WV pipeline.

Trends asserted (the subsystem's headline claim):
  * `none` degrades measurably; both refresh policies retain accuracy.
  * For the Hadamard methods (HD-PV / HARP), verify-triggered scrubbing
    retains accuracy at measurably lower maintenance energy than blind
    periodic re-programming — a Hadamard sweep screens all N cells of a
    column at once, so detection is ~N x cheaper than one-hot re-reads
    and the array only pays programming energy where it drifted.

Emits `BENCH_retention.json` (full time series per method x policy)
next to this file plus the standard ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import json
import pathlib
from functools import partial

import jax
import jax.numpy as jnp

import repro.core.device as dev_mod
from repro.core import CircuitCost, WVConfig, WVMethod, program_columns
from repro.lifetime import (
    DriftConfig,
    RefreshConfig,
    RefreshPolicy,
    advance,
    apply_refresh,
    init_cell_state,
)

from .common import WEIGHT_LSB, emit, export_trace, stopwatch

_POLICIES = [
    RefreshPolicy.NONE,
    RefreshPolicy.PERIODIC,
    RefreshPolicy.VERIFY_TRIGGERED,
]
_METHODS = [WVMethod.CW_SC, WVMethod.MRA, WVMethod.HD_PV, WVMethod.HARP]

# Accelerated-aging knobs: an hour per epoch with a heavy drift tail so
# six epochs of simulation show month-scale dispersion.
_EPOCHS = 6
_DT_S = 3600.0
_READS = 5e4
_DRIFT = DriftConfig(nu_drift=0.01, sigma_nu_frac=0.8)


# One compiled programming fn per config: the three policies of a method
# share shapes, so recompiling per _simulate call would triple compile time.
_PROG_CACHE: dict = {}


def _prog(cfg: WVConfig):
    fn = _PROG_CACHE.get(cfg)
    if fn is None:
        fn = jax.jit(partial(program_columns, cfg=cfg))
        _PROG_CACHE[cfg] = fn
    return fn


def _simulate(
    cfg: WVConfig, policy: RefreshPolicy, n_columns: int, seed: int
) -> dict:
    cost = CircuitCost()
    tkey, pkey, dkey, skey = jax.random.split(jax.random.PRNGKey(seed), 4)
    targets = jax.random.randint(
        tkey, (n_columns, cfg.n_cells), 0, cfg.device.levels
    ).astype(jnp.float32)
    d2d = dev_mod.sample_d2d(dkey, targets.shape, cfg.device)
    g, _ = _prog(cfg)(pkey, targets, d2d=d2d)
    state = init_cell_state(skey, g, d2d, cfg.device, _DRIFT)
    rcfg = RefreshConfig(policy=policy)
    series = []
    for epoch in range(_EPOCHS):
        k_e = jax.random.fold_in(jax.random.PRNGKey(seed + 1), epoch)
        k_adv, k_ref = jax.random.split(k_e)
        state = advance(k_adv, state, _DT_S, _READS, cfg.device, _DRIFT)
        rms_pre = float(jnp.sqrt(jnp.mean((state.g - targets) ** 2)))
        state, out = apply_refresh(
            k_ref, state, targets, cfg, cost, _DRIFT, rcfg, epoch
        )
        series.append(
            dict(
                epoch=epoch,
                t_s=(epoch + 1) * _DT_S,
                rms_cell_lsb=rms_pre,
                rms_weight=rms_pre * WEIGHT_LSB,
                reprogrammed=out.n_reprogrammed,
                verify_energy_pj=out.verify_energy_pj,
                program_energy_pj=out.program_energy_pj,
            )
        )
    return dict(
        method=cfg.method.value,
        policy=policy.value,
        series=series,
        final_rms_cell_lsb=series[-1]["rms_cell_lsb"],
        total_verify_energy_pj=sum(r["verify_energy_pj"] for r in series),
        total_program_energy_pj=sum(r["program_energy_pj"] for r in series),
        total_maintenance_energy_pj=sum(
            r["verify_energy_pj"] + r["program_energy_pj"] for r in series
        ),
    )


def main(n_columns: int = 192, seed: int = 0) -> dict:
    results = {}
    for m in _METHODS:
        cfg = WVConfig(method=m)
        for policy in _POLICIES:
            with stopwatch(
                f"retention.{m.value}.{policy.value}", cat="lifetime"
            ) as w:
                r = _simulate(cfg, policy, n_columns, seed)
            results[(m.value, policy.value)] = r
            emit(
                f"retention.{m.value}.{policy.value}",
                w.us,
                f"rms_final={r['final_rms_cell_lsb']:.3f} "
                f"E_maint_nj={r['total_maintenance_energy_pj'] / 1e3:.0f} "
                f"reprog={sum(s['reprogrammed'] for s in r['series'])}",
            )

    out = pathlib.Path(__file__).with_name("BENCH_retention.json")
    out.write_text(
        json.dumps(
            {f"{k[0]}.{k[1]}": v for k, v in results.items()}, indent=1
        )
    )
    export_trace("retention")

    for m in ("hd_pv", "harp"):
        none_r = results[(m, "none")]
        peri = results[(m, "periodic")]
        vt = results[(m, "verify_triggered")]
        # Retention: both refresh policies beat free-running drift...
        assert vt["final_rms_cell_lsb"] < none_r["final_rms_cell_lsb"], m
        # ...and verify-triggered stays within noise of blind periodic
        # (it leaves sub-threshold drift in place by design)...
        assert (
            vt["final_rms_cell_lsb"] < peri["final_rms_cell_lsb"] + 0.1
        ), m
        # ...at measurably lower maintenance energy.
        assert (
            vt["total_maintenance_energy_pj"]
            < 0.75 * peri["total_maintenance_energy_pj"]
        ), (m, vt["total_maintenance_energy_pj"],
            peri["total_maintenance_energy_pj"])
        emit(
            f"retention.{m}.vt_vs_periodic",
            0.0,
            f"energy_ratio="
            f"{vt['total_maintenance_energy_pj'] / peri['total_maintenance_energy_pj']:.2f} "
            f"drms={vt['final_rms_cell_lsb'] - peri['final_rms_cell_lsb']:+.3f}",
        )
    # The compare-only Hadamard detector is the cheapest verify spend.
    assert (
        results[("harp", "verify_triggered")]["total_verify_energy_pj"]
        < results[("hd_pv", "verify_triggered")]["total_verify_energy_pj"]
    )
    return results


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
