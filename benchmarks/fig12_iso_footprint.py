"""Fig. 12: accuracy / latency / energy vs 5-read averaging (iso-footprint).

Paper headline: at matched robustness, HD-PV is 6.1x faster and 6.2x
more energy-efficient than MRA-5; HARP is 3.5x faster and 9.5x more
energy-efficient.  Setting: sigma_map/Gmax = 0.10, read noise 0.7 LSB,
B=6, Bc=3, N=32, K=2, 9-bit ADC.
"""

from __future__ import annotations

from repro.core import WVConfig, WVMethod

from .common import ALL_METHODS, emit, run_wv

PAPER_RATIOS = {"hd_pv": (6.1, 6.2), "harp": (3.5, 9.5)}
BAND = 0.45  # accept within +-45% of the paper ratio (device-model spread)


def main(n_columns: int = 512) -> dict:
    res = {}
    for m in ALL_METHODS:
        r, us = run_wv(WVConfig(method=m), n_columns, seed=1)
        res[m.value] = r
        emit(
            f"fig12.{m.value}",
            us,
            f"rmsW={r['rms_weight']:.2f} lat_us={r['latency_us']:.1f} "
            f"e_nj={r['energy_nj']:.1f}",
        )
    mra = res["mra"]
    ok = True
    for v, (lat_ref, en_ref) in PAPER_RATIOS.items():
        lat = mra["latency_us"] / res[v]["latency_us"]
        en = mra["energy_nj"] / res[v]["energy_nj"]
        emit(
            f"fig12.ratio.{v}",
            0.0,
            f"lat={lat:.1f}x (paper {lat_ref}x) energy={en:.1f}x (paper {en_ref}x)",
        )
        ok &= abs(lat - lat_ref) / lat_ref < BAND or lat > lat_ref
        ok &= abs(en - en_ref) / en_ref < BAND or en > en_ref
    # robustness at matched footprint: both Hadamard methods at least as
    # accurate as MRA-5's recovery band relative to CW-SC
    assert res["hd_pv"]["rms_weight"] <= res["cw_sc"]["rms_weight"]
    assert ok, "latency/energy ratios left the paper band"
    return res


if __name__ == "__main__":
    main()
