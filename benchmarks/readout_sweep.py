"""Readout-variant sweep: per-column ADC reference drift x calibration.

The first scenario the unified readout subsystem (DESIGN.md Sec. 12)
unlocks as *config, not code*: every column's converter carries a static
reference offset (sigma_col_offset_lsb, a la ADC reference tuning —
arXiv:2502.05948), and programming runs under three read-path variants:

  clean       — no offset drift (the paper's baseline read path)
  drifted     — offsets sampled once per column, uncalibrated
  calibrated  — same offsets, trimmed from K reference reads
                (`readout.calibrate.calibrate_offsets`) before WV

One-hot readouts (CW-SC, MRA) eat a static offset as a systematic
per-cell programming error, so drift poisons them and reference tuning
rescues them.  Hadamard readouts cancel any measurement-constant offset
on the N-1 balanced rows at decode — the same structural immunity as
for common-mode noise — so they barely move with or without
calibration.  Calibration itself is priced through the shared cost
model (K full-SAR sweeps per column, `readout.cost.sweep_cost`).

Emits ``name,us_per_call,derived`` CSV rows and BENCH_readout.json
(BENCH_readout_quick.json for the CI smoke run, which must not clobber
the committed full-mode trajectory).

Asserts (ISSUE 4 satellite):
* drift degrades one-hot programming by > 2x RMS;
* calibration recovers one-hot RMS to < 1.4x clean;
* Hadamard methods degrade < half as much as one-hot under the same
  drift, with no calibration at all.
"""

from __future__ import annotations

import json
import pathlib
import sys

import jax
import jax.numpy as jnp

from repro.core import CircuitCost, NoiseConfig, WVMethod, default_config_for_array
from repro.core.wv import program_columns
from repro.readout import (
    Converter,
    ReadoutBasis,
    calibrate_offsets,
    for_wv_method,
    sample_col_offsets,
    sweep_cost,
)

from .common import emit, export_trace, timed

_SIGMA_READ = 0.7      # severe verify-read noise (paper Fig. 10 regime)
_SIGMA_OFFSET = 1.5    # static per-column reference drift, cell-LSB
_K_CAL = 8             # calibration reads per column


def main(quick: bool = False) -> dict:
    if quick:
        methods = [WVMethod.MRA, WVMethod.HARP]
        n_columns = 96
    else:
        methods = [WVMethod.CW_SC, WVMethod.MRA, WVMethod.HD_PV, WVMethod.HARP]
        n_columns = 384

    rows: dict[str, float] = {}
    rms: dict[tuple[str, str], float] = {}
    for m in methods:
        cfg = default_config_for_array(32).replace(
            method=m, noise=NoiseConfig(sigma_read_lsb=_SIGMA_READ)
        )
        rcfg = for_wv_method(cfg).replace(sigma_col_offset_lsb=_SIGMA_OFFSET)
        tkey, okey, ckey, pkey = jax.random.split(jax.random.PRNGKey(0), 4)
        targets = jax.random.randint(
            tkey, (n_columns, cfg.n_cells), 0, cfg.device.levels
        ).astype(jnp.float32)
        offsets = sample_col_offsets(okey, n_columns, rcfg)
        trimmed = calibrate_offsets(ckey, offsets, rcfg, k_reads=_K_CAL)

        fn = jax.jit(
            lambda k, t, o, cfg=cfg: program_columns(k, t, cfg, col_offset=o)
        )
        for scenario, offs in (
            ("clean", None),
            ("drifted", offsets),
            ("calibrated", trimmed),
        ):
            (g, st), us = timed(
                fn, pkey, targets, offs, name=f"readout.{m.value}.{scenario}"
            )
            r = float(jnp.mean(st.rms_error_lsb))
            en = float(jnp.mean(st.energy_pj))
            rms[(m.value, scenario)] = r
            rows[f"{m.value}.{scenario}.rms_cell_lsb"] = r
            rows[f"{m.value}.{scenario}.energy_pj"] = en
            derived = f"rms={r:.3f} energy_pj={en:.0f}"
            if scenario == "calibrated":
                # Reference tuning overhead: K full-SAR sweeps per
                # column (calibrate_offsets always reads through the SAR
                # converter regardless of the method's verify converter),
                # priced by the same sweep model WV verify pays.
                _, e_cal = sweep_cost(
                    rcfg.replace(converter=Converter.SAR, avg_reads=1),
                    CircuitCost(),
                )
                overhead = _K_CAL * float(e_cal) / en
                rows[f"{m.value}.calibration_energy_frac"] = overhead
                derived += f" cal_overhead={overhead:.3f}"
            emit(f"readout.{m.value}.{scenario}", us, derived)

    # --- contract: drift poisons one-hot readouts, calibration rescues
    # them, Hadamard readouts are structurally immune.
    one_hot = [m for m in methods
               if for_wv_method(default_config_for_array(32).replace(method=m)
                                ).basis == ReadoutBasis.ONE_HOT]
    hadamard = [m for m in methods if m not in one_hot]
    for m in one_hot:
        degr = rms[(m.value, "drifted")] / rms[(m.value, "clean")]
        recov = rms[(m.value, "calibrated")] / rms[(m.value, "clean")]
        assert degr > 2.0, (m.value, degr)
        assert recov < 1.4, (m.value, recov)
        for h in hadamard:
            degr_h = rms[(h.value, "drifted")] / rms[(h.value, "clean")]
            assert degr_h < 0.5 * degr, (h.value, degr_h, m.value, degr)
    emit("readout.contract", 0.0,
         "onehot-degrades calibration-recovers hadamard-immune")

    result = dict(
        quick=quick,
        sigma_read_lsb=_SIGMA_READ,
        sigma_col_offset_lsb=_SIGMA_OFFSET,
        k_calibration_reads=_K_CAL,
        n_columns=n_columns,
        **rows,
    )
    name = "BENCH_readout_quick.json" if quick else "BENCH_readout.json"
    out = pathlib.Path(__file__).with_name(name)
    out.write_text(json.dumps(result, indent=1))
    export_trace("readout", quick)
    return result


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main(quick="--quick" in sys.argv)
