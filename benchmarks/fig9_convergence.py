"""Fig. 9(a,b): WV convergence and final mapping quality.

Paper (B=6, Bc=3, N=32, K=2, sigma_map/Gmax=0.10, read noise 0.7 LSB):
  CW-SC : 4.76 LSB, 28.9 iters | HD-PV : 1.30 LSB, 9.0 iters (3.7x / 3.2x)
  HARP  : 2.20 LSB, 18.9 iters (tau_w = 4)

Reported in weight-domain LSB (x sqrt(65); see EXPERIMENTS.md metric
note).  Assertions check the *ordering and improvement factors*, the
calibrated quantities of the reproduction.
"""

from __future__ import annotations

from repro.core import WVConfig, WVMethod

from .common import ALL_METHODS, emit, run_wv

PAPER = {"cw_sc": (4.76, 28.9), "hd_pv": (1.30, 9.0), "harp": (2.20, 18.9)}


def main(n_columns: int = 512, sweep_tau: bool = False) -> dict:
    res = {}
    for m in ALL_METHODS:
        cfg = WVConfig(method=m)
        r, us = run_wv(cfg, n_columns)
        res[m.value] = r
        ref = PAPER.get(m.value)
        note = f"paper={ref[0]}/{ref[1]}" if ref else "paper=n/a"
        emit(
            f"fig9.{m.value}",
            us,
            f"rmsW={r['rms_weight']:.2f} iters={r['iterations']:.1f} {note}",
        )
    # Reproduction checks: ordering + improvement factors.
    assert res["hd_pv"]["rms_weight"] < res["harp"]["rms_weight"] < res["cw_sc"]["rms_weight"] * 1.6
    assert res["hd_pv"]["iterations"] < res["harp"]["iterations"] < res["cw_sc"]["iterations"]
    err_gain = res["cw_sc"]["rms_weight"] / res["hd_pv"]["rms_weight"]
    it_gain = res["cw_sc"]["iterations"] / res["hd_pv"]["iterations"]
    emit("fig9.hdpv_error_gain", 0.0, f"{err_gain:.2f}x (paper 3.7x)")
    emit("fig9.hdpv_iter_gain", 0.0, f"{it_gain:.2f}x (paper 3.2x)")
    assert err_gain > 1.5 and it_gain > 2.0

    if sweep_tau:
        for tau in (2.0, 4.0, 6.0, 8.0, 12.0):
            r, us = run_wv(WVConfig(method=WVMethod.HARP, tau_w=tau), n_columns)
            emit(
                f"fig9.tau_sweep.tau{tau:g}",
                us,
                f"rmsW={r['rms_weight']:.2f} iters={r['iterations']:.1f}",
            )
    return res


def convergence_curves(n_columns: int = 256) -> dict:
    """Fig. 9(a): RMS error vs sweep count (freezing disabled so the curve
    shows pure decision-quality dynamics, as in the paper's plot)."""
    out = {}
    for m in (WVMethod.CW_SC, WVMethod.HD_PV, WVMethod.HARP):
        curve = []
        for t in (2, 6, 12, 24, 40):
            cfg = WVConfig(method=m, max_fine_iters=t, k_streak=999)
            r, _ = run_wv(cfg, n_columns, seed=4)
            curve.append(r["rms_weight"])
        out[m.value] = curve
        emit(
            f"fig9a.curve.{m.value}", 0.0,
            "rmsW@[2,6,12,24,40]=" + "/".join(f"{v:.2f}" for v in curve),
        )
        # monotone improvement over sweeps
        assert curve[-1] <= curve[0] + 1e-6, (m, curve)
    # HD-PV has the steepest early descent (paper Sec. 5.1)
    assert out["hd_pv"][1] < out["harp"][1] < out["cw_sc"][1] * 1.3
    return out


def n_scaling(n_columns: int = 256) -> dict:
    """Fig. 11 trend: the Hadamard gain (CW-SC error / HD-PV error) GROWS
    with column length N (1/N variance + N-1 cancelled cells scale up)."""
    from repro.core import default_config_for_array

    import jax
    import jax.numpy as jnp

    from repro.core import hadamard as hd

    gains = {}
    for n in (16, 32, 64):
        res = {}
        for m in (WVMethod.CW_SC, WVMethod.HD_PV):
            cfg = default_config_for_array(n).replace(method=m)
            r, _ = run_wv(cfg, n_columns, seed=6)
            res[m.value] = r["rms_weight"]
        gains[n] = res["cw_sc"] / res["hd_pv"]
        emit(f"fig11.gain.n{n}", 0.0, f"cwsc/hdpv error gain = {gains[n]:.2f}x")
        assert gains[n] > 1.3, (n, gains)  # Hadamard wins at every N
    # The paper's "benefit grows with N" is the *decoded read-noise
    # variance* (Prop 2.1: sigma^2/N); final mapping error saturates at the
    # write-noise/freeze floor, so we assert the variance law directly.
    var = {}
    for n in (16, 64):
        noise = jax.random.normal(jax.random.PRNGKey(0), (4000, n))
        var[n] = float(jnp.var(hd.decode(noise)))
        emit(f"fig11.decoded_var.n{n}", 0.0, f"{var[n]:.5f} (1/N={1.0/n:.5f})")
    assert var[64] < var[16] / 3.0, var
    return gains


if __name__ == "__main__":
    main(sweep_tau=True)
