"""Kernel micro-benchmarks: FWHT / fused WV step / ACiM VMM vs oracles.

On CPU these time the *reference* path and validate the Pallas kernels
in interpret mode (numbers are not TPU-representative; the roofline for
the kernels comes from the dry-run HLO, not wall time here).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.cim.mvm import cim_vmm
from repro.kernels.fwht import ops as fwht_ops, ref as fwht_ref
from repro.kernels.wv_step import ops as wv_ops, ref as wv_ref
from repro.kernels.wv_step.ref import WVCellParams

from .common import emit, timed


def main() -> None:
    x = jax.random.normal(jax.random.PRNGKey(0), (4096, 32))
    ref_fn = jax.jit(fwht_ref.fwht)
    out_ref, us_ref = timed(ref_fn, x, name="kernels.fwht_ref")
    out_k = fwht_ops.fwht(x)
    err = float(jnp.max(jnp.abs(out_k - out_ref)))
    emit("kernels.fwht_ref", us_ref, f"C=4096 N=32 kernel_maxerr={err:.1e}")
    assert err < 1e-3

    C, N = 2048, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 8)
    args = (
        jax.random.normal(ks[0], (C, N)) * 8,
        jnp.abs(jax.random.normal(ks[1], (C, N))),
        jax.random.uniform(ks[2], (C, N), minval=0, maxval=7),
        jax.random.randint(ks[3], (C, N), 0, 3),
        jax.random.bernoulli(ks[4], 0.3, (C, N)),
        1 + 0.15 * jax.random.normal(ks[5], (C, N)),
        0.05 * jax.random.normal(ks[6], (C, N)),
        1 + 0.1 * jax.random.normal(ks[7], (C, N)),
    )
    p = WVCellParams(4.0, 2, True, True, 0.25, 16.0, 7.0, 0.35, 0.85)
    ref_fn = jax.jit(lambda *a: wv_ref.wv_cell_update(*a, p))
    out_ref, us = timed(ref_fn, *args, name="kernels.wv_step_ref")
    out_k = wv_ops.wv_cell_update(*args, p)
    err = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(out_k, out_ref)
    )
    emit("kernels.wv_step_ref", us, f"C={C} N={N} kernel_maxerr={err:.1e}")
    assert err < 1e-4

    # The shared CIM macro-readout entry (repro.cim.mvm.cim_vmm) — the
    # exact code path analog serving runs per tile, pre-ADC read noise
    # included — timed on the unfused reference and validated against
    # the fused Pallas kernel (bit-identical by contract).
    xb = jax.random.normal(jax.random.PRNGKey(2), (128, 32))
    gp = jax.random.randint(jax.random.PRNGKey(3), (2, 32, 256), 0, 8).astype(jnp.float32)
    gn = jax.random.randint(jax.random.PRNGKey(4), (2, 32, 256), 0, 8).astype(jnp.float32)
    nz = 0.3 * jax.random.normal(jax.random.PRNGKey(5), (2, 128, 256))
    ref_fn = jax.jit(
        lambda x, p_, n_, z: cim_vmm(
            x, p_, n_, bc=3, adc_bits=9, full_scale=448.0, noise=z,
            use_pallas=False,
        )
    )
    out_ref, us = timed(ref_fn, xb, gp, gn, nz, name="kernels.cim_vmm_ref")
    out_k = cim_vmm(
        xb, gp, gn, bc=3, adc_bits=9, full_scale=448.0, noise=nz,
        use_pallas=True,
    )
    err = float(jnp.max(jnp.abs(out_k - out_ref)))
    emit("kernels.cim_vmm_ref", us, f"B=128 K=32 M=256 kernel_maxerr={err:.1e}")
    assert err == 0.0, err


if __name__ == "__main__":
    main()
