"""Kernel micro-benchmarks: FWHT / fused WV step / ACiM VMM vs oracles,
plus the fused single-dispatch `cim_matmul` vs the pre-fusion per-tile
loop (DESIGN.md Sec. 17) swept over (n_tiles, DAC planes, batch).

On CPU these time the *reference* path and validate the Pallas kernels
in interpret mode (numbers are not TPU-representative; the roofline for
the kernels comes from the dry-run HLO, not wall time here).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.cim import CIMConfig, planes_per_token
from repro.cim.mvm import cim_matmul, cim_vmm
from repro.cim.tile import build_weight
from repro.core.programmer import ArrayState
from repro.kernels.fwht import ops as fwht_ops, ref as fwht_ref
from repro.kernels.wv_step import ops as wv_ops, ref as wv_ref
from repro.kernels.wv_step.ref import WVCellParams
from repro.quant import pack_columns

from .common import emit, export_trace, timed


def _looped_cim_matmul(x, w):
    """The pre-fusion `cim_matmul` datapath: Python-listed DAC planes,
    per-(tile, plane) noise draws concatenated per tile, and one
    `cim_vmm` dispatch per tile, eagerly accumulated.  Kept as the
    "looped" comparator for the fused single-dispatch forward; the
    microbench asserts bit-identity (noisy AND zero-noise) every run."""
    from repro.core import rng
    from repro.readout import noise as ro_noise

    cfg = w.cfg
    lead, k = x.shape[:-1], x.shape[-1]
    xf = x.reshape(-1, k).astype(jnp.float32)
    t = xf.shape[0]
    n_mag = cfg.dac_bits - 1
    q_max = float((1 << n_mag) - 1)
    s_tok = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / q_max
    s_tok = jnp.maximum(s_tok, 1e-12)
    q = jnp.clip(jnp.round(xf / s_tok), -q_max, q_max).astype(jnp.int32)
    pos, neg = jnp.maximum(q, 0), jnp.maximum(-q, 0)
    planes, weights = [], []
    for sign, mag in ((1.0, pos), (-1.0, neg)):
        for b in range(n_mag):
            planes.append(((mag >> b) & 1).astype(jnp.float32))
            weights.append(sign * float(1 << b) * s_tok[:, 0])
    planes, weights = jnp.stack(planes), jnp.stack(weights)
    p = planes.shape[0]
    n_tiles, s, r, m = w.g_pos.shape
    pad = n_tiles * r - k
    if pad:
        planes = jnp.pad(planes, ((0, 0), (0, 0), (0, pad)))
    xp = planes.reshape(p * t, n_tiles * r)
    full_scale = cfg.full_scale_frac * 2.0 * r * float(w.levels - 1)
    acc = jnp.zeros((p * t, m), jnp.float32)
    for ti in range(n_tiles):
        noise = None
        if cfg.sigma_read_lsb > 0.0:
            k_tile = rng.fold_in(w.key, ti)
            noise = jnp.concatenate(
                [
                    ro_noise.sample_token_read_noise(
                        rng.fold_in(k_tile, pi), t, s, m, cfg.sigma_read_lsb
                    )
                    for pi in range(p)
                ],
                axis=1,
            )
        acc = acc + cim_vmm(
            xp[:, ti * r : (ti + 1) * r], w.g_pos[ti], w.g_neg[ti],
            bc=w.bc, adc_bits=cfg.adc_bits, full_scale=full_scale,
            noise=noise,
        )
    y = jnp.einsum("pt,ptm->tm", weights, acc.reshape(p, t, m))
    y = y * w.scale[None, :]
    return y.reshape(*lead, m).astype(x.dtype)


def main(quick: bool = False) -> None:
    x = jax.random.normal(jax.random.PRNGKey(0), (4096, 32))
    ref_fn = jax.jit(fwht_ref.fwht)
    out_ref, us_ref = timed(ref_fn, x, name="kernels.fwht_ref")
    out_k = fwht_ops.fwht(x)
    err = float(jnp.max(jnp.abs(out_k - out_ref)))
    emit("kernels.fwht_ref", us_ref, f"C=4096 N=32 kernel_maxerr={err:.1e}")
    assert err < 1e-3

    C, N = 2048, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 8)
    args = (
        jax.random.normal(ks[0], (C, N)) * 8,
        jnp.abs(jax.random.normal(ks[1], (C, N))),
        jax.random.uniform(ks[2], (C, N), minval=0, maxval=7),
        jax.random.randint(ks[3], (C, N), 0, 3),
        jax.random.bernoulli(ks[4], 0.3, (C, N)),
        1 + 0.15 * jax.random.normal(ks[5], (C, N)),
        0.05 * jax.random.normal(ks[6], (C, N)),
        1 + 0.1 * jax.random.normal(ks[7], (C, N)),
    )
    p = WVCellParams(4.0, 2, True, True, 0.25, 16.0, 7.0, 0.35, 0.85)
    ref_fn = jax.jit(lambda *a: wv_ref.wv_cell_update(*a, p))
    out_ref, us = timed(ref_fn, *args, name="kernels.wv_step_ref")
    out_k = wv_ops.wv_cell_update(*args, p)
    err = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(out_k, out_ref)
    )
    emit("kernels.wv_step_ref", us, f"C={C} N={N} kernel_maxerr={err:.1e}")
    assert err < 1e-4

    # The shared CIM macro-readout entry (repro.cim.mvm.cim_vmm) — the
    # exact code path analog serving runs per tile, pre-ADC read noise
    # included — timed on the unfused reference and validated against
    # the fused Pallas kernel (bit-identical by contract).
    xb = jax.random.normal(jax.random.PRNGKey(2), (128, 32))
    gp = jax.random.randint(jax.random.PRNGKey(3), (2, 32, 256), 0, 8).astype(jnp.float32)
    gn = jax.random.randint(jax.random.PRNGKey(4), (2, 32, 256), 0, 8).astype(jnp.float32)
    nz = 0.3 * jax.random.normal(jax.random.PRNGKey(5), (2, 128, 256))
    ref_fn = jax.jit(
        lambda x, p_, n_, z: cim_vmm(
            x, p_, n_, bc=3, adc_bits=9, full_scale=448.0, noise=z,
            use_pallas=False,
        )
    )
    out_ref, us = timed(ref_fn, xb, gp, gn, nz, name="kernels.cim_vmm_ref")
    out_k = cim_vmm(
        xb, gp, gn, bc=3, adc_bits=9, full_scale=448.0, noise=nz,
        use_pallas=True,
    )
    err = float(jnp.max(jnp.abs(out_k - out_ref)))
    emit("kernels.cim_vmm_ref", us, f"B=128 K=32 M=256 kernel_maxerr={err:.1e}")
    assert err == 0.0, err

    # ---- fused single-dispatch cim_matmul vs the pre-fusion loop ----
    # ISSUE 9 tentpole: the whole bit-serial analog forward (DAC plane
    # streaming -> batched noise lattice -> tiled VMM scan -> slice
    # recombination) as ONE dispatch, swept over (n_tiles, DAC planes,
    # batch).  Zero-noise so the comparison is pure datapath; fused
    # bit-identity to the looped pre-PR path is asserted inline.
    macro_rows, m_out, bc, slices = 32, 64, 3, 2
    sweep = [(2, 4, 8)] if quick else [(1, 4, 8), (4, 4, 8), (4, 6, 8), (4, 4, 64)]
    for n_tiles, dac_bits, batch in sweep:
        k_in = n_tiles * macro_rows
        q_max = (1 << (bc * slices)) - 1
        q = jax.random.randint(
            jax.random.PRNGKey(6), (k_in, m_out), -q_max, q_max + 1
        )
        cols, layout = pack_columns(q, macro_rows, bc, slices)
        state = ArrayState(
            g=cols, targets=cols, d2d=jnp.ones_like(cols),
            scale=0.01 * (1.0 + jnp.arange(m_out, dtype=jnp.float32))[None, :],
            layout=layout, shape=(k_in, m_out), dtype=jnp.float32,
        )
        ccfg = CIMConfig(
            macro_rows=macro_rows, dac_bits=dac_bits, adc_bits=9,
            sigma_read_lsb=0.3,
        )
        w = build_weight(state, ccfg, jax.random.PRNGKey(7), name="bench")
        w0 = build_weight(
            state, ccfg.replace(sigma_read_lsb=0.0),
            jax.random.PRNGKey(7), name="bench",
        )
        x = jax.random.normal(jax.random.PRNGKey(8), (batch, k_in), jnp.float32)
        tag = f"t{n_tiles}_p{planes_per_token(ccfg)}_b{batch}"
        out_f, us_f = timed(
            jax.jit(lambda x_, w_=w: cim_matmul(x_, w_)), x,
            name=f"kernels.cim_matmul_fused.{tag}",
        )
        out_l, us_l = timed(
            jax.jit(lambda x_, w_=w: _looped_cim_matmul(x_, w_)), x,
            name=f"kernels.cim_matmul_looped.{tag}",
        )
        assert bool(jnp.all(out_f == out_l)), f"fused != looped (noisy) {tag}"
        out_f0 = cim_matmul(x, w0)
        out_l0 = _looped_cim_matmul(x, w0)
        assert bool(jnp.all(out_f0 == out_l0)), f"fused != looped (clean) {tag}"
        emit(
            f"kernels.cim_matmul_fused.{tag}", us_f,
            f"looped_us={us_l:.1f} speedup={us_l / max(us_f, 1e-9):.2f}x "
            f"bit_identical=1",
        )
    export_trace("kernels", quick)


if __name__ == "__main__":
    import sys

    main(quick="--quick" in sys.argv)
