"""Fig. 13: per-column WV latency/energy vs read noise, 32x32 and 64x64.

Paper trends asserted:
  * CW-SC is competitive at very low noise (<= 0.1 LSB) but its latency
    grows steeply with noise (misdirected updates -> extra iterations);
    above ~0.4 LSB it is the slowest.
  * HD-PV / HARP latency grows only modestly (paper: 16%/17% at 32x32,
    9.7%/8.9% at 64x64 over the sweep).
  * Energy: HD-PV pays full-SAR on every Hadamard read; HARP is the
    most energy-efficient in the high-noise regime (~65% of HD-PV at
    32x32, ~67% of CW-SC at 64x64).
"""

from __future__ import annotations

from repro.core import NoiseConfig, WVConfig, WVMethod, default_config_for_array

from .common import emit, run_wv

_METHODS = [WVMethod.CW_SC, WVMethod.HD_PV, WVMethod.HARP]
_NOISES = (0.1, 0.4, 0.7)


def main(n_cells: int = 32, n_columns: int = 384) -> dict:
    res = {}
    for sigma in _NOISES:
        for m in _METHODS:
            cfg = default_config_for_array(n_cells).replace(
                method=m, noise=NoiseConfig(sigma_read_lsb=sigma)
            )
            r, us = run_wv(cfg, n_columns, seed=2)
            res[(sigma, m.value)] = r
            emit(
                f"fig13.n{n_cells}.sigma{sigma:g}.{m.value}",
                us,
                f"lat_us={r['latency_us']:.1f} e_nj={r['energy_nj']:.1f} "
                f"iters={r['iterations']:.1f}",
            )
    lo, hi = min(_NOISES), max(_NOISES)
    # CW-SC latency blows up with noise; Hadamard methods grow modestly.
    cw_growth = res[(hi, "cw_sc")]["latency_us"] / res[(lo, "cw_sc")]["latency_us"]
    hd_growth = res[(hi, "hd_pv")]["latency_us"] / res[(lo, "hd_pv")]["latency_us"]
    emit(f"fig13.n{n_cells}.latency_growth", 0.0,
         f"cw_sc={cw_growth:.2f}x hd_pv={hd_growth:.2f}x")
    assert cw_growth > hd_growth
    # High-noise regime: CW-SC slowest, HARP lowest energy.
    assert res[(hi, "cw_sc")]["latency_us"] > res[(hi, "hd_pv")]["latency_us"]
    assert res[(hi, "harp")]["energy_nj"] < res[(hi, "hd_pv")]["energy_nj"]
    assert res[(hi, "harp")]["energy_nj"] < res[(hi, "cw_sc")]["energy_nj"]
    return res


if __name__ == "__main__":
    main(32)
    main(64)
