"""Fig. 9(c): common-mode noise sweep.

Total read power fixed at sqrt(uc^2 + cm^2) = 0.7 LSB while
rho = cm^2/(uc^2+cm^2) sweeps 0 -> 0.5.  Paper claim: HD-PV/HARP beat
CW-SC across the whole range (1/N on the uncorrelated part + exact
mu_cm cancellation on N-1 cells); multi-read averaging cannot cancel
mu_cm because repeated reads share the TIA/ADC.
"""

from __future__ import annotations

from repro.core import NoiseConfig, WVConfig, WVMethod

from .common import ALL_METHODS, emit, run_wv


def main(n_columns: int = 384) -> dict:
    out = {}
    for rho in (0.0, 0.25, 0.5):
        noise = NoiseConfig(sigma_read_lsb=0.7, rho_cm=rho)
        row = {}
        for m in ALL_METHODS:
            r, us = run_wv(WVConfig(method=m, noise=noise), n_columns, seed=7)
            row[m.value] = r
            emit(
                f"fig9c.rho{rho:g}.{m.value}",
                us,
                f"rmsW={r['rms_weight']:.2f} iters={r['iterations']:.1f}",
            )
        out[rho] = row
        assert row["hd_pv"]["rms_weight"] < row["cw_sc"]["rms_weight"]
        assert row["harp"]["rms_weight"] < row["cw_sc"]["rms_weight"]
    # MRA degrades with rho (cannot cancel mu_cm); Hadamard methods stay flat.
    mra_degrade = out[0.5]["mra"]["rms_weight"] / out[0.0]["mra"]["rms_weight"]
    hd_degrade = out[0.5]["hd_pv"]["rms_weight"] / out[0.0]["hd_pv"]["rms_weight"]
    emit("fig9c.mra_degradation", 0.0, f"{mra_degrade:.2f}x vs hd_pv {hd_degrade:.2f}x")
    assert hd_degrade < mra_degrade + 0.35
    return out


if __name__ == "__main__":
    main()
