"""Continuous-batching serving under Poisson offered load (BENCH_serving).

Drives the `ContinuousScheduler` (DESIGN.md Sec. 13) with Poisson
arrival streams of variable-length requests at increasing offered load
and records throughput (tokens/sec, tokens/step) and request latency
(p50/p99, in decode steps and seconds) for BOTH serving paths:

* digital — HARP-programmed weights materialized to dense matmuls;
* analog  — the same deployment served compute-in-memory through the
  `CIMExecutor` (bit-serial DAC -> tile VMM -> per-slice ADC), with the
  executor's read-disturb traffic draining into a `LifetimeSimulator`
  whose incremental scrub interleaves between decode steps.

The "slo" section (ISSUE-10) serves a mixed short/long-prompt stream
with per-request TTFT deadlines under PROPORTIONAL prefill pricing
(`prefill_tokens_per_step` — the honest clock; the old constant-cost
clock under-charged long buckets) and compares admission policies:
whole-prompt FIFO vs chunked FIFO/SPF/EDF (DESIGN.md Sec. 18).  The
headline gate: chunked prefill + EDF must CUT p99 TTFT vs whole-prompt
FIFO (``slo.ttft_p99_improvement > 1``), with the tokens of every
policy variant byte-identical per request (same RNG sub-streams).  The
"sharded" section measures decode-batch "data" sharding on a debug
mesh and hard-asserts token bit-identity vs the unsharded run.

Scheduler contracts are HARD-ASSERTED on every run (CI quick smoke):

* zero retraces after warmup — `trace_counts` stays flat across every
  load point, batch composition, and chunk schedule;
* exactly one device->host sync per decode step — `host_syncs ==
  decode_steps`.

Full mode commits BENCH_serving.json; `--quick` writes the (gitignored)
BENCH_serving_quick.json and shrinks the model/stream for CI.
"""

from __future__ import annotations

import json
import os
import sys

import jax
import jax.numpy as jnp

from repro.cim import CIMConfig, CIMExecutor
from repro.core import WVConfig, WVMethod
from repro.core.programmer import deploy_arrays
from repro.lifetime import LifetimeSimulator
from repro.lifetime.refresh import RefreshConfig, RefreshPolicy
from repro.models import ModelConfig, init_params
from repro.serving import ContinuousScheduler, ServeEngine, poisson_requests

from .common import emit, export_trace

OUT = os.path.join(os.path.dirname(__file__), "BENCH_serving.json")
OUT_QUICK = os.path.join(os.path.dirname(__file__), "BENCH_serving_quick.json")


def _model_cfg(quick: bool) -> ModelConfig:
    return ModelConfig(
        name="serve-bench",
        n_layers=2,
        d_model=32 if quick else 64,
        n_heads=2,
        n_kv_heads=2,
        head_dim=16,
        d_ff=64 if quick else 128,
        vocab_size=64 if quick else 128,
        dtype=jnp.float32,
        attn_chunk_q=16,
        attn_chunk_kv=16,
        remat=False,
        tie_embeddings=False,
    )


def _serve_loads(
    engine: ServeEngine,
    *,
    n_slots: int,
    max_len: int,
    loads: list[float],
    n_requests: int,
    prompt_lens: tuple[int, int],
    max_new: tuple[int, int],
    maintenance_fn=None,
    maintenance_every: int = 0,
    **sched_kw,
) -> tuple[list[dict], dict]:
    sched = ContinuousScheduler(
        engine, n_slots=n_slots, max_len=max_len, key=jax.random.PRNGKey(9),
        maintenance_fn=maintenance_fn, maintenance_every=maintenance_every,
        **sched_kw,
    )
    sched.warmup(prompt_range=prompt_lens)
    warm = dict(sched.trace_counts)
    rows = []
    for load in loads:
        sched.reset(keep_traces=True)
        reqs = poisson_requests(
            17, n_requests, rate=load, vocab=engine.cfg.vocab_size,
            prompt_lens=prompt_lens, max_new=max_new,
        )
        sched.run(reqs)
        stats = sched.latency_stats()
        # ---- scheduler contracts (hard-asserted, CI quick smoke) ----
        retraces = {k: sched.trace_counts[k] - warm[k] for k in warm}
        assert all(v == 0 for v in retraces.values()), (
            f"retrace after warmup at load {load}: {retraces}"
        )
        assert sched.host_syncs == sched.decode_steps, (
            sched.host_syncs, sched.decode_steps,
        )
        # step_us is the DECODE step (the datapath this benchmark
        # gates); wall_step_us additionally amortizes admission prefill
        # and interleaved lifetime maintenance over the same steps.
        step_s = sched.decode_wall_s / max(sched.decode_steps, 1)
        wall_step_s = sched.wall_s / max(sched.decode_steps, 1)
        rows.append(
            {
                "offered_load_req_per_step": load,
                "step_us": round(step_s * 1e6, 1),
                "wall_step_us": round(wall_step_s * 1e6, 1),
                "completed": stats["completed"],
                "tokens_per_step": round(stats["tokens_per_step"], 4),
                "tokens_per_s": round(stats["decode_tokens_per_s"], 2),
                "wall_tokens_per_s": round(stats["tokens_per_s"], 2),
                "p50_latency_steps": stats.get("p50_latency_steps", 0.0),
                "p99_latency_steps": stats.get("p99_latency_steps", 0.0),
                "p50_latency_s": round(
                    stats.get("p50_latency_steps", 0.0) * wall_step_s, 5
                ),
                "p99_latency_s": round(
                    stats.get("p99_latency_steps", 0.0) * wall_step_s, 5
                ),
                "p50_ttft_steps": stats.get("p50_ttft_steps", 0.0),
                "mean_queue_delay_steps": round(
                    stats.get("mean_queue_delay_steps", 0.0), 3
                ),
                "decode_steps": stats["decode_steps"],
            }
        )
    counters = {
        "retraces_after_warmup": 0,
        "host_syncs_per_step": 1.0,
        "warm_traces": warm,
    }
    return rows, counters


def _slo_policy_sweep(
    engine: ServeEngine,
    *,
    n_slots: int,
    max_len: int,
    load: float,
    n_requests: int,
    prompt_lens: tuple[int, int],
    long_prompt_lens: tuple[int, int],
    long_frac: float,
    max_new: tuple[int, int],
    ttft_slack: tuple[float, float],
    chunk: int,
) -> dict:
    """Admission-policy comparison on a mixed short/long deadline stream.

    Every variant runs under PROPORTIONAL prefill pricing (a bucket's
    clock charge is its physical token count / n_slots) so whole-prompt
    head-of-line blocking is priced honestly; per-request RNG makes the
    served tokens byte-identical across variants (hard-asserted), so
    the ONLY thing that moves is scheduling: TTFT and deadline misses.
    """
    stream = poisson_requests(
        23, n_requests, rate=load, vocab=engine.cfg.vocab_size,
        prompt_lens=prompt_lens, max_new=max_new,
        long_prompt_lens=long_prompt_lens, long_frac=long_frac,
        ttft_slack=ttft_slack,
    )
    variants = {
        "fifo_whole": dict(admission_policy="fifo"),
        "fifo_chunked": dict(admission_policy="fifo",
                             prefill_chunk_tokens=chunk),
        "spf_chunked": dict(admission_policy="spf",
                            prefill_chunk_tokens=chunk),
        "edf_chunked": dict(admission_policy="edf",
                            prefill_chunk_tokens=chunk),
    }
    warm_range = (prompt_lens[0], long_prompt_lens[1])
    rows, tokens_ref = {}, None
    for name, kw in variants.items():
        sched = ContinuousScheduler(
            engine, n_slots=n_slots, max_len=max_len,
            key=jax.random.PRNGKey(9),
            prefill_tokens_per_step=float(n_slots), **kw,
        )
        sched.warmup(prompt_range=warm_range)
        warm = dict(sched.trace_counts)
        recs = sched.run(stream)
        retraces = {k: sched.trace_counts[k] - warm[k] for k in warm}
        assert all(v == 0 for v in retraces.values()), (name, retraces)
        assert sched.host_syncs == sched.decode_steps, name
        toks = {r.rid: tuple(r.tokens) for r in recs}
        if tokens_ref is None:
            tokens_ref = toks
        else:
            assert toks == tokens_ref, (
                f"{name}: served tokens differ across admission policies"
            )
        stats = sched.latency_stats()
        rows[name] = {
            "p50_ttft_steps": stats["p50_ttft_steps"],
            "p99_ttft_steps": stats["p99_ttft_steps"],
            "p99_latency_steps": stats["p99_latency_steps"],
            "mean_queue_delay_steps": round(
                stats["mean_queue_delay_steps"], 3
            ),
            "deadline_miss_rate": round(stats.get("deadline_miss_rate", 0.0), 4),
            "completed": stats["completed"],
            "decode_steps": stats["decode_steps"],
        }
    improvement = rows["fifo_whole"]["p99_ttft_steps"] / max(
        rows["edf_chunked"]["p99_ttft_steps"], 1e-9
    )
    return {
        "config": {
            "offered_load_req_per_step": load,
            "n_requests": n_requests,
            "long_prompt_lens": list(long_prompt_lens),
            "long_frac": long_frac,
            "ttft_slack_steps": list(ttft_slack),
            "prefill_chunk_tokens": chunk,
            "prefill_tokens_per_step": float(n_slots),
        },
        "policies": rows,
        "summary": {
            # headline gate: chunked+EDF cuts p99 TTFT vs whole-FIFO
            "ttft_p99_improvement": round(improvement, 3),
            "edf_deadline_miss_rate": rows["edf_chunked"]["deadline_miss_rate"],
            "fifo_whole_deadline_miss_rate": rows["fifo_whole"][
                "deadline_miss_rate"
            ],
            # 0.0 == "no mismatched request" (asserted above; mirrored
            # here so --check-baselines can gate it declaratively)
            "tokens_bit_identical_across_policies": 0.0,
        },
    }


def _sharded_decode(
    engine: ServeEngine,
    *,
    n_slots: int,
    max_len: int,
    load: float,
    n_requests: int,
    prompt_lens: tuple[int, int],
    max_new: tuple[int, int],
    chunk: int,
) -> dict:
    """Decode-batch "data" sharding vs the meshless run (bit-identical).

    CI hosts expose one device, so the in-benchmark mesh is the 1x1
    debug mesh — a placement no-op that still exercises the full
    device_put + NamedSharding dispatch path and measures its per-step
    resharding overhead; the REAL 4x2-device equivalence runs in
    tests/test_serving_scheduler.py's forced-8-device subprocess.
    """
    from repro.launch.mesh import make_debug_mesh

    reqs = poisson_requests(
        29, n_requests, rate=load, vocab=engine.cfg.vocab_size,
        prompt_lens=prompt_lens, max_new=max_new,
    )

    def serve(mesh):
        sched = ContinuousScheduler(
            engine, n_slots=n_slots, max_len=max_len,
            key=jax.random.PRNGKey(9), prefill_chunk_tokens=chunk,
            batch_mesh=mesh,
        )
        sched.warmup(prompt_range=prompt_lens)
        warm = dict(sched.trace_counts)
        recs = sched.run(reqs)
        assert sched.trace_counts == warm, (sched.trace_counts, warm)
        assert sched.host_syncs == sched.decode_steps
        return {r.rid: tuple(r.tokens) for r in recs}, sched

    base, plain = serve(None)
    shard, sharded = serve(make_debug_mesh(1, 1))
    assert base == shard, "sharded decode tokens differ from unsharded"
    step_us = sharded.decode_wall_s / max(sharded.decode_steps, 1) * 1e6
    step_us_plain = plain.decode_wall_s / max(plain.decode_steps, 1) * 1e6
    return {
        "mesh": "1x1 (data, model)",
        "devices": jax.local_device_count(),
        "step_us": round(step_us, 1),
        "step_us_unsharded": round(step_us_plain, 1),
        "reshard_overhead_ratio": round(step_us / max(step_us_plain, 1e-9), 3),
        "host_syncs_per_step": 1.0,
        "tokens_bit_identical": 0.0,  # 0 mismatches (asserted above)
    }


def main(quick: bool = False) -> dict:
    cfg = _model_cfg(quick)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_slots = 4 if quick else 8
    max_len = 64 if quick else 96
    loads = [0.1, 0.4] if quick else [0.1, 0.3, 0.8]
    n_requests = 8 if quick else 24
    prompt_lens = (3, 14)
    max_new = (3, 8) if quick else (4, 12)

    # ---------------- program the deployment once (shared by both paths)
    wv = WVConfig(method=WVMethod.HARP, max_fine_iters=12, max_coarse_iters=4)
    deployed, report = deploy_arrays(jax.random.PRNGKey(1), params, wv)

    # ---------------- digital: materialized dense weights
    digital = ServeEngine(cfg, deployed.materialize(), temperature=0.7)
    rows_d, counters_d = _serve_loads(
        digital, n_slots=n_slots, max_len=max_len, loads=loads,
        n_requests=n_requests, prompt_lens=prompt_lens, max_new=max_new,
    )

    # ---------------- SLO: admission policies on a mixed deadline stream
    slo = _slo_policy_sweep(
        digital, n_slots=n_slots, max_len=max_len,
        load=0.4 if quick else 0.8,
        n_requests=10 if quick else 28,
        prompt_lens=prompt_lens,
        long_prompt_lens=(24, 40) if quick else (40, 56),
        long_frac=0.3,
        max_new=(3, 6) if quick else (4, 10),
        ttft_slack=(4.0, 16.0),
        chunk=16,
    )

    # ---------------- sharded decode: "data"-axis batch sharding
    sharded = _sharded_decode(
        digital, n_slots=n_slots, max_len=max_len,
        load=0.4, n_requests=6 if quick else 12,
        prompt_lens=prompt_lens, max_new=max_new, chunk=16,
    )

    # ---------------- analog: CIM executor + interleaved lifetime scrub
    executor = CIMExecutor(
        deployed,
        CIMConfig(dac_bits=4, adc_bits=10, sigma_read_lsb=0.2),
        jax.random.PRNGKey(7),
    )
    analog = ServeEngine(cfg, executor=executor, temperature=0.7)
    sim = LifetimeSimulator(
        jax.random.PRNGKey(3), deployed,
        refresh_cfg=RefreshConfig(policy=RefreshPolicy.VERIFY_TRIGGERED),
        traffic_fn=executor.drain_reads,
    )
    rows_a, counters_a = _serve_loads(
        analog, n_slots=n_slots, max_len=max_len, loads=loads,
        n_requests=n_requests, prompt_lens=prompt_lens, max_new=max_new,
        maintenance_fn=lambda: sim.step_epoch(1.0, max_leaves=2),
        maintenance_every=8,
    )
    lat_ns, e_pj = executor.token_cost()

    for tag, rows in (("digital", rows_d), ("analog", rows_a)):
        for r in rows:
            emit(
                f"serving.{tag}.load{r['offered_load_req_per_step']}",
                r["step_us"],
                f"tok/s={r['tokens_per_s']};p99={r['p99_latency_steps']}steps",
            )
    for name, r in slo["policies"].items():
        emit(
            f"serving.slo.{name}",
            r["p99_ttft_steps"],
            f"p50_ttft={r['p50_ttft_steps']};miss={r['deadline_miss_rate']}",
        )
    emit(
        "serving.slo.summary",
        slo["summary"]["ttft_p99_improvement"],
        "p99_ttft fifo_whole/edf_chunked (steps ratio, >1 = EDF wins)",
    )
    emit(
        "serving.sharded",
        sharded["step_us"],
        f"reshard_overhead={sharded['reshard_overhead_ratio']}x;bit_identical=yes",
    )

    # Headline throughput at the heaviest offered load, for the
    # --check-baselines regression gate (quick and full runs use the
    # same fused datapath; step time is dominated by per-step dispatch,
    # not model scale, so quick-vs-committed rel checks are meaningful).
    def _summary(rows: list[dict]) -> dict:
        r = rows[-1]
        return {"step_us": r["step_us"], "tokens_per_s": r["tokens_per_s"]}

    sum_d, sum_a = _summary(rows_d), _summary(rows_a)
    sum_a["step_us_vs_digital"] = round(
        sum_a["step_us"] / max(sum_d["step_us"], 1e-9), 3
    )
    out = {
        "config": {
            "quick": quick,
            "model": cfg.name,
            "n_slots": n_slots,
            "max_len": max_len,
            "n_requests": n_requests,
            "prompt_lens": list(prompt_lens),
            "max_new": list(max_new),
            "wv_method": "HARP",
            "rms_cell_error_lsb": round(float(report.rms_cell_error_lsb), 4),
        },
        "digital": {"loads": rows_d, "counters": counters_d, "summary": sum_d},
        "slo": slo,
        "sharded": sharded,
        "analog": {
            "loads": rows_a,
            "counters": counters_a,
            "summary": sum_a,
            "token_latency_ns": round(lat_ns, 1),
            "token_energy_pj": round(e_pj, 1),
            "lifetime_epochs": sim.epoch,
        },
    }
    path = OUT_QUICK if quick else OUT
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    export_trace("serving", quick)
    top_d = rows_d[-1]["tokens_per_s"]
    top_a = rows_a[-1]["tokens_per_s"]
    emit(
        "serving.traffic",
        0.0,
        f"digital={top_d}tok/s;analog={top_a}tok/s;retraces=0;json={os.path.basename(path)}",
    )
    return out


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
