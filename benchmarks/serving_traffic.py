"""Continuous-batching serving under Poisson offered load (BENCH_serving).

Drives the `ContinuousScheduler` (DESIGN.md Sec. 13) with Poisson
arrival streams of variable-length requests at increasing offered load
and records throughput (tokens/sec, tokens/step) and request latency
(p50/p99, in decode steps and seconds) for BOTH serving paths:

* digital — HARP-programmed weights materialized to dense matmuls;
* analog  — the same deployment served compute-in-memory through the
  `CIMExecutor` (bit-serial DAC -> tile VMM -> per-slice ADC), with the
  executor's read-disturb traffic draining into a `LifetimeSimulator`
  whose incremental scrub interleaves between decode steps.

Two scheduler contracts are HARD-ASSERTED on every run (CI quick smoke):

* zero retraces after warmup — `trace_counts` stays flat across every
  load point and batch composition;
* exactly one device->host sync per decode step — `host_syncs ==
  decode_steps`.

Full mode commits BENCH_serving.json; `--quick` writes the (gitignored)
BENCH_serving_quick.json and shrinks the model/stream for CI.
"""

from __future__ import annotations

import json
import os
import sys

import jax
import jax.numpy as jnp

from repro.cim import CIMConfig, CIMExecutor
from repro.core import WVConfig, WVMethod
from repro.core.programmer import deploy_arrays
from repro.lifetime import LifetimeSimulator
from repro.lifetime.refresh import RefreshConfig, RefreshPolicy
from repro.models import ModelConfig, init_params
from repro.serving import ContinuousScheduler, ServeEngine, poisson_requests

from .common import emit, export_trace

OUT = os.path.join(os.path.dirname(__file__), "BENCH_serving.json")
OUT_QUICK = os.path.join(os.path.dirname(__file__), "BENCH_serving_quick.json")


def _model_cfg(quick: bool) -> ModelConfig:
    return ModelConfig(
        name="serve-bench",
        n_layers=2,
        d_model=32 if quick else 64,
        n_heads=2,
        n_kv_heads=2,
        head_dim=16,
        d_ff=64 if quick else 128,
        vocab_size=64 if quick else 128,
        dtype=jnp.float32,
        attn_chunk_q=16,
        attn_chunk_kv=16,
        remat=False,
        tie_embeddings=False,
    )


def _serve_loads(
    engine: ServeEngine,
    *,
    n_slots: int,
    max_len: int,
    loads: list[float],
    n_requests: int,
    prompt_lens: tuple[int, int],
    max_new: tuple[int, int],
    maintenance_fn=None,
    maintenance_every: int = 0,
) -> tuple[list[dict], dict]:
    sched = ContinuousScheduler(
        engine, n_slots=n_slots, max_len=max_len, key=jax.random.PRNGKey(9),
        maintenance_fn=maintenance_fn, maintenance_every=maintenance_every,
    )
    sched.warmup(prompt_range=prompt_lens)
    warm = dict(sched.trace_counts)
    rows = []
    for load in loads:
        sched.reset(keep_traces=True)
        reqs = poisson_requests(
            17, n_requests, rate=load, vocab=engine.cfg.vocab_size,
            prompt_lens=prompt_lens, max_new=max_new,
        )
        sched.run(reqs)
        stats = sched.latency_stats()
        # ---- scheduler contracts (hard-asserted, CI quick smoke) ----
        retraces = {k: sched.trace_counts[k] - warm[k] for k in warm}
        assert all(v == 0 for v in retraces.values()), (
            f"retrace after warmup at load {load}: {retraces}"
        )
        assert sched.host_syncs == sched.decode_steps, (
            sched.host_syncs, sched.decode_steps,
        )
        # step_us is the DECODE step (the datapath this benchmark
        # gates); wall_step_us additionally amortizes admission prefill
        # and interleaved lifetime maintenance over the same steps.
        step_s = sched.decode_wall_s / max(sched.decode_steps, 1)
        wall_step_s = sched.wall_s / max(sched.decode_steps, 1)
        rows.append(
            {
                "offered_load_req_per_step": load,
                "step_us": round(step_s * 1e6, 1),
                "wall_step_us": round(wall_step_s * 1e6, 1),
                "completed": stats["completed"],
                "tokens_per_step": round(stats["tokens_per_step"], 4),
                "tokens_per_s": round(stats["decode_tokens_per_s"], 2),
                "wall_tokens_per_s": round(stats["tokens_per_s"], 2),
                "p50_latency_steps": stats.get("p50_latency_steps", 0.0),
                "p99_latency_steps": stats.get("p99_latency_steps", 0.0),
                "p50_latency_s": round(
                    stats.get("p50_latency_steps", 0.0) * wall_step_s, 5
                ),
                "p99_latency_s": round(
                    stats.get("p99_latency_steps", 0.0) * wall_step_s, 5
                ),
                "p50_ttft_steps": stats.get("p50_ttft_steps", 0.0),
                "mean_queue_delay_steps": round(
                    stats.get("mean_queue_delay_steps", 0.0), 3
                ),
                "decode_steps": stats["decode_steps"],
            }
        )
    counters = {
        "retraces_after_warmup": 0,
        "host_syncs_per_step": 1.0,
        "warm_traces": warm,
    }
    return rows, counters


def main(quick: bool = False) -> dict:
    cfg = _model_cfg(quick)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_slots = 4 if quick else 8
    max_len = 64 if quick else 96
    loads = [0.1, 0.4] if quick else [0.1, 0.3, 0.8]
    n_requests = 8 if quick else 24
    prompt_lens = (3, 14)
    max_new = (3, 8) if quick else (4, 12)

    # ---------------- program the deployment once (shared by both paths)
    wv = WVConfig(method=WVMethod.HARP, max_fine_iters=12, max_coarse_iters=4)
    deployed, report = deploy_arrays(jax.random.PRNGKey(1), params, wv)

    # ---------------- digital: materialized dense weights
    digital = ServeEngine(cfg, deployed.materialize(), temperature=0.7)
    rows_d, counters_d = _serve_loads(
        digital, n_slots=n_slots, max_len=max_len, loads=loads,
        n_requests=n_requests, prompt_lens=prompt_lens, max_new=max_new,
    )

    # ---------------- analog: CIM executor + interleaved lifetime scrub
    executor = CIMExecutor(
        deployed,
        CIMConfig(dac_bits=4, adc_bits=10, sigma_read_lsb=0.2),
        jax.random.PRNGKey(7),
    )
    analog = ServeEngine(cfg, executor=executor, temperature=0.7)
    sim = LifetimeSimulator(
        jax.random.PRNGKey(3), deployed,
        refresh_cfg=RefreshConfig(policy=RefreshPolicy.VERIFY_TRIGGERED),
        traffic_fn=executor.drain_reads,
    )
    rows_a, counters_a = _serve_loads(
        analog, n_slots=n_slots, max_len=max_len, loads=loads,
        n_requests=n_requests, prompt_lens=prompt_lens, max_new=max_new,
        maintenance_fn=lambda: sim.step_epoch(1.0, max_leaves=2),
        maintenance_every=8,
    )
    lat_ns, e_pj = executor.token_cost()

    for tag, rows in (("digital", rows_d), ("analog", rows_a)):
        for r in rows:
            emit(
                f"serving.{tag}.load{r['offered_load_req_per_step']}",
                r["step_us"],
                f"tok/s={r['tokens_per_s']};p99={r['p99_latency_steps']}steps",
            )

    # Headline throughput at the heaviest offered load, for the
    # --check-baselines regression gate (quick and full runs use the
    # same fused datapath; step time is dominated by per-step dispatch,
    # not model scale, so quick-vs-committed rel checks are meaningful).
    def _summary(rows: list[dict]) -> dict:
        r = rows[-1]
        return {"step_us": r["step_us"], "tokens_per_s": r["tokens_per_s"]}

    sum_d, sum_a = _summary(rows_d), _summary(rows_a)
    sum_a["step_us_vs_digital"] = round(
        sum_a["step_us"] / max(sum_d["step_us"], 1e-9), 3
    )
    out = {
        "config": {
            "quick": quick,
            "model": cfg.name,
            "n_slots": n_slots,
            "max_len": max_len,
            "n_requests": n_requests,
            "prompt_lens": list(prompt_lens),
            "max_new": list(max_new),
            "wv_method": "HARP",
            "rms_cell_error_lsb": round(float(report.rms_cell_error_lsb), 4),
        },
        "digital": {"loads": rows_d, "counters": counters_d, "summary": sum_d},
        "analog": {
            "loads": rows_a,
            "counters": counters_a,
            "summary": sum_a,
            "token_latency_ns": round(lat_ns, 1),
            "token_energy_pj": round(e_pj, 1),
            "lifetime_epochs": sim.epoch,
        },
    }
    path = OUT_QUICK if quick else OUT
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    export_trace("serving", quick)
    top_d = rows_d[-1]["tokens_per_s"]
    top_a = rows_a[-1]["tokens_per_s"]
    emit(
        "serving.traffic",
        0.0,
        f"digital={top_d}tok/s;analog={top_a}tok/s;retraces=0;json={os.path.basename(path)}",
    )
    return out


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
