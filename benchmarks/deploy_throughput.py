"""Deployment throughput: bucketed pipeline vs per-leaf baseline.

The paper's headline is programming *throughput* (up to 6.1x latency /
9.5x energy per column); this benchmark tracks whether the model-level
deployment path preserves it.  A synthetic multi-leaf transformer-style
parameter tree is deployed twice through each path:

* baseline    — the pre-pipeline deployment path reproduced verbatim
                (PR 1's `_program_leaf` loop): one EAGER
                `program_columns` call per leaf — the while loop
                re-traces on every call — plus `DeployReport.merge`'s
                7 scalar host pulls per leaf;
* perleaf_jit — `deploy_arrays(batched=False)`: per-leaf dispatches
                through the shared jit cache (one trace per distinct
                leaf shape), still per-leaf host syncs;
* pipeline    — `deploy_arrays(batched=True)`: all packed columns
                concatenated into power-of-two buckets, ONE jitted
                donated dispatch per bucket, device-side stats, exactly
                one host sync.

Emits ``name,us_per_call,derived`` CSV rows plus `BENCH_deploy.json`
with cold/warm columns-per-second, compile counts (must stay <= the
number of buckets) and host-sync counts — the deployment-throughput
trajectory tracked from PR 2 on.  `--quick` shrinks the model for CI
smoke runs.
"""

from __future__ import annotations

import json
import pathlib
import sys

import jax
import jax.numpy as jnp

from repro.core import WVConfig, WVMethod, pipeline, program_columns
from repro.core import device as dev_mod
from repro.core.cost import CircuitCost
from repro.core.programmer import DeployReport, _eligible_leaves, deploy_arrays
from repro.quant import QuantConfig, pack_columns, quantize_weight

from .common import emit, export_trace, stopwatch

_MIN_BUCKET = 256


def _toy_params(n_blocks: int, d_model: int, d_ff: int, seed: int = 0):
    """Multi-leaf transformer-shaped tree: repeated AND distinct shapes."""
    keys = jax.random.split(jax.random.PRNGKey(seed), n_blocks + 1)
    params = {
        "embed": jax.random.normal(keys[-1], (256, d_model)) * 0.02,
        "final_norm": jnp.ones((d_model,)),
    }
    for b in range(n_blocks):
        k = jax.random.split(keys[b], 6)
        params[f"block{b}"] = {
            "wq": jax.random.normal(k[0], (d_model, d_model)) * 0.02,
            "wkv": jax.random.normal(k[1], (d_model, d_model // 2)) * 0.02,
            "wo": jax.random.normal(k[2], (d_model, d_model)) * 0.02,
            "w_up": jax.random.normal(k[3], (d_model, d_ff)) * 0.02,
            "w_down": jax.random.normal(k[4], (d_ff, d_model)) * 0.02,
            "norm": jnp.ones((d_model,)),
        }
    return params


def _deploy_baseline_eager(params, cfg: WVConfig, seed: int = 1) -> DeployReport:
    """PR 1's per-leaf deployment loop, reproduced verbatim.

    Eager `program_columns` per leaf (the `lax.while_loop` re-traces on
    EVERY call — this is the "retraces per leaf" cost the pipeline
    removes), legacy batch-shaped RNG, and `DeployReport.merge` blocking
    on 7 scalar host pulls per leaf.
    """
    q_cfg = QuantConfig(weight_bits=cfg.weight_bits, cell_bits=cfg.device.bc)
    key = jax.random.PRNGKey(seed)
    cost = CircuitCost()
    report = DeployReport()
    records, _ = _eligible_leaves(params, False, None)
    for i, name, leaf, eligible in records:
        if not eligible:
            continue
        k = jax.random.fold_in(key, i)
        w2 = leaf.reshape((-1, leaf.shape[-1]))
        q, _ = quantize_weight(w2, q_cfg)
        cols, _ = pack_columns(q, cfg.n_cells, q_cfg.cell_bits, q_cfg.slices)
        k_d2d, _, _ = jax.random.split(k, 3)
        d2d = dev_mod.sample_d2d(k_d2d, cols.shape, cfg.device)
        _, stats = program_columns(k, cols, cfg, cost=cost, d2d=d2d)
        report.merge(name, stats, cfg.n_cells)
    return report


def _time_deploy(params, cfg, batched: bool, seed: int = 1):
    """One full deploy; returns (seconds, report, compiles, host_syncs)."""
    c0, s0 = pipeline.compile_count(), pipeline.host_sync_count()
    with stopwatch(
        "deploy_arrays", batched=batched, seed=seed
    ) as w:
        _, report = deploy_arrays(
            jax.random.PRNGKey(seed), params, cfg,
            batched=batched, min_bucket=_MIN_BUCKET,
        )
    return (
        w.seconds,
        report,
        pipeline.compile_count() - c0,
        pipeline.host_sync_count() - s0,
    )


def main(quick: bool = False) -> dict:
    if quick:
        params = _toy_params(n_blocks=2, d_model=64, d_ff=128)
    else:
        params = _toy_params(n_blocks=4, d_model=128, d_ff=256)
    cfg = WVConfig(method=WVMethod.HARP)

    rows = {}
    # Every call of the eager baseline re-traces, so one timed run IS
    # its steady state (cold == warm).
    with stopwatch("deploy_baseline_eager") as w:
        base_report = _deploy_baseline_eager(params, cfg)
    base_s = w.seconds
    n_leaves = len(base_report.leaves)
    rows["baseline"] = dict(
        columns=base_report.num_columns,
        leaves=n_leaves,
        cold_s=base_s,
        warm_s=base_s,
        cold_columns_per_sec=base_report.num_columns / base_s,
        warm_columns_per_sec=base_report.num_columns / base_s,
        compiles=n_leaves,        # eager: the WV loop re-traces per leaf
        warm_compiles=n_leaves,
        host_syncs=7 * n_leaves,  # DeployReport.merge scalar pulls
        mean_iterations=base_report.mean_iterations,
        rms_cell_error_lsb=base_report.rms_cell_error_lsb,
    )
    emit(
        f"deploy.baseline{'.quick' if quick else ''}",
        base_s * 1e6,
        f"cols_per_s={base_report.num_columns / base_s:.0f} "
        f"retraces={n_leaves} host_syncs={7 * n_leaves}",
    )

    for name, batched in (("perleaf_jit", False), ("pipeline", True)):
        cold_s, report, compiles, syncs = _time_deploy(params, cfg, batched)
        warm_s, _, warm_compiles, _ = _time_deploy(params, cfg, batched, seed=2)
        cols = report.num_columns
        # The per-leaf paths pay `DeployReport.merge`'s 7 scalar
        # device->host pulls per leaf; the pipeline path is counted by
        # `host_fetch`.
        host_syncs = syncs if batched else 7 * len(report.leaves)
        rows[name] = dict(
            columns=cols,
            leaves=len(report.leaves),
            cold_s=cold_s,
            warm_s=warm_s,
            cold_columns_per_sec=cols / cold_s,
            warm_columns_per_sec=cols / warm_s,
            compiles=compiles,
            warm_compiles=warm_compiles,
            host_syncs=host_syncs,
            mean_iterations=report.mean_iterations,
            rms_cell_error_lsb=report.rms_cell_error_lsb,
        )
        emit(
            f"deploy.{name}{'.quick' if quick else ''}",
            warm_s * 1e6,
            f"cols_per_s={cols / warm_s:.0f} compiles={compiles} "
            f"host_syncs={host_syncs}",
        )

    n_buckets = len(pipeline.bucket_sizes(
        rows["pipeline"]["columns"], _MIN_BUCKET
    ))
    speedup = (
        rows["pipeline"]["warm_columns_per_sec"]
        / rows["baseline"]["warm_columns_per_sec"]
    )
    cold_speedup = (
        rows["pipeline"]["cold_columns_per_sec"]
        / rows["baseline"]["cold_columns_per_sec"]
    )
    result = dict(
        quick=quick,
        method=cfg.method.value,
        n_buckets=n_buckets,
        min_bucket=_MIN_BUCKET,
        speedup_warm=speedup,
        speedup_cold=cold_speedup,
        **{f"{k}__{kk}": vv for k, v in rows.items() for kk, vv in v.items()},
    )
    emit(
        f"deploy.speedup{'.quick' if quick else ''}",
        0.0,
        f"warm={speedup:.1f}x cold={cold_speedup:.1f}x buckets={n_buckets}",
    )
    # Perf contract (ISSUE 2 acceptance): the bucketed pipeline must
    # beat the per-leaf path >= 3x, compile at most once per bucket,
    # never retrace on a same-shape redeploy, and sync exactly once.
    assert rows["pipeline"]["compiles"] <= n_buckets, result
    assert rows["pipeline"]["warm_compiles"] == 0, result
    assert rows["pipeline"]["host_syncs"] == 1, result
    assert speedup >= 3.0, result

    # Quick (CI smoke) runs must not clobber the committed full-mode
    # perf trajectory.
    name = "BENCH_deploy_quick.json" if quick else "BENCH_deploy.json"
    out = pathlib.Path(__file__).with_name(name)
    out.write_text(json.dumps(result, indent=1))
    export_trace("deploy", quick)
    return result


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main(quick="--quick" in sys.argv)
