"""Analog compute-in-memory serving: end-task accuracy vs read noise.

The closing of the paper's loop (DESIGN.md Sec. 11): Figs. 10-11 show
programming error in the *cell* domain; this benchmark shows it where
the paper says it matters — in logits computed *in* the array.  A tiny
LM is trained, deployed once per WV method under severe verify-read
noise (sigma = 0.7 LSB), then served through the analog path
(bit-serial DAC -> in-array VMM -> per-slice ADC, `repro.cim`) across a
sweep of inference read-noise levels.  Because CW-SC programs the
arrays badly under verify noise while HD-PV/HARP program them well, the
analog-served logits separate the methods even when all of them face
identical inference noise.

Metrics per (method, inference sigma): analog eval loss (dloss vs the
clean digital model), logit RMSE vs clean digital logits, plus analog
vs digital serving tokens/sec through the ServeEngine.  Emits
``name,us_per_call,derived`` CSV rows and BENCH_cim.json
(BENCH_cim_quick.json for the CI smoke run, which must not clobber the
committed full-mode trajectory).

Asserts (ISSUE 3 acceptance):
* ideal analog (DAC/ADC -> infinity, noise -> 0) matches the digitally
  materialized model to float tolerance;
* HD-PV and HARP retain end-task accuracy through the analog path where
  CW-SC degrades (logit-domain strictly; eval-loss with the same
  noise-level tolerance band as fig10).
"""

from __future__ import annotations

import json
import pathlib
import sys

import jax
import jax.numpy as jnp

from repro.cim import CIMConfig, CIMExecutor
from repro.core import NoiseConfig, WVMethod, default_config_for_array
from repro.core.programmer import deploy_arrays
from repro.models.transformer import forward
from repro.serving import ServeEngine

from .common import emit, export_trace, stopwatch
from .fig10_robustness import _train_tiny_lm

_VERIFY_SIGMA = 0.7  # severe verify-read noise (paper Fig. 10 regime)
_IDEAL = CIMConfig(dac_bits=None, adc_bits=None, sigma_read_lsb=0.0)


def _analog_cfg(sigma: float) -> CIMConfig:
    return CIMConfig(dac_bits=6, adc_bits=10, sigma_read_lsb=sigma)


def main(quick: bool = False) -> dict:
    if quick:
        methods = [WVMethod.CW_SC, WVMethod.HARP]
        sigmas = (0.0, 0.7)
        steps, gen_batch, gen_new = 120, 2, 4
    else:
        methods = [WVMethod.CW_SC, WVMethod.MRA, WVMethod.HD_PV, WVMethod.HARP]
        sigmas = (0.0, 0.35, 0.7)
        steps, gen_batch, gen_new = 220, 4, 8
    cfg, params, eval_fn, eval_batch = _train_tiny_lm(steps=steps)
    logits_fn = jax.jit(lambda p, b: forward(p, b, cfg)[0])
    clean_loss = float(eval_fn(params, eval_batch))
    clean_logits = logits_fn(params, eval_batch)
    emit("cim.clean", 0.0, f"eval_loss={clean_loss:.4f}")

    rows: dict[str, dict] = {}
    dloss: dict[tuple[str, float], float] = {}
    rmse: dict[tuple[str, float], float] = {}
    deployments = {}
    for m in methods:
        wv = default_config_for_array(32).replace(
            method=m, noise=NoiseConfig(sigma_read_lsb=_VERIFY_SIGMA)
        )
        deployed, report = deploy_arrays(jax.random.PRNGKey(42), params, wv)
        deployments[m] = deployed
        dig_loss = float(eval_fn(deployed.materialize(), eval_batch))
        rows[f"{m.value}.deploy"] = dict(
            rms_cell_error_lsb=report.rms_cell_error_lsb,
            digital_loss=dig_loss,
        )
        for sigma in sigmas:
            ex = CIMExecutor(deployed, _analog_cfg(sigma), jax.random.PRNGKey(7))
            ap = ex.params()
            loss = float(eval_fn(ap, eval_batch))
            lg = logits_fn(ap, eval_batch)
            err = float(
                jnp.sqrt(jnp.mean((lg - clean_logits) ** 2))
            )
            dloss[(m.value, sigma)] = loss - clean_loss
            rmse[(m.value, sigma)] = err
            rows[f"{m.value}.analog.sigma{sigma:g}"] = dict(
                eval_loss=loss, dloss=loss - clean_loss, logit_rmse=err
            )
            emit(
                f"cim.{m.value}.sigma{sigma:g}", 0.0,
                f"dloss={loss - clean_loss:+.4f} logit_rmse={err:.4f} "
                f"rms_cell={report.rms_cell_error_lsb:.2f}",
            )

    # --- materialize-vs-analog equivalence contract (ideal converters)
    harp = deployments[WVMethod.HARP]
    ex0 = CIMExecutor(harp, _IDEAL, jax.random.PRNGKey(7))
    ideal_loss = float(eval_fn(ex0.params(), eval_batch))
    harp_dig = rows["harp.deploy"]["digital_loss"]
    emit("cim.equivalence", 0.0,
         f"ideal_analog={ideal_loss:.6f} digital={harp_dig:.6f}")
    assert abs(ideal_loss - harp_dig) < 1e-4, (ideal_loss, harp_dig)

    # --- serving throughput: analog vs digital decode through ServeEngine
    prompts = jax.random.randint(
        jax.random.PRNGKey(5), (gen_batch, 8), 0, cfg.vocab_size
    )
    ex = CIMExecutor(harp, _analog_cfg(sigmas[-1]), jax.random.PRNGKey(9))
    tput = {}
    for name, engine in (
        ("digital", ServeEngine(cfg, harp.materialize())),
        ("analog", ServeEngine(cfg, executor=ex)),
    ):
        engine.generate(prompts, max_new=2)  # compile
        with stopwatch(f"cim_generate_{name}", path=name) as w:
            engine.generate(prompts, max_new=gen_new)
        dt = w.seconds
        tput[name] = gen_batch * gen_new / dt
        emit(f"cim.serve.{name}", dt * 1e6, f"tok_per_s={tput[name]:.1f}")
    lat_ns, e_pj = ex.token_cost()
    rows["serving"] = dict(
        digital_tok_per_s=tput["digital"],
        analog_tok_per_s=tput["analog"],
        planes_per_token=ex.planes,
        array_latency_ns_per_token=lat_ns,
        array_energy_pj_per_token=e_pj,
    )
    emit("cim.token_cost", 0.0,
         f"latency={lat_ns:.0f}ns energy={e_pj / 1e3:.1f}nJ planes={ex.planes}")

    # --- robustness contract: Hadamard-domain programming survives the
    # analog readout where the one-hot baseline degrades.  Logit-domain
    # strictly; end-task dloss with fig10's noise-level tolerance band.
    hadamard = [m for m in (WVMethod.HD_PV, WVMethod.HARP) if m in deployments]
    for sigma in sigmas:
        for m in hadamard:
            assert rmse[(m.value, sigma)] < rmse[("cw_sc", sigma)], (
                m.value, sigma, rmse
            )
            assert dloss[(m.value, sigma)] < dloss[("cw_sc", sigma)] + 0.01, (
                m.value, sigma, dloss
            )

    result = dict(
        quick=quick,
        verify_sigma=_VERIFY_SIGMA,
        inference_sigmas=list(sigmas),
        clean_loss=clean_loss,
        **{f"{k}__{kk}": vv for k, v in rows.items() for kk, vv in v.items()},
    )
    name = "BENCH_cim_quick.json" if quick else "BENCH_cim.json"
    out = pathlib.Path(__file__).with_name(name)
    out.write_text(json.dumps(result, indent=1))
    export_trace("cim", quick)
    return result


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main(quick="--quick" in sys.argv)
