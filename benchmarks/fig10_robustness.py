"""Figs. 10-11: end-task robustness vs verify-read noise, iso-footprint.

Paper: CW-SC collapses above ~0.2-0.4 LSB read noise (>20% accuracy
loss on CIFAR-10 at ~0.8 LSB); HD-PV/HARP stay within ~1-3% everywhere;
the 64-cell/10-bit arrays (Fig. 11) show the same trend (the Hadamard
gain grows with N).

Dataset substitution (DESIGN.md Sec. 6): CIFAR/KWS are offline-
unavailable, so the end task is a small LM trained on the synthetic
bigram corpus, deployed through the identical quantize -> slice ->
program -> read-back pipeline.  The metric is eval-loss degradation vs
the clean quantized model (independent noise seeds for deploy/eval).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import NoiseConfig, WVConfig, WVMethod, default_config_for_array
from repro.core.programmer import deploy_params
from repro.data import SyntheticLM
from repro.models import ModelConfig, init_params
from repro.models.transformer import loss_fn
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.training import make_train_step, init_train_state, TrainState

from .common import emit

_METHODS = [WVMethod.CW_SC, WVMethod.HD_PV, WVMethod.HARP]


def _train_tiny_lm(steps: int = 220):
    cfg = ModelConfig(
        name="bench-lm", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=64, dtype=jnp.float32,
        attn_chunk_q=32, attn_chunk_kv=32, remat=False,
    )
    data = SyntheticLM(vocab_size=64, seq_len=64, global_batch=16, seed=3)
    opt_cfg = AdamWConfig(lr_peak=1e-2)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, total_steps=steps))
    for i in range(steps):
        state, metrics = step(state, data.global_batch_at(i)._asdict())
    eval_batch = data.global_batch_at(10_000)._asdict()
    eval_fn = jax.jit(lambda p, b: loss_fn(p, b, cfg)[0])
    return cfg, state.params, eval_fn, eval_batch


def main(n_cells: int = 32, noise_points=(0.1, 0.4, 0.7)) -> dict:
    cfg, params, eval_fn, eval_batch = _train_tiny_lm()
    clean = float(eval_fn(params, eval_batch))
    emit(f"fig10.n{n_cells}.clean", 0.0, f"eval_loss={clean:.4f}")

    out = {}
    rms = {}
    for sigma in noise_points:
        for m in _METHODS:
            wv = default_config_for_array(n_cells).replace(
                method=m, noise=NoiseConfig(sigma_read_lsb=sigma)
            )
            prog, report = deploy_params(
                jax.random.PRNGKey(42), params, wv
            )
            loss = float(eval_fn(prog, eval_batch))
            out[(sigma, m.value)] = loss - clean
            rms[(sigma, m.value)] = report.rms_cell_error_lsb
            emit(
                f"fig10.n{n_cells}.sigma{sigma:g}.{m.value}",
                0.0,
                f"dloss={loss - clean:+.4f} rms_cell={report.rms_cell_error_lsb:.2f}",
            )
    # Trend assertions at severe noise: Hadamard-domain verification
    # dominates the one-hot baseline in the programmed-cell domain...
    hi = max(noise_points)
    assert rms[(hi, "hd_pv")] < rms[(hi, "cw_sc")]
    assert rms[(hi, "harp")] < rms[(hi, "cw_sc")]
    # ...while the tiny bench LM's end-task deltas are noise-level
    # (<~0.01 nats), so they get a tolerance band (as in test_system).
    assert out[(hi, "hd_pv")] < out[(hi, "cw_sc")] + 0.01
    assert out[(hi, "harp")] < out[(hi, "cw_sc")] + 0.01
    return out


def main_fig11() -> dict:
    """64-cell columns with the 10-bit ADC (paper Fig. 11)."""
    return main(n_cells=64, noise_points=(0.4, 0.7))


if __name__ == "__main__":
    main()
    main_fig11()
