# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
# The registry is declarative and LAZY: ``--list`` and unknown-name
# errors never import jax (or any benchmark module), so sweep drivers
# and the tier-1 registry smoke test stay fast.  Each registered
# benchmark runs in sequence; a benchmark that raises aborts the run
# LOUDLY — full traceback to stderr and a non-zero exit — so CI and
# sweep drivers can never mistake a half-finished run for a passing one.
#
#   python -m benchmarks.run                      # run everything
#   python -m benchmarks.run --list               # names only, no imports
#   python -m benchmarks.run serving.traffic --quick
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

# (name, module under benchmarks/, attribute, kwargs)
REGISTRY: list[tuple[str, str, str, dict]] = [
    ("fig9.tau_sweep", "fig9_convergence", "main", {"sweep_tau": True}),
    ("fig9.convergence", "fig9_convergence", "convergence_curves", {}),
    ("fig9.n_scaling", "fig9_convergence", "n_scaling", {}),
    ("fig9c.common_mode", "fig9c_common_mode", "main", {}),
    ("fig10.robustness", "fig10_robustness", "main", {}),
    ("fig11.iso_footprint_64", "fig10_robustness", "main_fig11", {}),
    ("fig12.iso_footprint", "fig12_iso_footprint", "main", {}),
    ("fig13.latency_energy_32", "fig13_latency_energy", "main", {"n_cells": 32}),
    ("fig13.latency_energy_64", "fig13_latency_energy", "main", {"n_cells": 64}),
    ("table2.prior_work", "table2_prior_work", "main", {}),
    ("retention.refresh", "retention_refresh", "main", {}),
    ("kernels.bench", "kernels_bench", "main", {}),
    ("deploy.throughput", "deploy_throughput", "main", {}),
    ("cim.inference", "cim_inference", "main", {}),
    ("readout.sweep", "readout_sweep", "main", {}),
    ("serving.traffic", "serving_traffic", "main", {}),
    ("fault.tolerance", "fault_tolerance", "main", {}),
]

# Benchmarks whose entry accepts quick=True (CI smoke mode).
QUICK_CAPABLE = {
    "deploy.throughput",
    "cim.inference",
    "readout.sweep",
    "serving.traffic",
    "fault.tolerance",
}


def names() -> list[str]:
    return [name for name, _, _, _ in REGISTRY]


def _resolve(module: str, attr: str):
    pkg = __package__ or "benchmarks"
    return getattr(importlib.import_module(f"{pkg}.{module}"), attr)


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.run")
    ap.add_argument("benchmarks", nargs="*", metavar="NAME",
                    help="benchmark names to run (default: all)")
    ap.add_argument("--list", action="store_true", help="print names and exit")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode (quick-capable benchmarks only)")
    args = ap.parse_args(argv)

    if args.list:
        for n in names():
            tag = " [quick]" if n in QUICK_CAPABLE else ""
            print(f"{n}{tag}")
        return

    selected = REGISTRY
    if args.benchmarks:
        by_name = {entry[0]: entry for entry in REGISTRY}
        unknown = [n for n in args.benchmarks if n not in by_name]
        if unknown:
            print(
                f"unknown benchmark(s): {', '.join(unknown)}; "
                f"known: {', '.join(names())}",
                file=sys.stderr,
            )
            sys.exit(2)
        selected = [by_name[n] for n in args.benchmarks]
    if args.quick:
        bad = [n for n, _, _, _ in selected if n not in QUICK_CAPABLE]
        if args.benchmarks and bad:
            print(f"not quick-capable: {', '.join(bad)}", file=sys.stderr)
            sys.exit(2)
        selected = [e for e in selected if e[0] in QUICK_CAPABLE]

    t0 = time.time()
    print("name,us_per_call,derived")
    for name, module, attr, kwargs in selected:
        kw = dict(kwargs, quick=True) if args.quick else kwargs
        try:
            # Lazy import (keeps --list jax-free): fresh telemetry per
            # benchmark, so each exported TRACE_*.json is self-contained.
            from repro import obs  # noqa: PLC0415

            obs.reset_all()
            _resolve(module, attr)(**kw)
        except Exception:
            traceback.print_exc()
            print(
                f"benchmarks.total,{(time.time() - t0) * 1e6:.0f},"
                f"FAILED:{name}",
                file=sys.stderr,
            )
            sys.exit(1)
    print(f"benchmarks.total,{(time.time() - t0) * 1e6:.0f},all-passed")


if __name__ == "__main__":
    main()
