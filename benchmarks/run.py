# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import time


def main() -> None:
    t0 = time.time()
    from . import (
        fig9_convergence,
        fig9c_common_mode,
        fig10_robustness,
        fig12_iso_footprint,
        fig13_latency_energy,
        retention_refresh,
        table2_prior_work,
        kernels_bench,
        deploy_throughput,
        cim_inference,
    )

    print("name,us_per_call,derived")
    fig9_convergence.main(sweep_tau=True)
    fig9_convergence.convergence_curves()
    fig9_convergence.n_scaling()
    fig9c_common_mode.main()
    fig10_robustness.main()
    fig10_robustness.main_fig11()
    fig12_iso_footprint.main()
    fig13_latency_energy.main(32)
    fig13_latency_energy.main(64)
    table2_prior_work.main()
    retention_refresh.main()
    kernels_bench.main()
    deploy_throughput.main()
    cim_inference.main()
    print(f"benchmarks.total,{(time.time() - t0) * 1e6:.0f},all-passed")


if __name__ == "__main__":
    main()
