# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
# The registry is declarative and LAZY: ``--list`` and unknown-name
# errors never import jax (or any benchmark module), so sweep drivers
# and the tier-1 registry smoke test stay fast.  Each registered
# benchmark runs in sequence; a benchmark that raises aborts the run
# LOUDLY — full traceback to stderr and a non-zero exit — so CI and
# sweep drivers can never mistake a half-finished run for a passing one.
#
#   python -m benchmarks.run                      # run everything
#   python -m benchmarks.run --list               # names only, no imports
#   python -m benchmarks.run serving.traffic --quick
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

# (name, module under benchmarks/, attribute, kwargs)
REGISTRY: list[tuple[str, str, str, dict]] = [
    ("fig9.tau_sweep", "fig9_convergence", "main", {"sweep_tau": True}),
    ("fig9.convergence", "fig9_convergence", "convergence_curves", {}),
    ("fig9.n_scaling", "fig9_convergence", "n_scaling", {}),
    ("fig9c.common_mode", "fig9c_common_mode", "main", {}),
    ("fig10.robustness", "fig10_robustness", "main", {}),
    ("fig11.iso_footprint_64", "fig10_robustness", "main_fig11", {}),
    ("fig12.iso_footprint", "fig12_iso_footprint", "main", {}),
    ("fig13.latency_energy_32", "fig13_latency_energy", "main", {"n_cells": 32}),
    ("fig13.latency_energy_64", "fig13_latency_energy", "main", {"n_cells": 64}),
    ("table2.prior_work", "table2_prior_work", "main", {}),
    ("retention.refresh", "retention_refresh", "main", {}),
    ("kernels.bench", "kernels_bench", "main", {}),
    ("deploy.throughput", "deploy_throughput", "main", {}),
    ("cim.inference", "cim_inference", "main", {}),
    ("readout.sweep", "readout_sweep", "main", {}),
    ("serving.traffic", "serving_traffic", "main", {}),
    ("fault.tolerance", "fault_tolerance", "main", {}),
    ("fleet.health", "fleet_health", "main", {}),
]

# Benchmarks whose entry accepts quick=True (CI smoke mode).
QUICK_CAPABLE = {
    "kernels.bench",
    "deploy.throughput",
    "cim.inference",
    "readout.sweep",
    "serving.traffic",
    "fault.tolerance",
    "fleet.health",
}

# --check-baselines: declarative quick-vs-committed comparison table.
#
# Quick and full runs use different model/stream sizes, so raw
# magnitudes are NOT comparable; each check names a key that is either
# a hard contract (mode "eq": must match the committed value exactly),
# scale-invariant within a declared relative tolerance (mode "rel"),
# or a ratio with a floor (mode "min").  Key paths resolve dotted
# segments longest-prefix-first so literal dotted key names (e.g.
# "sigma0.7__logit_rmse") resolve correctly.
#   (key_path, mode, tolerance_or_floor)
BASELINE_CHECKS: dict[str, tuple[str, str, list[tuple[str, str, float]]]] = {
    "deploy.throughput": ("BENCH_deploy.json", "BENCH_deploy_quick.json", [
        ("pipeline__host_syncs", "eq", 0.0),
        ("pipeline__warm_compiles", "eq", 0.0),
        ("speedup_warm", "min", 1.0),
        ("speedup_cold", "min", 1.0),
        ("pipeline__rms_cell_error_lsb", "rel", 0.10),
        ("baseline__rms_cell_error_lsb", "rel", 0.10),
        ("pipeline__mean_iterations", "rel", 0.10),
    ]),
    "cim.inference": ("BENCH_cim.json", "BENCH_cim_quick.json", [
        ("harp.deploy__rms_cell_error_lsb", "rel", 0.15),
        ("cw_sc.deploy__rms_cell_error_lsb", "rel", 0.15),
        ("harp.analog.sigma0__logit_rmse", "rel", 0.50),
        ("harp.analog.sigma0.7__logit_rmse", "rel", 0.50),
        ("serving__planes_per_token", "eq", 0.0),
    ]),
    "readout.sweep": ("BENCH_readout.json", "BENCH_readout_quick.json", [
        ("harp.clean.rms_cell_lsb", "rel", 0.15),
        ("harp.drifted.rms_cell_lsb", "rel", 0.15),
        ("harp.calibrated.rms_cell_lsb", "rel", 0.15),
        ("mra.drifted.rms_cell_lsb", "rel", 0.25),
        ("mra.calibrated.rms_cell_lsb", "rel", 0.25),
    ]),
    "serving.traffic": ("BENCH_serving.json", "BENCH_serving_quick.json", [
        ("digital.counters.host_syncs_per_step", "eq", 0.0),
        ("digital.counters.retraces_after_warmup", "eq", 0.0),
        ("analog.counters.host_syncs_per_step", "eq", 0.0),
        ("analog.counters.retraces_after_warmup", "eq", 0.0),
        ("config.rms_cell_error_lsb", "rel", 0.15),
        # Fused analog decode throughput gate (DESIGN.md Sec. 17): the
        # pre-fusion interpreter loop cost 25-90x more per decode step,
        # so even these generous runner-jitter tolerances fail loudly
        # if per-tile/per-plane Python dispatch ever creeps back.
        ("analog.summary.step_us", "rel", 2.0),
        ("analog.summary.tokens_per_s", "rel", 0.9),
        # SLO sweep (ISSUE-10): chunked prefill + EDF admission must cut
        # p99 TTFT vs whole-prompt FIFO on the mixed deadline stream
        # (>1 = improvement), and the policy variants must serve
        # byte-identical tokens (0.0 = zero mismatched requests).
        ("slo.summary.ttft_p99_improvement", "min", 1.0),
        ("slo.summary.tokens_bit_identical_across_policies", "eq", 0.0),
        # Data-sharded decode must stay bit-identical to the unsharded
        # run (0.0 = zero mismatches) with one host sync per step.
        ("sharded.tokens_bit_identical", "eq", 0.0),
        ("sharded.host_syncs_per_step", "eq", 0.0),
    ]),
    "fault.tolerance": ("BENCH_faults.json", "BENCH_faults_quick.json", [
        ("contracts.host_syncs_per_deploy", "eq", 0.0),
        ("contracts.zero_fault_bit_identical", "eq", 0.0),
        ("config.give_up_pulses", "eq", 0.0),
    ]),
    "fleet.health": ("BENCH_fleet.json", "BENCH_fleet_quick.json", [
        ("contracts.host_syncs_per_step", "eq", 0.0),
        ("contracts.retraces_after_warmup", "eq", 0.0),
        ("contracts.no_breach_before_inject", "eq", 0.0),
        ("contracts.give_up_first_breach_window", "eq", 0.0),
        ("config.inject_window", "eq", 0.0),
    ]),
}


def names() -> list[str]:
    return [name for name, _, _, _ in REGISTRY]


def _resolve_key(doc, path: str):
    """Resolve a dotted key path, longest key prefix first, so literal
    dotted key names inside the json resolve too.  Returns None when
    any segment is missing."""
    if not path:
        return doc
    if not isinstance(doc, dict):
        return None
    parts = path.split(".")
    for i in range(len(parts), 0, -1):
        head = ".".join(parts[:i])
        if head in doc:
            rest = ".".join(parts[i:])
            if not rest:
                return doc[head]
            found = _resolve_key(doc[head], rest)
            if found is not None:
                return found
    return None


def check_baselines(selected_names: list[str] | None = None) -> int:
    """Compare fresh quick metrics against the committed BENCH json.

    For every BASELINE_CHECKS entry whose committed baseline exists:
    run the quick benchmark if its quick json is missing (CI runs the
    quick smokes first, so this is normally a pure file comparison),
    then evaluate each declared check.  Returns the number of failed
    checks; prints one grep-able CSV row per check:
    ``check,<bench>,<key>,<mode>,<quick>,<committed>,<ok|FAIL>``.
    """
    import json
    import os

    here = os.path.dirname(os.path.abspath(__file__))
    failures = 0
    items = [
        (n, BASELINE_CHECKS[n])
        for n in (selected_names or list(BASELINE_CHECKS))
        if n in BASELINE_CHECKS
    ]
    print("check,benchmark,key,mode,quick,committed,status")
    for bench, (full_file, quick_file, checks) in items:
        full_path = os.path.join(here, full_file)
        quick_path = os.path.join(here, quick_file)
        if not os.path.exists(full_path):
            print(f"check,{bench},-,-,-,-,SKIP:no-baseline")
            continue
        if not os.path.exists(quick_path):
            by_name = {e[0]: e for e in REGISTRY}
            _, module, attr, kwargs = by_name[bench]
            from repro import obs  # noqa: PLC0415

            obs.reset_all()
            _resolve(module, attr)(**dict(kwargs, quick=True))
        with open(full_path) as f:
            full = json.load(f)
        with open(quick_path) as f:
            quick = json.load(f)
        for key, mode, arg in checks:
            qv, fv = _resolve_key(quick, key), _resolve_key(full, key)
            ok = qv is not None and fv is not None
            if ok:
                if mode == "eq":
                    ok = qv == fv
                elif mode == "min":
                    ok = float(qv) >= arg
                elif mode == "rel":
                    ok = abs(float(qv) - float(fv)) <= arg * max(
                        abs(float(fv)), 1e-9
                    )
                else:
                    raise ValueError(f"unknown check mode {mode!r}")
            status = "ok" if ok else "FAIL"
            failures += 0 if ok else 1
            print(f"check,{bench},{key},{mode},{qv},{fv},{status}")
    return failures


def _resolve(module: str, attr: str):
    pkg = __package__ or "benchmarks"
    return getattr(importlib.import_module(f"{pkg}.{module}"), attr)


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.run")
    ap.add_argument("benchmarks", nargs="*", metavar="NAME",
                    help="benchmark names to run (default: all)")
    ap.add_argument("--list", action="store_true", help="print names and exit")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode (quick-capable benchmarks only)")
    ap.add_argument("--check-baselines", action="store_true",
                    help="compare fresh quick metrics against committed "
                         "BENCH_*.json baselines; non-zero exit on drift")
    args = ap.parse_args(argv)

    if args.check_baselines:
        failures = check_baselines(args.benchmarks or None)
        if failures:
            print(f"baseline-check,{failures},FAILED", file=sys.stderr)
            sys.exit(1)
        print("baseline-check,0,all-within-tolerance")
        return

    if args.list:
        for n in names():
            tag = " [quick]" if n in QUICK_CAPABLE else ""
            print(f"{n}{tag}")
        return

    selected = REGISTRY
    if args.benchmarks:
        by_name = {entry[0]: entry for entry in REGISTRY}
        unknown = [n for n in args.benchmarks if n not in by_name]
        if unknown:
            print(
                f"unknown benchmark(s): {', '.join(unknown)}; "
                f"known: {', '.join(names())}",
                file=sys.stderr,
            )
            sys.exit(2)
        selected = [by_name[n] for n in args.benchmarks]
    if args.quick:
        bad = [n for n, _, _, _ in selected if n not in QUICK_CAPABLE]
        if args.benchmarks and bad:
            print(f"not quick-capable: {', '.join(bad)}", file=sys.stderr)
            sys.exit(2)
        selected = [e for e in selected if e[0] in QUICK_CAPABLE]

    t0 = time.time()
    print("name,us_per_call,derived")
    for name, module, attr, kwargs in selected:
        kw = dict(kwargs, quick=True) if args.quick else kwargs
        try:
            # Lazy import (keeps --list jax-free): fresh telemetry per
            # benchmark, so each exported TRACE_*.json is self-contained.
            from repro import obs  # noqa: PLC0415

            obs.reset_all()
            _resolve(module, attr)(**kw)
        except Exception:
            traceback.print_exc()
            print(
                f"benchmarks.total,{(time.time() - t0) * 1e6:.0f},"
                f"FAILED:{name}",
                file=sys.stderr,
            )
            sys.exit(1)
    print(f"benchmarks.total,{(time.time() - t0) * 1e6:.0f},all-passed")


if __name__ == "__main__":
    main()
