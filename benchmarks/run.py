# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
# Each registered benchmark runs in sequence; a benchmark that raises
# aborts the run LOUDLY — full traceback to stderr and a non-zero exit —
# so CI and sweep drivers can never mistake a half-finished run for a
# passing one.
from __future__ import annotations

import sys
import time
import traceback


def _registry():
    from . import (
        cim_inference,
        deploy_throughput,
        fig9_convergence,
        fig9c_common_mode,
        fig10_robustness,
        fig12_iso_footprint,
        fig13_latency_energy,
        kernels_bench,
        readout_sweep,
        retention_refresh,
        table2_prior_work,
    )

    return [
        ("fig9.tau_sweep", lambda: fig9_convergence.main(sweep_tau=True)),
        ("fig9.convergence", fig9_convergence.convergence_curves),
        ("fig9.n_scaling", fig9_convergence.n_scaling),
        ("fig9c.common_mode", fig9c_common_mode.main),
        ("fig10.robustness", fig10_robustness.main),
        ("fig11.iso_footprint_64", fig10_robustness.main_fig11),
        ("fig12.iso_footprint", fig12_iso_footprint.main),
        ("fig13.latency_energy_32", lambda: fig13_latency_energy.main(32)),
        ("fig13.latency_energy_64", lambda: fig13_latency_energy.main(64)),
        ("table2.prior_work", table2_prior_work.main),
        ("retention.refresh", retention_refresh.main),
        ("kernels.bench", kernels_bench.main),
        ("deploy.throughput", deploy_throughput.main),
        ("cim.inference", cim_inference.main),
        ("readout.sweep", readout_sweep.main),
    ]


def main() -> None:
    t0 = time.time()
    print("name,us_per_call,derived")
    for name, fn in _registry():
        try:
            fn()
        except Exception:
            traceback.print_exc()
            print(
                f"benchmarks.total,{(time.time() - t0) * 1e6:.0f},"
                f"FAILED:{name}",
                file=sys.stderr,
            )
            sys.exit(1)
    print(f"benchmarks.total,{(time.time() - t0) * 1e6:.0f},all-passed")


if __name__ == "__main__":
    main()
