"""Table 2: headline comparison, normalized against the CW-SC baseline.

The paper reports HD-PV/HARP improvements normalized to CW-SC (itself
stronger than cell-by-cell WV): energy 6.2x / 9.5x and latency 6.1x /
3.5x refer to the MRA comparison (Fig. 12); this table reports the
direct CW-SC-relative gains of the whole framework run.
"""

from __future__ import annotations

from repro.core import WVConfig, WVMethod

from .common import ALL_METHODS, emit, run_wv


def main(n_columns: int = 512) -> dict:
    res = {}
    for m in ALL_METHODS:
        r, _us = run_wv(WVConfig(method=m), n_columns, seed=5)
        res[m.value] = r
    base = res["cw_sc"]
    for v in ("hd_pv", "harp", "mra"):
        r = res[v]
        emit(
            f"table2.{v}_vs_cwsc",
            0.0,
            f"error={base['rms_weight'] / r['rms_weight']:.2f}x "
            f"latency={base['latency_us'] / r['latency_us']:.2f}x "
            f"energy={base['energy_nj'] / r['energy_nj']:.2f}x "
            f"iters={base['iterations'] / r['iterations']:.2f}x",
        )
    assert res["hd_pv"]["rms_weight"] < base["rms_weight"]
    assert res["harp"]["energy_nj"] < base["energy_nj"]
    return res


if __name__ == "__main__":
    main()
