"""Batched serving demo: prefill + decode with the ServeEngine.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-0.6b

Uses the smoke-size config of the chosen architecture (CPU-friendly),
runs batched greedy generation, and reports tokens/s.  With --rram it
first programs the weights onto simulated RRAM with HARP and serves the
programmed model (the paper's iso-footprint deployment).
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import WVConfig, WVMethod
from repro.core.programmer import deploy_params
from repro.models import init_params
from repro.serving import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--rram", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if cfg.block == "rwkv6" or cfg.frontend == "embed_stub":
        raise SystemExit("pick a token-input arch for this demo (dense/moe/hybrid)")
    params = init_params(jax.random.PRNGKey(0), cfg)
    if args.rram:
        print("programming weights onto RRAM with HARP ...")
        params, report = deploy_params(
            jax.random.PRNGKey(1), params, WVConfig(method=WVMethod.HARP)
        )
        print(f"  programmed {report.num_cells:,} cells, "
              f"rms={report.rms_cell_error_lsb:.3f} LSB")

    engine = ServeEngine(cfg, params)
    prompts = jax.random.randint(
        jax.random.PRNGKey(2), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    t0 = time.time()
    out = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    total = args.batch * args.max_new
    print(f"arch={args.arch} (smoke config) batch={args.batch}")
    print(f"generated {out.shape} in {dt:.2f}s ({total / dt:.1f} tok/s incl. compile)")
    print("first sequence:", out[0][:16].tolist(), "...")


if __name__ == "__main__":
    main()
