"""Batched serving demo: prefill + decode with the ServeEngine.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-0.6b

Uses the smoke-size config of the chosen architecture (CPU-friendly),
runs batched greedy generation, and reports tokens/s.  Two RRAM modes:

  --rram    program the weights with HARP, read them back, serve the
            materialized digital weights (the paper's iso-footprint
            deployment, programming error frozen into dense matmuls);
  --analog  program with HARP and serve straight off the live
            `DeployedModel` arrays — no materialize(): every matmul is
            computed *in* the programmed conductance tiles through the
            bit-serial DAC -> analog VMM -> per-slice ADC path, with
            per-read noise, and the cost model's inference phase prices
            every token (repro.cim, DESIGN.md Sec. 11).

`--continuous` swaps the fixed-batch generate loop for the
continuous-batching scheduler (DESIGN.md Sec. 13): a Poisson stream of
variable-length requests is admitted into a fixed decode batch with
zero retraces after warmup, and per-request latency is reported.
"""

import argparse
import time

import jax

from repro.configs import get_smoke_config
from repro.core import WVConfig, WVMethod
from repro.core.programmer import deploy_arrays, deploy_params
from repro.models import init_params
from repro.serving import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--rram", action="store_true")
    ap.add_argument("--analog", action="store_true",
                    help="serve off the live arrays (compute-in-memory)")
    ap.add_argument("--dac-bits", type=int, default=6)
    ap.add_argument("--adc-bits", type=int, default=10)
    ap.add_argument("--read-noise", type=float, default=0.2,
                    help="per-read TIA/ADC noise std, cell-LSB")
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--continuous", action="store_true",
                    help="serve a Poisson request stream via the scheduler")
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--load", type=float, default=0.3,
                    help="offered load, requests per decode step")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if cfg.block == "rwkv6" or cfg.frontend == "embed_stub":
        raise SystemExit("pick a token-input arch for this demo (dense/moe/hybrid)")
    params = init_params(jax.random.PRNGKey(0), cfg)

    executor = None
    if args.analog:
        from repro.cim import CIMConfig, CIMExecutor

        print("programming weights onto RRAM with HARP ...")
        deployed, report = deploy_arrays(
            jax.random.PRNGKey(1), params, WVConfig(method=WVMethod.HARP)
        )
        print(f"  programmed {report.num_cells:,} cells, "
              f"rms={report.rms_cell_error_lsb:.3f} LSB")
        executor = CIMExecutor(
            deployed,
            CIMConfig(
                dac_bits=args.dac_bits, adc_bits=args.adc_bits,
                sigma_read_lsb=args.read_noise, use_pallas=args.use_pallas,
            ),
            jax.random.PRNGKey(7),
        )
        s = executor.summary()
        print(f"  analog serving: {s['analog_leaves']} leaves on tiles, "
              f"{s['digital_fallback_leaves']} digital fallback, "
              f"{s['planes_per_token']} read planes/token")
        params = None
    elif args.rram:
        print("programming weights onto RRAM with HARP ...")
        params, report = deploy_params(
            jax.random.PRNGKey(1), params, WVConfig(method=WVMethod.HARP)
        )
        print(f"  programmed {report.num_cells:,} cells, "
              f"rms={report.rms_cell_error_lsb:.3f} LSB")

    engine = ServeEngine(cfg, params, executor=executor)

    if args.continuous:
        from repro.serving import ContinuousScheduler, poisson_requests

        max_len = args.prompt_len + args.max_new + 8
        sched = ContinuousScheduler(
            engine, n_slots=args.n_slots, max_len=max_len,
            key=jax.random.PRNGKey(11),
        )
        lo, hi = max(args.prompt_len // 2, 2), args.prompt_len
        print(f"warming prefill buckets for prompts in [{lo}, {hi}] ...")
        sched.warmup(prompt_range=(lo, hi))
        reqs = poisson_requests(
            3, args.requests, rate=args.load, vocab=cfg.vocab_size,
            prompt_lens=(lo, hi), max_new=(args.max_new // 2, args.max_new),
        )
        recs = sched.run(reqs)
        s = sched.latency_stats()
        print(f"served {len(recs)} requests in {sched.decode_steps} decode "
              f"steps ({s['tokens_per_s']:.1f} tok/s, "
              f"{s['tokens_per_step']:.2f} tok/step)")
        print(f"latency p50={s['p50_latency_steps']:.1f} "
              f"p99={s['p99_latency_steps']:.1f} steps; "
              f"ttft p50={s['p50_ttft_steps']:.1f} steps")
        print(f"retraces after warmup: admit={sched.trace_counts['admit']} "
              f"decode={sched.trace_counts['decode']} (counts incl. warmup)")
        if executor is not None:
            lat_ns, e_pj = executor.token_cost()
            print(f"analog cost model: {lat_ns / 1e3:.2f} us/token, "
                  f"{e_pj / 1e3:.1f} nJ/token")
        return

    prompts = jax.random.randint(
        jax.random.PRNGKey(2), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    t0 = time.time()
    out = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    total = args.batch * args.max_new
    print(f"arch={args.arch} (smoke config) batch={args.batch}")
    print(f"generated {out.shape} in {dt:.2f}s ({total / dt:.1f} tok/s incl. compile)")
    if executor is not None:
        lat_ns, e_pj = executor.token_cost()
        s = executor.summary()
        print(
            f"analog cost model: {lat_ns / 1e3:.2f} us/token array latency, "
            f"{e_pj / 1e3:.1f} nJ/token "
            f"({s['total_energy_pj'] / 1e6:.2f} uJ for {s['tokens_served']} tokens)"
        )
    print("first sequence:", out[0][:16].tolist(), "...")


if __name__ == "__main__":
    main()
