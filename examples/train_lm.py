"""End-to-end training driver: fault-tolerant LM training on synthetic data.

Default is a CPU-friendly reduced config; `--arch smollm-360m --full`
selects the real config (sized for the production mesh).  A ~100M-param
run a few hundred steps long:

    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

Features exercised: deterministic sharded data pipeline, AdamW + cosine
schedule, async checkpointing with keep-k rotation, fault injection +
restore (--inject-failure), straggler monitor, resume (--resume).
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data import SyntheticLM
from repro.distributed import FaultInjector, FaultTolerantRunner, StragglerMonitor
from repro.models import ModelConfig
from repro.optim import AdamWConfig
from repro.training import init_train_state, make_train_step

PRESETS = {
    # ~1M params: smoke-speed
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
                 d_ff=256, vocab_size=512, seq=128, batch=8),
    # ~100M params: the "train a ~100M model for a few hundred steps" driver
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
                 d_ff=2048, vocab_size=32768, seq=512, batch=8),
}


def build_cfg(args) -> tuple[ModelConfig, int, int]:
    if args.arch:
        cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
        return cfg, args.seq or 256, args.batch or 8
    p = PRESETS[args.preset]
    cfg = ModelConfig(
        name=f"lm-{args.preset}", n_layers=p["n_layers"], d_model=p["d_model"],
        n_heads=p["n_heads"], n_kv_heads=p["n_kv_heads"], head_dim=p["head_dim"],
        d_ff=p["d_ff"], vocab_size=p["vocab_size"], dtype=jnp.float32,
        attn_chunk_q=128, attn_chunk_kv=128, remat=False,
    )
    return cfg, args.seq or p["seq"], args.batch or p["batch"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--arch", default=None, help="use a registry architecture")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/harp_jax_train")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-failure", type=int, nargs="*", default=())
    args = ap.parse_args()

    cfg, seq, batch = build_cfg(args)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch)
    opt_cfg = AdamWConfig(lr_peak=1e-3)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"model={cfg.name} params={n_params:,} seq={seq} batch={batch}")

    raw_step = jax.jit(make_train_step(cfg, opt_cfg, total_steps=args.steps))
    monitor = StragglerMonitor()
    t_last = [time.perf_counter()]

    def step_fn(state, batch):
        state, metrics = raw_step(state, batch)
        loss = float(metrics["loss"])
        now = time.perf_counter()
        monitor.observe(int(state.opt.step), now - t_last[0])
        t_last[0] = now
        return state, {"loss": loss}

    manager = CheckpointManager(args.ckpt_dir, keep=3)
    start = 0
    if args.resume:
        try:
            start, state = manager.restore_latest(template=state)
            print(f"resumed from step {start}")
        except FileNotFoundError:
            print("no checkpoint found; starting fresh")

    runner = FaultTolerantRunner(
        step_fn,
        lambda s: data.global_batch_at(s)._asdict(),
        manager,
        checkpoint_every=args.ckpt_every,
        injector=FaultInjector(fail_at_steps=tuple(args.inject_failure)),
    )
    t0 = time.time()
    state, logs = runner.run(state, start, args.steps)
    dt = time.time() - t0
    first, last = logs[0]["loss"], logs[-1]["loss"]
    print(
        f"steps={len(logs)} loss {first:.4f} -> {last:.4f} "
        f"({dt:.1f}s, {dt / max(len(logs), 1) * 1e3:.0f} ms/step, "
        f"restarts={runner.restarts}, straggler_flags={len(monitor.flagged_steps)})"
    )
    assert last < first, "training did not reduce the loss"


if __name__ == "__main__":
    main()
