"""Deploy a trained LM onto simulated RRAM with HARP write-and-verify.

The paper's pipeline end-to-end: train a small LM -> quantize (B=6,
Bc=3) -> bit-slice onto signed column pairs -> program with CW-SC /
MRA / HD-PV / HARP under severe read noise -> serve with the programmed
(noisy) weights and compare eval loss.  This is Fig. 10's experiment on
the framework's own workload.

    PYTHONPATH=src python examples/deploy_rram.py --steps 150 --noise 0.7
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core import NoiseConfig, WVConfig, WVMethod
from repro.core.programmer import deploy_params
from repro.data import SyntheticLM
from repro.models import ModelConfig
from repro.models.transformer import loss_fn
from repro.optim import AdamWConfig
from repro.training import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--noise", type=float, default=0.7, help="read noise, LSB")
    ap.add_argument("--n-cells", type=int, default=32)
    args = ap.parse_args()

    cfg = ModelConfig(
        name="deploy-demo", n_layers=2, d_model=96, n_heads=4, n_kv_heads=2,
        head_dim=24, d_ff=192, vocab_size=64, dtype=jnp.float32,
        attn_chunk_q=32, attn_chunk_kv=32, remat=False,
    )
    data = SyntheticLM(vocab_size=64, seq_len=64, global_batch=16, seed=1)
    opt_cfg = AdamWConfig(lr_peak=1e-2)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, total_steps=args.steps))
    for i in range(args.steps):
        state, m = step(state, data.global_batch_at(i)._asdict())
    eval_batch = data.global_batch_at(99_999)._asdict()
    eval_fn = jax.jit(lambda p, b: loss_fn(p, b, cfg)[0])
    clean = float(eval_fn(state.params, eval_batch))
    print(f"trained {args.steps} steps; clean eval loss = {clean:.4f}\n")

    print(f"{'method':8s} {'eval loss':>10s} {'dloss':>8s} {'rms[LSB]':>9s} "
          f"{'iters':>6s} {'E[uJ]':>8s}")
    for method in WVMethod:
        wv = WVConfig(
            method=method, n_cells=args.n_cells,
            noise=NoiseConfig(sigma_read_lsb=args.noise),
        )
        prog, report = deploy_params(jax.random.PRNGKey(7), state.params, wv)
        loss = float(eval_fn(prog, eval_batch))
        print(
            f"{method.value:8s} {loss:10.4f} {loss - clean:+8.4f} "
            f"{report.rms_cell_error_lsb:9.3f} {report.mean_iterations:6.1f} "
            f"{report.total_energy_pj / 1e6:8.2f}"
        )
    print("\nUnder severe read noise the Hadamard-domain methods (hd_pv,")
    print("harp) should preserve eval loss where cw_sc degrades.")


if __name__ == "__main__":
    main()
