"""Quickstart: program an RRAM array with all four WV methods.

Runs in ~1 minute on CPU:

    PYTHONPATH=src python examples/quickstart.py

Programs 256 columns of 32 cells (the paper's default array) from HRS to
random 3-bit targets under severe read noise (0.7 LSB) and prints the
Fig.-9-style comparison: mapping error, iterations, latency, energy.
"""

import jax
import jax.numpy as jnp

from repro.core import WVConfig, WVMethod, program_columns


def main():
    tkey, pkey = jax.random.split(jax.random.PRNGKey(0))
    targets = jax.random.randint(tkey, (256, 32), 0, 8).astype(jnp.float32)

    print(f"{'method':8s} {'rms[LSB]':>9s} {'iters':>6s} {'lat[us]':>8s} {'E[nJ]':>7s}")
    for method in WVMethod:
        cfg = WVConfig(method=method)
        g, stats = jax.jit(lambda k, t, c=cfg: program_columns(k, t, c))(pkey, targets)
        print(
            f"{method.value:8s} "
            f"{float(jnp.mean(stats.rms_error_lsb)):9.3f} "
            f"{float(jnp.mean(stats.iterations)):6.1f} "
            f"{float(jnp.mean(stats.latency_ns)) / 1e3:8.1f} "
            f"{float(jnp.mean(stats.energy_pj)) / 1e3:7.2f}"
        )
    print("\nHadamard-domain verification (hd_pv/harp) should show the")
    print("lowest error/iterations (hd_pv) and the lowest energy (harp).")


if __name__ == "__main__":
    main()
