"""Serve an RRAM-deployed LM across device aging with scrub refresh.

End-to-end lifetime scenario (DESIGN.md Sec. 9): train a small LM,
burn it onto simulated RRAM with `deploy_arrays` (the persistent-state
path — conductances stay live), then serve traffic across wall-clock
epochs while the devices relax, drift, and wear.  Each epoch the
refresh policy decides what to scrub (verify-triggered by default: one
cheap Hadamard sweep per column, re-program only flagged columns), the
refreshed weights are re-materialized and hot-swapped into the serving
engine, and the `LifetimeReport` time series records accuracy retained
vs maintenance energy spent.

    PYTHONPATH=src python examples/lifetime_serve.py --epochs 4
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core import NoiseConfig, WVConfig, WVMethod
from repro.core.programmer import deploy_arrays
from repro.data import SyntheticLM
from repro.lifetime import (
    DriftConfig,
    LifetimeSimulator,
    RefreshConfig,
    RefreshPolicy,
)
from repro.models import ModelConfig
from repro.models.transformer import loss_fn
from repro.optim import AdamWConfig
from repro.serving import ServeEngine
from repro.training import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--dt-hours", type=float, default=1.0)
    ap.add_argument("--noise", type=float, default=0.7, help="read noise, LSB")
    ap.add_argument("--method", default="harp", choices=[m.value for m in WVMethod])
    ap.add_argument(
        "--policy", default="verify_triggered",
        choices=[p.value for p in RefreshPolicy],
    )
    args = ap.parse_args()

    cfg = ModelConfig(
        name="lifetime-demo", n_layers=2, d_model=96, n_heads=4, n_kv_heads=2,
        head_dim=24, d_ff=192, vocab_size=64, dtype=jnp.float32,
        attn_chunk_q=32, attn_chunk_kv=32, remat=False,
    )
    data = SyntheticLM(vocab_size=64, seq_len=64, global_batch=16, seed=1)
    state = init_train_state(jax.random.PRNGKey(0), cfg, AdamWConfig(lr_peak=1e-2))
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr_peak=1e-2), total_steps=args.steps))
    for i in range(args.steps):
        state, _ = step(state, data.global_batch_at(i)._asdict())
    eval_batch = data.global_batch_at(99_999)._asdict()
    eval_fn = jax.jit(lambda p, b: loss_fn(p, b, cfg)[0])
    clean = float(eval_fn(state.params, eval_batch))
    print(f"trained {args.steps} steps; clean eval loss = {clean:.4f}")

    wv = WVConfig(
        method=WVMethod(args.method),
        noise=NoiseConfig(sigma_read_lsb=args.noise),
    )
    deployed, report = deploy_arrays(jax.random.PRNGKey(7), state.params, wv)
    print(
        f"deployed {report.num_columns} columns "
        f"({report.num_cells} cells) with {args.method}; "
        f"rms err = {report.rms_cell_error_lsb:.3f} LSB\n"
    )

    engine = ServeEngine(cfg, deployed.materialize())
    sim = LifetimeSimulator(
        jax.random.PRNGKey(11),
        deployed,
        drift_cfg=DriftConfig(nu_drift=0.01, sigma_nu_frac=0.8),
        refresh_cfg=RefreshConfig(policy=RefreshPolicy(args.policy)),
        on_refresh=engine.swap_params,
    )

    prompt = data.global_batch_at(0).tokens[:4, :16]
    print(f"{'epoch':>5s} {'t[h]':>6s} {'loss':>8s} {'dloss':>8s} {'rms[LSB]':>9s} "
          f"{'flags':>6s} {'reprog':>6s} {'E_maint[nJ]':>12s}")
    records = []
    for _ in range(args.epochs):
        # Serving traffic: every decoded token is one ACiM read of every
        # column (that is the traffic the read-disturb model sees).
        toks = engine.generate(prompt, max_new=24, key=jax.random.PRNGKey(3))
        reads = int(toks.shape[0] * toks.shape[1]) * 100  # scale to epoch traffic
        rec = sim.step_epoch(
            dt_s=args.dt_hours * 3600.0,
            reads_per_column=float(reads),
            eval_fn=lambda p: eval_fn(p, eval_batch),
        )
        records.append(rec)
        print(
            f"{rec.epoch:5d} {rec.t_s / 3600:6.1f} {rec.eval_metric:8.4f} "
            f"{rec.eval_metric - clean:+8.4f} {rec.rms_drift_lsb:9.3f} "
            f"{rec.columns_flagged:6d} {rec.columns_reprogrammed:6d} "
            f"{(rec.verify_energy_pj + rec.program_energy_pj) / 1e3:12.1f}"
        )

    total_e = sum(r.verify_energy_pj + r.program_energy_pj for r in records)
    print(
        f"\npolicy={args.policy}: final dloss "
        f"{records[-1].eval_metric - clean:+.4f}, total maintenance "
        f"energy {total_e / 1e3:.1f} nJ over {args.epochs} epochs"
    )
    print("Try --policy none (drift unchecked) and --policy periodic")
    print("(blind full re-program) to compare retention vs energy.")


if __name__ == "__main__":
    main()
