"""Name-based parameter sharding rules (logical -> mesh axes).

Rules map parameter path patterns to PartitionSpecs.  Conventions (see
DESIGN.md Sec. 4):

* 2D weights: FSDP on the *input* dim over "data", TP on the *output*
  dim over "model" — GSPMD all-gathers the FSDP shard at use.
* Stacked scan weights carry a leading layer axis (never sharded).
* MoE expert stacks: experts over "model" (EP), d_model over "data".
* Embeddings / logits: vocab over "model".
* Non-divisible dims rely on GSPMD padding (<= 1/16 waste, documented).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Ordered (regex, spec) pairs; first match wins."""

    rules: Sequence[tuple[str, P]]
    default: P = P()

    def spec(self, path: str) -> P:
        for pat, spec in self.rules:
            if re.search(pat, path):
                return spec
        return self.default


def spec_for_path(rules: ShardingRules, path: str) -> P:
    return rules.spec(path)


def shard_params_tree(params: Any, mesh: Mesh, rules: ShardingRules) -> Any:
    """NamedSharding pytree matching `params` structure (for jit shardings
    or device_put)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    shardings = []
    for path, leaf in flat:
        spec = rules.spec(jax.tree_util.keystr(path))
        # Drop trailing spec entries beyond leaf rank.
        spec = P(*spec[: getattr(leaf, "ndim", 0)])
        shardings.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, shardings)
