from .sharding import ShardingRules, spec_for_path, shard_params_tree  # noqa: F401
from .straggler import StragglerMonitor  # noqa: F401
from .fault import FaultInjector, FaultTolerantRunner, SimulatedFailure  # noqa: F401
