"""Fault injection + checkpoint/restart runner.

`FaultTolerantRunner` wraps a training step with the recovery protocol a
multi-pod job needs:

  * periodic async checkpoints (CheckpointManager);
  * on failure (real exception or injected `SimulatedFailure`): restore
    the latest checkpoint, rebuild the step iterator from the restored
    step (the stateless data pipeline makes this exact), and continue;
  * bounded retries per step to avoid crash loops;
  * straggler escalations route through the same restart path (an
    escalation at scale means "re-mesh without the slow host", which is
    a restore-from-checkpoint event for the survivors).

The runner is deliberately framework-level (works for any (state, batch)
-> (state, metrics) step function closed over jit).
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Iterator

from repro import obs
from repro.checkpoint import CheckpointManager

log = logging.getLogger(__name__)


class SimulatedFailure(RuntimeError):
    """Injected node/process failure."""


@dataclasses.dataclass
class FaultInjector:
    """Deterministically fail at given steps (each fires once).

    Every injection lands a zero-duration marker in the obs trace, so a
    recovery timeline read in Perfetto shows exactly where the failures
    were planted relative to the checkpoint/restore spans.
    """

    fail_at_steps: tuple[int, ...] = ()
    _fired: set[int] = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            obs.instant("fault.injected", cat="fault", step=step)
            raise SimulatedFailure(f"injected failure at step {step}")

    def reset(self) -> None:
        """Re-arm every planned failure (for runner reuse across runs)."""
        self._fired.clear()


@dataclasses.dataclass
class FaultTolerantRunner:
    step_fn: Callable[[Any, Any], tuple[Any, dict]]
    batch_fn: Callable[[int], Any]          # step -> batch (stateless pipeline)
    manager: CheckpointManager
    checkpoint_every: int = 50
    max_retries_per_step: int = 3
    injector: FaultInjector | None = None

    restarts: int = 0

    def run(self, state: Any, start_step: int, num_steps: int) -> tuple[Any, list]:
        """Run to start_step + num_steps with recovery; returns (state, metrics)."""
        metrics_log: list = []
        step = start_step
        end = start_step + num_steps
        # Pre-checkpoint recovery needs the true initial state: resetting
        # only `step` would re-apply steps to an already-advanced state
        # (step_fn is functional, so holding the reference is free).
        initial_state = state
        # Retries are tracked PER STEP: a rolling counter resets while
        # replaying checkpointed steps, turning a persistently-failing
        # step into an infinite restore loop (caught by the crash-loop
        # test).
        fail_counts: dict[int, int] = {}
        while step < end:
            try:
                if self.injector is not None:
                    self.injector.maybe_fail(step)
                state, metrics = self.step_fn(state, self.batch_fn(step))
                metrics_log.append({"step": step, **metrics})
                step += 1
                if step % self.checkpoint_every == 0:
                    self.manager.save(step, state, blocking=False)
            except SimulatedFailure as e:
                self.restarts += 1
                fail_counts[step] = fail_counts.get(step, 0) + 1
                if fail_counts[step] > self.max_retries_per_step:
                    raise RuntimeError(
                        f"step {step} failed {fail_counts[step]} times; giving up"
                    ) from e
                log.warning("failure at step %d (%s); restoring", step, e)
                try:
                    restored_step, state = self.manager.restore_latest(template=state)
                    step = restored_step
                except FileNotFoundError:
                    # No checkpoint yet: restart from the initial state.
                    step = start_step
                    state = initial_state
        self.manager.save(step, state, blocking=True)
        return state, metrics_log
