"""Straggler detection and mitigation hooks.

At 1000+ nodes, tail-latency hosts dominate synchronous step time.  The
monitor implements the standard control loop:

  1. track per-step wall times (EWMA + robust deviation);
  2. flag a step whose duration exceeds `threshold x` the EWMA;
  3. after `strikes` consecutive flags, escalate: the runner's
     `on_straggler` callback fires (in production: demote the host to a
     hot spare / shrink the data-parallel group; in this simulation:
     recorded + surfaced to the fault-tolerant runner which can trigger
     an elastic re-mesh through the same path as a failure).

The detector is deliberately host-local and stateless across restarts —
it must keep working when the cluster membership changes under it.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 2.0          # x EWMA to flag
    strikes_to_escalate: int = 3
    ewma_alpha: float = 0.1
    warmup_steps: int = 5           # ignore compile-dominated first steps

    _ewma: float = 0.0
    _seen: int = 0
    _strikes: int = 0
    flagged_steps: list = dataclasses.field(default_factory=list)
    escalations: int = 0
    on_straggler: Callable[[int, float], None] | None = None

    def observe(self, step: int, duration_s: float) -> bool:
        """Record a step duration; returns True if flagged as straggler."""
        self._seen += 1
        if self._seen <= self.warmup_steps:
            self._ewma = duration_s if self._ewma == 0 else (
                0.5 * self._ewma + 0.5 * duration_s
            )
            return False
        flagged = duration_s > self.threshold * max(self._ewma, 1e-9)
        if flagged:
            self.flagged_steps.append((step, duration_s))
            self._strikes += 1
            if self._strikes >= self.strikes_to_escalate:
                self.escalations += 1
                self._strikes = 0
                if self.on_straggler is not None:
                    self.on_straggler(step, duration_s)
        else:
            self._strikes = 0
            self._ewma = (
                (1 - self.ewma_alpha) * self._ewma + self.ewma_alpha * duration_s
            )
        return flagged

    def timed(self, step: int):
        """Context manager: `with monitor.timed(step): run_step()`."""
        monitor = self

        class _Ctx:
            def __enter__(self):
                self.t0 = time.perf_counter()
                return self

            def __exit__(self, *exc):
                monitor.observe(step, time.perf_counter() - self.t0)
                return False

        return _Ctx()
