"""Batched serving engine: prefill + decode steps and a request loop.

`make_decode_step` / `make_prefill_step` build the pure step functions
the dry-run lowers (decode_32k / long_500k lower the decode step with a
pre-allocated cache; prefill_32k lowers the prefill step).  `ServeEngine`
drives them for real batched generation (examples/serve_lm.py): greedy
or temperature sampling, per-sequence stop handling, continuous token
accounting, and RRAM-programmed weights served transparently (the paper
deployment produces ordinary parameter pytrees).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro import obs
from repro.models import (
    ModelConfig,
    decode_step,
    init_cache,
    prefill,
    prefill_chunk,
)


def make_prefill_step(cfg: ModelConfig, mesh=None, max_len: int | None = None):
    def prefill_step(params, batch: dict):
        return prefill(params, batch, cfg, mesh, max_len=max_len)

    return prefill_step


def make_prefill_chunk_step(
    cfg: ModelConfig, mesh=None, *, start: int, final: bool,
    park_pos: int | None = None,
):
    """Step function for ONE chunk of a chunked prefill (DESIGN.md
    Sec. 18).  `start` is static (one compiled dispatch per chunk
    offset — a bounded set, all warmed by `ContinuousScheduler.warmup`);
    the slot index and true length stay traced, so any request in any
    slot reuses the same dispatch."""

    def chunk_step(params, cache, tokens, true_len, slot):
        return prefill_chunk(
            params, cache, tokens, cfg, mesh, start=start, slot=slot,
            true_len=true_len if final else None,
            park_pos=park_pos if start == 0 else None,
        )

    return chunk_step


def make_decode_step(cfg: ModelConfig, mesh=None, sample: bool = False):
    def step(params, cache, batch: dict, key=None):
        logits, cache = decode_step(params, cache, batch, cfg, mesh)
        last = logits[:, -1] if logits.ndim == 3 else logits[:, -1, 0]
        if sample and key is not None:
            tok = jax.random.categorical(key, last.astype(jnp.float32), axis=-1)
        else:
            tok = jnp.argmax(last, axis=-1)
        return tok.astype(jnp.int32), logits, cache

    return step


@dataclasses.dataclass
class ServeEngine:
    cfg: ModelConfig
    params: Any = None
    mesh: Any = None
    temperature: float = 0.0
    # Analog serving (repro.cim.CIMExecutor): when set, every prefill /
    # decode access pulls fresh params from the executor — deployed
    # matmul leaves arrive as CIMWeight tiles (computed in-array by
    # models.layers.matmul), read-noise keys advance per access, and the
    # executor accounts per-array read-disturb traffic and token costs.
    # Only the tiny noise-key leaves change between accesses, so the
    # jitted step functions never retrace.
    executor: Any = None

    def __post_init__(self):
        if self.executor is not None and self.params is None:
            self.params = self.executor.params()
        self._prefill = jax.jit(make_prefill_step(self.cfg, self.mesh))
        self._decode = jax.jit(
            make_decode_step(self.cfg, self.mesh, sample=self.temperature > 0)
        )

    def access_params(self, n_tokens: int) -> Any:
        """Params for one engine access of `n_tokens` batch tokens.

        The single parameter-access chokepoint: analog deployments tick
        the executor here (read-disturb traffic + fresh noise
        sub-streams), and hot swaps land on the next access.  The
        continuous-batching scheduler routes every prefill/decode
        dispatch through this, so executor accounting sees the real
        scheduled traffic.
        """
        if self.executor is not None:
            self.params = self.executor.tick(n_tokens)
        return self.params

    # Back-compat alias (pre-scheduler name).
    _access_params = access_params

    def swap_params(self, params: Any) -> None:
        """Hot-swap served weights (e.g. after an RRAM refresh).

        Step functions are jitted with params as a traced argument, so a
        swap is free: no recompilation, next decode step serves the new
        weights.  This is the re-materialize hook the lifetime
        subsystem's scrub loop drives (`LifetimeSimulator(on_refresh=
        engine.swap_params)`).
        """
        self.params = params

    def generate(
        self, tokens: jax.Array, max_new: int, key=None, eos_id: int | None = None
    ) -> jax.Array:
        """tokens: (B, S) prompt; returns (B, max_new) generated ids."""
        b, s = tokens.shape
        key = key if key is not None else jax.random.PRNGKey(0)
        t0 = time.perf_counter()
        with obs.span(
            "serve.generate", cat="serve", batch=b, prompt_len=s,
            max_new=max_new,
        ) as sp:
            last, cache = self._prefill(
                self._access_params(b * s), {"tokens": tokens}
            )
            cur = jnp.argmax(last, axis=-1).astype(jnp.int32)[:, None]
            outs = [cur]
            done = jnp.zeros((b,), bool)
            for i in range(max_new - 1):
                key, sub = jax.random.split(key)
                tok, _, cache = self._decode(
                    self._access_params(b), cache, {"tokens": cur}, sub
                )
                cur = tok[:, None]
                if eos_id is not None:
                    done = done | (tok == eos_id)
                    if bool(jnp.all(done)):
                        outs.append(cur)
                        break
                outs.append(cur)
            out = jnp.concatenate(outs, axis=1)
            sp["generated"] = int(out.shape[0] * out.shape[1])
        # Host-born wall-clock digest (DESIGN.md Sec. 16): per-token
        # generate latency percentiles without per-request arrays.
        obs.digests.observe(
            "serve.generate_us_per_token",
            (time.perf_counter() - t0) * 1e6
            / max(int(out.shape[0] * out.shape[1]), 1),
            lo=0.0, hi=1e6, n_buckets=128,
        )
        return out
