from .engine import (  # noqa: F401
    ServeEngine,
    make_decode_step,
    make_prefill_chunk_step,
    make_prefill_step,
)
from .scheduler import (  # noqa: F401
    ADMISSION_POLICIES,
    ContinuousScheduler,
    Request,
    RequestRecord,
    admission_key,
    poisson_requests,
    select_next,
)
