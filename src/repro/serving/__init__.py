from .engine import ServeEngine, make_decode_step, make_prefill_step  # noqa: F401
from .scheduler import (  # noqa: F401
    ContinuousScheduler,
    Request,
    RequestRecord,
    poisson_requests,
)
