"""Continuous-batching request scheduler for analog serving.

`ServeEngine.generate` runs one fixed batch to completion; under a real
arrival stream that leaves decode slots idle whenever sequences finish
at different times.  `ContinuousScheduler` keeps a fixed-shape decode
batch of `n_slots` busy against a request queue:

* **Admission** — arriving requests claim free slots; the prompt is
  right-padded to a power-of-two bucket and prefilled *into the shared
  pre-allocated cache* at the slot index (`models.decoding.prefill`
  with ``true_len`` + `write_cache_slot`).  One compiled dispatch per
  bucket size serves every admission, any slot, any neighbors.
  Admission ORDER among ready requests is pluggable (`admission_policy`):
  "fifo" (arrival), "spf" (shortest prompt first), "edf" (earliest
  TTFT deadline first, `Request.deadline`); `select_next` is the pure,
  property-tested order.
* **Chunked prefill** — with `prefill_chunk_tokens=C`, prompts whose
  bucket exceeds C prefill in C-token chunks interleaved between decode
  steps (`models.decoding.prefill_chunk` writes each chunk into the
  shared cache in place), so a short request's first token no longer
  waits out a long prompt's whole-bucket prefill.  The first chunk
  parks the slot's cache position at `max_len` (interleaved decode
  writes for that row land out of bounds and are dropped); the final
  chunk — the one holding the last REAL token, trailing all-padding
  chunks are never dispatched — restores ``pos`` and samples the first
  token from the same per-request sub-stream as whole-prompt admission,
  so served tokens are bit-identical either way (DESIGN.md Sec. 18).
* **Clock accounting** — `prefill_tokens_per_step` prices prefill
  occupancy proportionally to the physical tokens driven (a 64-token
  bucket charges 4x a 16-token chunk); the legacy constant
  `prefill_cost_steps` remains the default for old baselines.
* **Decode** — every step runs the whole batch through ONE jitted step
  of fixed shape; per-slot positions, per-slot stop bookkeeping, and
  per-slot sampling keys mean batch composition never enters the
  compiled computation's shape.  **Zero retrace across batch
  compositions** is a hard contract: `trace_counts` is asserted flat
  after `warmup()` by tests and `benchmarks/serving_traffic.py`.
* **Per-request RNG** — token i of request `rid` is sampled with
  ``fold_in(fold_in(master_key, rid), i)``, so a request's served
  tokens are bit-identical whether it rides alone or in a full batch,
  and in whichever slot it lands (the decode batch is row-independent:
  attention, matmuls and sampling all act per slot).
* **Accounting** — per-request queue delay, time-to-first-token and
  total latency in decode-step units plus wall clock; exactly ONE
  device->host sync per decode step (the (B,) token fetch), counted in
  `host_syncs` and asserted by the serving benchmark.
* **Analog path** — params are pulled through `ServeEngine.
  access_params` every access, so a `CIMExecutor` ticks real
  read-disturb traffic per scheduled step (prefill ticks the padded
  bucket length — the physical tokens driven through the tiles; decode
  ticks the full batch) and only tiny noise-key leaves change between
  accesses: no retrace.  An optional `maintenance_fn` (e.g. a
  `LifetimeSimulator` epoch with `traffic_fn=executor.drain_reads`)
  interleaves between decode steps without touching the batch state.

Ownership contract (DESIGN.md Sec. 13): the scheduler owns admission
and slot lifecycle, the engine owns step functions and parameter
access, the executor owns traffic/cost accounting.
"""

from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.cim import token_stream_ids
from repro.models import (
    decode_step,
    init_cache,
    prefill,
    prefill_chunk,
    write_cache_slot,
)

__all__ = [
    "ADMISSION_POLICIES",
    "Request",
    "RequestRecord",
    "ContinuousScheduler",
    "admission_key",
    "select_next",
    "poisson_requests",
]


@dataclasses.dataclass
class Request:
    """One serving request: prompt tokens + generation budget."""

    rid: int                        # unique id (RNG sub-stream + records key)
    prompt: Any                     # 1-D int token ids
    max_new: int                    # generation budget (includes first token)
    arrival: float = 0.0            # arrival time, decode-step units
    eos_id: int | None = None       # per-request stop token
    deadline: float | None = None   # absolute TTFT deadline (step clock):
    #                                 first token must complete by this time


ADMISSION_POLICIES = ("fifo", "spf", "edf")


def admission_key(policy: str, req: Request):
    """Total order over ready requests for one admission decision.

    * "fifo" — arrival order (the pre-policy behavior);
    * "spf"  — shortest prompt first (cheap prefill jumps the queue;
      can starve long prompts under sustained load — it is here as the
      classic TTFT-optimal comparison point, not a recommendation);
    * "edf"  — earliest `Request.deadline` first; deadline-less
      requests sort last (infinite deadline).

    Ties always break (arrival, rid), so every policy is a strict total
    order and admission is deterministic — the EDF ordering property in
    tests/test_serving_scheduler.py holds on exactly this function.
    """
    if policy == "fifo":
        return (req.arrival, req.rid)
    if policy == "spf":
        return (len(req.prompt), req.arrival, req.rid)
    if policy == "edf":
        d = req.deadline if req.deadline is not None else math.inf
        return (d, req.arrival, req.rid)
    raise ValueError(
        f"unknown admission policy {policy!r}; known: {ADMISSION_POLICIES}"
    )


def select_next(ready: list[Request], policy: str) -> Request:
    """The request `policy` admits next from the ready set (pure)."""
    return min(ready, key=lambda r: admission_key(policy, r))


@dataclasses.dataclass
class RequestRecord:
    """Lifecycle + latency accounting for one served request.

    All times are in decode-step units on the scheduler's clock.  The
    admitting prefill occupies the engine for `prefill_cost_steps`
    (default 1.0), and a token emitted by a decode step completes at
    the END of that step — so an unqueued request's total latency is
    ``prefill_cost + (max_new - 1)`` steps.
    """

    rid: int
    arrival: float
    prompt_len: int
    bucket_len: int                 # padded prefill length (physical tokens)
    admit_step: float = 0.0         # admission (prefill dispatch) time
    first_token_step: float = 0.0   # first token completion time
    done_step: float = 0.0          # last token completion time
    deadline: float | None = None   # absolute TTFT deadline, if any
    n_chunks: int = 1               # prefill dispatches (1 = whole-bucket)
    tokens: list = dataclasses.field(default_factory=list)

    @property
    def n_generated(self) -> int:
        return len(self.tokens)

    @property
    def queue_delay_steps(self) -> float:
        return self.admit_step - self.arrival

    @property
    def ttft_steps(self) -> float:
        return self.first_token_step - self.arrival

    @property
    def latency_steps(self) -> float:
        return self.done_step - self.arrival

    @property
    def deadline_missed(self) -> bool:
        """True when the first token completed after the TTFT deadline."""
        return (
            self.deadline is not None and self.first_token_step > self.deadline
        )


@dataclasses.dataclass
class _ChunkedPrefill:
    """In-flight chunked prefill occupying a reserved slot."""

    req: Request
    padded: np.ndarray              # (1, bucket) right-padded prompt
    bucket: int
    chunk: int                      # C, the per-dispatch token count
    next_start: int = 0

    @property
    def last_start(self) -> int:
        """Start of the chunk holding the last REAL token; trailing
        all-padding chunks are inert junk and are never dispatched."""
        return (len(self.req.prompt) - 1) // self.chunk * self.chunk


def _next_pow2(n: int) -> int:
    return 1 << max(int(n - 1).bit_length(), 0)


class ContinuousScheduler:
    """Slot-based continuous batching over a `ServeEngine`'s step functions.

    Args:
      engine: `ServeEngine` (digital params or a `CIMExecutor`-backed
        analog deployment).  The scheduler builds its own jitted step
        functions (it needs per-slot sampling keys and slot admission)
        but routes every parameter access through the engine so hot
        swaps and executor ticking keep working.
      n_slots: fixed decode batch size.
      max_len: shared cache length; prompt_len + max_new must fit.
      min_prefill_bucket: smallest padded prompt length (buckets are
        powers of two in [min_prefill_bucket, max_len]).
      key: master sampling key; request sub-streams fold from it.
      maintenance_fn: called between decode steps every
        `maintenance_every` steps (lifetime scrub epochs, metrics
        flushes).  Runs on the host between dispatches: it never blocks
        or reshapes the batch.
      device_metrics: compute per-step metrics (active slots, greedy
        agreement) and the in-jit batch-occupancy digest inside the
        jitted decode and fetch them on the SAME device_get as the
        tokens.  Token bits are identical either way; the flag exists
        so tests can assert that.
      name: digest namespace prefix ("serve" by default) — fleet
        replicas pass distinct names so their latency/TTFT/occupancy
        digests stay separable and merge into fleet-wide views.
    """

    def __init__(
        self,
        engine,
        *,
        n_slots: int = 4,
        max_len: int = 128,
        min_prefill_bucket: int = 8,
        key: jax.Array | None = None,
        maintenance_fn: Callable[[], Any] | None = None,
        maintenance_every: int = 0,
        prefill_cost_steps: float = 1.0,
        prefill_tokens_per_step: float | None = None,
        prefill_chunk_tokens: int | None = None,
        admission_policy: str = "fifo",
        batch_mesh=None,
        device_metrics: bool = True,
        name: str = "serve",
    ):
        self.engine = engine
        self.cfg = engine.cfg
        self.mesh = engine.mesh
        self.temperature = float(engine.temperature)
        self.n_slots = n_slots
        self.max_len = max_len
        if min_prefill_bucket < 1 or min_prefill_bucket & (min_prefill_bucket - 1):
            raise ValueError(
                f"min_prefill_bucket must be a power of two: {min_prefill_bucket}"
            )
        self.min_bucket = min_prefill_bucket
        self.prefill_cost_steps = float(prefill_cost_steps)
        # Proportional prefill pricing (step-clock accounting): a prefill
        # of n physical tokens occupies the engine n / rate steps.  None
        # keeps the legacy constant-cost clock for old baselines.
        self.prefill_tokens_per_step = (
            float(prefill_tokens_per_step)
            if prefill_tokens_per_step is not None else None
        )
        if admission_policy not in ADMISSION_POLICIES:
            raise ValueError(
                f"unknown admission policy {admission_policy!r}; "
                f"known: {ADMISSION_POLICIES}"
            )
        self.admission_policy = admission_policy
        if prefill_chunk_tokens is not None:
            c = int(prefill_chunk_tokens)
            if c < 1 or c & (c - 1):
                raise ValueError(
                    f"prefill_chunk_tokens must be a power of two (so every "
                    f"larger power-of-two bucket divides into whole chunks): {c}"
                )
            for nm, cs in (("attn_chunk_q", self.cfg.attn_chunk_q),
                           ("attn_chunk_kv", self.cfg.attn_chunk_kv)):
                if c % cs:
                    raise ValueError(
                        f"prefill_chunk_tokens={c} must be a multiple of "
                        f"{nm}={cs}: chunk boundaries must align with the "
                        "attention kernel's chunk grid for bit-identity "
                        "with whole-prompt prefill"
                    )
            if c >= max_len:
                raise ValueError(
                    f"prefill_chunk_tokens={c} >= max_len={max_len}: nothing "
                    "would ever chunk"
                )
            if self.cfg.is_moe:
                raise ValueError(
                    "chunked prefill does not support MoE blocks (capacity "
                    "routing couples tokens across the sequence)"
                )
        self.prefill_chunk_tokens = (
            int(prefill_chunk_tokens) if prefill_chunk_tokens is not None
            else None
        )
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.maintenance_fn = maintenance_fn
        self.maintenance_every = maintenance_every
        # Device-side decode metrics (obs, DESIGN.md Sec. 14): computed
        # inside the jitted step and fetched on the SAME device_get as
        # the tokens — never an extra sync, never a retrace (the flag is
        # fixed per scheduler, so each jit has one stable output treedef).
        self.device_metrics = bool(device_metrics)
        # Streaming digests (DESIGN.md Sec. 16): `name` prefixes this
        # scheduler's digest namespace so fleet replicas keep separate
        # histograms that merge into fleet-wide views.  The batch-
        # occupancy digest is an in-jit carry, fetched cumulatively on
        # the per-step token device_get; latency/TTFT/queue digests are
        # host-born (wall clock / step clock) and never touch the device.
        self.name = str(name)
        self._occ_digest = (
            obs.StreamingDigest.zeros(0.0, n_slots + 1.0, n_slots + 1)
            if self.device_metrics else None
        )

        cache = init_cache(self.cfg, n_slots, max_len)
        if set(cache) != {"k", "v", "pos"}:
            raise ValueError(
                "continuous batching needs a pure attention cache (k/v/pos); "
                f"got {sorted(cache)} for block={self.cfg.block}"
            )
        # Data-sharded decode (DESIGN.md Sec. 18): ONLY the batch axis
        # shards, over "data" — sharding the sequence axis would split
        # each attention reduction across devices and break the
        # bit-identity contract.  CIM tile planes shard over "model"
        # independently (`launch.shardings.cim_weight_specs`).
        self.batch_mesh = batch_mesh
        self._vec_sharding = None
        if batch_mesh is not None:
            from repro.launch.shardings import (
                decode_batch_sharding,
                decode_vec_sharding,
            )

            cache = jax.device_put(
                cache, decode_batch_sharding(batch_mesh, cache)
            )
            self._vec_sharding = decode_vec_sharding(batch_mesh, n_slots)
        if self.cfg.pos_embedding == "sinusoidal":
            # decode_step applies cache["pos"][0] as the batch-wide
            # embedding offset; heterogeneous per-slot positions would
            # silently read a neighbor's offset (RoPE is per-slot).
            raise ValueError(
                "continuous batching needs per-slot positions; sinusoidal "
                "embeddings take a batch-wide offset"
            )
        if self.cfg.n_codebooks > 1:
            raise ValueError("multi-codebook heads are not admissible")
        self.cache = cache

        # Trace-time side effects: each counter bumps once per compiled
        # trace, so a steady-state serve asserts them flat.
        self.trace_counts = {"admit": 0, "decode": 0, "chunk": 0}
        self._admit_jit = self._build_admit()
        self._decode_jit = jax.jit(self._build_decode())
        # Chunk dispatches specialize on (start, is_final) ONLY — the
        # chunk width is fixed and true_len/slot/rid stay traced — so
        # the compile count is bounded by 2 * max_len / C regardless of
        # bucket mix, and warmup() covers every reachable pair.
        self._chunk_jits: dict[tuple[int, bool], Any] = {}
        self._prefilling: dict[int, _ChunkedPrefill] = {}

        self._rid = np.full((n_slots,), -1, np.int32)
        self._gen = np.zeros((n_slots,), np.int32)
        self._cur = np.zeros((n_slots,), np.int32)
        self._slot_req: list[Request | None] = [None] * n_slots
        self.records: dict[int, RequestRecord] = {}
        self.completed: list[RequestRecord] = []
        self.now = 0.0
        self.decode_steps = 0
        self.host_syncs = 0
        self.admit_syncs = 0
        self.admits = 0
        self.tokens_generated = 0
        self.prefill_tokens = 0
        self.wall_s = 0.0
        self.decode_wall_s = 0.0

    # ------------------------------------------------------- step builders
    def _select_token(self, logits: jax.Array, key, rid, gen) -> jax.Array:
        """Sample/argmax ONE slot's next token from its own sub-stream."""
        if self.temperature > 0.0:
            k = jax.random.fold_in(jax.random.fold_in(key, rid), gen)
            return jax.random.categorical(
                k, logits.astype(jnp.float32) / self.temperature
            )
        return jnp.argmax(logits, axis=-1)

    def _build_admit(self):
        cfg, mesh, max_len = self.cfg, self.mesh, self.max_len

        def admit(params, tokens, true_len, rid, master, cache, slot):
            # One jit specializes per padded bucket shape; this bump
            # fires once per specialization (trace time only).
            self.trace_counts["admit"] += 1
            last, single = prefill(
                params, {"tokens": tokens}, cfg, mesh,
                max_len=max_len, true_len=true_len,
            )
            tok = self._select_token(last[0], master, rid, jnp.int32(0))
            cache = write_cache_slot(cache, single, slot)
            return tok.astype(jnp.int32), cache

        return jax.jit(admit)

    def _build_decode(self):
        cfg, mesh = self.cfg, self.mesh
        device_metrics = self.device_metrics

        def decode(params, cache, cur, rids, gens, master, dig):
            self.trace_counts["decode"] += 1  # fires at trace time only
            # Analog CIM leaves fold the REQUEST id (a traced argument —
            # no retrace) into their per-row noise sub-streams, so a
            # request's served logits are bit-identical in any slot and
            # any batch composition (DESIGN.md Sec. 17).  Digital params
            # ignore the context entirely.
            with token_stream_ids(rids):
                logits, cache = decode_step(
                    params, cache, {"tokens": cur[:, None]}, cfg, mesh
                )
            last = logits[:, -1] if logits.ndim == 3 else logits[:, -1, 0]
            toks = jax.vmap(
                lambda l, r, g: self._select_token(l, master, r, g)
            )(last, rids, gens)
            toks = toks.astype(jnp.int32)
            # Step metrics ride the token fetch (never their own sync).
            # The token computation above is untouched either way, so
            # served bits are identical with metrics on or off.
            m = {}
            if device_metrics:
                active = rids >= 0
                n_active = jnp.sum(active).astype(jnp.float32)
                greedy = jnp.argmax(last, axis=-1).astype(jnp.int32)
                m = {
                    "decode_active_slots": n_active,
                    "decode_greedy_agree": jnp.sum(
                        active & (toks == greedy)
                    ).astype(jnp.float32),
                }
                # In-jit streaming digest (DESIGN.md Sec. 16): batch
                # occupancy accumulates inside the compiled step; the
                # carry stays on device and its cumulative counts ride
                # the same per-step fetch as the tokens.
                dig = dig.add(n_active)
            return toks, m, dig, cache

        return decode

    def _get_chunk_jit(self, start: int, final: bool):
        """Compiled dispatch for one prefill chunk at static `start`."""
        fn = self._chunk_jits.get((start, final))
        if fn is not None:
            return fn
        cfg, mesh, max_len = self.cfg, self.mesh, self.max_len

        def chunk(params, cache, tokens, true_len, rid, master, slot):
            self.trace_counts["chunk"] += 1  # fires at trace time only
            last, cache = prefill_chunk(
                params, cache, tokens, cfg, mesh, start=start, slot=slot,
                true_len=true_len if final else None,
                park_pos=max_len if start == 0 else None,
            )
            if final:
                # Same sub-stream as whole-bucket admission: the first
                # token is bit-identical chunked or not.
                tok = self._select_token(last[0], master, rid, jnp.int32(0))
                return tok.astype(jnp.int32), cache
            return cache

        fn = self._chunk_jits[(start, final)] = jax.jit(chunk)
        return fn

    # ------------------------------------------------------------ plumbing
    def bucket_len(self, prompt_len: int) -> int:
        b = max(_next_pow2(prompt_len), self.min_bucket)
        return min(b, self.max_len)

    def prefill_cost(self, n_tokens: int, bucket: int | None = None) -> float:
        """Step-clock charge for prefilling `n_tokens` physical tokens.

        Proportional when `prefill_tokens_per_step` is set — a 64-token
        bucket occupies the engine 4x as long as a 16-token chunk, which
        is what makes whole-prompt head-of-line blocking visible in
        queue-delay/TTFT accounting.  Legacy fallback: the constant
        `prefill_cost_steps` per whole bucket, pro-rated per chunk (so a
        fully chunked prompt never charges more than the constant).
        """
        if self.prefill_tokens_per_step is not None:
            return n_tokens / self.prefill_tokens_per_step
        if bucket is None or n_tokens >= bucket:
            return self.prefill_cost_steps
        return self.prefill_cost_steps * n_tokens / bucket

    def _free_slot(self) -> int | None:
        free = [
            i for i in range(self.n_slots)
            if self._rid[i] < 0 and i not in self._prefilling
        ]
        return free[0] if free else None

    def active_slots(self) -> int:
        return int(np.sum(self._rid >= 0))

    def _digest_hi(self) -> float:
        """Shared bucket range for the step-clock digests (latency, TTFT,
        queue delay).  Static per scheduler geometry, so replicas with
        the same max_len merge their digests fleet-wide."""
        return 8.0 * self.max_len

    def _finish(self, slot: int, t_done: float | None = None) -> None:
        rec = self.records[self._slot_req[slot].rid]
        rec.done_step = self.now if t_done is None else t_done
        self.completed.append(rec)
        obs.digests.observe(
            f"{self.name}.latency_steps", rec.latency_steps,
            lo=0.0, hi=self._digest_hi(), n_buckets=128,
        )
        self._rid[slot] = -1
        self._gen[slot] = 0
        self._cur[slot] = 0
        self._slot_req[slot] = None

    def _emit(self, slot: int, tok: int, t_done: float) -> bool:
        """Record one generated token (completing at `t_done`); returns
        True if the slot finished."""
        req = self._slot_req[slot]
        rec = self.records[req.rid]
        if not rec.tokens:
            rec.first_token_step = t_done
            obs.digests.observe(
                f"{self.name}.ttft_steps", rec.ttft_steps,
                lo=0.0, hi=self._digest_hi(), n_buckets=128,
            )
        rec.tokens.append(tok)
        self._gen[slot] += 1
        self._cur[slot] = tok
        self.tokens_generated += 1
        done = self._gen[slot] >= req.max_new or (
            req.eos_id is not None and tok == req.eos_id
        )
        if done:
            self._finish(slot, t_done)
        return done

    # ------------------------------------------------------------- serving
    def admit(self, req: Request, slot: int | None = None) -> int:
        """Prefill `req` into a free slot of the shared cache.

        Whole-bucket admission (bucket <= `prefill_chunk_tokens`, or
        chunking disabled) dispatches one prefill and emits the first
        token before returning.  Chunked admission reserves the slot and
        dispatches only the FIRST chunk; `run()` (or a manual driver
        calling `prefill_tick()`) interleaves the remaining chunks
        between decode steps, and the first token is emitted by the
        final chunk.
        """
        if slot is None:
            slot = self._free_slot()
        if slot is None:
            raise RuntimeError("no free slot")
        if self._rid[slot] >= 0 or slot in self._prefilling:
            raise RuntimeError(
                f"slot {slot} is occupied by request "
                f"{self._rid[slot] if self._rid[slot] >= 0 else self._prefilling[slot].req.rid}"
            )
        plen = len(req.prompt)
        if plen < 1:
            raise ValueError(f"request {req.rid}: empty prompt")
        if plen + req.max_new > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {plen} + max_new {req.max_new} "
                f"exceeds max_len {self.max_len}"
            )
        bucket = self.bucket_len(plen)
        chunk = self.prefill_chunk_tokens
        chunked = chunk is not None and bucket > chunk
        padded_len = bucket if not chunked else (
            ((plen - 1) // chunk + 1) * chunk
        )
        padded = np.zeros((1, padded_len), np.int32)
        padded[0, :plen] = np.asarray(req.prompt, np.int32)
        self.records[req.rid] = RequestRecord(
            rid=req.rid, arrival=req.arrival, prompt_len=plen,
            bucket_len=bucket, admit_step=self.now, deadline=req.deadline,
            n_chunks=(plen - 1) // chunk + 1 if chunked else 1,
        )
        obs.digests.observe(
            f"{self.name}.queue_delay_steps", self.now - req.arrival,
            lo=0.0, hi=self._digest_hi(), n_buckets=128,
        )
        self.admits += 1
        obs.registry.inc("serve.admits")
        self._slot_req[slot] = req
        if chunked:
            self._prefilling[slot] = _ChunkedPrefill(
                req=req, padded=padded, bucket=bucket, chunk=chunk
            )
            self._dispatch_chunk(slot)
            return slot
        with obs.span(
            "serve.admit", cat="serve", rid=req.rid, bucket=bucket, slot=slot
        ):
            params = self.engine.access_params(bucket)  # physical prefill toks
            with jax.transfer_guard_device_to_host("disallow"):
                tok, self.cache = self._admit_jit(
                    params,
                    jnp.asarray(padded),
                    jnp.asarray([plen], jnp.int32),
                    jnp.int32(req.rid),
                    self.key,
                    self.cache,
                    jnp.int32(slot),
                )
            tok = int(jax.device_get(tok))  # the one (small) admit sync
        self.admit_syncs += 1
        self.prefill_tokens += bucket
        obs.registry.inc("serve.prefill_tokens", bucket)
        self._rid[slot] = req.rid
        self._gen[slot] = 0
        # The prefill occupies the engine: advance the clock before the
        # first token completes.
        self.now += self.prefill_cost(bucket, bucket)
        self._emit(slot, tok, self.now)
        return slot

    def _dispatch_chunk(self, slot: int) -> None:
        """Run ONE chunk of the in-flight prefill reserved on `slot`."""
        st = self._prefilling[slot]
        start, chunk = st.next_start, st.chunk
        final = start == st.last_start
        req = st.req
        with obs.span(
            "serve.prefill_chunk", cat="serve", rid=req.rid, start=start,
            slot=slot, final=final,
        ):
            fn = self._get_chunk_jit(start, final)
            tokens = jnp.asarray(st.padded[:, start:start + chunk])
            params = self.engine.access_params(chunk)  # physical chunk toks
            with jax.transfer_guard_device_to_host("disallow"):
                out = fn(
                    params,
                    self.cache,
                    tokens,
                    jnp.asarray([len(req.prompt)], jnp.int32),
                    jnp.int32(req.rid),
                    self.key,
                    jnp.int32(slot),
                )
            if final:
                tok, self.cache = out
                tok = int(jax.device_get(tok))  # the one (small) admit sync
                self.admit_syncs += 1
            else:
                self.cache = out
        self.prefill_tokens += chunk
        obs.registry.inc("serve.prefill_tokens", chunk)
        self.now += self.prefill_cost(chunk, st.bucket)
        st.next_start = start + chunk
        if final:
            del self._prefilling[slot]
            self._rid[slot] = req.rid
            self._gen[slot] = 0
            self._emit(slot, tok, self.now)

    def prefill_tick(self) -> bool:
        """Dispatch ONE pending prefill chunk (the oldest reservation);
        returns False when no chunked prefill is in flight.  `run()`
        calls this once per loop iteration, interleaving chunks between
        decode steps."""
        if not self._prefilling:
            return False
        slot = next(iter(self._prefilling))
        self._dispatch_chunk(slot)
        return True

    def step(self) -> None:
        """One decode step of the whole batch + slot bookkeeping.

        Exactly one device->host sync: the (B,) token fetch.  ENFORCED,
        not just counted — the dispatch runs under a device->host
        transfer guard, so any implicit sync creeping into the decode
        path (a stray `float()`/`np.asarray` on a device value) raises
        instead of silently serializing the loop.
        """
        t0 = time.perf_counter()
        with obs.span("serve.decode", cat="serve") as sp:
            params = self.engine.access_params(self.n_slots)
            if self._vec_sharding is not None:
                # Host->device placements (allowed under the guard): the
                # per-slot vectors land pre-sharded over "data" so the
                # compiled step never reshards its batch inputs.
                vecs = [
                    jax.device_put(v, self._vec_sharding)
                    for v in (self._cur, self._rid, self._gen)
                ]
            else:
                vecs = [
                    jnp.asarray(self._cur),
                    jnp.asarray(self._rid),
                    jnp.asarray(self._gen),
                ]
            with jax.transfer_guard_device_to_host("disallow"):
                toks, m, dig, self.cache = self._decode_jit(
                    params,
                    self.cache,
                    *vecs,
                    self.key,
                    self._occ_digest,
                )
            # THE per-step host sync: tokens, step metrics AND the
            # cumulative occupancy digest, one fetch.
            toks, m, dig_h = jax.device_get((toks, m, dig))
            toks = np.asarray(toks)
            self._occ_digest = dig
            self.host_syncs += 1
            self.decode_steps += 1
            obs.registry.inc("serve.decode_steps")
            obs.registry.fold(m, prefix="serve.")
            if dig_h is not None:
                # Cumulative carry -> replace, never merge (DigestRegistry.put)
                obs.digests.put(f"{self.name}.batch_occupancy", dig_h)
            obs.digests.observe(
                f"{self.name}.step_latency_us",
                (time.perf_counter() - t0) * 1e6,
                lo=0.0, hi=1e5, n_buckets=128,
            )
            emitted = 0
            for slot in np.flatnonzero(self._rid >= 0):
                # a decode-emitted token completes at the END of this step
                self._emit(int(slot), int(toks[slot]), self.now + 1.0)
                emitted += 1
            obs.registry.inc("serve.decode_tokens", emitted)
            sp["tokens"] = emitted
        # Decode-only wall clock: excludes admission prefill and
        # interleaved maintenance, so `decode_wall_s / decode_steps` is
        # the analog/digital datapath step time the benchmarks gate on.
        self.decode_wall_s += time.perf_counter() - t0

    def warmup(
        self,
        prompt_lens: list[int] | None = None,
        prompt_range: tuple[int, int] | None = None,
    ) -> None:
        """Compile every dispatch the serve loop will hit, then reset.

        Admits one throwaway request per distinct prefill bucket and
        runs one decode step; afterwards `trace_counts` must stay flat
        for any traffic whose prompts map onto the warmed buckets.
        `prompt_range=(lo, hi)` warms EVERY bucket a prompt length in
        [lo, hi] can map to (the usual serve-loop precondition).
        """
        if prompt_range is not None:
            lo, hi = prompt_range
            plens = list(range(lo, hi + 1))
        else:
            plens = list(prompt_lens or [self.min_bucket])
        # derive the warmed set from the same mapping real traffic
        # uses, so it can never diverge from bucket_len()
        chunk = self.prefill_chunk_tokens
        buckets = sorted({
            self.bucket_len(p) for p in plens
            if chunk is None or self.bucket_len(p) <= chunk
        })
        if chunk is not None:
            # Chunked buckets: warm every reachable (start, is_final)
            # dispatch pair.  One dummy admission per distinct final-
            # chunk offset covers them all (its mid chunks warm every
            # smaller start; chunk jits are bucket-independent).
            lasts = sorted({
                (p - 1) // chunk * chunk for p in plens
                if self.bucket_len(p) > chunk and p + 1 <= self.max_len
            })
            for j, last in enumerate(lasts):
                plen = max(
                    p for p in plens
                    if self.bucket_len(p) > chunk
                    and (p - 1) // chunk * chunk == last
                    and p + 1 <= self.max_len
                )
                slot = self._free_slot()
                if slot is None:
                    self._finish(0)
                    slot = 0
                self.admit(
                    Request(rid=(1 << 29) + j, prompt=[0] * plen, max_new=1,
                            arrival=self.now),
                    slot,
                )
                while slot in self._prefilling:
                    self.prefill_tick()
        for i, b in enumerate(buckets):
            slot = self._free_slot()
            if slot is None:  # more buckets than slots: recycle slot 0
                self._finish(0)
                slot = 0
            # A b-token prompt maps exactly onto bucket b; a clamped top
            # bucket (b == max_len) warms with max_len - 1 (any length in
            # (b/2, b] still maps to b).  A bucket no admissible request
            # can reach (bucket_len(plen) != b once max_new >= 1 is
            # accounted) is skipped.  Dummy rids sit far above real ones.
            plen = min(b, self.max_len - 1)
            if self.bucket_len(plen) != b:
                continue
            self.admit(
                Request(rid=(1 << 30) + i, prompt=[0] * plen,
                        max_new=2 if plen + 2 <= self.max_len else 1,
                        arrival=self.now),
                slot,
            )
        if not self.active_slots():
            # every dummy finished at admission (max_new=1 top buckets):
            # keep one slot live so the decode dispatch compiles too
            plen = max(1, min(self.min_bucket, self.max_len - 2))
            self.admit(
                Request(rid=(1 << 30) + len(buckets), prompt=[0] * plen,
                        max_new=2, arrival=self.now)
            )
        self.step()
        # Second step: the first decode consumes the FRESH occupancy
        # digest (host-born leaves); every later step consumes the
        # previous step's OUTPUT digest, whose sharding a batch_mesh
        # jit stamps differently.  Both variants must be compiled here,
        # or the first post-warmup steady-state step silently re-lowers
        # (invisible to trace_counts — jax reuses the python trace).
        self.step()
        self.reset(keep_traces=True)

    def reset(self, keep_traces: bool = False) -> None:
        """Clear slot state, records and counters (compiled fns survive)."""
        self._rid[:] = -1
        self._gen[:] = 0
        self._cur[:] = 0
        self._slot_req = [None] * self.n_slots
        self._prefilling = {}
        self.records = {}
        self.completed = []
        self.now = 0.0
        self.decode_steps = 0
        self.host_syncs = 0
        self.admit_syncs = 0
        self.admits = 0
        self.tokens_generated = 0
        self.prefill_tokens = 0
        self.wall_s = 0.0
        self.decode_wall_s = 0.0
        if self.device_metrics:
            self._occ_digest = obs.StreamingDigest.zeros(
                0.0, self.n_slots + 1.0, self.n_slots + 1
            )
        obs.digests.reset(f"{self.name}.")
        if not keep_traces:
            self.trace_counts = {"admit": 0, "decode": 0, "chunk": 0}

    def run(
        self, requests: list[Request], *, max_steps: int = 1_000_000
    ) -> list[RequestRecord]:
        """Serve an arrival stream to completion.

        The clock is the decode step: each step advances `now` by 1,
        prefills charge `prefill_cost`, and idle periods fast-forward to
        the next arrival.  Ready requests (arrived, not yet admitted)
        are admitted into free slots in `admission_policy` order; with
        chunked prefill enabled, ONE pending chunk is dispatched per
        loop iteration before the decode step, so long-prompt prefills
        interleave with (rather than block) decode traffic.  Returns
        the completed `RequestRecord`s sorted by rid.
        """
        pending = collections.deque(
            sorted(requests, key=lambda r: (r.arrival, r.rid))
        )
        ready: list[Request] = []
        t0 = time.perf_counter()
        steps0 = self.decode_steps
        with obs.span(
            "serve.run", cat="serve", requests=len(requests),
            n_slots=self.n_slots, policy=self.admission_policy,
        ) as sp:
            while pending or ready or self.active_slots() or self._prefilling:
                while pending and pending[0].arrival <= self.now:
                    ready.append(pending.popleft())
                progressed = False
                while ready and self._free_slot() is not None:
                    req = select_next(ready, self.admission_policy)
                    ready.remove(req)
                    self.admit(req)
                    progressed = True
                    # admission advanced the clock: newly arrived
                    # requests join the ready set before the next pick
                    while pending and pending[0].arrival <= self.now:
                        ready.append(pending.popleft())
                if self.prefill_tick():
                    progressed = True
                if self.active_slots():
                    self.step()
                    self.now += 1.0
                    progressed = True
                    if (
                        self.maintenance_fn is not None
                        and self.maintenance_every > 0
                        and self.decode_steps % self.maintenance_every == 0
                    ):
                        with obs.span("serve.maintenance", cat="serve"):
                            self.maintenance_fn()
                    if self.decode_steps - steps0 >= max_steps:
                        break
                if not progressed:
                    if not pending:  # every remaining request finished
                        break
                    self.now = max(self.now, pending[0].arrival)
            sp["decode_steps"] = self.decode_steps - steps0
            sp["completed"] = len(self.completed)
        self.wall_s += time.perf_counter() - t0
        return sorted(self.completed, key=lambda r: r.rid)

    # ----------------------------------------------------------- reporting
    def digest_stats(self) -> dict[str, dict]:
        """This scheduler's digest summaries (percentiles, no arrays)."""
        prefix = f"{self.name}."
        return {
            n: d.summary()
            for n, d in (
                (n, obs.digests.get(n)) for n in obs.digests.names()
            )
            if n.startswith(prefix)
        }

    def latency_stats(self) -> dict[str, float]:
        """Aggregate latency/throughput stats over completed requests.

        Percentiles use `obs.rank_quantile` — the SAME rank-based
        definition `StreamingDigest.quantile` estimates — so the exact
        stats here and the streaming `digest_stats()` agree to bucket
        resolution (asserted by tests).  np.percentile's interpolating
        default disagrees with the digests on small samples, which is
        exactly the p99 regime these numbers gate.
        """
        lats = np.array([r.latency_steps for r in self.completed])
        ttfts = np.array([r.ttft_steps for r in self.completed])
        queue = np.array([r.queue_delay_steps for r in self.completed])
        steps = max(self.decode_steps, 1)
        out = {
            "completed": float(len(self.completed)),
            "decode_steps": float(self.decode_steps),
            "tokens_generated": float(self.tokens_generated),
            "tokens_per_step": self.tokens_generated / steps,
            "wall_s": self.wall_s,
            "tokens_per_s": (
                self.tokens_generated / self.wall_s if self.wall_s > 0 else 0.0
            ),
            "decode_wall_s": self.decode_wall_s,
            "decode_step_us": self.decode_wall_s / steps * 1e6,
            "decode_tokens_per_s": (
                self.tokens_generated / self.decode_wall_s
                if self.decode_wall_s > 0 else 0.0
            ),
        }
        if len(lats):
            out.update(
                p50_latency_steps=obs.rank_quantile(lats, 0.50),
                p99_latency_steps=obs.rank_quantile(lats, 0.99),
                p50_ttft_steps=obs.rank_quantile(ttfts, 0.50),
                p99_ttft_steps=obs.rank_quantile(ttfts, 0.99),
                mean_queue_delay_steps=float(queue.mean()),
            )
        with_deadline = [r for r in self.completed if r.deadline is not None]
        if with_deadline:
            missed = sum(r.deadline_missed for r in with_deadline)
            out["deadline_requests"] = float(len(with_deadline))
            out["deadline_misses"] = float(missed)
            out["deadline_miss_rate"] = missed / len(with_deadline)
        return out


def poisson_requests(
    seed: int,
    n: int,
    *,
    rate: float,
    vocab: int,
    prompt_lens: tuple[int, int] = (4, 24),
    max_new: tuple[int, int] = (4, 16),
    eos_id: int | None = None,
    start_rid: int = 0,
    long_prompt_lens: tuple[int, int] | None = None,
    long_frac: float = 0.0,
    ttft_slack: tuple[float, float] | None = None,
) -> list[Request]:
    """A Poisson arrival stream of variable-length requests.

    `rate` is the offered load in requests per decode step; inter-arrival
    times are Exp(1/rate).  Prompt lengths and generation budgets draw
    uniformly from their (lo, hi) ranges.

    `long_prompt_lens` + `long_frac` mix in a heavy-tail fraction of
    long prompts (the SLO benchmark's head-of-line-blocking stressor);
    `ttft_slack=(lo, hi)` attaches a TTFT deadline of ``arrival +
    Uniform(lo, hi)`` steps to every request (EDF admission input and
    the deadline-miss-rate denominator).
    """
    g = np.random.default_rng(seed)
    arrivals = np.cumsum(g.exponential(1.0 / rate, size=n))
    reqs = []
    for i in range(n):
        lens = prompt_lens
        if long_prompt_lens is not None and g.random() < long_frac:
            lens = long_prompt_lens
        plen = int(g.integers(lens[0], lens[1] + 1))
        deadline = None
        if ttft_slack is not None:
            deadline = float(
                arrivals[i] + g.uniform(ttft_slack[0], ttft_slack[1])
            )
        reqs.append(
            Request(
                rid=start_rid + i,
                prompt=g.integers(0, vocab, size=plen).astype(np.int32),
                max_new=int(g.integers(max_new[0], max_new[1] + 1)),
                arrival=float(arrivals[i]),
                eos_id=eos_id,
                deadline=deadline,
            )
        )
    return reqs
