# Post-programming device dynamics + verify-driven refresh scheduling:
# a deployed model's conductances are state that ages (relaxation, drift,
# read disturb, endurance wear) and gets scrubbed back — see DESIGN.md
# Sec. 9 for the architecture and state-ownership contract.
from .drift import (  # noqa: F401
    CellState,
    DriftConfig,
    advance,
    effective_d2d,
    init_cell_state,
    reset_programmed,
    wear_efficiency,
)
from .refresh import (  # noqa: F401
    RefreshConfig,
    RefreshOutcome,
    RefreshPolicy,
    apply_refresh,
    default_flag_params,
    flag_columns,
)
from .service import EpochRecord, LifetimeReport, LifetimeSimulator  # noqa: F401
