"""Lifetime serving simulation: age, verify, scrub, re-materialize.

`LifetimeSimulator` owns the analog side of a deployment — the
`DeployedModel` array state plus one aging `CellState` per RRAM leaf —
and steps wall-clock epochs interleaved with serving traffic:

    for each epoch:
        1. age every array by `dt_s` under the epoch's read traffic
           (every ACiM inference reads every column once per token);
        2. run the refresh policy (verify sweeps / re-programming);
        3. re-materialize dense params and push them to the serving
           engine via the `on_refresh` hook (`ServeEngine.swap_params`);
        4. evaluate (optional `eval_fn`) and append an `EpochRecord`.

The report carries both sides of the trade: accuracy retained (eval
metric + weight-domain RMS drift) and what retention cost (verify
energy, re-program energy, write pulses, wall latency) — so policies
are comparable as energy-per-retained-accuracy (DESIGN.md Sec. 9).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.programmer import DeployedModel

from .drift import DriftConfig, advance, init_cell_state
from .refresh import RefreshConfig, apply_refresh

__all__ = ["EpochRecord", "LifetimeReport", "LifetimeSimulator"]


@dataclasses.dataclass
class EpochRecord:
    """One epoch of the lifetime time series (aggregated over leaves)."""

    epoch: int
    t_s: float                       # wall-clock age at end of epoch
    reads_per_column: float          # traffic applied this epoch
    rms_drift_lsb: float             # cell-domain RMS |g - target|
    stuck_frac: float                # fraction of cells stuck
    columns_flagged: int             # VT verify flags this epoch
    columns_reprogrammed: int
    verify_energy_pj: float
    program_energy_pj: float
    maintenance_latency_ns: float
    write_pulses: float
    eval_metric: float | None = None


@dataclasses.dataclass
class LifetimeReport:
    """Accuracy-vs-time trajectory with per-epoch maintenance costs."""

    policy: str
    method: str
    records: list[EpochRecord] = dataclasses.field(default_factory=list)

    @property
    def total_maintenance_energy_pj(self) -> float:
        return sum(r.verify_energy_pj + r.program_energy_pj for r in self.records)

    @property
    def total_verify_energy_pj(self) -> float:
        return sum(r.verify_energy_pj for r in self.records)

    @property
    def final_rms_drift_lsb(self) -> float:
        return self.records[-1].rms_drift_lsb if self.records else 0.0

    def to_dict(self) -> dict:
        return {
            "policy": self.policy,
            "method": self.method,
            "total_maintenance_energy_pj": self.total_maintenance_energy_pj,
            "records": [dataclasses.asdict(r) for r in self.records],
        }

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)


class LifetimeSimulator:
    """Owns deployed array state and drives it through aging epochs.

    Args:
      key: PRNG key (per-leaf aging randomness derives from it).
      deployed: `deploy_arrays` output; the simulator takes ownership of
        its conductances (state-ownership contract, DESIGN.md Sec. 9).
      drift_cfg / refresh_cfg: dynamics and scrub policy.
      on_refresh: optional hook called with freshly materialized params
        after every epoch whose refresh re-programmed at least one
        column (e.g. ``engine.swap_params``).  Analog serving
        (`CIMExecutor`) needs no hook — it re-views the live arrays.
      traffic_fn: optional source of REAL per-array read counts for the
        epoch — e.g. ``CIMExecutor.drain_reads``, which counts every
        column read the analog serving path actually issued.  Each
        epoch's per-leaf reads are ``traffic_fn()[name]`` (plus the
        abstract `reads_per_column` scalar, for synthetic extra load).
    """

    def __init__(
        self,
        key: jax.Array,
        deployed: DeployedModel,
        drift_cfg: DriftConfig | None = None,
        refresh_cfg: RefreshConfig | None = None,
        on_refresh: Callable[[Any], None] | None = None,
        traffic_fn: Callable[[], dict[str, float]] | None = None,
    ):
        self.key = key
        self.deployed = deployed
        self.drift_cfg = drift_cfg or DriftConfig()
        self.refresh_cfg = refresh_cfg or RefreshConfig()
        self.on_refresh = on_refresh
        self.traffic_fn = traffic_fn
        self.t_s = 0.0
        self.epoch = 0
        self._scrub_cursor = 0
        k = key
        self.states = {}
        for name, arr in deployed.arrays.items():
            k, sub = jax.random.split(k)
            self.states[name] = init_cell_state(
                sub, arr.g, arr.d2d, deployed.wv_cfg.device, self.drift_cfg
            )

    def _sync_deployed(self) -> None:
        for name, st in self.states.items():
            self.deployed.update_array(name, st.g)

    def _rms_drift_lsb(self) -> float:
        num = 0.0
        den = 0
        for name, st in self.states.items():
            arr = self.deployed.arrays[name]
            err = st.g - arr.targets.astype(jnp.float32)
            if arr.remap is not None:
                # Remapped arrays: only physical rows carrying live
                # weight count — a remapped-away stuck column parked at
                # its pinned level is not drift the model experiences.
                act = arr.remap.active
                num += float(jnp.sum(jnp.where(act[:, None], err * err, 0.0)))
                den += int(jnp.sum(act)) * err.shape[1]
            else:
                num += float(jnp.sum(err * err))
                den += err.size
        return (num / max(den, 1)) ** 0.5

    def _stuck_frac(self) -> float:
        tot = sum(st.stuck.size for st in self.states.values())
        bad = sum(float(jnp.sum(st.stuck)) for st in self.states.values())
        return bad / max(tot, 1)

    def step_epoch(
        self,
        dt_s: float,
        reads_per_column: float = 0.0,
        eval_fn: Callable[[Any], float] | None = None,
        max_leaves: int | None = None,
    ) -> EpochRecord:
        """Age by `dt_s`, refresh, re-materialize, evaluate.

        `max_leaves` bounds the scrub to a rotating window of at most
        that many leaves per epoch (aging always applies to every
        leaf).  This is the incremental-maintenance mode the
        continuous-batching scheduler interleaves between decode steps:
        per-epoch verify/re-program work stays O(max_leaves) instead of
        O(model), so serving never stalls on a whole-model scrub, and
        the cursor guarantees every leaf is visited every
        ceil(n_leaves / max_leaves) epochs.  Each leaf's RNG stream
        depends only on (key, epoch, leaf index), so the window changes
        no drawn value — only which leaves run their refresh.
        """
        wv_cfg, cost = self.deployed.wv_cfg, self.deployed.cost
        flagged = reprogrammed = 0
        en_v = en_p = lat = pulses = 0.0
        traffic = self.traffic_fn() if self.traffic_fn is not None else {}
        applied_reads = []
        names = sorted(self.states)
        if max_leaves is not None and max_leaves <= 0:
            chosen = set()  # a zero budget scrubs nothing (aging still runs)
        elif max_leaves is not None and max_leaves < len(names):
            start = self._scrub_cursor % len(names)
            chosen = {names[(start + j) % len(names)] for j in range(max_leaves)}
            self._scrub_cursor = (start + max_leaves) % len(names)
        else:
            chosen = set(names)
        with obs.span(
            "lifetime.scrub", cat="lifetime", epoch=self.epoch,
            scrubbed_leaves=len(chosen),
        ) as sp:
            for li, name in enumerate(names):
                st = self.states[name]
                k_adv, k_ref = jax.random.split(
                    jax.random.fold_in(
                        jax.random.fold_in(self.key, self.epoch), li
                    )
                )
                leaf_reads = float(reads_per_column) + float(
                    traffic.get(name, 0.0)
                )
                applied_reads.append(leaf_reads)
                st = advance(
                    k_adv, st, dt_s, leaf_reads, wv_cfg.device, self.drift_cfg
                )
                if name in chosen:
                    arr = self.deployed.arrays[name]
                    st, out = apply_refresh(
                        k_ref, st, arr.targets, wv_cfg,
                        cost, self.drift_cfg, self.refresh_cfg, self.epoch,
                        active=(
                            arr.remap.active if arr.remap is not None else None
                        ),
                        fault=arr.fault,
                    )
                    if out.flagged is not None:
                        flagged += int(out.flagged.sum())
                    reprogrammed += out.n_reprogrammed
                    en_v += out.verify_energy_pj
                    en_p += out.program_energy_pj
                    lat = max(lat, out.maintenance_latency_ns)  # in parallel
                    pulses += out.write_pulses
                self.states[name] = st
            sp["flagged"] = flagged
            sp["reprogrammed"] = reprogrammed
        obs.registry.inc("lifetime.scrub_epochs")
        obs.registry.inc("lifetime.reprogrammed_columns", reprogrammed)
        obs.charge(
            "lifetime.scrub",
            energy_pj=en_v + en_p,
            latency_ns=lat,
            epoch=self.epoch,
            reprogrammed=reprogrammed,
        )

        self.t_s += dt_s
        self.epoch += 1
        self._sync_deployed()
        params = None
        if reprogrammed and self.on_refresh is not None:
            params = self.deployed.materialize()
            self.on_refresh(params)
        metric = None
        if eval_fn is not None:
            if params is None:
                params = self.deployed.materialize()
            metric = float(eval_fn(params))
        return EpochRecord(
            epoch=self.epoch - 1,
            t_s=self.t_s,
            reads_per_column=(
                sum(applied_reads) / len(applied_reads)
                if applied_reads else float(reads_per_column)
            ),
            rms_drift_lsb=self._rms_drift_lsb(),
            stuck_frac=self._stuck_frac(),
            columns_flagged=flagged,
            columns_reprogrammed=reprogrammed,
            verify_energy_pj=en_v,
            program_energy_pj=en_p,
            maintenance_latency_ns=lat,
            write_pulses=pulses,
            eval_metric=metric,
        )

    def run(
        self,
        epochs: int,
        dt_s: float,
        reads_per_column: float = 0.0,
        eval_fn: Callable[[Any], float] | None = None,
        max_leaves: int | None = None,
    ) -> LifetimeReport:
        """Step `epochs` fixed-size epochs; returns the full time series."""
        report = LifetimeReport(
            policy=self.refresh_cfg.policy.value,
            method=self.deployed.wv_cfg.method.value,
        )
        for _ in range(epochs):
            report.records.append(
                self.step_epoch(dt_s, reads_per_column, eval_fn, max_leaves)
            )
        return report
