"""Lifetime serving simulation: age, verify, scrub, re-materialize.

`LifetimeSimulator` owns the analog side of a deployment — the
`DeployedModel` array state plus one aging `CellState` per RRAM leaf —
and steps wall-clock epochs interleaved with serving traffic:

    for each epoch:
        1. age every array by `dt_s` under the epoch's read traffic
           (every ACiM inference reads every column once per token);
        2. run the refresh policy (verify sweeps / re-programming);
        3. re-materialize dense params and push them to the serving
           engine via the `on_refresh` hook (`ServeEngine.swap_params`);
        4. evaluate (optional `eval_fn`) and append an `EpochRecord`.

The report carries both sides of the trade: accuracy retained (eval
metric + weight-domain RMS drift) and what retention cost (verify
energy, re-program energy, write pulses, wall latency) — so policies
are comparable as energy-per-retained-accuracy (DESIGN.md Sec. 9).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.programmer import DeployedModel

from .drift import DriftConfig, advance, init_cell_state
from .refresh import RefreshConfig, apply_refresh

__all__ = ["EpochRecord", "LifetimeReport", "LifetimeSimulator"]


@dataclasses.dataclass
class EpochRecord:
    """One epoch of the lifetime time series (aggregated over leaves)."""

    epoch: int
    t_s: float                       # wall-clock age at end of epoch
    reads_per_column: float          # traffic applied this epoch
    rms_drift_lsb: float             # cell-domain RMS |g - target|
    stuck_frac: float                # fraction of cells stuck
    columns_flagged: int             # VT verify flags this epoch
    columns_reprogrammed: int
    verify_energy_pj: float
    program_energy_pj: float
    maintenance_latency_ns: float
    write_pulses: float
    eval_metric: float | None = None
    gave_up_cells: float = 0.0       # refresh give-ups (SLO signal)
    retry_pulses: float = 0.0        # pulses burned on gave-up cells
    refresh_debt_epochs: float = 0.0  # max epochs since any leaf scrubbed


@dataclasses.dataclass
class LifetimeReport:
    """Accuracy-vs-time trajectory with per-epoch maintenance costs."""

    policy: str
    method: str
    records: list[EpochRecord] = dataclasses.field(default_factory=list)

    @property
    def total_maintenance_energy_pj(self) -> float:
        return sum(r.verify_energy_pj + r.program_energy_pj for r in self.records)

    @property
    def total_verify_energy_pj(self) -> float:
        return sum(r.verify_energy_pj for r in self.records)

    @property
    def final_rms_drift_lsb(self) -> float:
        return self.records[-1].rms_drift_lsb if self.records else 0.0

    def to_dict(self) -> dict:
        return {
            "policy": self.policy,
            "method": self.method,
            "total_maintenance_energy_pj": self.total_maintenance_energy_pj,
            "records": [dataclasses.asdict(r) for r in self.records],
        }

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)


class LifetimeSimulator:
    """Owns deployed array state and drives it through aging epochs.

    Args:
      key: PRNG key (per-leaf aging randomness derives from it).
      deployed: `deploy_arrays` output; the simulator takes ownership of
        its conductances (state-ownership contract, DESIGN.md Sec. 9).
      drift_cfg / refresh_cfg: dynamics and scrub policy.
      on_refresh: optional hook called with freshly materialized params
        after every epoch whose refresh re-programmed at least one
        column (e.g. ``engine.swap_params``).  Analog serving
        (`CIMExecutor`) needs no hook — it re-views the live arrays.
      traffic_fn: optional source of REAL per-array read counts for the
        epoch — e.g. ``CIMExecutor.drain_reads``, which counts every
        column read the analog serving path actually issued.  Each
        epoch's per-leaf reads are ``traffic_fn()[name]`` (plus the
        abstract `reads_per_column` scalar, for synthetic extra load).
    """

    def __init__(
        self,
        key: jax.Array,
        deployed: DeployedModel,
        drift_cfg: DriftConfig | None = None,
        refresh_cfg: RefreshConfig | None = None,
        on_refresh: Callable[[Any], None] | None = None,
        traffic_fn: Callable[[], dict[str, float]] | None = None,
        columns_per_tile: int = 128,
    ):
        self.key = key
        self.deployed = deployed
        self.drift_cfg = drift_cfg or DriftConfig()
        self.refresh_cfg = refresh_cfg or RefreshConfig()
        self.on_refresh = on_refresh
        self.traffic_fn = traffic_fn
        # Tile geometry for the scrub-time health maps (obs.health).
        # Must match the deploy's FaultConfig.columns_per_tile so drift
        # maps land on the same tile ids as the deploy's give-up maps.
        self.columns_per_tile = int(columns_per_tile)
        self.t_s = 0.0
        self.epoch = 0
        self._scrub_cursor = 0
        # Refresh debt: epochs since each leaf last sat in the scrub
        # window (0 = scrubbed by the deploy itself).
        self._last_scrub = {name: 0 for name in deployed.arrays}
        k = key
        self.states = {}
        for name, arr in deployed.arrays.items():
            k, sub = jax.random.split(k)
            self.states[name] = init_cell_state(
                sub, arr.g, arr.d2d, deployed.wv_cfg.device, self.drift_cfg
            )

    def _sync_deployed(self) -> None:
        for name, st in self.states.items():
            self.deployed.update_array(name, st.g)

    # Drift-digest bucket geometry (static so every epoch/replica folds
    # into the same histogram): per-column RMS drift in cell LSB.
    _DRIFT_DIGEST = ("lifetime.drift_lsb", 0.0, 8.0, 64)

    def _epoch_health(self) -> tuple[float, float]:
        """Global drift RMS + stuck fraction, with health maps riding.

        All reductions are device-side jnp ops; ONE `metrics.fetch` at
        the end transfers the scalars, the per-tile sums, and the
        drift digest together (DESIGN.md Sec. 16).  The old per-leaf
        `float()` pulls did one sync per leaf; this does one per epoch.
        Per-tile attribution uses the deploy's physical column uids
        (`ArrayState.uids`, host numpy) — remapped-away rows count
        neither drift nor tiles (a parked stuck column is not drift the
        model experiences).
        """
        import numpy as np

        col_e2, col_cnt, col_uids = [], [], []
        stuck_bad = jnp.zeros((), jnp.float32)
        stuck_tot = 0
        have_uids = all(
            a.uids is not None for a in self.deployed.arrays.values()
        )
        for name in sorted(self.states):
            st = self.states[name]
            arr = self.deployed.arrays[name]
            err = st.g - arr.targets.astype(jnp.float32)
            if arr.remap is not None:
                act = arr.remap.active.astype(jnp.float32)
                col_e2.append(jnp.sum(err * err, axis=1) * act)
                col_cnt.append(act * err.shape[1])
            else:
                col_e2.append(jnp.sum(err * err, axis=1))
                col_cnt.append(
                    jnp.full((err.shape[0],), float(err.shape[1]), jnp.float32)
                )
            if have_uids:
                col_uids.append(np.asarray(arr.uids, np.int64))
            stuck_bad = stuck_bad + jnp.sum(st.stuck)
            stuck_tot += int(st.stuck.size)
        e2 = jnp.concatenate(col_e2)
        cnt = jnp.concatenate(col_cnt)
        col_rms = jnp.sqrt(e2 / jnp.maximum(cnt, 1.0))
        dig_name, lo, hi, nb = self._DRIFT_DIGEST
        tree: dict[str, Any] = {
            "num": jnp.sum(e2),
            "den": jnp.sum(cnt),
            "stuck": stuck_bad,
            "digest": obs.StreamingDigest.zeros(lo, hi, nb).add_weighted(
                col_rms, (cnt > 0).astype(jnp.float32)
            ),
        }
        tile_ids = None
        if have_uids and col_uids:
            uids = np.concatenate(col_uids)
            tile_ids, inv = np.unique(
                uids // self.columns_per_tile, return_inverse=True
            )
            n_tiles = int(tile_ids.shape[0])
            tree["tile_e2"] = obs.health.tile_reduce(e2, inv, n_tiles)
            tree["tile_cnt"] = obs.health.tile_reduce(cnt, inv, n_tiles)
        # THE per-epoch health sync (rides nothing else — but replaces
        # the old 2-pulls-per-leaf pattern with a single fetch).
        h = obs.metrics.fetch(tree, counter="lifetime.health_syncs")
        rms = (float(h["num"]) / max(float(h["den"]), 1.0)) ** 0.5
        stuck = float(h["stuck"]) / max(stuck_tot, 1)
        obs.digests.put(dig_name, h["digest"])
        if tile_ids is not None:
            tile_rms = np.sqrt(
                np.asarray(h["tile_e2"])
                / np.maximum(np.asarray(h["tile_cnt"]), 1.0)
            )
            obs.health_registry.fold_tiles(
                "lifetime.drift_rms_lsb", tile_ids, tile_rms, mode="last"
            )
        return rms, stuck

    def step_epoch(
        self,
        dt_s: float,
        reads_per_column: float = 0.0,
        eval_fn: Callable[[Any], float] | None = None,
        max_leaves: int | None = None,
    ) -> EpochRecord:
        """Age by `dt_s`, refresh, re-materialize, evaluate.

        `max_leaves` bounds the scrub to a rotating window of at most
        that many leaves per epoch (aging always applies to every
        leaf).  This is the incremental-maintenance mode the
        continuous-batching scheduler interleaves between decode steps:
        per-epoch verify/re-program work stays O(max_leaves) instead of
        O(model), so serving never stalls on a whole-model scrub, and
        the cursor guarantees every leaf is visited every
        ceil(n_leaves / max_leaves) epochs.  Each leaf's RNG stream
        depends only on (key, epoch, leaf index), so the window changes
        no drawn value — only which leaves run their refresh.
        """
        wv_cfg, cost = self.deployed.wv_cfg, self.deployed.cost
        flagged = reprogrammed = 0
        en_v = en_p = lat = pulses = gave_up = retry = 0.0
        traffic = self.traffic_fn() if self.traffic_fn is not None else {}
        applied_reads = []
        names = sorted(self.states)
        if max_leaves is not None and max_leaves <= 0:
            chosen = set()  # a zero budget scrubs nothing (aging still runs)
        elif max_leaves is not None and max_leaves < len(names):
            start = self._scrub_cursor % len(names)
            chosen = {names[(start + j) % len(names)] for j in range(max_leaves)}
            self._scrub_cursor = (start + max_leaves) % len(names)
        else:
            chosen = set(names)
        with obs.span(
            "lifetime.scrub", cat="lifetime", epoch=self.epoch,
            scrubbed_leaves=len(chosen),
        ) as sp:
            for li, name in enumerate(names):
                st = self.states[name]
                k_adv, k_ref = jax.random.split(
                    jax.random.fold_in(
                        jax.random.fold_in(self.key, self.epoch), li
                    )
                )
                leaf_reads = float(reads_per_column) + float(
                    traffic.get(name, 0.0)
                )
                applied_reads.append(leaf_reads)
                st = advance(
                    k_adv, st, dt_s, leaf_reads, wv_cfg.device, self.drift_cfg
                )
                if name in chosen:
                    arr = self.deployed.arrays[name]
                    st, out = apply_refresh(
                        k_ref, st, arr.targets, wv_cfg,
                        cost, self.drift_cfg, self.refresh_cfg, self.epoch,
                        active=(
                            arr.remap.active if arr.remap is not None else None
                        ),
                        fault=arr.fault,
                    )
                    if out.flagged is not None:
                        flagged += int(out.flagged.sum())
                    reprogrammed += out.n_reprogrammed
                    en_v += out.verify_energy_pj
                    en_p += out.program_energy_pj
                    lat = max(lat, out.maintenance_latency_ns)  # in parallel
                    pulses += out.write_pulses
                    gave_up += out.gave_up_cells
                    retry += out.retry_pulses
                    self._last_scrub[name] = self.epoch
                self.states[name] = st
            sp["flagged"] = flagged
            sp["reprogrammed"] = reprogrammed
        obs.registry.inc("lifetime.scrub_epochs")
        obs.registry.inc("lifetime.reprogrammed_columns", reprogrammed)
        obs.registry.inc("lifetime.gave_up_cells", gave_up)
        obs.registry.inc("lifetime.retry_pulses", retry)
        obs.charge(
            "lifetime.scrub",
            energy_pj=en_v + en_p,
            latency_ns=lat,
            epoch=self.epoch,
            reprogrammed=reprogrammed,
        )

        self.t_s += dt_s
        self.epoch += 1
        self._sync_deployed()
        # Refresh debt (scrub backlog): epochs since each leaf was last
        # in the scrub window — the scrub-backlog SLO signal.
        debt = max(
            (self.epoch - 1 - e for e in self._last_scrub.values()),
            default=0.0,
        )
        obs.health_registry.set_gauge("lifetime.refresh_debt_epochs", debt)
        params = None
        if reprogrammed and self.on_refresh is not None:
            params = self.deployed.materialize()
            self.on_refresh(params)
        metric = None
        if eval_fn is not None:
            if params is None:
                params = self.deployed.materialize()
            metric = float(eval_fn(params))
        rms_drift, stuck = self._epoch_health()
        return EpochRecord(
            epoch=self.epoch - 1,
            t_s=self.t_s,
            reads_per_column=(
                sum(applied_reads) / len(applied_reads)
                if applied_reads else float(reads_per_column)
            ),
            rms_drift_lsb=rms_drift,
            stuck_frac=stuck,
            columns_flagged=flagged,
            columns_reprogrammed=reprogrammed,
            verify_energy_pj=en_v,
            program_energy_pj=en_p,
            maintenance_latency_ns=lat,
            write_pulses=pulses,
            eval_metric=metric,
            gave_up_cells=gave_up,
            retry_pulses=retry,
            refresh_debt_epochs=float(debt),
        )

    def run(
        self,
        epochs: int,
        dt_s: float,
        reads_per_column: float = 0.0,
        eval_fn: Callable[[Any], float] | None = None,
        max_leaves: int | None = None,
    ) -> LifetimeReport:
        """Step `epochs` fixed-size epochs; returns the full time series."""
        report = LifetimeReport(
            policy=self.refresh_cfg.policy.value,
            method=self.deployed.wv_cfg.method.value,
        )
        for _ in range(epochs):
            report.records.append(
                self.step_epoch(dt_s, reads_per_column, eval_fn, max_leaves)
            )
        return report
