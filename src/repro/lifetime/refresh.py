"""Scrub policies: when and what to re-program on an aging array.

Three policies, each cost-accounted through `core.cost` so that
"latency/energy per retained accuracy" is a first-class metric:

* ``none``             — never touch the array (the drift baseline).
* ``periodic``         — blind full re-program of *every* column each
                         `period_epochs`.  Maximum retention, maximum
                         cost: pays the whole WV pipeline per column
                         per period, no verify needed.
* ``verify_triggered`` — the HD-PV/HARP showcase: one Hadamard verify
                         sweep per column (N reads — the same sweep the
                         WV loop uses, so one sweep costs exactly one
                         `read_phase_cost`) flags columns whose decoded
                         deviation exceeds the threshold; only flagged
                         columns re-enter `program_columns`.  A one-hot
                         (CW-SC/MRA-style) detector would spend the same
                         N reads for ONE cell's worth of information;
                         the Hadamard sweep screens all N cells at once,
                         which is what makes cheap scrubbing possible.

Re-programming subsets: flagged column counts vary per epoch, so naive
re-tracing would recompile `program_columns` for every new count.  The
subset is padded to the next power of two and dispatched through the
shared batched-programming entry point (`core.pipeline.get_program_fn`)
— the SAME jit cache the deployment pipeline uses, so a refresh after a
deploy hits warm compiles and the whole simulation stays at most
log2(C)+1 compilations per method.
"""

from __future__ import annotations

import dataclasses
import enum

import jax
import jax.numpy as jnp
import numpy as np

from repro import readout as ro
from repro.core import device as dev_mod
from repro.core import pipeline
from repro.core.cost import CircuitCost, read_phase_cost
from repro.core.types import WVConfig, WVMethod
from repro.core.wv import verify_sweep

from .drift import CellState, DriftConfig, effective_d2d, reset_programmed

__all__ = [
    "RefreshPolicy",
    "RefreshConfig",
    "RefreshOutcome",
    "default_flag_params",
    "flag_columns",
    "apply_refresh",
]


class RefreshPolicy(str, enum.Enum):
    NONE = "none"
    PERIODIC = "periodic"
    VERIFY_TRIGGERED = "verify_triggered"


@dataclasses.dataclass(frozen=True)
class RefreshConfig:
    """Scrub policy configuration.

    The verify-triggered detector repeats the method's own verify sweep
    `verify_sweeps` times and flags a cell only when `votes` sweeps
    agree on the sign of its deviation — a repetition vote that crushes
    the single-sweep false-alarm rate (a lone HARP ternary sweep at the
    programming threshold fires on nearly every healthy column).  The
    `None` defaults resolve per method via `default_flag_params`,
    calibrated so a healthy column flags <~10% of the time while a
    >=1-LSB drifted cell is caught with >90% probability.
    """

    policy: RefreshPolicy = RefreshPolicy.VERIFY_TRIGGERED
    period_epochs: int = 1        # PERIODIC cadence / VT verify cadence
    max_bad_cells: int = 1        # VT: flag a column when more than this
                                  # many cells read out-of-threshold
    verify_sweeps: int | None = None    # None -> per-method default
    votes: int | None = None            # sweeps that must agree per cell
    threshold_lsb: float | None = None  # compare threshold override
    tau_w_scale: float = 2.0      # HARP flag threshold: tau_w_scale * tau_w

    def replace(self, **kw) -> "RefreshConfig":
        return dataclasses.replace(self, **kw)


def default_flag_params(method: WVMethod) -> tuple[int, int, float]:
    """(verify_sweeps, votes, threshold_lsb) calibrated per method.

    HD-PV decodes a near-unbiased magnitude estimate (read noise down
    ~sqrt(N)) so 2 agreeing sweeps suffice; HARP's ternary aggregate and
    CW-SC's raw one-hot compares are noisier and take a 3-of-4 / 4-of-4
    vote.  Even at 4 sweeps HARP's compare-only detector costs less
    energy than a single HD-PV full-SAR sweep.
    """
    return {
        WVMethod.CW_SC: (4, 4, 0.75),
        WVMethod.MRA: (2, 2, 0.75),
        WVMethod.HD_PV: (2, 2, 0.75),
        WVMethod.HARP: (4, 3, 1.0),
    }[method]


@dataclasses.dataclass
class RefreshOutcome:
    """What one refresh step did and what it cost (per column batch)."""

    flagged: np.ndarray | None = None   # (C,) bool, VT only
    n_reprogrammed: int = 0
    verify_latency_ns: float = 0.0
    verify_energy_pj: float = 0.0
    program_latency_ns: float = 0.0     # critical path: max over columns
    program_energy_pj: float = 0.0
    write_pulses: float = 0.0
    # Give-up accounting (DESIGN.md Secs. 15/16): cells the bounded-
    # retry budget declared unprogrammable during THIS refresh, and the
    # fine pulses burned on them — the fleet give-up-rate SLO signal.
    gave_up_cells: float = 0.0
    retry_pulses: float = 0.0

    @property
    def maintenance_energy_pj(self) -> float:
        return self.verify_energy_pj + self.program_energy_pj

    @property
    def maintenance_latency_ns(self) -> float:
        return self.verify_latency_ns + self.program_latency_ns


def flag_columns(
    key: jax.Array,
    g: jax.Array,
    targets: jax.Array,
    cfg: WVConfig,
    refresh_cfg: RefreshConfig | None = None,
) -> tuple[jax.Array, int]:
    """Voted verify sweeps -> ((C,) bool drifted-column mask, sweeps used).

    The detector is `sweeps` independent readout calls voted per cell:
    each sweep is the configured WV method's own verify read
    (`verify_sweep` -> `repro.readout.read_columns`), so HD-PV/HARP
    detection inherits exactly the paper's read model — N Hadamard
    reads, common-mode cancellation, ADC quantization and all — and the
    vote accumulation is `readout.voted_signs` over fold-in sub-streams.
    A cell is bad when `votes` of `verify_sweeps` independent sweeps
    agree on its deviation sign; a column is flagged when more than
    `max_bad_cells` cells are bad.
    """
    rc = refresh_cfg or RefreshConfig()
    sweeps, votes, thr = default_flag_params(cfg.method)
    sweeps = rc.verify_sweeps if rc.verify_sweeps is not None else sweeps
    votes = rc.votes if rc.votes is not None else votes
    thr = rc.threshold_lsb if rc.threshold_lsb is not None else thr
    cfg = cfg.replace(
        decision_threshold_lsb=thr, tau_w=rc.tau_w_scale * cfg.tau_w
    )
    if sweeps == 0:  # detection disabled: nothing read, nothing flagged
        return jnp.zeros((g.shape[0],), bool), 0
    targets = targets.astype(jnp.float32)
    pos, neg = ro.voted_signs(
        key, sweeps, lambda k: verify_sweep(k, g, targets, cfg)[0]
    )
    bad = jnp.sum(jnp.maximum(pos, neg) >= votes, axis=-1)
    return bad > rc.max_bad_cells, sweeps


def _pad_pow2(idx: np.ndarray, c: int) -> np.ndarray:
    """Pad a flagged-index set to the next power of two (capped at C)."""
    n = len(idx)
    size = 1
    while size < n:
        size *= 2
    size = min(size, c)
    if size > n:
        # Filler columns: recycle flagged indices (re-programming the
        # same column twice in one batch is harmless — only the first
        # occurrence is scattered back).
        filler = idx[np.arange(size - n) % n]
        idx = np.concatenate([idx, filler])
    return idx


def _reprogram_subset(
    key: jax.Array,
    state: CellState,
    targets: jax.Array,
    mask: np.ndarray,
    cfg: WVConfig,
    cost: CircuitCost,
    drift_cfg: DriftConfig,
    fault: dev_mod.FaultMap | None = None,
) -> tuple[CellState, float, float, float, float, float]:
    """Re-program the masked columns; returns
    (state, lat, energy, pulses, gave_up_cells, retry_pulses).

    Wear-degraded step efficiency feeds `program_columns` through its
    d2d argument, so an old array genuinely takes more WV iterations to
    converge (and may fail to).  A deployment-time `FaultMap` is physical
    state (DESIGN.md Sec. 15): its rows are gathered for the flagged
    columns and passed through the dispatch, NEVER resampled — the scrub
    re-programs the same silicon the deploy hit.  Latency is the max
    over re-programmed columns (they run array-parallel); energy the sum.
    """
    c, n = targets.shape
    idx = np.nonzero(mask)[0]
    if len(idx) == 0:
        return state, 0.0, 0.0, 0.0, 0.0, 0.0
    idx_p = _pad_pow2(idx, c)
    sub_targets = targets[idx_p]
    sub_d2d = effective_d2d(state, drift_cfg)[idx_p]
    k_prog, k_state = jax.random.split(key)
    # Shared batched entry point (one compile cache with deployment);
    # col_ids are the physical column indices, so each column's refresh
    # noise stream is independent of which other columns were flagged.
    fn = pipeline.get_program_fn(cfg, cost, with_fault=fault is not None)
    fargs = (
        (jax.tree.map(lambda x: x[idx_p], fault),) if fault is not None else ()
    )
    g_sub, stats = fn(
        k_prog, sub_targets, sub_d2d, jnp.asarray(idx_p, jnp.int32), *fargs
    )

    # Scatter back; idx_p = [idx, filler], so rows 0..len(idx)-1 are the
    # real flagged columns and filler rows are discarded duplicates.
    rows = np.arange(len(idx))
    g_new = state.g.at[idx].set(g_sub[rows])
    refreshed = jnp.zeros((c,), bool).at[idx].set(True)
    # Per-cell pulse attribution: the engine reports per-column totals;
    # spread uniformly over the column's cells (documented approximation,
    # DESIGN.md Sec. 9).
    pulses_col = stats.write_pulses[rows] / n                    # (|idx|,)
    pulses_cell = jnp.zeros_like(state.cycles).at[idx].set(
        jnp.broadcast_to(pulses_col[:, None], (len(idx), n))
    )
    new_state = reset_programmed(
        k_state, state, g_new, refreshed, pulses_cell, cfg.device, drift_cfg
    )
    # One consolidated fetch for the scalar outcome — the give-up sums
    # (DESIGN.md Sec. 16) ride the same device_get the cost accounting
    # was already paying, not their own.
    lat, en, pulses, gave_up, retry = (
        float(v) for v in jax.device_get((
            jnp.max(stats.latency_ns[rows]),
            jnp.sum(stats.energy_pj[rows]),
            jnp.sum(stats.write_pulses[rows]),
            jnp.sum(stats.gave_up[rows]),
            jnp.sum(stats.retry_pulses[rows]),
        ))
    )
    return new_state, lat, en, pulses, gave_up, retry


def apply_refresh(
    key: jax.Array,
    state: CellState,
    targets: jax.Array,
    cfg: WVConfig,
    cost: CircuitCost,
    drift_cfg: DriftConfig,
    refresh_cfg: RefreshConfig,
    epoch: int,
    active: jax.Array | None = None,
    fault: dev_mod.FaultMap | None = None,
) -> tuple[CellState, RefreshOutcome]:
    """Run one epoch's refresh decision for a batch of columns.

    Remapped arrays (DESIGN.md Sec. 15): `active` masks the physical
    rows that carry live weight.  Inactive rows — remapped-away
    primaries (often unprogrammable silicon that would flag every
    epoch) and unused spares — are never flagged or re-programmed,
    and under PERIODIC only active rows are scrubbed.  `fault` is the
    deployment's sampled fault map, threaded into re-programming.
    """
    c = targets.shape[0]
    outcome = RefreshOutcome()
    policy = refresh_cfg.policy
    due = (epoch + 1) % max(refresh_cfg.period_epochs, 1) == 0
    if policy == RefreshPolicy.NONE or not due:
        return state, outcome
    active_h = (
        np.ones((c,), bool) if active is None else np.asarray(active)
    )
    n_active = int(active_h.sum())

    k_v, k_p = jax.random.split(key)
    if policy == RefreshPolicy.PERIODIC:
        mask = active_h.copy()
    elif policy == RefreshPolicy.VERIFY_TRIGGERED:
        flagged, sweeps = flag_columns(k_v, state.g, targets, cfg, refresh_cfg)
        mask = np.asarray(flagged) & active_h
        # Every active column pays `sweeps` verify sweeps (read phase,
        # no writes); inactive rows are not driven.
        lat_v, en_v = read_phase_cost(cfg, cost)
        outcome.verify_latency_ns = float(lat_v) * sweeps  # array-parallel
        outcome.verify_energy_pj = float(en_v) * sweeps * n_active
        outcome.flagged = mask
    else:
        raise ValueError(policy)

    state, lat, en, pulses, gave_up, retry = _reprogram_subset(
        k_p, state, targets, mask, cfg, cost, drift_cfg, fault=fault
    )
    outcome.n_reprogrammed = int(mask.sum())
    outcome.program_latency_ns = lat
    outcome.program_energy_pj = en
    outcome.write_pulses = pulses
    outcome.gave_up_cells = gave_up
    outcome.retry_pulses = retry
    return state, outcome
