"""Temporal RRAM device dynamics: relaxation, drift, disturb, wear.

The WV engine (core.wv) models *programming-time* noise only; this
module models what happens to a programmed conductance *afterwards*,
so a deployed model can be aged and re-verified (DESIGN.md Sec. 9).
Four effects, all in cell-LSB units:

1. **Post-programming relaxation** (arXiv:2301.08516): within minutes of
   the final pulse the filament partially relaxes toward a per-cell
   equilibrium.  We model the equilibrium as the programmed level pulled
   fractionally toward mid-scale (cells near the rails relax hardest)
   plus a static per-cell offset, and the approach as exponential
   settling with time constant `tau_relax_s`.
2. **Log-time drift**: the classic conductance decay
   g(t) = g(t_p) * ((t + t0) / (t_p + t0))^-nu, with a static per-cell
   drift exponent nu (dispersion sampled at program time).  Advancing
   from age a to a + dt multiplies by ((a + dt + t0)/(a + t0))^-nu, so
   repeated small steps compose exactly to one large step.
3. **Read disturb**: every ACiM read stresses the whole column with a
   sub-switching voltage; accumulated reads nudge conductance SET-ward
   by `read_disturb_lsb` per read (deterministic, first-order).
4. **Endurance wear**: each write pulse consumes cycle budget.  Step
   efficiency degrades smoothly as (1 + cycles/endurance)^-wear_exponent
   (monotone in cycles), and a cell whose cycle count crosses its
   per-cell sampled limit becomes *stuck*: it no longer responds to
   programming or drift (a formed/ruptured filament frozen in place).

`advance` is pure ((key, state, dt, reads) -> state) and shape-stable,
so it drops into `jax.lax.scan` for long horizons; `LifetimeSimulator`
(service.py) calls it per epoch from Python instead, interleaved with
refresh decisions that change column subsets.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.types import DeviceConfig

__all__ = [
    "DriftConfig",
    "CellState",
    "init_cell_state",
    "advance",
    "wear_efficiency",
    "effective_d2d",
    "reset_programmed",
]


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    """Post-programming dynamics parameters (cell-LSB / seconds)."""

    # Relaxation (minutes-scale, arXiv:2301.08516 Fig. 2 shape).
    tau_relax_s: float = 120.0       # exponential settling time constant
    relax_frac: float = 0.05         # equilibrium pull toward mid-scale
    sigma_relax_lsb: float = 0.10    # static per-cell equilibrium offset std
    # Log-time drift.
    nu_drift: float = 0.01           # mean drift exponent
    sigma_nu_frac: float = 0.8       # per-cell dispersion of nu (lognormal-ish)
    t0_s: float = 30.0               # drift reference time (merges the
                                     # sub-t0 transient into relaxation)
    # Read disturb (SET-ward, per accumulated column read).
    read_disturb_lsb: float = 1e-7
    # Endurance wear.
    endurance_cycles: float = 1e6    # median cycles-to-failure
    sigma_endurance_dec: float = 0.3 # lognormal spread, decades
    wear_exponent: float = 1.0       # step-efficiency decay power

    def replace(self, **kw) -> "DriftConfig":
        return dataclasses.replace(self, **kw)


class CellState(NamedTuple):
    """Aging state of a batch of columns (leading shape (C, N) / (C, 1)).

    A NamedTuple of arrays = a pytree: scan-able, jit-able, shardable on
    the column axis like everything else in the WV stack.
    """

    g: jax.Array        # (C, N) live analog conductance, LSB
    g_eq: jax.Array     # (C, N) relaxation equilibrium, LSB
    nu: jax.Array       # (C, N) static per-cell drift exponent
    d2d: jax.Array      # (C, N) static per-cell step efficiency (pristine)
    age_s: jax.Array    # (C, 1) seconds since the column's last program
    reads: jax.Array    # (C, 1) accumulated column reads since last program
    cycles: jax.Array   # (C, N) lifetime write pulses seen by each cell
    limit: jax.Array    # (C, N) per-cell cycles-to-failure
    stuck: jax.Array    # (C, N) bool: cell no longer switches


def _sample_equilibrium(
    key: jax.Array, g: jax.Array, dev: DeviceConfig, cfg: DriftConfig
) -> jax.Array:
    """Per-cell relaxation equilibrium for freshly programmed levels."""
    g_mid = 0.5 * dev.g_max_lsb
    offset = cfg.sigma_relax_lsb * jax.random.normal(key, g.shape, jnp.float32)
    return jnp.clip(
        g + cfg.relax_frac * (g_mid - g) + offset, 0.0, dev.g_max_lsb
    )


def _sample_nu(key: jax.Array, shape, cfg: DriftConfig) -> jax.Array:
    """Static per-cell drift exponent, strictly positive."""
    spread = jnp.exp(
        cfg.sigma_nu_frac * jax.random.normal(key, shape, jnp.float32)
        - 0.5 * cfg.sigma_nu_frac**2
    )
    return cfg.nu_drift * spread


def init_cell_state(
    key: jax.Array,
    g: jax.Array,
    d2d: jax.Array,
    dev: DeviceConfig,
    cfg: DriftConfig,
    initial_cycles: jax.Array | float = 0.0,
) -> CellState:
    """Aging state for freshly programmed conductances `g` (C, N)."""
    c = g.shape[0]
    k_eq, k_nu, k_lim = jax.random.split(key, 3)
    limit = cfg.endurance_cycles * jnp.power(
        10.0,
        cfg.sigma_endurance_dec
        * jax.random.normal(k_lim, g.shape, jnp.float32),
    )
    cycles = jnp.broadcast_to(
        jnp.asarray(initial_cycles, jnp.float32), g.shape
    ).astype(jnp.float32)
    return CellState(
        g=g.astype(jnp.float32),
        g_eq=_sample_equilibrium(k_eq, g, dev, cfg),
        nu=_sample_nu(k_nu, g.shape, cfg),
        d2d=d2d.astype(jnp.float32),
        age_s=jnp.zeros((c, 1), jnp.float32),
        reads=jnp.zeros((c, 1), jnp.float32),
        cycles=cycles,
        limit=limit,
        stuck=cycles > limit,
    )


def wear_efficiency(cycles: jax.Array, cfg: DriftConfig) -> jax.Array:
    """Step-efficiency multiplier after `cycles` write pulses.

    1.0 for a pristine cell, monotonically decreasing, never negative:
    (1 + cycles/endurance)^-wear_exponent.  Multiplies the static d2d
    efficiency wherever pulses are applied (refresh re-programming).
    """
    return jnp.power(
        1.0 + cycles / cfg.endurance_cycles, -cfg.wear_exponent
    )


def effective_d2d(state: CellState, cfg: DriftConfig) -> jax.Array:
    """Current per-cell step efficiency: pristine d2d degraded by wear."""
    return state.d2d * wear_efficiency(state.cycles, cfg)


def advance(
    key: jax.Array,
    state: CellState,
    dt_s: jax.Array | float,
    reads: jax.Array | float,
    dev: DeviceConfig,
    cfg: DriftConfig,
) -> CellState:
    """Age all columns by `dt_s` seconds with `reads` column reads.

    Pure and deterministic under a fixed key; `reads` may be a scalar or
    a (C, 1) per-column count (every ACiM read senses the whole column).
    The key only feeds *future* extensions (e.g. RTN); the current four
    effects are deterministic given the state, which is what makes a
    Hadamard verify sweep a faithful drift detector.
    """
    del key  # all current dynamics are deterministic given state
    dt = jnp.asarray(dt_s, jnp.float32)
    reads = jnp.broadcast_to(
        jnp.asarray(reads, jnp.float32), state.reads.shape
    )
    # 1. Exponential relaxation toward the per-cell equilibrium.
    settle = 1.0 - jnp.exp(-dt / cfg.tau_relax_s)
    g = state.g + (state.g_eq - state.g) * settle
    # 2. Log-time drift, exact composition over the age increment.  The
    # equilibrium decays too — drift is filament dissolution, not a
    # displacement relaxation could undo — otherwise relaxation would
    # restore drifted cells for free.
    factor = jnp.power(
        (state.age_s + dt + cfg.t0_s) / (state.age_s + cfg.t0_s), -state.nu
    )
    g = g * factor
    g_eq = state.g_eq * factor
    # 3. Read disturb: SET-ward, proportional to new reads this step.
    g = g + cfg.read_disturb_lsb * reads
    g = jnp.clip(g, 0.0, dev.g_max_lsb)
    # 4. Stuck cells are frozen filaments: they neither drift nor switch.
    g = jnp.where(state.stuck, state.g, g)
    g_eq = jnp.where(state.stuck, state.g_eq, g_eq)
    return state._replace(
        g=g, g_eq=g_eq, age_s=state.age_s + dt, reads=state.reads + reads
    )


def reset_programmed(
    key: jax.Array,
    state: CellState,
    g_new: jax.Array,
    refreshed: jax.Array,
    pulses_per_cell: jax.Array,
    dev: DeviceConfig,
    cfg: DriftConfig,
) -> CellState:
    """Fold a re-programming event into the aging state.

    Args:
      key: PRNG key (fresh relaxation equilibria for refreshed columns).
      state: state *before* the re-program.
      g_new: (C, N) conductances produced by the WV engine.
      refreshed: (C,) bool — which columns were actually re-programmed.
      pulses_per_cell: (C, N) write pulses this event charged per cell.
      dev, cfg: device / drift configs.

    Refreshed columns restart their relaxation clock (age, reads, fresh
    g_eq); stuck cells ignore the new conductance (writes cannot move
    them); every applied pulse adds endurance wear, which may newly
    exceed a cell's limit and stick it.
    """
    k_eq, k_nu = jax.random.split(key)
    col = refreshed[:, None]
    g = jnp.where(col & ~state.stuck, g_new, state.g)
    cycles = state.cycles + jnp.where(
        state.stuck, 0.0, pulses_per_cell.astype(jnp.float32)
    )
    stuck = state.stuck | (cycles > state.limit)
    g_eq = jnp.where(col, _sample_equilibrium(k_eq, g, dev, cfg), state.g_eq)
    nu = jnp.where(col, _sample_nu(k_nu, g.shape, cfg), state.nu)
    zeros = jnp.zeros_like(state.age_s)
    return state._replace(
        g=g,
        g_eq=g_eq,
        nu=nu,
        age_s=jnp.where(col, zeros, state.age_s),
        reads=jnp.where(col, zeros, state.reads),
        cycles=cycles,
        stuck=stuck,
    )
