"""Train-step construction: loss + grad + AdamW on sharded pytrees."""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from .models import ModelConfig
from .models.transformer import loss_fn
from .optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from .optim.adamw import AdamWState


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def init_train_state(key, cfg: ModelConfig, opt_cfg: AdamWConfig) -> TrainState:
    from .models import init_params

    params = init_params(key, cfg)
    return TrainState(params=params, opt=adamw_init(params, opt_cfg))


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    mesh=None,
    schedule: Callable | None = None,
    total_steps: int = 10000,
    grad_accum: int = 1,
):
    """Returns train_step(state, batch) -> (state, metrics). Pure function
    of its inputs — jit/shard it at the launch layer.

    grad_accum > 1 splits the global batch into microbatches processed by
    a lax.scan, dividing activation memory by the accumulation factor at
    the cost of serialized microbatch compute (the standard big-model
    trade; per-cell factors live in launch/dryrun.py)."""
    if schedule is None:
        schedule = lambda s: cosine_schedule(
            s, opt_cfg.lr_peak, warmup_steps=min(500, total_steps // 10),
            total_steps=total_steps,
        )

    def grads_of(params, batch):
        def lf(p):
            return loss_fn(p, batch, cfg, mesh)

        return jax.value_and_grad(lf, has_aux=True)(params)

    def train_step(state: TrainState, batch: dict):
        # schedule indexed from 1: warmup must not zero the first step
        lr = schedule(state.opt.step + 1)
        if grad_accum == 1:
            (loss, metrics), grads = grads_of(state.params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]),
                batch,
            )

            def body(carry, mb):
                g_acc, m_acc = carry
                (_, m), g = grads_of(state.params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                m_acc = jax.tree.map(jnp.add, m_acc, m)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            m0 = {"loss": 0.0, "ce": 0.0, "router_aux": 0.0}
            m0 = jax.tree.map(jnp.float32, m0)
            (grads, metrics), _ = jax.lax.scan(body, (g0, m0), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            metrics = jax.tree.map(lambda m: m / grad_accum, metrics)
        params, opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, opt_cfg, lr
        )
        metrics = {**metrics, **opt_metrics, "lr": lr}
        return TrainState(params, opt), metrics

    return train_step


def make_eval_step(cfg: ModelConfig, mesh=None):
    def eval_step(params, batch: dict):
        _, metrics = loss_fn(params, batch, cfg, mesh)
        return metrics

    return eval_step
