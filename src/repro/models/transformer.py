"""Unified decoder-only model covering all assigned architectures.

One config-driven implementation provides:

* dense / MoE transformer blocks (GQA, qk-norm, RoPE or sinusoidal pos);
* RWKV6 blocks (attention-free);
* Hymba hybrid blocks (parallel GQA + SSM heads; SWA with every-k global
  attention layers);
* cross-attention conditioning (VLM image patches every k layers,
  MusicGen text conditioning every layer);
* multi-codebook output heads (MusicGen).

Compile hygiene: homogeneous layer stacks are scanned (`lax.scan` over
stacked params — a 94-layer MoE compiles as one block body); Hymba's
heterogeneous global/SWA layers use an unrolled loop over stacked params
(32 layers, two cache groups); the VLM interleaves scanned groups of
self-attention layers between unrolled cross-attention blocks.

All entry points work under `jax.eval_shape` (the multi-pod dry-run
never materializes parameters).
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from . import rwkv6 as rwkv_mod
from . import ssm as ssm_mod
from .act_sharding import constrain
from .attention import (
    chunked_causal_attention,
    cross_attention,
    decode_attention,
)
from .config import ModelConfig
from .layers import (
    apply_rope,
    cross_entropy_loss,
    dense_init,
    head_rms_norm,
    matmul,
    rms_norm,
    sinusoidal_positions,
    swiglu,
    truncated_normal,
)
from .moe import init_moe_params, moe_block


# --------------------------------------------------------------------------
# Parameter initialization
# --------------------------------------------------------------------------
def _attn_layer_params(key, cfg: ModelConfig, n_layers: int) -> dict[str, Any]:
    d, dt = cfg.d_model, cfg.dtype
    ks = jax.random.split(key, 8)
    L = n_layers

    def stack(k, din, dout):
        return jax.vmap(lambda kk: dense_init(kk, din, dout, dt))(
            jax.random.split(k, L)
        )

    p = {
        "attn_norm": jnp.zeros((L, d), jnp.float32),
        "wq": stack(ks[0], d, cfg.q_dim),
        "wk": stack(ks[1], d, cfg.kv_dim),
        "wv": stack(ks[2], d, cfg.kv_dim),
        "wo": stack(ks[3], cfg.q_dim, d),
        "mlp_norm": jnp.zeros((L, d), jnp.float32),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((L, cfg.head_dim), jnp.float32)
        p["k_norm"] = jnp.zeros((L, cfg.head_dim), jnp.float32)
    if cfg.is_moe:
        p["moe"] = init_moe_params(ks[4], cfg, L)
    else:
        p["w_gate"] = stack(ks[4], d, cfg.d_ff)
        p["w_up"] = stack(ks[5], d, cfg.d_ff)
        p["w_down"] = stack(ks[6], cfg.d_ff, d)
    return p


def _cross_layer_params(key, cfg: ModelConfig, n_layers: int) -> dict[str, Any]:
    d, dt, dc = cfg.d_model, cfg.dtype, cfg.cross_d_cond or cfg.d_model
    ks = jax.random.split(key, 4)
    L = n_layers

    def stack(k, din, dout):
        return jax.vmap(lambda kk: dense_init(kk, din, dout, dt))(
            jax.random.split(k, L)
        )

    return {
        "norm": jnp.zeros((L, d), jnp.float32),
        "wq": stack(ks[0], d, cfg.q_dim),
        "wk": stack(ks[1], dc, cfg.kv_dim),
        "wv": stack(ks[2], dc, cfg.kv_dim),
        "wo": stack(ks[3], cfg.q_dim, d),
        "gate": jnp.zeros((L,), jnp.float32),  # zero-init gated residual
    }


def init_params(key, cfg: ModelConfig) -> dict[str, Any]:
    ks = jax.random.split(key, 6)
    params: dict[str, Any] = {
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if cfg.frontend != "embed_stub":
        params["tok_embed"] = truncated_normal(
            ks[0], (cfg.vocab_size, cfg.d_model), 0.02, cfg.dtype
        )
    if not cfg.tie_embeddings or cfg.frontend == "embed_stub":
        params["lm_head"] = truncated_normal(
            ks[1],
            (cfg.n_codebooks, cfg.d_model, cfg.vocab_size)
            if cfg.n_codebooks > 1
            else (cfg.d_model, cfg.vocab_size),
            0.02,
            cfg.dtype,
        )
    if cfg.block == "rwkv6":
        params["layers"] = rwkv_mod.init_rwkv_params(ks[2], cfg, cfg.n_layers)
        return params
    params["layers"] = _attn_layer_params(ks[2], cfg, cfg.n_layers)
    if cfg.block == "hymba":
        params["ssm"] = ssm_mod.init_ssm_params(ks[3], cfg, cfg.n_layers)
        params["branch_norm"] = jnp.zeros((cfg.n_layers, 2, cfg.d_model), jnp.float32)
    if cfg.cross_attn_every > 0 or cfg.cross_kv_len > 0:
        # grouped (VLM, every k layers) or per-layer (MusicGen) conditioning
        n_cross = cfg.num_cross_layers if cfg.cross_attn_every > 0 else cfg.n_layers
        params["cross_layers"] = _cross_layer_params(ks[4], cfg, n_cross)
    return params


# --------------------------------------------------------------------------
# Blocks (single layer, given sliced params)
# --------------------------------------------------------------------------
def _project_qkv(x, pl, cfg: ModelConfig, positions):
    b, s, _ = x.shape
    h = rms_norm(x, pl["attn_norm"], cfg.norm_eps)
    q = matmul(h, pl["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = matmul(h, pl["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = matmul(h, pl["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = head_rms_norm(q, pl["q_norm"], cfg.norm_eps)
        k = head_rms_norm(k, pl["k_norm"], cfg.norm_eps)
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _ffn(x, pl, cfg: ModelConfig, mesh):
    h = rms_norm(x, pl["mlp_norm"], cfg.norm_eps)
    if cfg.is_moe:
        out, aux = moe_block(h, pl["moe"], cfg, mesh)
        return out, aux
    return swiglu(h, pl["w_gate"], pl["w_up"], pl["w_down"]), jnp.zeros((), jnp.float32)


def _attn_block_train(x, pl, cfg: ModelConfig, mesh, positions, window: int):
    """One layer, full-sequence (training / prefill). Returns
    (x_out, aux, k, v) — k/v exported for prefill cache capture."""
    x = constrain(x, mesh, ("batch", None, None))
    q, k, v = _project_qkv(x, pl, cfg, positions)
    q = constrain(q, mesh, ("batch", None, "model", None))
    k = constrain(k, mesh, ("batch", None, "model", None))
    v = constrain(v, mesh, ("batch", None, "model", None))
    attn = chunked_causal_attention(
        q, k, v, chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv, window=window
    )
    attn = matmul(attn.reshape(*x.shape[:2], cfg.q_dim), pl["wo"])
    x = constrain(x + attn, mesh, ("batch", None, None))
    ff, aux = _ffn(x, pl, cfg, mesh)
    res_spec = ("batch", None, "model" if cfg.shard_residual else None)
    return constrain(x + ff, mesh, res_spec), aux, k, v


def _cross_block(x, cl, cond_kv, cfg: ModelConfig):
    """Gated cross-attention conditioning block (precomputed cond k/v)."""
    b, s, _ = x.shape
    h = rms_norm(x, cl["norm"], cfg.norm_eps)
    q = matmul(h, cl["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k, v = cond_kv
    out = cross_attention(q, k, v, chunk_q=cfg.attn_chunk_q)
    out = matmul(out.reshape(b, s, cfg.q_dim), cl["wo"])
    gate = jnp.tanh(cl["gate"].astype(jnp.float32)).astype(x.dtype)
    return x + gate * out


def _cond_kv(cond, cl, cfg: ModelConfig):
    b, t, _ = cond.shape
    k = matmul(cond.astype(cfg.dtype), cl["wk"]).reshape(
        b, t, cfg.n_kv_heads, cfg.head_dim
    )
    v = matmul(cond.astype(cfg.dtype), cl["wv"]).reshape(
        b, t, cfg.n_kv_heads, cfg.head_dim
    )
    return k, v


def _hymba_window(cfg: ModelConfig, li: int) -> int:
    """Hymba: every `global_layer_every`-th layer (plus first/last) is
    global full attention; the rest use the sliding window."""
    if cfg.block != "hymba" or cfg.sliding_window <= 0:
        return cfg.sliding_window if cfg.block != "hymba" else 0
    is_global = (
        li == 0
        or li == cfg.n_layers - 1
        or (cfg.global_layer_every > 0 and li % cfg.global_layer_every == 0)
    )
    return 0 if is_global else cfg.sliding_window


# --------------------------------------------------------------------------
# Embedding / heads
# --------------------------------------------------------------------------
def embed_inputs(params, batch: dict, cfg: ModelConfig):
    if cfg.frontend == "embed_stub":
        x = batch["embeds"].astype(cfg.dtype)
    else:
        x = params["tok_embed"][batch["tokens"]].astype(cfg.dtype)
    if cfg.pos_embedding == "sinusoidal":
        s = x.shape[1]
        off = batch.get("pos_offset", 0)
        pos = off + jnp.arange(s)
        x = x + sinusoidal_positions(pos, cfg.d_model)[None].astype(cfg.dtype)
    return x


def output_logits(params, x, cfg: ModelConfig, mesh=None):
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.n_codebooks > 1:
        out = jnp.einsum(
            "bsd,cdv->bscv", h, params["lm_head"], preferred_element_type=jnp.float32
        )
        return constrain(out, mesh, ("batch", None, None, "model"))
    if "lm_head" in params:
        out = matmul(h, params["lm_head"]).astype(jnp.float32)
    else:
        out = jnp.einsum(
            "bsd,vd->bsv", h, params["tok_embed"], preferred_element_type=jnp.float32
        )
    return constrain(out, mesh, ("batch", None, "model"))


# --------------------------------------------------------------------------
# Full-sequence forward (training / prefill)
# --------------------------------------------------------------------------
def forward(
    params,
    batch: dict,
    cfg: ModelConfig,
    mesh=None,
    *,
    collect_cache: bool = False,
    pos_offset: int = 0,
):
    """Full-sequence forward.  batch: tokens (B,S) or embeds (B,S,D),
    optional cond (B,T,dc).  Returns (logits, aux_loss, caches|None)."""
    x = embed_inputs(params, batch, cfg)
    b, s, _ = x.shape
    positions = pos_offset + jnp.arange(s)[None, :]

    if cfg.block == "rwkv6":
        return _forward_rwkv(params, x, cfg, mesh, collect_cache)

    cond = batch.get("cond")
    lay = params["layers"]

    if cfg.block == "hymba":
        return _forward_hymba(params, x, cfg, mesh, positions, collect_cache)

    if cfg.cross_attn_every > 0 and cfg.cross_attn_every < cfg.n_layers:
        return _forward_grouped_cross(
            params, x, cond, cfg, mesh, positions, collect_cache
        )

    # Homogeneous stack: one scan over layers (optionally with per-layer
    # cross-attention conditioning, e.g. MusicGen).
    per_layer_cross = cfg.cross_attn_every == 0 and cond is not None
    aux0 = jnp.zeros((), jnp.float32)

    def body(carry, idx):
        x, aux = carry
        pl = jax.tree.map(lambda a: a[idx], lay)
        x, aux_i, k, v = _attn_block_train(
            x, pl, cfg, mesh, positions, window=cfg.sliding_window
        )
        if per_layer_cross:
            cl = jax.tree.map(lambda a: a[idx], params["cross_layers"])
            x = _cross_block(x, cl, _cond_kv(cond, cl, cfg), cfg)
        ys = (k, v) if collect_cache else None
        return (x, aux + aux_i), ys

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), kv = jax.lax.scan(body_fn, (x, aux0), jnp.arange(cfg.n_layers))
    caches = None
    if collect_cache:
        caches = {"k": kv[0], "v": kv[1]}  # (L, B, S, KV, hd)
    return output_logits(params, x, cfg, mesh), aux / cfg.n_layers, caches


def _forward_rwkv(params, x, cfg: ModelConfig, mesh, collect_cache):
    lay = params["layers"]
    b = x.shape[0]
    st0 = rwkv_mod.init_rwkv_state(cfg, b)

    def body(carry, idx):
        x, _ = carry
        x = constrain(x, mesh, ("batch", None, None))
        y, wkv_fin, shift_t = rwkv_mod.time_mix(
            x, lay, idx, cfg,
            rwkv_mod.RWKVState(st0.wkv, st0.shift_t, st0.shift_c), mesh,
        )
        x = x + y
        cm, shift_c = rwkv_mod.channel_mix(
            x, lay, idx, cfg,
            rwkv_mod.RWKVState(st0.wkv, st0.shift_t, st0.shift_c), mesh,
        )
        x = x + cm
        res_spec = ("batch", None, "model" if cfg.shard_residual else None)
        x = constrain(x, mesh, res_spec)
        ys = (wkv_fin, shift_t, shift_c) if collect_cache else None
        return (x, jnp.zeros((), jnp.float32)), ys

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), states = jax.lax.scan(
        body_fn, (x, jnp.zeros((), jnp.float32)), jnp.arange(cfg.n_layers)
    )
    caches = None
    if collect_cache:
        caches = {"wkv": states[0], "shift_t": states[1], "shift_c": states[2]}
    return output_logits(params, x, cfg, mesh), aux, caches


def _hymba_runs(cfg: ModelConfig) -> list[tuple[int, int, int]]:
    """Consecutive layer runs with equal attention window: (start, end, win).
    Hymba's 3 global layers split the 29 SWA layers into long homogeneous
    runs that can be scanned (compile-time hygiene for the 32-layer stack)."""
    runs: list[tuple[int, int, int]] = []
    for li in range(cfg.n_layers):
        w = _hymba_window(cfg, li)
        if runs and runs[-1][2] == w:
            runs[-1] = (runs[-1][0], li + 1, w)
        else:
            runs.append((li, li + 1, w))
    return runs


def _forward_hymba(params, x, cfg: ModelConfig, mesh, positions, collect_cache):
    """Heterogeneous stack as scanned homogeneous runs (global vs SWA)."""
    lay, ssm_p = params["layers"], params["ssm"]
    aux = jnp.zeros((), jnp.float32)
    kv_global, kv_swa, ssm_finals = [], [], []
    res_spec = ("batch", None, "model" if cfg.shard_residual else None)

    def layer(x, pl, spl, bn, win):
        x = constrain(x, mesh, ("batch", None, None))
        q, k, v = _project_qkv(x, pl, cfg, positions)
        q = constrain(q, mesh, ("batch", None, "model", None))
        k = constrain(k, mesh, ("batch", None, "model", None))
        v = constrain(v, mesh, ("batch", None, "model", None))
        attn = chunked_causal_attention(
            q, k, v, chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv, window=win
        )
        attn = matmul(attn.reshape(*x.shape[:2], cfg.q_dim), pl["wo"])
        ssm_out, ssm_fin = ssm_mod.ssm_branch(
            x, spl, cfg, ssm_mod.init_ssm_state(cfg, x.shape[0]), mesh
        )
        x = x + 0.5 * (
            rms_norm(attn, bn[0], cfg.norm_eps) + rms_norm(ssm_out, bn[1], cfg.norm_eps)
        )
        ff, aux_i = _ffn(x, pl, cfg, mesh)
        return constrain(x + ff, mesh, res_spec), aux_i, k, v, ssm_fin.h

    for start, end, win in _hymba_runs(cfg):
        sub_lay = jax.tree.map(lambda a: a[start:end], lay)
        sub_ssm = jax.tree.map(lambda a: a[start:end], ssm_p)
        sub_bn = params["branch_norm"][start:end]
        keep = win if win else None
        if end - start == 1:
            pl = jax.tree.map(lambda a: a[0], sub_lay)
            spl = jax.tree.map(lambda a: a[0], sub_ssm)
            x, aux_i, k, v, hfin = layer(x, pl, spl, sub_bn[0], win)
            aux += aux_i
            if collect_cache:
                kv = (k[:, -win:], v[:, -win:]) if win else (k, v)
                (kv_global if win == 0 else kv_swa).append(kv)
                ssm_finals.append(hfin)
        else:

            def body(carry, xs, win=win):
                x, aux = carry
                pl, spl, bn = xs
                x, aux_i, k, v, hfin = layer(x, pl, spl, bn, win)
                ys = None
                if collect_cache:
                    kv = (k[:, -win:], v[:, -win:]) if win else (k, v)
                    ys = (kv, hfin)
                return (x, aux + aux_i), ys

            body_fn = jax.checkpoint(body) if cfg.remat else body
            (x, aux), ys = jax.lax.scan(body_fn, (x, aux), (sub_lay, sub_ssm, sub_bn))
            if collect_cache:
                kv, hfin = ys
                tgt = kv_global if win == 0 else kv_swa
                for i in range(end - start):
                    tgt.append((kv[0][i], kv[1][i]))
                    ssm_finals.append(hfin[i])

    caches = None
    if collect_cache:
        caches = {
            "k_global": jnp.stack([k for k, _ in kv_global]),
            "v_global": jnp.stack([v for _, v in kv_global]),
            "k_swa": jnp.stack([k for k, _ in kv_swa]),
            "v_swa": jnp.stack([v for _, v in kv_swa]),
            "ssm_h": jnp.stack(ssm_finals),
        }
    return output_logits(params, x, cfg, mesh), aux / cfg.n_layers, caches


def _forward_grouped_cross(params, x, cond, cfg: ModelConfig, mesh, positions, collect_cache):
    """VLM: unrolled cross-attn blocks between scanned self-attn groups."""
    n_groups = cfg.num_cross_layers
    per = cfg.n_layers // n_groups
    lay = params["layers"]
    aux = jnp.zeros((), jnp.float32)
    kv_all = []

    def self_body(carry, pl):
        x, aux = carry
        x, aux_i, k, v = _attn_block_train(
            x, pl, cfg, mesh, positions, window=cfg.sliding_window
        )
        return (x, aux + aux_i), (k, v) if collect_cache else None

    body_fn = jax.checkpoint(self_body) if cfg.remat else self_body
    for gi in range(n_groups):
        cl = jax.tree.map(lambda a: a[gi], params["cross_layers"])
        x = _cross_block(x, cl, _cond_kv(cond, cl, cfg), cfg)
        group = jax.tree.map(
            lambda a: a[gi * per : (gi + 1) * per], lay
        )
        (x, aux), kv = jax.lax.scan(body_fn, (x, aux), group)
        if collect_cache:
            kv_all.append(kv)

    caches = None
    if collect_cache:
        caches = {
            "k": jnp.concatenate([kv[0] for kv in kv_all], axis=0),
            "v": jnp.concatenate([kv[1] for kv in kv_all], axis=0),
        }
    return output_logits(params, x, cfg, mesh), aux / cfg.n_layers, caches


def loss_fn(params, batch: dict, cfg: ModelConfig, mesh=None):
    """Next-token CE (+ router aux); returns (loss, metrics)."""
    logits, aux, _ = forward(params, batch, cfg, mesh)
    if cfg.n_codebooks > 1:
        tgt = batch["targets"]  # (B, S, C)
        mask = batch["mask"][..., None] * jnp.ones(
            (1, 1, cfg.n_codebooks), jnp.float32
        )
        ce = cross_entropy_loss(logits, tgt, mask)
    else:
        ce = cross_entropy_loss(logits, batch["targets"], batch["mask"])
    loss = ce + cfg.router_aux_coef * aux
    return loss, {"loss": loss, "ce": ce, "router_aux": aux}
