"""Unified model configuration covering all assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    block: str = "attn"              # "attn" | "rwkv6" | "hymba"

    # MoE (token-choice top-k; experts EP-sharded over "model")
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # attention details
    qk_norm: bool = False
    rope_theta: float = 10000.0
    pos_embedding: str = "rope"      # "rope" | "sinusoidal" | "none"
    sliding_window: int = 0          # 0 = full attention (hymba SWA uses >0)
    global_layer_every: int = 0      # hymba: every k-th layer is global attn

    # cross-attention conditioning (vlm image tower / musicgen text)
    cross_attn_every: int = 0        # insert a cross-attn block every k layers
    cross_kv_len: int = 0            # stub-frontend context length
    cross_d_cond: int = 0            # conditioning embedding width

    # SSM branch (hymba) / rwkv
    ssm_state: int = 0

    # embeddings / heads
    tie_embeddings: bool = True
    n_codebooks: int = 1             # musicgen: parallel output heads
    frontend: str = "none"           # "none" | "embed_stub" (precomputed frame
                                     # or patch embeddings from input_specs)

    # numerics / runtime
    dtype: Any = jnp.bfloat16
    remat: bool = True
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    norm_eps: float = 1e-5
    opt_state_dtype: Any = jnp.float32

    # beyond-paper perf knobs (see EXPERIMENTS.md Sec. Perf)
    fuse_qkv: bool = False           # single fused QKV projection matmul
    # Megatron-SP-style residual-stream sharding: the scan-saved layer
    # carries keep d_model sharded over "model" (all-gathered at use),
    # cutting saved-activation HBM by the TP degree.  Default on — the
    # before/after is recorded in EXPERIMENTS.md Sec. Perf.
    shard_residual: bool = True

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    @property
    def num_cross_layers(self) -> int:
        if self.cross_attn_every <= 0:
            return 0
        return self.n_layers // self.cross_attn_every

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.head_dim
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qk_norm:
            attn += 2 * hd
        if self.is_moe:
            ffn = self.moe_experts * 3 * d * self.moe_d_ff + d * self.moe_experts
        else:
            ffn = 3 * d * self.d_ff
        if self.block == "rwkv6":
            # time-mix: r,k,v,g,o + decay/bonus + lerp params; channel-mix 2 mats
            attn = 5 * d * d + 2 * d + 6 * d + d * 64
            ffn = d * self.d_ff + self.d_ff * d
        if self.block == "hymba":
            # parallel SSM branch: in-proj (x,z), dt/B/C proj, out-proj
            n = self.ssm_state
            attn += 2 * d * d + d * (2 * n + d // hd) + d * d
        per_layer = attn + ffn + 2 * d
        total = self.n_layers * per_layer + self.vocab_size * d + d
        if not self.tie_embeddings:
            total += self.n_codebooks * d * self.vocab_size
        if self.cross_attn_every:
            cross = (
                d * self.q_dim
                + 2 * self.cross_d_cond * self.kv_dim
                + self.q_dim * d
                + 2 * d
            )
            total += self.num_cross_layers * cross
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        dense_ffn = self.moe_experts * 3 * d * self.moe_d_ff
        active_ffn = self.moe_top_k * 3 * d * self.moe_d_ff
        return int(self.param_count() - self.n_layers * (dense_ffn - active_ffn))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
