from .config import ModelConfig  # noqa: F401
from .transformer import forward, init_params, loss_fn  # noqa: F401
from .decoding import (  # noqa: F401
    decode_step,
    init_cache,
    prefill,
    prefill_chunk,
    write_cache_slot,
)
