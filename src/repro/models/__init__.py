from .config import ModelConfig  # noqa: F401
from .transformer import forward, init_params, loss_fn  # noqa: F401
from .decoding import decode_step, init_cache, prefill, write_cache_slot  # noqa: F401
