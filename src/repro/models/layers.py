"""Shared neural-net primitives (pure functions over param dicts).

All parameters are plain jnp arrays in nested dicts; initializers take an
explicit PRNG key.  Compute follows the mixed-precision policy: params
are stored in cfg.dtype (bf16), matmuls accumulate in f32
(preferred_element_type), norms/softmax run in f32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.cim.mvm import cim_matmul, current_token_ids
from repro.cim.tile import CIMWeight


def truncated_normal(key, shape, std, dtype):
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def dense_init(key, d_in, d_out, dtype, std=None):
    std = std if std is not None else (1.0 / np.sqrt(d_in))
    return truncated_normal(key, (d_in, d_out), std, dtype)


def matmul(x, w):
    """bf16 x bf16 -> f32 accumulate -> bf16 (TPU MXU policy).

    A `CIMWeight` leaf (analog serving, `repro.cim`) routes through the
    in-array forward instead: the weight never exists digitally — the
    programmed conductance tiles compute the product, noise and ADC
    included.  Same contract (f32 accumulate, cast back to x.dtype).
    The ambient token-id stream (`cim.token_stream_ids` — request ids
    in the serving scheduler) keys the per-row noise sub-streams.
    """
    if isinstance(w, CIMWeight):
        return cim_matmul(x, w, token_ids=current_token_ids())
    y = jnp.einsum("...k,kn->...n", x, w, preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def rms_norm(x, scale, eps):
    """RMS norm: statistics in f32, application in the storage dtype.

    Applying the normalizer in x.dtype (not upcasting x wholesale) keeps
    every full-size intermediate in bf16 — any elementwise convert(x)
    makes XLA hoist the convert out of the backward layer-loop and
    materialize an f32 copy of the entire stacked residual carry
    (observed +11 GiB/dev in the train_4k dry-run).  The square runs in
    x.dtype; only the reduction accumulates in f32 (`dtype=f32`), which
    keeps the statistics accurate without a full-size f32 tensor.
    """
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + scale).astype(x.dtype)


def head_rms_norm(x, scale, eps):
    """Per-head RMS norm over head_dim (Qwen3 qk-norm)."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + scale).astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = matmul(x, w_gate)
    u = matmul(x, w_up)
    return matmul(jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u, w_down)


# ---------------------------------------------------------------- positions
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions: jax.Array, d_model: int) -> jax.Array:
    """(..., S) -> (..., S, D) fixed sinusoidal embeddings (MusicGen-style)."""
    half = d_model // 2
    freqs = jnp.exp(
        -jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1)
    )
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def cross_entropy_loss(logits, targets, mask):
    """Mean next-token CE over masked positions; logits (B,S,V) f32-safe."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
