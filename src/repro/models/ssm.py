"""Selective state-space (Mamba-style S6) branch for Hymba layers.

Diagonal SSM with input-dependent (Delta, B, C):

    h_t = exp(Delta_t * A) * h_{t-1} + Delta_t * B_t * x_t
    y_t = C_t . h_t + D * x_t,   gated by silu(z)

Training/prefill runs a chunked scan: `lax.scan` over chunks of
SSM_CHUNK tokens with `associative_scan` inside the chunk, bounding the
(B, c, d_inner, n) working set so the d_inner axis can stay sharded over
"model" with a small per-chip footprint (DESIGN.md Sec. 4).  Decode is
the exact single-step recurrence on the carried (B, d_inner, n) state.

Simplification (documented): Mamba's depthwise conv1d front-end is
omitted (Hymba's hybrid-head ablation attributes the win to the SSM +
attention fusion, not the conv).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, matmul

SSM_CHUNK = 128


class SSMState(NamedTuple):
    h: jax.Array  # (B, d_inner, n)


def init_ssm_params(key, cfg: ModelConfig, n_layers: int) -> dict[str, Any]:
    d = cfg.d_model
    n = cfg.ssm_state
    d_in = d  # inner width = model width (parallel-branch design)
    ks = jax.random.split(key, 6)
    L = n_layers

    def stack(k, din, dout):
        return jax.vmap(lambda kk: dense_init(kk, din, dout, cfg.dtype))(
            jax.random.split(k, L)
        )

    a_init = jnp.log(
        jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None], (d_in, 1))
    )
    return {
        "in_x": stack(ks[0], d, d_in),
        "in_z": stack(ks[1], d, d_in),
        "w_bc": stack(ks[2], d, 2 * n),
        "w_dt": stack(ks[3], d, d_in),
        "dt_bias": jnp.zeros((L, d_in), jnp.float32),
        "a_log": jnp.tile(a_init[None], (L, 1, 1)),
        "d_skip": jnp.ones((L, d_in), jnp.float32),
        "out": stack(ks[4], d_in, d),
    }


def ssm_branch(
    x: jax.Array, pl: dict, cfg: ModelConfig, state: SSMState, mesh=None
) -> tuple[jax.Array, SSMState]:
    """One layer's SSM branch with *pre-sliced* params (no layer axis).
    x: (B, S, D) -> (y, new_state).  Handles S == 1 (decode) exactly."""
    from .act_sharding import constrain

    b, s, d = x.shape
    n = cfg.ssm_state
    xi = constrain(matmul(x, pl["in_x"]), mesh, ("batch", None, "model"))
    z = constrain(matmul(x, pl["in_z"]), mesh, ("batch", None, "model"))
    bc = matmul(x, pl["w_bc"]).astype(jnp.float32)      # (B,S,2n)
    b_t, c_t = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(
        matmul(x, pl["w_dt"]).astype(jnp.float32) + pl["dt_bias"][None, None]
    )                                                    # (B,S,d_in)
    a = -jnp.exp(pl["a_log"].astype(jnp.float32))        # (d_in, n)

    xf = xi.astype(jnp.float32)

    if s == 1:
        decay0 = jnp.exp(dt[:, 0, :, None] * a[None])      # (B,d_in,n)
        drive0 = (dt * xf)[:, 0, :, None] * b_t[:, 0, None, :]
        h = decay0 * state.h + drive0
        y = jnp.einsum("bdn,bn->bd", h, c_t[:, 0])[:, None]
        h_fin = h
    else:
        # The (B, S, d_in, n) decay/drive tensors are never materialized
        # full-sequence (6.7 GiB/dev/layer at hymba train_4k): the outer
        # products are formed inside each SSM_CHUNK-token chunk, and the
        # chunk body is checkpointed so backward recomputes them.
        pad = (-s) % SSM_CHUNK
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0))) if pad else dt
        xf_p = jnp.pad(xf, ((0, 0), (0, pad), (0, 0))) if pad else xf
        bt_p = jnp.pad(b_t, ((0, 0), (0, pad), (0, 0))) if pad else b_t
        ct_p = jnp.pad(c_t, ((0, 0), (0, pad), (0, 0))) if pad else c_t
        nc = dt_p.shape[1] // SSM_CHUNK

        def chunks(t):
            return t.reshape(b, nc, SSM_CHUNK, *t.shape[2:]).swapaxes(0, 1)

        @jax.checkpoint
        def per_chunk(h0, xs):
            dtc, xfc, btc, ctc = xs                      # (B, c, ...)
            dec = jnp.exp(dtc[..., None] * a[None, None])
            drv = (dtc * xfc)[..., None] * btc[:, :, None, :]

            def op(e1, e2):
                a1, b1 = e1
                a2, b2 = e2
                return a1 * a2, a2 * b1 + b2

            acc_a, acc_b = jax.lax.associative_scan(op, (dec, drv), axis=1)
            h_all = acc_a * h0[:, None] + acc_b          # (B, c, d_in, n)
            yc = jnp.einsum("bcdn,bcn->bcd", h_all, ctc)
            return h_all[:, -1], yc

        h_fin, ys = jax.lax.scan(
            per_chunk, state.h, (chunks(dt_p), chunks(xf_p), chunks(bt_p), chunks(ct_p))
        )
        y = ys.swapaxes(0, 1).reshape(b, -1, ys.shape[-1])[:, :s]

    y = y + pl["d_skip"][None, None] * xf
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = matmul(y.astype(x.dtype), pl["out"])
    return out, SSMState(h=h_fin)


def init_ssm_state(cfg: ModelConfig, batch: int) -> SSMState:
    return SSMState(h=jnp.zeros((batch, cfg.d_model, cfg.ssm_state), jnp.float32))
