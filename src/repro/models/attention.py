"""Attention: chunked (flash-style) causal self-attention, GQA, sliding
window, cross-attention, and single-token decode over KV caches.

The training/prefill path never materializes the (S, S) score matrix:
an outer `lax.scan` walks query chunks and an inner `lax.fori_loop`
walks only the key/value chunks inside the causal (and sliding-window)
footprint, carrying the online-softmax state (m, l, acc).  This is the
flash dataflow expressed in pure JAX — it lowers on any backend, keeps
peak memory at (chunk_q x chunk_kv), and does no masked-out chunk work
(the fori bounds are exact, not masked).

GQA is computed in grouped form: q is reshaped to (KV, G) head groups so
k/v are never repeated in memory.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _group_q(q: jax.Array, n_kv: int) -> jax.Array:
    """(B, S, H, hd) -> (B, S, KV, G, hd)."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, hd)


def chunked_causal_attention(
    q: jax.Array,          # (B, S, H, hd)
    k: jax.Array,          # (B, S, KV, hd)
    v: jax.Array,          # (B, S, KV, hd)
    *,
    chunk_q: int,
    chunk_kv: int,
    window: int = 0,       # 0 = full causal; >0 = sliding window
    pos_offset: int = 0,   # absolute position of q[0] (prefill continuation)
) -> jax.Array:
    b, s, h, hd = q.shape
    kv_heads = k.shape[2]
    cq = min(chunk_q, s)
    ck = min(chunk_kv, k.shape[1])
    # Pad to chunk multiples: padded kv sits at positions beyond every real
    # query, so the causal mask already excludes it; padded q rows are
    # sliced off at the end.
    pad_q = (-s) % cq
    pad_k = (-k.shape[1]) % ck
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    s_orig, s = s, s + pad_q
    nq, nk = s // cq, k.shape[1] // ck
    scale = hd**-0.5
    qg = _group_q(q, kv_heads)  # (B, S, KV, G, hd)
    g = qg.shape[3]

    q_chunks = qg.reshape(b, nq, cq, kv_heads, g, hd).transpose(1, 0, 2, 3, 4, 5)

    # Outer loop over q chunks is a Python loop: the causal / windowed kv
    # footprint [j_start, j_end) is then STATIC per chunk, so the inner
    # lax.scan has a fixed trip count — no masked-out chunk work AND
    # reverse-mode differentiability (dynamic-bound fori_loop has no VJP).
    #
    # Memory discipline under autodiff: the (cq x ck) probability tiles
    # must NEVER be saved for backward (that reconstitutes the O(S^2)
    # matrix).  Both the per-q-chunk body and the per-kv-step body are
    # jax.checkpoint'ed, so backward recomputes one probability tile at a
    # time — peak live set is O(cq*ck) + the small (m, l, acc) carries.

    def make_kv_step(qpos):
        def kv_step(st, qi, kj, vj, j):
            m, l, acc = st
            s_ij = (
                jnp.einsum(
                    "bqkgd,bckd->bqkgc", qi, kj, preferred_element_type=jnp.float32
                )
                * scale
            )  # (B, cq, KV, G, ck)
            kpos = j * ck + jnp.arange(ck)
            mask = qpos[:, None] >= kpos[None, :]
            if window > 0:
                mask &= qpos[:, None] - kpos[None, :] < window
            s_ij = jnp.where(mask[None, :, None, None, :], s_ij, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s_ij, axis=-1))
            p = jnp.exp(s_ij - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqkgc,bckd->bqkgd",
                p.astype(v.dtype),
                vj,
                preferred_element_type=jnp.float32,
            )
            return m_new, l_new, acc_new

        return kv_step

    outs = []
    for i in range(nq):
        qpos = pos_offset + i * cq + jnp.arange(cq)  # (cq,)
        kv_step = jax.checkpoint(make_kv_step(qpos))
        if window > 0:
            j_start = max(0, (pos_offset + i * cq - window) // ck)
        else:
            j_start = 0
        j_end = min(nk, (pos_offset + (i + 1) * cq - 1) // ck + 1)
        n_j = j_end - j_start

        def one_chunk(qi, k_sl, v_sl):
            m0 = jnp.full((b, cq, kv_heads, g), NEG_INF, jnp.float32)
            l0 = jnp.zeros((b, cq, kv_heads, g), jnp.float32)
            a0 = jnp.zeros((b, cq, kv_heads, g, hd), jnp.float32)

            def body(st, xs):
                kj, vj, j = xs
                return kv_step(st, qi, kj, vj, j), None

            (m, l, acc), _ = jax.lax.scan(
                body,
                (m0, l0, a0),
                (
                    k_sl.swapaxes(0, 1),
                    v_sl.swapaxes(0, 1),
                    j_start + jnp.arange(n_j, dtype=jnp.int32),
                ),
            )
            return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

        qi = q_chunks[i]
        k_sl = k[:, j_start * ck : j_end * ck].reshape(b, n_j, ck, kv_heads, hd)
        v_sl = v[:, j_start * ck : j_end * ck].reshape(b, n_j, ck, kv_heads, hd)
        outs.append(jax.checkpoint(one_chunk)(qi, k_sl, v_sl))

    # nq x (B, cq, KV, G, hd) -> (B, S, H, hd)
    outs = jnp.stack(outs, axis=1).reshape(b, s, kv_heads, g, hd)
    return outs.reshape(b, s, h, hd)[:, :s_orig]


def decode_attention(
    q: jax.Array,          # (B, 1, H, hd)
    k_cache: jax.Array,    # (B, Smax, KV, hd)
    v_cache: jax.Array,    # (B, Smax, KV, hd)
    pos: jax.Array,        # (B,) index of the current token (its kv is written)
    *,
    window: int = 0,
) -> jax.Array:
    """Single-token attention over a (possibly windowed) KV cache."""
    b, smax, kv_heads, hd = k_cache.shape
    h = q.shape[2]
    g = h // kv_heads
    qg = q.reshape(b, kv_heads, g, hd)
    s = (
        jnp.einsum("bkgd,bskd->bkgs", qg, k_cache, preferred_element_type=jnp.float32)
        * hd**-0.5
    )
    idx = jnp.arange(smax)[None, :]  # (1, Smax)
    valid = idx <= pos[:, None]
    if window > 0:
        valid &= idx > pos[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache, preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def cross_attention(
    q: jax.Array,          # (B, S, H, hd)
    k: jax.Array,          # (B, T, KV, hd) conditioning keys
    v: jax.Array,          # (B, T, KV, hd)
    *,
    chunk_q: int,
) -> jax.Array:
    """Unmasked cross-attention, chunked over the query axis only (the
    conditioning context T — image patches / text tokens — is short)."""
    b, s, h, hd = q.shape
    kv_heads = k.shape[2]
    g = h // kv_heads
    cq = min(chunk_q, s)
    pad_q = (-s) % cq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    s_orig, s = s, s + pad_q
    nq = s // cq
    qg = _group_q(q, kv_heads).reshape(b, nq, cq, kv_heads, g, hd).transpose(
        1, 0, 2, 3, 4, 5
    )

    def per_chunk(carry, qi):
        sc = (
            jnp.einsum("bqkgd,btkd->bqkgt", qi, k, preferred_element_type=jnp.float32)
            * hd**-0.5
        )
        p = jax.nn.softmax(sc, axis=-1).astype(v.dtype)
        out = jnp.einsum("bqkgt,btkd->bqkgd", p, v, preferred_element_type=jnp.float32)
        return carry, out.astype(q.dtype)

    _, outs = jax.lax.scan(per_chunk, None, qg)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, hd)[:, :s_orig]
