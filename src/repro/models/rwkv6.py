"""RWKV6 "Finch" block: time-mix with data-dependent decay + channel-mix.

Faithful-in-structure implementation of the arch-defining pieces:
  * token-shift lerp between x_t and x_{t-1} feeding r/k/v/w/g projections;
  * **data-dependent decay** w_t = exp(-exp(w0 + tanh(x W_a) W_b)) — the
    headline RWKV6 feature;
  * per-head wkv state S in R^{hd x hd}: y_t = r_t (S_{t-1} + u * k_t^T v_t),
    S_t = diag(w_t) S_{t-1} + k_t^T v_t;
  * squared-ReLU channel mix.

Training uses the chunked linear-attention form (GLA-style): within a
chunk the pairwise decay products factor as exp(L_t - L_s) = exp(L_t -
L_c) * exp(L_c - L_s) (L = cumulative log-decay), giving two matmuls per
chunk plus a cross-chunk recurrent state carried by `lax.scan`.  The
per-step log-decay is clamped to >= LOG_W_MIN so the intra-chunk
exponentials stay inside f32 range at CHUNK=16 (documented deviation:
bounds the decay half-life below at ~0.3 tokens).

Simplification (documented in DESIGN.md): the token-shift lerp factors
are learned per-channel constants (RWKV6's additional low-rank
data-dependent lerp is omitted); decay keeps its full LoRA form.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, matmul, rms_norm

CHUNK = 16
LOG_W_MIN = -3.5
DECAY_LORA = 64


class RWKVState(NamedTuple):
    wkv: jax.Array     # (B, H, hd, hd)
    shift_t: jax.Array  # (B, D) last token's x (time-mix shift)
    shift_c: jax.Array  # (B, D) last token's x (channel-mix shift)


def init_rwkv_params(key, cfg: ModelConfig, n_layers: int) -> dict[str, Any]:
    d, dt = cfg.d_model, cfg.dtype
    ff = cfg.d_ff
    ks = jax.random.split(key, 12)
    L = n_layers

    def stack(k, din, dout, std=None):
        return jax.vmap(lambda kk: dense_init(kk, din, dout, dt, std))(
            jax.random.split(k, L)
        )

    hd = cfg.head_dim
    h = d // hd
    return {
        "mix_r": jnp.full((L, d), 0.5, dt),
        "mix_k": jnp.full((L, d), 0.5, dt),
        "mix_v": jnp.full((L, d), 0.5, dt),
        "mix_w": jnp.full((L, d), 0.5, dt),
        "mix_g": jnp.full((L, d), 0.5, dt),
        "mix_c": jnp.full((L, d), 0.5, dt),
        "w_r": stack(ks[0], d, d),
        "w_k": stack(ks[1], d, d),
        "w_v": stack(ks[2], d, d),
        "w_g": stack(ks[3], d, d),
        "w_o": stack(ks[4], d, d),
        "decay_base": jnp.tile(
            jnp.linspace(-6.0, -1.0, d, dtype=jnp.float32)[None], (L, 1)
        ),
        "decay_a": stack(ks[5], d, DECAY_LORA, std=0.01),
        "decay_b": stack(ks[6], DECAY_LORA, d, std=0.01),
        "bonus_u": jnp.zeros((L, h, hd), jnp.float32),
        "ln_x": jnp.zeros((L, d), jnp.float32),  # per-head group-norm scale
        "cm_k": stack(ks[7], d, ff),
        "cm_v": stack(ks[8], ff, d),
        "cm_r": stack(ks[9], d, d),
    }


def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """x_{t-1} sequence (first slot = prev carry); x: (B, S, D)."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _decay_logw(x_mix, p, li):
    """Data-dependent per-channel log decay, clamped for stability."""
    lora = jnp.einsum(
        "bsd,dr->bsr", jnp.tanh(matmul(x_mix, p["decay_a"][li])).astype(x_mix.dtype),
        p["decay_b"][li].astype(x_mix.dtype) * 1.0,
        preferred_element_type=jnp.float32,
    )
    raw = p["decay_base"][li][None, None].astype(jnp.float32) + lora
    return jnp.clip(-jnp.exp(raw), LOG_W_MIN, -1e-4)  # log w_t


def time_mix(
    x: jax.Array, p: dict, li, cfg: ModelConfig, state: RWKVState, mesh=None
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (y, new_wkv, new_shift). x: (B, S, D)."""
    from .act_sharding import constrain

    b, s, d = x.shape
    hd = cfg.head_dim
    h = d // hd
    xprev = _token_shift(x, state.shift_t)

    def mixed(name):
        mu = p[f"mix_{name}"][li][None, None].astype(x.dtype)
        return x * mu + xprev * (1.0 - mu)

    r = matmul(mixed("r"), p["w_r"][li]).reshape(b, s, h, hd)
    k = matmul(mixed("k"), p["w_k"][li]).reshape(b, s, h, hd)
    v = matmul(mixed("v"), p["w_v"][li]).reshape(b, s, h, hd)
    r = constrain(r, mesh, ("batch", None, "model", None))
    k = constrain(k, mesh, ("batch", None, "model", None))
    v = constrain(v, mesh, ("batch", None, "model", None))
    g = jax.nn.silu(matmul(mixed("g"), p["w_g"][li]).astype(jnp.float32))
    logw = _decay_logw(mixed("w"), p, li).reshape(b, s, h, hd)  # f32
    u = p["bonus_u"][li].astype(jnp.float32)  # (h, hd)

    # ---- chunked wkv ----
    pad = (-s) % CHUNK
    if pad:
        r, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (r, k, v))
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // CHUNK

    def to_chunks(t):
        return t.reshape(b, nc, CHUNK, h, hd).transpose(1, 0, 3, 2, 4)  # (nc,B,H,c,hd)

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, logw))

    def per_chunk(S_carry, xs):
        rc_, kc_, vc_, lw_ = xs  # (B, H, c, hd)
        rf = rc_.astype(jnp.float32)
        kf = kc_.astype(jnp.float32)
        vf = vc_.astype(jnp.float32)
        L = jnp.cumsum(lw_, axis=2)                 # (B,H,c,hd) inclusive
        Lc = L[:, :, -1:, :]                        # chunk-total log decay
        Lm1 = jnp.concatenate(
            [jnp.zeros_like(L[:, :, :1]), L[:, :, :-1]], axis=2
        )                                            # L_{t-1}
        q_t = rf * jnp.exp(Lm1 - Lc)                # bounded by exp(|Lc|)
        k_s = kf * jnp.exp(Lc - L)                  # <= 1
        att = jnp.einsum("bhtd,bhsd->bhts", q_t, k_s)
        tri = jnp.tril(jnp.ones((CHUNK, CHUNK), bool), k=-1)
        att = jnp.where(tri[None, None], att, 0.0)
        # bonus diagonal
        diag = jnp.einsum("bhtd,bhtd->bht", rf, u[None, :, None] * kf)
        y = jnp.einsum("bhts,bhsd->bhtd", att, vf)
        y += diag[..., None] * vf
        # cross-chunk state read: y_t += (r_t * exp(L_{t-1})) @ S
        y += jnp.einsum("bhtd,bhde->bhte", rf * jnp.exp(Lm1), S_carry)
        # state update: S' = diag(exp(Lc)) S + sum_s (k_s*exp(Lc-L_s)) (x) v_s
        S_new = jnp.exp(Lc.squeeze(2))[..., None] * S_carry + jnp.einsum(
            "bhsd,bhse->bhde", k_s, vf
        )
        return S_new, y

    S0 = state.wkv.astype(jnp.float32)
    # Nested scan + inner remat: the flat chunk scan would save nc
    # (B,H,hd,hd) carries for backward (34 GiB/dev at S=4096 in the
    # dry-run); grouping GROUP chunks per outer step saves only nc/GROUP
    # boundary states and recomputes the inner chain one group at a time.
    nc_total = rc.shape[0]
    group = min(16, nc_total)
    pad_g = (-nc_total) % group
    if pad_g:
        # pad with identity chunks (zero k/v/log-decay)
        rc, kc, vc = (
            jnp.concatenate([t, jnp.zeros((pad_g, *t.shape[1:]), t.dtype)])
            for t in (rc, kc, vc)
        )
        lwc = jnp.concatenate([lwc, jnp.zeros((pad_g, *lwc.shape[1:]), lwc.dtype)])
    n_outer = rc.shape[0] // group

    def regroup(t):
        return t.reshape(n_outer, group, *t.shape[1:])

    @jax.checkpoint
    def outer_body(S_carry, xs_group):
        return jax.lax.scan(per_chunk, S_carry, xs_group)

    S_fin, ys = jax.lax.scan(
        outer_body, S0, tuple(map(regroup, (rc, kc, vc, lwc)))
    )
    ys = ys.reshape(n_outer * group, *ys.shape[2:])[: nc_total]
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, sp, h, hd)[:, :s]

    # per-head group norm + gate + output proj
    yf = y.reshape(b, s, h, hd)
    mean = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.var(yf, axis=-1, keepdims=True)
    yn = (yf - mean) * jax.lax.rsqrt(var + 64e-5)
    yn = yn.reshape(b, s, d) * (1.0 + p["ln_x"][li][None, None])
    out = matmul((yn * g).astype(x.dtype), p["w_o"][li])
    return out, S_fin, x[:, -1]


def channel_mix(
    x: jax.Array, p: dict, li, cfg: ModelConfig, state: RWKVState, mesh=None
) -> tuple[jax.Array, jax.Array]:
    from .act_sharding import constrain

    xprev = _token_shift(x, state.shift_c)
    mu = p["mix_c"][li][None, None].astype(x.dtype)
    xk = x * mu + xprev * (1.0 - mu)
    k = matmul(xk, p["cm_k"][li])
    k = constrain(k, mesh, ("batch", None, "model"))
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    r = jax.nn.sigmoid(matmul(xk, p["cm_r"][li]).astype(jnp.float32))
    return (r * matmul(k, p["cm_v"][li]).astype(jnp.float32)).astype(x.dtype), x[:, -1]


# Decode: time_mix/channel_mix handle S=1 directly (the chunk is padded
# with zero k/v and zero log-decay, which leaves the state update exact),
# so the same code path serves training, prefill and decode.


def init_rwkv_state(cfg: ModelConfig, batch: int) -> RWKVState:
    h = cfg.d_model // cfg.head_dim
    return RWKVState(
        wkv=jnp.zeros((batch, h, cfg.head_dim, cfg.head_dim), jnp.float32),
        shift_t=jnp.zeros((batch, cfg.d_model), cfg.dtype),
        shift_c=jnp.zeros((batch, cfg.d_model), cfg.dtype),
    )
