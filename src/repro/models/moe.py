"""Token-choice top-k Mixture-of-Experts with expert parallelism.

Sharding strategy (DESIGN.md Sec. 4): activations are replicated along
the "model" mesh axis and sharded along ("pod","data"); expert weight
stacks are sharded over "model" (EP) on the expert axis and over "data"
(FSDP) on d_model.  The layer runs inside `shard_map` so routing stays
*local* to each device's token shard (no global argsort / no cross-shard
prefix sums — the classic pjit-MoE pitfall), each device computes only
its own experts over a capacity-bounded gather buffer, and a single
psum over "model" combines the partial expert outputs (the same
collective TP already pays for its MLP output reduction).

Dispatch is the sort-free rank-via-cumsum construction:
  rank_in_expert(t, e) = cumsum of assignment one-hots over local tokens
Tokens with rank >= capacity are dropped (pass through the residual),
matching capacity-factor semantics of production MoE stacks.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .config import ModelConfig
from .layers import dense_init, matmul


def init_moe_params(key, cfg: ModelConfig, n_layers: int) -> dict[str, Any]:
    d, e, f = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": jax.vmap(lambda k: dense_init(k, d, e, jnp.float32))(
            jax.random.split(ks[0], n_layers)
        ),
        "w_gate": jax.vmap(lambda k: jax.vmap(lambda kk: dense_init(kk, d, f, cfg.dtype))(
            jax.random.split(k, e)
        ))(jax.random.split(ks[1], n_layers)),
        "w_up": jax.vmap(lambda k: jax.vmap(lambda kk: dense_init(kk, d, f, cfg.dtype))(
            jax.random.split(k, e)
        ))(jax.random.split(ks[2], n_layers)),
        "w_down": jax.vmap(lambda k: jax.vmap(lambda kk: dense_init(kk, f, d, cfg.dtype))(
            jax.random.split(k, e)
        ))(jax.random.split(ks[3], n_layers)),
    }


def _local_capacity(t_local: int, cfg: ModelConfig) -> int:
    cap = int(t_local * cfg.moe_top_k * cfg.capacity_factor / cfg.moe_experts)
    return max(cap, 4)


def _moe_local(
    x,            # (T_local, D) local token shard (replicated over "model")
    router_w,     # (D, E) replicated
    w_gate,       # (E_local, D, F) this device's experts
    w_up,
    w_down,
    *,
    cfg: ModelConfig,
    axis: str,
):
    t_local, d = x.shape
    e = cfg.moe_experts
    e_local = w_gate.shape[0]
    k = cfg.moe_top_k
    cap = _local_capacity(t_local, cfg)
    my_first = jax.lax.axis_index(axis) * e_local if axis else 0

    logits = jnp.einsum(
        "td,de->te", x.astype(jnp.float32), router_w.astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, sel = jax.lax.top_k(probs, k)                   # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # rank of each (token, choice) within its expert, over local tokens
    onehot = jax.nn.one_hot(sel, e, dtype=jnp.int32)           # (T, k, E)
    flat = onehot.reshape(t_local * k, e)
    ranks = jnp.cumsum(flat, axis=0) - flat                    # exclusive
    rank_te = jnp.sum(ranks * flat, axis=-1).reshape(t_local, k)
    keep = rank_te < cap                                        # capacity drop

    # build this device's (E_local, cap) token-index buffer via scatter
    sel_local = sel - my_first                                  # (T, k)
    mine = (sel_local >= 0) & (sel_local < e_local) & keep
    slot = jnp.where(mine, sel_local * cap + rank_te, e_local * cap)
    buf_tok = jnp.full((e_local * cap + 1,), t_local, jnp.int32)
    buf_gate = jnp.zeros((e_local * cap + 1,), jnp.float32)
    flat_slot = slot.reshape(-1)
    tok_ids = jnp.broadcast_to(
        jnp.arange(t_local, dtype=jnp.int32)[:, None], (t_local, k)
    ).reshape(-1)
    buf_tok = buf_tok.at[flat_slot].set(tok_ids, mode="drop")
    buf_gate = buf_gate.at[flat_slot].set(gate_vals.reshape(-1), mode="drop")
    buf_tok = buf_tok[:-1].reshape(e_local, cap)
    buf_gate = buf_gate[:-1].reshape(e_local, cap)

    # gather tokens (pad row = zeros), grouped expert FFN, combine-scatter
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xe = x_pad[buf_tok]                                         # (E_l, cap, D)
    g = jnp.einsum("ecd,edf->ecf", xe, w_gate, preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", xe, w_up, preferred_element_type=jnp.float32)
    hmid = (jax.nn.silu(g) * u).astype(x.dtype)
    ye = jnp.einsum("ecf,efd->ecd", hmid, w_down, preferred_element_type=jnp.float32)
    ye = ye * buf_gate[..., None]

    out = jnp.zeros((t_local + 1, d), jnp.float32)
    out = out.at[buf_tok.reshape(-1)].add(ye.reshape(-1, d), mode="drop")
    out = out[:-1]
    # combine partial expert outputs across the EP axis
    if axis:
        out = jax.lax.psum(out, axis)

    # load-balance auxiliary loss (Switch-style), local fraction statistics
    me = jnp.mean(probs, axis=0)                                # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(sel, e, dtype=jnp.float32), axis=1), axis=0
    )
    aux = e * jnp.sum(me * ce)
    if axis:
        aux = jax.lax.pmean(aux, axis)
    return out.astype(x.dtype), aux


def moe_block(
    x: jax.Array,            # (B, S, D) global view
    layer_params: dict,      # single layer's router/w_gate/w_up/w_down
    cfg: ModelConfig,
    mesh: Mesh,
) -> tuple[jax.Array, jax.Array]:
    """Global-view MoE FFN; returns (output, aux_loss)."""
    b, s, d = x.shape
    if mesh is None:
        # Single-device fallback (tests / smoke): full expert set, no EP.
        out, aux = _moe_local(
            x.reshape(-1, d),
            layer_params["router"].astype(jnp.float32),
            layer_params["w_gate"],
            layer_params["w_up"],
            layer_params["w_down"],
            cfg=cfg,
            axis=None,
        )
        return out.reshape(x.shape), aux
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    ep_axis = "model"

    def body(xl, rw, wg, wu, wd):
        tl = xl.reshape(-1, d)
        out, aux = _moe_local(tl, rw, wg, wu, wd, cfg=cfg, axis=ep_axis)
        return out.reshape(xl.shape), aux[None]

    out, aux = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(batch_axes, None, None),
            P(None, None),                # router replicated
            P(ep_axis, None, None),       # experts EP-sharded
            P(ep_axis, None, None),
            P(ep_axis, None, None),
        ),
        out_specs=(P(batch_axes, None, None), P(batch_axes)),
        check_vma=False,
    )(
        x,
        layer_params["router"].astype(jnp.float32),
        layer_params["w_gate"],
        layer_params["w_up"],
        layer_params["w_down"],
    )
    return out, jnp.mean(aux)
