"""KV-cache construction, prefill and single-token decode for all archs.

Cache layouts (all pytrees of arrays — checkpointable / shardable):

  attn models : k/v stacked (L, B, Smax, KV, hd) + pos (B,)
  + cross-attn: cross_k/cross_v (L_cross, B, T, KV, hd) precomputed once
  rwkv6       : wkv (L, B, H, hd, hd), shift_t/shift_c (L, B, D)
  hymba       : k/v_global (Lg, B, Smax, KV, hd) — full-length cache for
                the few global layers; k/v_swa (Ls, B, W, KV, hd) — ring
                buffers for sliding-window layers (RoPE is applied at
                write time with absolute positions, so ring order is
                irrelevant to attention); ssm_h (L, B, d, n)

`long_500k` viability comes from exactly this split: at 524288 context,
rwkv6 carries O(1) state and hymba carries 3 full-length caches + 29
window-sized rings instead of 32 full caches (DESIGN.md Sec. 5).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import rwkv6 as rwkv_mod
from . import ssm as ssm_mod
from .attention import decode_attention
from .config import ModelConfig
from .transformer import (
    _cond_kv,
    _ffn,
    _hymba_window,
    _project_qkv,
    embed_inputs,
    forward,
    output_logits,
)
from .layers import matmul, rms_norm


# --------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict[str, Any]:
    kvshape = lambda L, s: (L, batch, s, cfg.n_kv_heads, cfg.head_dim)
    pos = jnp.zeros((batch,), jnp.int32)
    if cfg.block == "rwkv6":
        h = cfg.d_model // cfg.head_dim
        return {
            "wkv": jnp.zeros((cfg.n_layers, batch, h, cfg.head_dim, cfg.head_dim), jnp.float32),
            "shift_t": jnp.zeros((cfg.n_layers, batch, cfg.d_model), cfg.dtype),
            "shift_c": jnp.zeros((cfg.n_layers, batch, cfg.d_model), cfg.dtype),
            "pos": pos,
        }
    if cfg.block == "hymba":
        n_global = sum(
            1 for li in range(cfg.n_layers) if _hymba_window(cfg, li) == 0
        )
        n_swa = cfg.n_layers - n_global
        w = min(cfg.sliding_window, max_len)
        return {
            "k_global": jnp.zeros(kvshape(n_global, max_len), cfg.dtype),
            "v_global": jnp.zeros(kvshape(n_global, max_len), cfg.dtype),
            "k_swa": jnp.zeros(kvshape(n_swa, w), cfg.dtype),
            "v_swa": jnp.zeros(kvshape(n_swa, w), cfg.dtype),
            "ssm_h": jnp.zeros(
                (cfg.n_layers, batch, cfg.d_model, cfg.ssm_state), jnp.float32
            ),
            "pos": pos,
        }
    cache: dict[str, Any] = {
        "k": jnp.zeros(kvshape(cfg.n_layers, max_len), cfg.dtype),
        "v": jnp.zeros(kvshape(cfg.n_layers, max_len), cfg.dtype),
        "pos": pos,
    }
    if cfg.cross_attn_every > 0 or cfg.cross_d_cond > 0:
        lc = cfg.num_cross_layers if cfg.cross_attn_every > 0 else cfg.n_layers
        t = cfg.cross_kv_len
        cache["cross_k"] = jnp.zeros(kvshape(lc, t), cfg.dtype)
        cache["cross_v"] = jnp.zeros(kvshape(lc, t), cfg.dtype)
    return cache


# --------------------------------------------------------------------------
def prefill(
    params,
    batch: dict,
    cfg: ModelConfig,
    mesh=None,
    max_len: int | None = None,
    true_len: jax.Array | None = None,
):
    """Run the full prompt, materialize caches sized to max_len.
    Returns (last_logits, cache).

    `true_len` (B,) int32 supports right-padded prompts (the continuous-
    batching scheduler pads every prompt to a fixed bucket length so
    admission never retraces): the returned logits are gathered at each
    sequence's true last token and `cache["pos"]` is set per sequence to
    ``true_len - 1``.  Causal attention plus the decode-time pos mask
    make the padding inert — positions >= true_len hold junk kv that no
    later read ever attends.  Only valid for pure attention caches
    (recurrent rwkv6/hymba states would absorb the padding tokens).
    """
    tokens_or = batch.get("tokens", batch.get("embeds"))
    b, s = tokens_or.shape[:2]
    max_len = max_len or s
    if true_len is not None and cfg.block in ("rwkv6", "hymba"):
        raise ValueError(
            f"padded prefill (true_len) is attention-only; got block={cfg.block}"
        )
    logits, _aux, kv = forward(params, batch, cfg, mesh, collect_cache=True)
    cache = init_cache(cfg, b, max_len)
    if true_len is not None:
        if "cross_k" in cache:
            raise ValueError("padded prefill does not support cross-attention caches")
        if cfg.n_codebooks > 1:
            raise ValueError("padded prefill does not support multi-codebook heads")
        cache["pos"] = (true_len - 1).astype(jnp.int32)
        last = jnp.take_along_axis(
            logits, (true_len - 1)[:, None, None], axis=1
        )[:, 0]
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], kv["k"], 0, axis=2
        )
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], kv["v"], 0, axis=2
        )
        return last, cache
    cache["pos"] = jnp.full((b,), s - 1, jnp.int32)

    if cfg.block == "rwkv6":
        cache.update(kv)
        return logits[:, -1], cache
    if cfg.block == "hymba":
        w = min(cfg.sliding_window, max_len)
        cache["k_global"] = jax.lax.dynamic_update_slice_in_dim(
            cache["k_global"], kv["k_global"], 0, axis=2
        )
        cache["v_global"] = jax.lax.dynamic_update_slice_in_dim(
            cache["v_global"], kv["v_global"], 0, axis=2
        )
        # SWA caches were already truncated to the window in forward();
        # write them at ring slots matching absolute positions.
        kswa, vswa = kv["k_swa"], kv["v_swa"]
        wlen = kswa.shape[2]
        slots = (s - wlen + jnp.arange(wlen)) % w
        cache["k_swa"] = cache["k_swa"].at[:, :, slots].set(kswa[:, :, -w:])
        cache["v_swa"] = cache["v_swa"].at[:, :, slots].set(vswa[:, :, -w:])
        cache["ssm_h"] = kv.get("ssm_h", cache["ssm_h"])
        return logits[:, -1], cache

    cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], kv["k"], 0, axis=2)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], kv["v"], 0, axis=2)
    if "cross_k" in cache and batch.get("cond") is not None:
        cls = params["cross_layers"]
        n_cl = jax.tree.leaves(cls)[0].shape[0]
        ks, vs = [], []
        for gi in range(n_cl):
            cl = jax.tree.map(lambda a: a[gi], cls)
            ck, cv = _cond_kv(batch["cond"], cl, cfg)
            ks.append(ck)
            vs.append(cv)
        cache["cross_k"] = jnp.stack(ks)
        cache["cross_v"] = jnp.stack(vs)
    return logits[:, -1], cache


# --------------------------------------------------------------------------
def prefill_chunk(
    params,
    cache: dict,
    tokens: jax.Array,      # (1, C) — prompt slice [start, start+C), padded
    cfg: ModelConfig,
    mesh=None,
    *,
    start: int,             # static: absolute position of tokens[:, 0]
    slot,                   # traced int32 scalar: batch row in the cache
    true_len: jax.Array | None = None,  # (1,) — final chunk: emit logits
    park_pos: int | None = None,        # first chunk: park cache pos here
):
    """Prefill ONE bounded chunk of a prompt directly into the shared
    decode cache at batch row `slot` (chunked prefill, DESIGN.md Sec. 18).

    Bit-compatibility contract: layer bodies mirror `_attn_block_train`
    exactly, with `chunked_causal_attention(..., pos_offset=start)` over
    prefix kv (read back from the cache) + this chunk's kv.  When C and
    `start` are multiples of both attn chunk sizes, the (m, l, acc)
    online-softmax op sequence for every row is IDENTICAL to the
    whole-prompt `prefill`, so cache contents over [0, true_len) and the
    first sampled token are bitwise equal (tests/test_serving_scheduler).

    Decode steps interleave between chunks and blindly advance/write
    every cache row; `park_pos` (first chunk) moves this slot's position
    to `max_len`, so interleaved junk writes land out of bounds (scatter
    drops them) and junk reads are never attended.  The final chunk
    (true_len given) restores ``pos = true_len - 1`` and returns the
    last real token's logits; mid chunks return ``(None, cache)``.

    Dense attention stacks only: MoE capacity routing couples tokens
    across the whole sequence, recurrent blocks absorb padding, and
    cross/multi-codebook caches are rejected like padded `prefill`.
    """
    if cfg.block in ("rwkv6", "hymba"):
        raise ValueError(
            f"chunked prefill is attention-only; got block={cfg.block}"
        )
    if cfg.is_moe:
        raise ValueError(
            "chunked prefill does not support MoE blocks: capacity-based "
            "routing couples tokens across the whole sequence, so chunk "
            "boundaries would change the routed computation"
        )
    if cfg.n_codebooks > 1 or "cross_k" in cache:
        raise ValueError(
            "chunked prefill does not support cross-attention caches or "
            "multi-codebook heads"
        )
    C = tokens.shape[1]
    for nm, cs in (("attn_chunk_q", cfg.attn_chunk_q),
                   ("attn_chunk_kv", cfg.attn_chunk_kv)):
        if C % cs or start % cs:
            raise ValueError(
                f"chunk [{start}, {start + C}) must align to {nm}={cs} for "
                "bit-identity with whole-prompt prefill"
            )
    from .act_sharding import constrain
    from .attention import chunked_causal_attention

    L, _, _, KV, hd = cache["k"].shape
    x = embed_inputs(params, {"tokens": tokens, "pos_offset": start}, cfg)
    b = x.shape[0]
    positions = start + jnp.arange(C)[None, :]
    lay = params["layers"]
    if start > 0:
        k_pre = jax.lax.dynamic_slice(
            cache["k"], (0, slot, 0, 0, 0), (L, 1, start, KV, hd)
        )
        v_pre = jax.lax.dynamic_slice(
            cache["v"], (0, slot, 0, 0, 0), (L, 1, start, KV, hd)
        )
        xs = (jnp.arange(L), k_pre, v_pre)
    else:
        xs = (jnp.arange(L),)

    def body(carry, xs_i):
        x = carry
        idx, rest = xs_i[0], xs_i[1:]
        pl = jax.tree.map(lambda a: a[idx], lay)
        x = constrain(x, mesh, ("batch", None, None))
        q, k, v = _project_qkv(x, pl, cfg, positions)
        q = constrain(q, mesh, ("batch", None, "model", None))
        k = constrain(k, mesh, ("batch", None, "model", None))
        v = constrain(v, mesh, ("batch", None, "model", None))
        if rest:
            kf = jnp.concatenate([rest[0], k], axis=1)
            vf = jnp.concatenate([rest[1], v], axis=1)
        else:
            kf, vf = k, v
        attn = chunked_causal_attention(
            q, kf, vf, chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
            window=cfg.sliding_window, pos_offset=start,
        )
        attn = matmul(attn.reshape(b, C, cfg.q_dim), pl["wo"])
        x = constrain(x + attn, mesh, ("batch", None, None))
        ff, _aux = _ffn(x, pl, cfg, mesh)
        res_spec = ("batch", None, "model" if cfg.shard_residual else None)
        return constrain(x + ff, mesh, res_spec), (k, v)

    x, (knew, vnew) = jax.lax.scan(body, x, xs)
    new_cache = dict(cache)
    new_cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], knew.astype(cache["k"].dtype), (0, slot, start, 0, 0)
    )
    new_cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], vnew.astype(cache["v"].dtype), (0, slot, start, 0, 0)
    )
    if true_len is not None:
        new_cache["pos"] = jax.lax.dynamic_update_slice(
            cache["pos"], (true_len - 1).astype(jnp.int32), (slot,)
        )
        logits = output_logits(params, x, cfg, mesh)
        last = jnp.take_along_axis(
            logits, (true_len - 1 - start)[:, None, None], axis=1
        )[:, 0]
        return last, new_cache
    if park_pos is not None:
        new_cache["pos"] = jax.lax.dynamic_update_slice(
            cache["pos"], jnp.full((1,), park_pos, jnp.int32), (slot,)
        )
    return None, new_cache


# --------------------------------------------------------------------------
def write_cache_slot(shared: dict, single: dict, slot) -> dict:
    """Insert a single-request cache (B=1, same max_len) into batch slot
    `slot` of a pre-allocated decode cache.

    Every cache leaf carries the batch on axis 1 (stacked (L, B, ...)
    layouts) except "pos" (B,); `slot` may be a traced int32 scalar, so
    admission into any slot reuses one compiled dispatch (the continuous-
    batching scheduler's refill path).
    """
    out = dict(shared)
    for name, dst in shared.items():
        src = single[name].astype(dst.dtype)
        axis = 0 if name == "pos" else 1
        out[name] = jax.lax.dynamic_update_slice_in_dim(dst, src, slot, axis=axis)
    return out


# --------------------------------------------------------------------------
def _decode_attn_layer(x, pl, cfg, kc, vc, pos, window, positions):
    """One decode attention sublayer; returns (attn_out, kc', vc')."""
    b = x.shape[0]
    q, k1, v1 = _project_qkv(x, pl, cfg, positions)
    if window > 0:
        slot = pos % kc.shape[1]
    else:
        slot = pos
    kc = kc.at[jnp.arange(b), slot].set(k1[:, 0])
    vc = vc.at[jnp.arange(b), slot].set(v1[:, 0])
    if window > 0:
        # ring buffer: every slot holds an in-window entry once warm;
        # mask invalid (not yet written) slots for pos < window.
        valid_count = jnp.minimum(pos + 1, kc.shape[1])
        attn = decode_attention(
            q, kc, vc, jnp.maximum(valid_count - 1, 0), window=0
        )
    else:
        attn = decode_attention(q, kc, vc, pos, window=0)
    return matmul(attn.reshape(b, 1, cfg.q_dim), pl["wo"]), kc, vc


def _decode_cross(x, cl, cache, gi, cfg):
    b = x.shape[0]
    h = rms_norm(x, cl["norm"], cfg.norm_eps)
    q = matmul(h, cl["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    t = cache["cross_k"].shape[2]
    out = decode_attention(
        q, cache["cross_k"][gi], cache["cross_v"][gi],
        jnp.full((b,), t - 1, jnp.int32),
    )
    out = matmul(out.reshape(b, 1, cfg.q_dim), cl["wo"])
    gate = jnp.tanh(cl["gate"].astype(jnp.float32)).astype(x.dtype)
    return x + gate * out


def decode_step(params, cache: dict, batch: dict, cfg: ModelConfig, mesh=None):
    """One token for the whole batch. batch: tokens (B,1) or embeds
    (B,1,D).  Returns (logits (B,1,V...), new_cache)."""
    x = embed_inputs(
        params, {**batch, "pos_offset": cache["pos"][0] + 1}, cfg
    )
    b = x.shape[0]
    pos = cache["pos"] + 1  # position of the current token
    positions = pos[:, None]
    new_cache = dict(cache)
    new_cache["pos"] = pos
    lay = params["layers"] if cfg.block != "rwkv6" else None

    if cfg.block == "rwkv6":
        lay = params["layers"]

        def body(x, xs):
            wkv, st_, sc_ = xs
            st = rwkv_mod.RWKVState(wkv, st_, sc_)
            y, wkv_new, shift_t = rwkv_mod.time_mix(x, lay, 0, cfg, st)
            x = x + y
            cm, shift_c = rwkv_mod.channel_mix(x, lay, 0, cfg, st)
            return x + cm, (wkv_new, shift_t, shift_c)

        # scan over layers: index via stacked params closure
        def body_idx(carry, xs):
            x = carry
            idx, wkv, st_, sc_ = xs
            st = rwkv_mod.RWKVState(wkv, st_, sc_)
            y, wkv_new, shift_t = rwkv_mod.time_mix(x, lay, idx, cfg, st)
            x = x + y
            cm, shift_c = rwkv_mod.channel_mix(x, lay, idx, cfg, st)
            return x + cm, (wkv_new, shift_t, shift_c)

        x, states = jax.lax.scan(
            body_idx,
            x,
            (jnp.arange(cfg.n_layers), cache["wkv"], cache["shift_t"], cache["shift_c"]),
        )
        new_cache["wkv"], new_cache["shift_t"], new_cache["shift_c"] = states
        return output_logits(params, x, cfg, mesh), new_cache

    if cfg.block == "hymba":
        # Homogeneous-run scans (compile hygiene, mirrors _forward_hymba):
        # each run of equal-window layers scans with its cache slices as
        # scan xs/ys; run boundaries advance the global/SWA cache cursors.
        from .transformer import _hymba_runs

        kg, vg = cache["k_global"], cache["v_global"]
        ks, vs = cache["k_swa"], cache["v_swa"]
        hs = cache["ssm_h"]
        gi = si = 0

        def one_layer(x, pl, spl, bn, kc, vc, win, h0):
            attn, kc, vc = _decode_attn_layer(
                x, pl, cfg, kc, vc, pos, win, positions
            )
            ssm_out, st_new = ssm_mod.ssm_branch(
                x, spl, cfg, ssm_mod.SSMState(h0)
            )
            x = x + 0.5 * (
                rms_norm(attn, bn[0], cfg.norm_eps)
                + rms_norm(ssm_out, bn[1], cfg.norm_eps)
            )
            ff, _ = _ffn(x, pl, cfg, mesh)
            return x + ff, kc, vc, st_new.h

        for start, end, win in _hymba_runs(cfg):
            n_run = end - start
            sub_lay = jax.tree.map(lambda a: a[start:end], lay)
            sub_ssm = jax.tree.map(lambda a: a[start:end], params["ssm"])
            sub_bn = params["branch_norm"][start:end]
            if win == 0:
                kc_sl, vc_sl = kg[gi : gi + n_run], vg[gi : gi + n_run]
            else:
                kc_sl, vc_sl = ks[si : si + n_run], vs[si : si + n_run]
            h_sl = hs[start:end]

            if n_run == 1:
                pl = jax.tree.map(lambda a: a[0], sub_lay)
                spl = jax.tree.map(lambda a: a[0], sub_ssm)
                x, kc1, vc1, h1 = one_layer(
                    x, pl, spl, sub_bn[0], kc_sl[0], vc_sl[0], win, h_sl[0]
                )
                knew, vnew, hnew = kc1[None], vc1[None], h1[None]
            else:

                def body(carry, xs, win=win):
                    x = carry
                    pl, spl, bn, kc, vc, h0 = xs
                    x, kc, vc, h1 = one_layer(x, pl, spl, bn, kc, vc, win, h0)
                    return x, (kc, vc, h1)

                x, (knew, vnew, hnew) = jax.lax.scan(
                    body, x, (sub_lay, sub_ssm, sub_bn, kc_sl, vc_sl, h_sl)
                )
            if win == 0:
                kg = jax.lax.dynamic_update_slice_in_dim(kg, knew, gi, axis=0)
                vg = jax.lax.dynamic_update_slice_in_dim(vg, vnew, gi, axis=0)
                gi += n_run
            else:
                ks = jax.lax.dynamic_update_slice_in_dim(ks, knew, si, axis=0)
                vs = jax.lax.dynamic_update_slice_in_dim(vs, vnew, si, axis=0)
                si += n_run
            hs = jax.lax.dynamic_update_slice_in_dim(hs, hnew, start, axis=0)
        new_cache.update(
            k_global=kg, v_global=vg, k_swa=ks, v_swa=vs, ssm_h=hs
        )
        return output_logits(params, x, cfg, mesh), new_cache

    # attention stacks (dense / moe / musicgen / vlm)
    per_layer_cross = (
        cfg.cross_attn_every == 0 and "cross_k" in cache and cfg.cross_kv_len > 0
    )
    grouped_cross = cfg.cross_attn_every > 0

    if grouped_cross:
        n_groups = cfg.num_cross_layers
        per = cfg.n_layers // n_groups
        kc_all, vc_all = cache["k"], cache["v"]
        k_out, v_out = [], []
        for gi in range(n_groups):
            cl = jax.tree.map(lambda a, gi=gi: a[gi], params["cross_layers"])
            x = _decode_cross(x, cl, cache, gi, cfg)

            def body(carry, xs):
                x = carry
                pl, kc, vc = xs
                attn, kc, vc = _decode_attn_layer(
                    x, pl, cfg, kc, vc, pos, cfg.sliding_window, positions
                )
                x = x + attn
                ff, _ = _ffn(x, pl, cfg, mesh)
                return x + ff, (kc, vc)

            group = jax.tree.map(
                lambda a, gi=gi: a[gi * per : (gi + 1) * per], lay
            )
            x, (knew, vnew) = jax.lax.scan(
                body, x, (group, kc_all[gi * per : (gi + 1) * per],
                          vc_all[gi * per : (gi + 1) * per])
            )
            k_out.append(knew)
            v_out.append(vnew)
        new_cache["k"] = jnp.concatenate(k_out, axis=0)
        new_cache["v"] = jnp.concatenate(v_out, axis=0)
        return output_logits(params, x, cfg, mesh), new_cache

    def body(carry, xs):
        x = carry
        pl, kc, vc = xs
        attn, kc, vc = _decode_attn_layer(
            x, pl, cfg, kc, vc, pos, cfg.sliding_window, positions
        )
        x = x + attn
        ff, _ = _ffn(x, pl, cfg, mesh)
        return x + ff, (kc, vc)

    if per_layer_cross:
        # MusicGen: cross-attn every layer, using precomputed cond kv.
        def body_cross(carry, xs):
            x = carry
            pl, cl, kc, vc, ck, cv = xs
            attn, kc, vc = _decode_attn_layer(
                x, pl, cfg, kc, vc, pos, cfg.sliding_window, positions
            )
            x = x + attn
            b = x.shape[0]
            h = rms_norm(x, cl["norm"], cfg.norm_eps)
            q = matmul(h, cl["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
            t = ck.shape[1]
            c_out = decode_attention(
                q, ck, cv, jnp.full((b,), t - 1, jnp.int32)
            )
            c_out = matmul(c_out.reshape(b, 1, cfg.q_dim), cl["wo"])
            gate = jnp.tanh(cl["gate"].astype(jnp.float32)).astype(x.dtype)
            x = x + gate * c_out
            ff, _ = _ffn(x, pl, cfg, mesh)
            return x + ff, (kc, vc)

        x, (knew, vnew) = jax.lax.scan(
            body_cross,
            x,
            (lay, params["cross_layers"], cache["k"], cache["v"],
             cache["cross_k"], cache["cross_v"]),
        )
    else:
        x, (knew, vnew) = jax.lax.scan(body, x, (lay, cache["k"], cache["v"]))
    new_cache["k"], new_cache["v"] = knew, vnew
    return output_logits(params, x, cfg, mesh), new_cache
