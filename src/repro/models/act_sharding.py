"""Activation sharding constraints at layer boundaries.

GSPMD propagates operand shardings well through simple chains but loses
them through chunk-loop reshapes and nested remat (observed in the
dry-run: batch-replicated (L, B, S, D) saved carries and (B*S, V) logit
grads).  Pinning the batch and tensor axes of the *residual stream* and
the *logits* is the standard production fix (MaxText does the same).

`constrain(x, mesh, dims)` is a no-op without a mesh, so model code stays
mesh-agnostic.  dims entries: "batch" (largest ("pod","data") prefix
dividing the leading dim), "model", or None.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _batch_axes(mesh: Mesh, dim: int):
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    chosen, total = [], 1
    for a in axes:
        if dim % (total * sizes[a]) == 0:
            chosen.append(a)
            total *= sizes[a]
    return tuple(chosen) if chosen else None


def constrain(x: jax.Array, mesh: Mesh | None, dims: tuple):
    """with_sharding_constraint with symbolic dims; no-op if mesh is None."""
    if mesh is None:
        return x
    spec = []
    for i, d in enumerate(dims):
        if d == "batch":
            spec.append(_batch_axes(mesh, x.shape[i]))
        elif d is None:
            spec.append(None)
        else:
            size = dict(zip(mesh.axis_names, mesh.devices.shape)).get(d, 1)
            spec.append(d if x.shape[i] % size == 0 else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
