"""Pure-jnp oracle for the batched FWHT kernel.

Delegates to the core butterfly implementation — the kernel must match
this bit-for-bit in f32 (both compute exact +-1 combinations).
"""

from __future__ import annotations

import jax

from repro.core.hadamard import fwht as _fwht_butterfly


def fwht(x: jax.Array) -> jax.Array:
    """(C, N) -> (C, N) Walsh-Hadamard transform along the last axis."""
    return _fwht_butterfly(x, axis=-1)
