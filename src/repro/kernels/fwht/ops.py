"""Jit'd public wrapper for the FWHT kernel with backend dispatch."""

from __future__ import annotations

import jax

from . import ref
from .fwht import fwht_pallas


def fwht(x: jax.Array, *, force_pallas: bool = False) -> jax.Array:
    """Batched Walsh-Hadamard transform along the last axis.

    Any leading batch dims are flattened to the kernel's (C, N) layout.
    On TPU backends the Pallas kernel runs compiled; elsewhere it runs in
    interpret mode (same kernel body, Python evaluation) unless the shape
    is unsupported, in which case the pure-jnp oracle is used.
    """
    n = x.shape[-1]
    lead = x.shape[:-1]
    if n & (n - 1) or n > 128:
        return ref.fwht(x.reshape((-1, n))).reshape(lead + (n,))
    on_tpu = jax.default_backend() == "tpu"
    y = fwht_pallas(
        x.reshape((-1, n)), interpret=not on_tpu if not force_pallas else False
    )
    return y.reshape(lead + (n,))
