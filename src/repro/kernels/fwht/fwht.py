"""Pallas TPU kernel: batched Walsh-Hadamard transform over RRAM columns.

Hardware co-design note (TPU adaptation of the paper's digital decode):
the classic O(N log N) FWHT butterfly is the right dataflow for CPUs and
for the paper's shift-and-add periphery, but on TPU the butterfly's
pair-swap stages are *lane-crossing* operations on the 8x128 VREG tiles,
each compiled to expensive cross-lane shuffles.  For RRAM verify columns
N <= 128 (the paper uses N = 32 / 64), one column fits inside a single
MXU tile, so the transform is fastest as a dense matmul against the
constant +-1 Sylvester matrix: the MXU performs the N^2 MACs in the same
number of passes the VPU would need for a single butterfly stage.  We
therefore express the kernel as a block matmul `out = x @ H` with the
column batch tiled into VMEM blocks, and reserve the butterfly for the
pure-jnp oracle (ref.py).

Grid: one program per batch block of `block_c` columns.
BlockSpecs: x block (block_c, N) in VMEM, H (N, N) broadcast to every
program, out block (block_c, N) in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hadamard import _hadamard_np

DEFAULT_BLOCK_C = 512


def _fwht_kernel(x_ref, h_ref, o_ref):
    # One MXU matmul per block: (block_c, N) @ (N, N).
    o_ref[...] = jnp.dot(
        x_ref[...], h_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def fwht_pallas(
    x: jax.Array, *, block_c: int = DEFAULT_BLOCK_C, interpret: bool = True
) -> jax.Array:
    """Batched FWHT: (C, N) -> (C, N), N a power of two <= 128.

    `interpret=True` runs the kernel body on CPU for validation; on a real
    TPU backend pass interpret=False.
    """
    c, n = x.shape
    if n & (n - 1) or n > 128:
        raise ValueError(f"kernel supports power-of-two N <= 128, got {n}")
    h = jnp.asarray(_hadamard_np(n), jnp.float32)

    block_c = min(block_c, c)
    # Pad the column batch to a multiple of the block size.
    pad = (-c) % block_c
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    grid = (x.shape[0] // block_c,)

    out = pl.pallas_call(
        _fwht_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_c, n), lambda i: (i, 0)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_c, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], n), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), h)
    return out[:c]
