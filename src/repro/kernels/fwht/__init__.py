from . import ops, ref  # noqa: F401
from .ops import fwht  # noqa: F401
