"""Public wrapper for the bit-sliced ACiM VMM kernel."""

from __future__ import annotations

import jax

from . import ref
from .acim_vmm import acim_vmm_pallas, acim_vmm_tiled_pallas


def acim_vmm(
    x, g_pos, g_neg, *, bc: int, adc_bits: int | None, full_scale: float,
    noise=None, use_pallas: bool = True,
):
    """Bit-sliced signed ACiM VMM with per-slice ADC quantization.

    `noise` (S, B, M) is added to each slice's analog partial sums
    before conversion; `adc_bits=None` bypasses the ADC (ideal
    converter).  The Pallas and reference paths are bit-identical.
    """
    if not use_pallas:
        return ref.acim_vmm(x, g_pos, g_neg, bc, adc_bits, full_scale, noise)
    on_tpu = jax.default_backend() == "tpu"
    return acim_vmm_pallas(
        x, g_pos, g_neg, noise, bc=bc, adc_bits=adc_bits, full_scale=full_scale,
        interpret=not on_tpu,
    )


def acim_vmm_tiled(
    x, g_pos, g_neg, *, bc: int, adc_bits: int | None, full_scale: float,
    noise=None, use_pallas: bool = True,
):
    """Whole-leaf fused ACiM VMM: every macro tile in one dispatch.

    x (B, T*R) drives per-tile planes g_pos/g_neg (T, S, R, M) with
    per-tile pre-ADC `noise` (T, S, B, M); the result (B, M) is the sum
    over tiles of each tile's ADC-quantized slice recombination.  The
    Pallas mega-kernel and the scanned reference are bit-identical, and
    both preserve the pre-fusion per-tile loop's float association.
    """
    if not use_pallas:
        return ref.acim_vmm_tiled(
            x, g_pos, g_neg, bc, adc_bits, full_scale, noise
        )
    on_tpu = jax.default_backend() == "tpu"
    return acim_vmm_tiled_pallas(
        x, g_pos, g_neg, noise, bc=bc, adc_bits=adc_bits,
        full_scale=full_scale, interpret=not on_tpu,
    )
