from . import ops, ref  # noqa: F401
from .ops import acim_vmm  # noqa: F401
