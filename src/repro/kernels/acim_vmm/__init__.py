from . import ops, ref  # noqa: F401
from .ops import acim_vmm, acim_vmm_tiled  # noqa: F401
