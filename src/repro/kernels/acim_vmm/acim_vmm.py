"""Pallas TPU kernel: bit-sliced ACiM VMM with fused ADC epilogue.

Hardware co-design: the paper's CBA macro computes y = sum_l 2^(Bc l) *
ADC(x @ G_l) with analog column sums and per-slice ADCs.  On TPU the
natural mapping is: each conductance slice is a dense operand plane, the
column dimension maps to MXU lanes (128-wide, matching the paper's
128-column macro scaling), and the ADC transfer function (clamp +
uniform quantization) is fused into the matmul epilogue in VMEM — so the
quantized-slice recombination never round-trips to HBM.

Grid: (M/block_m, B/block_b); the slice loop (k = B/Bc, typically 2) is
unrolled inside the kernel, accumulating the shifted slices in VMEM.
The contraction dim K is kept whole per block (RRAM macro columns are
short: K = N <= 128 rows).

Inference extensions (the analog serving path, DESIGN.md Sec. 11):

* an optional per-read noise operand (S, B, M) — sampled outside under
  the fold_in RNG policy — is added to every slice's analog partial sum
  *before* the ADC epilogue, exactly where TIA/ADC thermal noise enters
  the macro;
* ``adc_bits=None`` models an ideal (infinite-resolution) converter:
  the epilogue reduces to the identity, which is what makes the analog
  forward provably collapse to the digitally materialized matmul.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _acim_kernel(*refs, bc, adc_bits, full_scale, with_noise):
    if with_noise:
        x_ref, gp_ref, gn_ref, nz_ref, o_ref = refs
    else:
        x_ref, gp_ref, gn_ref, o_ref = refs
        nz_ref = None
    x = x_ref[...]
    s = gp_ref.shape[0]
    acc = jnp.zeros((x.shape[0], gp_ref.shape[2]), jnp.float32)
    if adc_bits is not None:
        w = full_scale / float(1 << adc_bits)
        lo = -full_scale / 2.0
    for l in range(s):  # static unroll over bit slices
        part = jnp.dot(
            x, gp_ref[l] - gn_ref[l], preferred_element_type=jnp.float32
        )
        if nz_ref is not None:
            part = part + nz_ref[l]
        if adc_bits is None:
            acc = acc + part * float(1 << (bc * l))
            continue
        # fused ADC epilogue: clamp to full scale, quantize to code grid
        code = jnp.clip(
            jnp.round((jnp.clip(part, lo, -lo) - lo) / w), 0.0, float((1 << adc_bits) - 1)
        )
        acc = acc + (lo + code * w) * float(1 << (bc * l))
    o_ref[...] = acc


def _acim_tiled_kernel(*refs, bc, adc_bits, full_scale, with_noise):
    """Fused whole-leaf kernel: every macro tile's slice loop + ADC
    epilogue + tile summation in one VMEM-resident accumulation.

    The per-tile inner accumulator recombines that tile's shifted slices
    first and the outer accumulator adds tiles in order — the same float
    association as the scanned reference (`ref.acim_vmm_tiled`), which
    itself preserves the pre-fusion per-tile Python loop bit-for-bit.
    """
    if with_noise:
        x_ref, gp_ref, gn_ref, nz_ref, o_ref = refs
    else:
        x_ref, gp_ref, gn_ref, o_ref = refs
        nz_ref = None
    x = x_ref[...]
    n_tiles, s, r = gp_ref.shape[0], gp_ref.shape[1], gp_ref.shape[2]
    acc = jnp.zeros((x.shape[0], gp_ref.shape[3]), jnp.float32)
    if adc_bits is not None:
        w = full_scale / float(1 << adc_bits)
        lo = -full_scale / 2.0
    for ti in range(n_tiles):  # static unroll over macro tiles
        xi = x[:, ti * r : (ti + 1) * r]
        tacc = jnp.zeros_like(acc)
        for l in range(s):  # static unroll over bit slices
            part = jnp.dot(
                xi, gp_ref[ti, l] - gn_ref[ti, l],
                preferred_element_type=jnp.float32,
            )
            if nz_ref is not None:
                part = part + nz_ref[ti, l]
            if adc_bits is None:
                tacc = tacc + part * float(1 << (bc * l))
                continue
            code = jnp.clip(
                jnp.round((jnp.clip(part, lo, -lo) - lo) / w),
                0.0,
                float((1 << adc_bits) - 1),
            )
            tacc = tacc + (lo + code * w) * float(1 << (bc * l))
        acc = acc + tacc
    o_ref[...] = acc


@functools.partial(
    jax.jit,
    static_argnames=("bc", "adc_bits", "full_scale", "block_b", "block_m", "interpret"),
)
def acim_vmm_tiled_pallas(
    x: jax.Array,            # (B, T*R)
    g_pos: jax.Array,        # (T, S, R, M)
    g_neg: jax.Array,        # (T, S, R, M)
    noise: jax.Array | None = None,  # (T, S, B, M)
    *,
    bc: int,
    adc_bits: int | None,
    full_scale: float,
    block_b: int = 128,
    block_m: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """One `pallas_call` for a whole weight leaf: grid over (B, M)
    blocks, tiles and slices statically unrolled in VMEM.  The K axis
    stays whole per block (RRAM macro rows are short), so each grid cell
    reads its x rows once and drives every tile's conductance planes."""
    b, k = x.shape
    n_tiles, s, r, m = g_pos.shape
    assert k == n_tiles * r and g_neg.shape == g_pos.shape
    if noise is not None:
        assert noise.shape == (n_tiles, s, b, m), (
            noise.shape, (n_tiles, s, b, m),
        )
    block_b = min(block_b, b)
    block_m = min(block_m, m)
    pad_b, pad_m = (-b) % block_b, (-m) % block_m
    if pad_b:
        x = jnp.pad(x, ((0, pad_b), (0, 0)))
        if noise is not None:
            noise = jnp.pad(noise, ((0, 0), (0, 0), (0, pad_b), (0, 0)))
    if pad_m:
        g_pos = jnp.pad(g_pos, ((0, 0), (0, 0), (0, 0), (0, pad_m)))
        g_neg = jnp.pad(g_neg, ((0, 0), (0, 0), (0, 0), (0, pad_m)))
        if noise is not None:
            noise = jnp.pad(noise, ((0, 0), (0, 0), (0, 0), (0, pad_m)))
    bb, mm = x.shape[0], g_pos.shape[3]

    in_specs = [
        pl.BlockSpec((block_b, k), lambda i, j: (i, 0)),
        pl.BlockSpec((n_tiles, s, r, block_m), lambda i, j: (0, 0, 0, j)),
        pl.BlockSpec((n_tiles, s, r, block_m), lambda i, j: (0, 0, 0, j)),
    ]
    operands = [
        x.astype(jnp.float32),
        g_pos.astype(jnp.float32),
        g_neg.astype(jnp.float32),
    ]
    if noise is not None:
        in_specs.append(
            pl.BlockSpec((n_tiles, s, block_b, block_m), lambda i, j: (0, 0, i, j))
        )
        operands.append(noise.astype(jnp.float32))

    out = pl.pallas_call(
        functools.partial(
            _acim_tiled_kernel, bc=bc, adc_bits=adc_bits,
            full_scale=full_scale, with_noise=noise is not None,
        ),
        grid=(bb // block_b, mm // block_m),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, block_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bb, mm), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out[:b, :m]


@functools.partial(
    jax.jit,
    static_argnames=("bc", "adc_bits", "full_scale", "block_b", "block_m", "interpret"),
)
def acim_vmm_pallas(
    x: jax.Array,
    g_pos: jax.Array,
    g_neg: jax.Array,
    noise: jax.Array | None = None,
    *,
    bc: int,
    adc_bits: int | None,
    full_scale: float,
    block_b: int = 128,
    block_m: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, k = x.shape
    s, k2, m = g_pos.shape
    assert k == k2 and g_neg.shape == g_pos.shape
    if noise is not None:
        assert noise.shape == (s, b, m), (noise.shape, (s, b, m))
    block_b = min(block_b, b)
    block_m = min(block_m, m)
    pad_b, pad_m = (-b) % block_b, (-m) % block_m
    if pad_b:
        x = jnp.pad(x, ((0, pad_b), (0, 0)))
        if noise is not None:
            noise = jnp.pad(noise, ((0, 0), (0, pad_b), (0, 0)))
    if pad_m:
        g_pos = jnp.pad(g_pos, ((0, 0), (0, 0), (0, pad_m)))
        g_neg = jnp.pad(g_neg, ((0, 0), (0, 0), (0, pad_m)))
        if noise is not None:
            noise = jnp.pad(noise, ((0, 0), (0, 0), (0, pad_m)))
    bb, mm = x.shape[0], g_pos.shape[2]

    in_specs = [
        pl.BlockSpec((block_b, k), lambda i, j: (i, 0)),
        pl.BlockSpec((s, k, block_m), lambda i, j: (0, 0, j)),
        pl.BlockSpec((s, k, block_m), lambda i, j: (0, 0, j)),
    ]
    operands = [
        x.astype(jnp.float32),
        g_pos.astype(jnp.float32),
        g_neg.astype(jnp.float32),
    ]
    if noise is not None:
        in_specs.append(pl.BlockSpec((s, block_b, block_m), lambda i, j: (0, i, j)))
        operands.append(noise.astype(jnp.float32))

    out = pl.pallas_call(
        functools.partial(
            _acim_kernel, bc=bc, adc_bits=adc_bits, full_scale=full_scale,
            with_noise=noise is not None,
        ),
        grid=(bb // block_b, mm // block_m),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, block_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bb, mm), jnp.float32),
        interpret=interpret,
    )(*operands)
    return out[:b, :m]
