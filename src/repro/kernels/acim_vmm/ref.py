"""Pure-jnp oracle for the bit-sliced ACiM VMM kernel.

Simulates the CBA macro's inference datapath (paper Fig. 2 / 6(b)): a
weight matrix stored as k = B/Bc conductance slices on signed column
pairs, with per-column ADC quantization of every slice's partial sums
and digital shift-and-add recombination:

    y = sum_l 2^(Bc*(l-1)) * ADC( x @ (G+_l - G-_l) + n_l )

The ADC clamps each slice's analog partial sums to its full-scale range
(n-bit over [-FS/2, FS/2]) — literally the same converter model the
verify path uses: `adc_quantize` is `repro.readout.converter.
sar_quantize` in centered mode (the Pallas kernel inlines the identical
expression in VMEM and is bit-identity-tested against this reference).
`noise` (S, B, M) models per-read TIA/ADC thermal noise entering the
analog partial sum before conversion; `adc_bits=None` is an ideal
converter (identity), the limit in which the analog forward equals the
digital matmul exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.readout.converter import sar_quantize


def adc_quantize(y: jax.Array, bits: int, full_scale: float) -> jax.Array:
    """n-bit uniform quantization over [-FS/2, FS/2] (dequantized)."""
    return sar_quantize(y, bits, full_scale, centered=True)


def acim_vmm(
    x: jax.Array,            # (B, K) activations
    g_pos: jax.Array,        # (S, K, M) positive-column conductance levels
    g_neg: jax.Array,        # (S, K, M) negative-column conductance levels
    bc: int,                 # bits per cell
    adc_bits: int | None,
    full_scale: float,
    noise: jax.Array | None = None,  # (S, B, M) pre-ADC read noise
) -> jax.Array:
    """Bit-sliced signed VMM with per-slice ADC quantization: (B, M)."""
    s = g_pos.shape[0]
    acc = jnp.zeros((x.shape[0], g_pos.shape[2]), jnp.float32)
    for l in range(s):
        part = x.astype(jnp.float32) @ (g_pos[l] - g_neg[l]).astype(jnp.float32)
        if noise is not None:
            part = part + noise[l].astype(jnp.float32)
        if adc_bits is not None:
            part = adc_quantize(part, adc_bits, full_scale)
        acc = acc + part * float(1 << (bc * l))
    return acc


def acim_vmm_tiled(
    x: jax.Array,            # (B, T*R) row drives, tiles contiguous on K
    g_pos: jax.Array,        # (T, S, R, M) per-tile positive planes
    g_neg: jax.Array,        # (T, S, R, M) per-tile negative planes
    bc: int,
    adc_bits: int | None,
    full_scale: float,
    noise: jax.Array | None = None,  # (T, S, B, M) per-tile pre-ADC noise
) -> jax.Array:
    """Whole-leaf tiled VMM: every macro tile's readout + tile summation.

    One `lax.scan` over the tile axis, each step the single-tile
    `acim_vmm` followed by ``acc + tile_result`` — the EXACT float
    association of the per-tile Python loop this replaced (the outer
    accumulator adds each tile's fully recombined slice sum), so the
    fused forward is bit-identical to the pre-fusion path.
    """
    n_tiles, s, r, m = g_pos.shape
    b = x.shape[0]
    xt = jnp.moveaxis(x.reshape(b, n_tiles, r), 1, 0)  # (T, B, R)
    acc0 = jnp.zeros((b, m), jnp.float32)
    if noise is None:
        def body(acc, op):
            xi, gp, gn = op
            return acc + acim_vmm(xi, gp, gn, bc, adc_bits, full_scale), None
        acc, _ = jax.lax.scan(body, acc0, (xt, g_pos, g_neg))
    else:
        def body(acc, op):
            xi, gp, gn, nz = op
            return (
                acc + acim_vmm(xi, gp, gn, bc, adc_bits, full_scale, nz),
                None,
            )
        acc, _ = jax.lax.scan(body, acc0, (xt, g_pos, g_neg, noise))
    return acc
