"""Pallas TPU kernel: fused WV cell update (verify tail -> write).

The fine-WV loop applies, per cell: threshold -> streak -> freeze ->
pulse-size -> device-step -> clip.  Unfused, XLA materializes ~6
intermediate (C, N) arrays in HBM per iteration; programming a 1B-param
model touches ~0.5e9 cells x 50 iterations, so the loop is pure
memory-bandwidth.  This kernel performs the whole chain in one VMEM pass
(everything after the verify aggregate, which comes from the FWHT
kernel), making the per-iteration traffic exactly: 8 input planes read +
5 output planes written.

Layout: cells are processed as 2D blocks (block_r, n) — the column axis
N (32/64/128) is the lane dimension, the column-batch axis is tiled over
the grid.  The column-active reduction (`all(frozen)` along N) happens
in-register per block.

All stochastic fields (c2c jitter, mapping noise, d2d) are pre-sampled
outside — keeping the kernel deterministic and the RNG in one place.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import WVCellParams

DEFAULT_BLOCK_R = 256


def _wv_kernel(
    agg_ref, mag_ref, g_ref, streak_ref, frozen_ref, c2c_ref, nmap_ref,
    d2d_ref, g_out, streak_out, frozen_out, np_out, dir_out, *, p: WVCellParams
):
    agg = agg_ref[...]
    g = g_ref[...]
    streak = streak_ref[...]
    frozen = frozen_ref[...] != 0

    decision = jnp.where(
        agg > p.threshold, 1.0, jnp.where(agg < -p.threshold, -1.0, 0.0)
    )
    in_thr = decision == 0.0
    streak_new = jnp.where(in_thr, streak + 1, 0)
    frozen_new = frozen | (
        jnp.asarray(p.can_freeze) & (streak_new >= p.k_streak)
    )
    col_active = ~jnp.all(frozen, axis=-1, keepdims=True)

    if p.ternary:
        n_p = jnp.ones_like(g)
    else:
        n_p = jnp.clip(jnp.round(mag_ref[...] / p.fine_step), 1.0, p.max_pulses)
    act = (~frozen) & (decision != 0.0) & col_active
    n_p = jnp.where(act, n_p, 0.0)
    direction = jnp.where(act, -decision, 0.0)

    frac = jnp.clip(g / p.g_max, 0.0, 1.0)
    set_eff = (1.0 - frac) ** p.nonlinearity
    reset_eff = frac ** p.nonlinearity * p.reset_asymmetry
    eff = jnp.where(direction > 0, set_eff, reset_eff)
    delta = direction * p.fine_step * eff * d2d_ref[...] * n_p * c2c_ref[...]
    nmap = nmap_ref[...]
    if p.nmap_sqrt_pulses:
        nmap = nmap * jnp.sqrt(jnp.maximum(n_p, 1.0))
    g_new = jnp.clip(
        g + delta + jnp.where(n_p > 0, nmap, 0.0), 0.0, p.g_max
    )
    g_out[...] = jnp.where(n_p > 0, g_new, g)
    streak_out[...] = streak_new
    frozen_out[...] = frozen_new.astype(jnp.int8)
    np_out[...] = n_p
    dir_out[...] = direction


@functools.partial(
    jax.jit, static_argnames=("p", "block_r", "interpret")
)
def wv_cell_update_pallas(
    agg, dev_mag, g, streak, frozen, c2c, nmap, d2d,
    p: WVCellParams, *, block_r: int = DEFAULT_BLOCK_R, interpret: bool = True,
):
    c, n = g.shape
    block_r = min(block_r, c)
    pad = (-c) % block_r

    def pad2(x):
        return jnp.pad(x, ((0, pad), (0, 0))) if pad else x

    args = [agg, dev_mag, g, streak, frozen.astype(jnp.int8), c2c, nmap, d2d]
    args = [pad2(x) for x in args]
    rows = args[0].shape[0]
    grid = (rows // block_r,)
    spec = pl.BlockSpec((block_r, n), lambda i: (i, 0))

    outs = pl.pallas_call(
        functools.partial(_wv_kernel, p=p),
        grid=grid,
        in_specs=[spec] * 8,
        out_specs=[spec] * 5,
        out_shape=[
            jax.ShapeDtypeStruct((rows, n), jnp.float32),
            jax.ShapeDtypeStruct((rows, n), jnp.int32),
            jax.ShapeDtypeStruct((rows, n), jnp.int8),
            jax.ShapeDtypeStruct((rows, n), jnp.float32),
            jax.ShapeDtypeStruct((rows, n), jnp.float32),
        ],
        interpret=interpret,
    )(*args)
    g_new, streak_new, frozen_new, n_p, direction = [o[:c] for o in outs]
    return g_new, streak_new, frozen_new != 0, n_p, direction
