"""Pure-jnp oracle for the fused WV cell-update kernel.

One fine-WV iteration's *cell-domain* tail, given the per-cell decision
signal from the verify stage:

  1. ternary decision from the aggregate (threshold)
  2. streak / freeze bookkeeping (K consecutive stops, warmup gate)
  3. pulse sizing (ternary: 1; magnitude: round(|dev|/step) capped)
  4. nominal pulse application with the nonlinear/asymmetric device step
     (pre-sampled noise fields are inputs: RNG stays outside the kernel)

This chain is 6 elementwise HBM round-trips when left unfused; the Pallas
kernel does it in one pass over VMEM blocks.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class WVCellParams(NamedTuple):
    threshold: float        # decision threshold on the aggregate
    k_streak: int
    can_freeze: bool        # warmup gate (static per iteration)
    ternary: bool           # 1 pulse vs magnitude pulses
    fine_step: float
    max_pulses: float
    g_max: float
    nonlinearity: float
    reset_asymmetry: float
    # "pulse"-mode mapping noise (core.device): nmap carries the
    # single-pulse sigma and the burst accumulates as a random walk, so
    # the applied noise scales with sqrt(n_pulses).  Off = "event" mode.
    nmap_sqrt_pulses: bool = False


def wv_cell_update(
    agg: jax.Array,        # verify aggregate (dev estimate or s_w), (C, N)
    dev_mag: jax.Array,    # |deviation| estimate for pulse sizing, (C, N)
    g: jax.Array,          # conductances (C, N)
    streak: jax.Array,     # int32 (C, N)
    frozen: jax.Array,     # bool (C, N)
    c2c: jax.Array,        # pre-sampled multiplicative jitter (C, N)
    nmap: jax.Array,       # pre-sampled additive mapping noise (C, N)
    d2d: jax.Array,        # static per-cell efficiency (C, N)
    p: WVCellParams,
):
    decision = jnp.where(
        agg > p.threshold, 1.0, jnp.where(agg < -p.threshold, -1.0, 0.0)
    )
    in_thr = decision == 0.0
    streak_new = jnp.where(in_thr, streak + 1, 0)
    frozen_new = frozen | (
        jnp.asarray(p.can_freeze) & (streak_new >= p.k_streak)
    )
    col_active = ~jnp.all(frozen, axis=-1, keepdims=True)

    if p.ternary:
        n_p = jnp.ones_like(g)
    else:
        n_p = jnp.clip(jnp.round(dev_mag / p.fine_step), 1.0, p.max_pulses)
    act = (~frozen) & (decision != 0.0) & col_active
    n_p = jnp.where(act, n_p, 0.0)
    direction = jnp.where(act, -decision, 0.0)

    frac = jnp.clip(g / p.g_max, 0.0, 1.0)
    set_eff = (1.0 - frac) ** p.nonlinearity
    reset_eff = frac ** p.nonlinearity * p.reset_asymmetry
    eff = jnp.where(direction > 0, set_eff, reset_eff)
    delta = direction * p.fine_step * eff * d2d * n_p * c2c
    if p.nmap_sqrt_pulses:
        nmap = nmap * jnp.sqrt(jnp.maximum(n_p, 1.0))
    g_new = jnp.clip(g + delta + jnp.where(n_p > 0, nmap, 0.0), 0.0, p.g_max)
    g_new = jnp.where(n_p > 0, g_new, g)
    return g_new, streak_new, frozen_new, n_p, direction
