"""Public wrapper for the fused WV cell-update kernel."""

from __future__ import annotations

import jax

from . import ref
from .ref import WVCellParams  # noqa: F401
from .wv_step import wv_cell_update_pallas


def wv_cell_update(
    agg, dev_mag, g, streak, frozen, c2c, nmap, d2d, p: WVCellParams,
    *, use_pallas: bool = True,
):
    """Fused verify-tail + write for one WV iteration (see ref.py)."""
    if not use_pallas:
        return ref.wv_cell_update(agg, dev_mag, g, streak, frozen, c2c, nmap, d2d, p)
    on_tpu = jax.default_backend() == "tpu"
    return wv_cell_update_pallas(
        agg, dev_mag, g, streak, frozen, c2c, nmap, d2d, p, interpret=not on_tpu
    )
