from . import ops, ref  # noqa: F401
from .ops import wv_cell_update  # noqa: F401
