# Pallas TPU kernels for the compute hot-spots the paper optimizes:
#   fwht     - the digital inverse-Hadamard decode (HD-PV/HARP periphery)
#   wv_step  - fused verify-tail -> write cell update (the WV inner loop)
#   acim_vmm - bit-sliced CBA inference VMM with fused ADC epilogue
# Each subpackage ships <name>.py (pl.pallas_call + BlockSpec), ops.py
# (jit'd wrapper with backend dispatch) and ref.py (pure-jnp oracle).
