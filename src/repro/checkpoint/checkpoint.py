"""Fault-tolerant checkpointing: atomic, async, elastic.

Design (single-process simulation of the multi-host protocol):

* **Atomic**: arrays are written to `step_<n>.tmp/` and the directory is
  renamed to `step_<n>/` only after the manifest fsyncs — a crashed save
  can never shadow a good checkpoint.
* **Async**: `CheckpointManager.save(..., blocking=False)` snapshots to
  host memory (device_get) on the caller's thread — the only part that
  must be consistent with the step — then serializes on a background
  thread so training resumes immediately (the standard async-ckpt
  overlap).
* **Elastic**: leaves are saved *unsharded* (global view).  Restore
  takes an optional `sharding_tree`; arrays are `device_put` with the
  new sharding, so a checkpoint from a 16x16 mesh restores onto 2x16x16
  (or a debug CPU mesh) unchanged — resharding is free at load time.
* **Self-describing**: a JSON manifest stores the flattened key paths,
  shapes and dtypes; restore can rebuild the pytree with or without a
  template.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _to_savable(leaf) -> tuple[np.ndarray, str]:
    """numpy cannot round-trip ml_dtypes (bf16/fp8) through .npy without
    pickle; store such leaves widened to f32 and record the true dtype in
    the manifest (restore casts back via the template or manifest)."""
    arr = np.asarray(leaf)
    orig = str(arr.dtype)
    if arr.dtype.kind not in "biufc":  # custom dtypes (bfloat16, fp8, ...)
        arr = arr.astype(np.float32)
    return arr, orig


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Blocking atomic save; returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}}
    for i, (key, leaf) in enumerate(flat.items()):
        fname = f"leaf_{i:05d}.npy"
        arr, orig_dtype = _to_savable(leaf)
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": orig_dtype,
        }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, _MANIFEST)):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    step: int | None = None,
    template: Any = None,
    sharding_tree: Any = None,
) -> tuple[int, Any]:
    """Restore (step, tree).  With a template, the pytree structure and
    leaf order come from it (robust to key-order drift); otherwise a flat
    {path: array} dict is returned.  `sharding_tree` (same structure as
    template) device_puts each leaf with the target sharding — elastic
    across mesh shapes."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    def _load(info):
        arr = np.load(os.path.join(path, info["file"]))
        if str(arr.dtype) != info["dtype"]:
            try:  # cast widened ml_dtypes leaves back (bf16 etc.)
                import ml_dtypes  # noqa: F401

                arr = arr.astype(np.dtype(info["dtype"]))
            except (TypeError, ImportError):
                pass  # template-based restore casts below
        return arr

    loaded = {key: _load(info) for key, info in manifest["leaves"].items()}
    if template is None:
        return step, loaded
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = jax.tree_util.keystr(p)
        if key not in loaded:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = loaded[key].astype(leaf.dtype) if hasattr(leaf, "dtype") else loaded[key]
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if sharding_tree is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else jax.numpy.asarray(x),
            tree,
            sharding_tree,
            is_leaf=lambda x: x is None,
        )
    return step, tree


class CheckpointManager:
    """Keep-k rotation + async background saves + failure-safe restore."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any, blocking: bool = True) -> None:
        # Snapshot on the caller thread (consistency point), serialize later.
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()

        def work():
            save_checkpoint(self.directory, step, host_tree)
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    def restore_latest(self, template: Any = None, sharding_tree: Any = None):
        self.wait()
        return restore_checkpoint(
            self.directory, None, template=template, sharding_tree=sharding_tree
        )
