import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh, prove it shards/fits, and extract roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        [--arch olmoe-1b-7b ...] [--shape train_4k ...] \
        [--multi-pod | --both] [--out results/dryrun]

Per cell this script:
  1. builds the step function (train_step / prefill_step / decode_step),
  2. jits it with the DESIGN.md Sec.-4 shardings,
  3. .lower(**input ShapeDtypeStructs)  — no arrays are allocated,
  4. .compile()                          — sharding errors surface here,
  5. prints compiled.memory_analysis() (proves per-device fit) and
     cost_analysis(), parses collective bytes from the per-device HLO,
  6. appends the roofline row to <out>/<mesh>/<arch>__<shape>.json.
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, input_specs, runnable_cells
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import (
    batch_sharding,
    cache_sharding,
    state_sharding,
)
from repro.models import ModelConfig, init_params
from repro.optim import AdamWConfig
from repro.serving import make_decode_step, make_prefill_step
from repro.training import init_train_state, make_train_step


# Per-arch gradient-accumulation factors for train_4k: big-activation
# stacks split the 256-sequence global batch into microbatches so the
# per-device working set fits HBM (EXPERIMENTS.md Sec. Perf, H8).
GRAD_ACCUM = {
    "qwen3-moe-235b-a22b": 8,
    "llama-3.2-vision-11b": 8,
    "hymba-1.5b": 4,
    "musicgen-medium": 2,
}


def build_lowerable(arch: str, shape: str, mesh, grad_accum: int | None = None):
    """Returns (jitted_fn, example_args) ready for .lower(*args)."""
    cfg = get_config(arch)
    spec = SHAPES[shape]
    specs = input_specs(cfg, spec)
    key = jax.random.PRNGKey(0)

    if spec.kind == "train":
        if grad_accum is None:
            grad_accum = GRAD_ACCUM.get(arch, 1)
        opt_cfg = AdamWConfig(state_dtype=cfg.opt_state_dtype)
        step = make_train_step(cfg, opt_cfg, mesh, grad_accum=grad_accum)
        state_sds = jax.eval_shape(
            lambda: init_train_state(key, cfg, opt_cfg)
        )
        st_sh = state_sharding(mesh, state_sds, cfg)
        b_sh = batch_sharding(mesh, specs["batch"], spec.global_batch)
        fn = jax.jit(
            step,
            in_shardings=(st_sh, b_sh),
            out_shardings=(st_sh, None),
            donate_argnums=(0,),
        )
        return fn, (state_sds, specs["batch"]), cfg, spec

    params_sds = jax.eval_shape(lambda: init_params(key, cfg))
    p_sh = state_sharding(mesh, params_sds, cfg)
    if spec.kind == "prefill":
        step = make_prefill_step(cfg, mesh, max_len=spec.seq_len)
        b_sh = batch_sharding(mesh, specs["batch"], spec.global_batch)
        cache_sds = jax.eval_shape(lambda p, b: step(p, b)[1], params_sds, specs["batch"])
        c_sh = cache_sharding(mesh, cache_sds, cfg, spec.global_batch)
        fn = jax.jit(step, in_shardings=(p_sh, b_sh), out_shardings=(None, c_sh))
        return fn, (params_sds, specs["batch"]), cfg, spec

    # decode
    step = make_decode_step(cfg, mesh)
    b_sh = batch_sharding(mesh, specs["batch"], spec.global_batch)
    c_sh = cache_sharding(mesh, specs["cache"], cfg, spec.global_batch)
    fn = jax.jit(
        lambda p, c, b: step(p, c, b),
        in_shardings=(p_sh, c_sh, b_sh),
        out_shardings=(None, None, c_sh),
        donate_argnums=(1,),
    )
    return fn, (params_sds, specs["cache"], specs["batch"]), cfg, spec


def run_cell(arch: str, shape: str, mesh, mesh_name: str, out_dir: str):
    t0 = time.time()
    fn, args, cfg, spec = build_lowerable(arch, shape, mesh)
    with jax.set_mesh(mesh):
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = rf.summarize_memory_analysis(compiled.memory_analysis())
    cost = rf.summarize_cost_analysis(compiled.cost_analysis())
    hlo = compiled.as_text()
    coll = rf.collective_bytes_from_hlo(hlo)

    chips = mesh.devices.size
    tokens = spec.global_batch * (spec.seq_len if spec.kind != "decode" else 1)
    # cost_analysis flops are per-device for SPMD modules: scale to job.
    flops_job = cost.get("flops", 0.0) * chips
    bytes_job = cost.get("bytes accessed", 0.0) * chips
    terms = rf.RooflineTerms(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=flops_job,
        hlo_bytes=bytes_job,
        collective_bytes=coll["total_bytes"],
        model_flops=rf.model_flops(cfg, spec, tokens),
        collective_detail=coll,
        memory_analysis=mem,
    ).finalize()

    row = terms.to_json()
    row["compile_seconds"] = t_compile
    row["status"] = "ok"
    os.makedirs(os.path.join(out_dir, mesh_name), exist_ok=True)
    path = os.path.join(out_dir, mesh_name, f"{arch}__{shape}.json")
    with open(path, "w") as f:
        json.dump(row, f, indent=1)

    print(
        f"[{mesh_name}] {arch} x {shape}: compiled in {t_compile:.0f}s | "
        f"mem/device argbytes={mem.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
        f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB | "
        f"flops/job={flops_job:.3e} | coll={coll['total_bytes']/2**20:.1f}MiB "
        f"| bottleneck={terms.bottleneck}",
        flush=True,
    )
    print("  memory_analysis:", mem, flush=True)
    print("  cost_analysis:", {k: v for k, v in cost.items() if v}, flush=True)
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=None)
    ap.add_argument("--shape", nargs="*", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    meshes = []
    if args.both or not args.multi_pod:
        meshes.append(("pod16x16", make_production_mesh(multi_pod=False)))
    if args.both or args.multi_pod:
        meshes.append(("multipod2x16x16", make_production_mesh(multi_pod=True)))

    cells = runnable_cells()
    if args.arch:
        cells = [(a, s) for a, s in cells if a in args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s in args.shape]

    failures = []
    for mesh_name, mesh in meshes:
        for arch, shape in cells:
            if args.skip_existing and os.path.exists(
                os.path.join(args.out, mesh_name, f"{arch}__{shape}.json")
            ):
                continue
            try:
                run_cell(arch, shape, mesh, mesh_name, args.out)
            except Exception as e:  # noqa: BLE001 - report and continue
                failures.append((mesh_name, arch, shape, repr(e)))
                print(f"[{mesh_name}] {arch} x {shape}: FAILED {e!r}", flush=True)
                traceback.print_exc()
    print(f"\ndone: {len(cells) * len(meshes) - len(failures)} ok, "
          f"{len(failures)} failed")
    for f in failures:
        print("  FAIL:", f)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
