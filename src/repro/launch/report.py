"""Render the roofline table (EXPERIMENTS.md Sec. Roofline) from the
dry-run JSONs.

    PYTHONPATH=src python -m repro.launch.report [--dir results/dryrun] \
        [--mesh pod16x16]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load_rows(dir_: str, mesh: str) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, mesh, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt_row(r: dict) -> str:
    """XLA:CPU cost_analysis counts while-loop bodies ONCE (scan trip
    counts omitted), so HLO-FLOPs is a lower bound on scanned models.
    We report the HLO-based compute term alongside the MODEL_FLOPS-based
    term (6ND / 2ND) and classify the bottleneck with the larger of the
    two; roofline-fraction = model-compute / (dominant-term)."""
    from repro.launch.roofline import PEAK_FLOPS

    ms = lambda s: f"{s * 1e3:9.3f}"
    model_comp = r["model_flops"] / (r["chips"] * PEAK_FLOPS)
    comp = max(r["compute_s"], model_comp)
    terms = {
        "compute": comp,
        "memory": r["memory_s"],
        "collective": r["collective_s"],
    }
    dom = max(terms, key=terms.get)
    frac = model_comp / max(max(terms.values()), 1e-30)
    mem = r.get("memory_analysis", {})
    temp_gib = mem.get("temp_size_in_bytes", 0) / 2**30
    arg_gib = mem.get("argument_size_in_bytes", 0) / 2**30
    return (
        f"| {r['arch']} | {r['shape']} | {ms(r['compute_s'])} | {ms(model_comp)} | "
        f"{ms(r['memory_s'])} | {ms(r['collective_s'])} | {dom} | "
        f"{frac:.2f} | {arg_gib:.2f} | {temp_gib:.2f} |"
    )


HEADER = (
    "| arch | shape | HLO-comp [ms] | 6ND-comp [ms] | memory [ms] | "
    "collective [ms] | bottleneck | roofline-frac | args GiB/dev | temp GiB/dev |\n"
    "|---|---|---|---|---|---|---|---|---|---|"
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    rows = load_rows(args.dir, args.mesh)
    print(HEADER)
    for r in rows:
        print(fmt_row(r))
    print(f"\n{len(rows)} cells; mesh={args.mesh}; "
          "terms per formulae in launch/roofline.py (v5e constants)")


if __name__ == "__main__":
    main()
