import os

if "--dryrun" in __import__("sys").argv:
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", "")
    )

"""Distributed RRAM programming driver — the paper's technique at scale.

Columns are embarrassingly parallel: the launcher shards the packed
column axis over the ENTIRE mesh (("data","model") — 256 chips/pod) so
programming a 235B-parameter model's 2.1e9 columns runs with zero
cross-chip traffic inside the verify loop.

Modes:
  * real (default): program a smoke-config model end-to-end on CPU.
  * --dryrun: lower + compile `program_columns` for a production-scale
    column batch on the 16x16 mesh and emit the roofline row — this is
    the paper-representative cell of EXPERIMENTS.md Sec. Perf.
"""

import argparse
import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import WVConfig, WVMethod, program_columns


def run_dryrun(method: str, n_columns: int, use_pallas: bool, out_dir: str):
    from repro.launch import roofline as rf
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh()
    cfg = WVConfig(method=WVMethod(method), use_pallas=use_pallas)
    spec = NamedSharding(mesh, P(("data", "model"), None))
    t_sds = jax.ShapeDtypeStruct((n_columns, cfg.n_cells), jnp.float32)
    k_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)

    fn = jax.jit(
        lambda k, t: program_columns(k, t, cfg),
        in_shardings=(NamedSharding(mesh, P()), spec),
        out_shardings=(spec, None),
    )
    with jax.set_mesh(mesh):
        compiled = fn.lower(k_sds, t_sds).compile()
    cost = rf.summarize_cost_analysis(compiled.cost_analysis())
    mem = rf.summarize_memory_analysis(compiled.memory_analysis())
    coll = rf.collective_bytes_from_hlo(compiled.as_text())
    chips = mesh.devices.size
    cells = n_columns * cfg.n_cells
    terms = rf.RooflineTerms(
        arch=f"program-wv-{method}" + ("-pallas" if use_pallas else ""),
        shape=f"cols{n_columns}",
        mesh="pod16x16",
        chips=chips,
        hlo_flops=cost.get("flops", 0.0) * chips,
        hlo_bytes=cost.get("bytes accessed", 0.0) * chips,
        collective_bytes=coll["total_bytes"],
        model_flops=2.0 * cells * 50,  # ~50 sweeps x O(cells) work floor
        collective_detail=coll,
        memory_analysis=mem,
    ).finalize()
    row = terms.to_json()
    row["status"] = "ok"
    os.makedirs(os.path.join(out_dir, "pod16x16"), exist_ok=True)
    path = os.path.join(
        out_dir, "pod16x16", f"{terms.arch}__{terms.shape}.json"
    )
    with open(path, "w") as f:
        json.dump(row, f, indent=1)
    print(
        f"[program-wv {method}{'+pallas' if use_pallas else ''}] cols={n_columns} "
        f"flops/job={terms.hlo_flops:.3e} bytes/job={terms.hlo_bytes:.3e} "
        f"coll={coll['total_bytes'] / 2**20:.1f}MiB bottleneck={terms.bottleneck}"
    )
    print("  memory_analysis:", mem)


def run_real(method: str, arch: str, baseline: bool = False):
    """Program a smoke-config model end-to-end.

    Default: the bucketed whole-model pipeline (one jitted dispatch per
    column bucket, device-side stats, column axis sharded over all local
    devices when there are several).  `--baseline` forces the per-leaf
    path for comparison.
    """
    import time

    from repro.configs import get_smoke_config
    from repro.core import pipeline
    from repro.core.programmer import deploy_params
    from repro.models import init_params

    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    mesh = None
    if not baseline and jax.device_count() > 1:
        mesh = jax.make_mesh((jax.device_count(),), ("cols",))
    pipeline.reset_counters()
    t0 = time.perf_counter()
    prog, report = deploy_params(
        jax.random.PRNGKey(1), params, WVConfig(method=WVMethod(method)),
        batched=not baseline, mesh=mesh,
    )
    dt = time.perf_counter() - t0
    path = "per-leaf baseline" if baseline else (
        f"bucketed pipeline ({pipeline.compile_count()} compiles, "
        f"{pipeline.host_sync_count()} host sync)"
    )
    print(
        f"programmed {arch} (smoke) with {method} [{path}]: "
        f"{report.num_cells:,} cells, "
        f"{report.num_columns:,} columns, rms={report.rms_cell_error_lsb:.3f} LSB, "
        f"mean iters={report.mean_iterations:.1f}, "
        f"energy={report.total_energy_pj / 1e6:.2f} uJ, "
        f"{report.num_columns / dt:,.0f} columns/s"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="harp",
                    choices=[m.value for m in WVMethod])
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--baseline", action="store_true",
                    help="per-leaf deployment path (vs bucketed pipeline)")
    ap.add_argument("--columns", type=int, default=1 << 22)
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    if args.dryrun:
        run_dryrun(args.method, args.columns, args.pallas, args.out)
    else:
        run_real(args.method, args.arch, baseline=args.baseline)


if __name__ == "__main__":
    main()
