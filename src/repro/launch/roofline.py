"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e targets):

    compute    = HLO_FLOPs / (chips * 197e12 FLOP/s)      [bf16 MXU peak]
    memory     = HLO_bytes / (chips * 819e9 B/s)          [HBM]
    collective = collective_bytes / (chips * 50e9 B/s)    [per-link ICI]

`compiled.cost_analysis()` supplies FLOPs / bytes-accessed of the
SPMD-partitioned per-device module (multiplied back to chip count where
the analysis is per-device).  Collective bytes are NOT in cost_analysis:
we parse the post-optimization per-device HLO and sum the result-shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (methodology note: result bytes upper-bound ring
wire bytes for all-gather/all-reduce and under-count reduce-scatter by
1/n — recorded per-op-type so the table stays auditable).

MODEL_FLOPS uses 6*N*D (dense) or 6*N_active*D (MoE) for train cells and
2*N*D for inference cells; the ratio MODEL_FLOPS / HLO_FLOPs exposes
remat / redundancy waste.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Any

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, Any]:
    """Sum result-shape bytes per collective op type (per-device HLO)."""
    per_type: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    counts: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if not ls.startswith("%") and " = " not in ls:
            continue
        for cname in _COLLECTIVES:
            # match the op invocation, e.g. "= bf16[...] all-gather(" or
            # "all-gather-start("; skip -done ops (same bytes as -start).
            if f" {cname}(" in ls or f" {cname}-start(" in ls:
                head = ls.split(f" {cname}")[0]
                shapes = _SHAPE_RE.findall(head)
                total = sum(_shape_bytes(d, s) for d, s in shapes)
                per_type[cname] += total
                counts[cname] += 1
                break
    return {
        "bytes_by_type": per_type,
        "counts_by_type": counts,
        "total_bytes": sum(per_type.values()),
    }


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # whole-job FLOPs (per-device x chips)
    hlo_bytes: float            # whole-job HBM bytes
    collective_bytes: float     # per-device collective result bytes
    model_flops: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0
    per_device_bytes: float = 0.0
    collective_detail: dict = dataclasses.field(default_factory=dict)
    memory_analysis: dict = dataclasses.field(default_factory=dict)

    def finalize(self) -> "RooflineTerms":
        self.compute_s = self.hlo_flops / (self.chips * PEAK_FLOPS)
        self.memory_s = self.hlo_bytes / (self.chips * HBM_BW)
        # collective bytes parsed from the per-device module already;
        # each device drives its own links.
        self.collective_s = self.collective_bytes / ICI_BW
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        self.bottleneck = max(terms, key=terms.get)
        self.useful_ratio = (
            self.model_flops / self.hlo_flops if self.hlo_flops else 0.0
        )
        return self

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def model_flops(cfg, spec, tokens: int) -> float:
    """6*N_active*D for training, 2*N_active*D for inference steps."""
    n_active = cfg.active_param_count()
    mult = 6.0 if spec.kind == "train" else 2.0
    return mult * n_active * tokens


def summarize_cost_analysis(cost: Any) -> dict[str, float]:
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    out = {}
    for k, v in dict(cost).items():
        if isinstance(v, (int, float)):
            out[k] = float(v)
    return out


def summarize_memory_analysis(mem: Any) -> dict[str, float]:
    if mem is None:
        return {}
    keys = (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
        "peak_memory_in_bytes",
    )
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            try:
                out[k] = float(v)
            except (TypeError, ValueError):
                pass
    return out


def save_results(path: str, rows: list[dict]) -> None:
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
