"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — required because the dry-run
must set XLA_FLAGS before any jax initialization.

`AxisType` landed in jax 0.5 (explicit-sharding API); on older jax the
axis-type kwarg simply doesn't exist and every mesh axis is implicitly
Auto, so we pass it only when available.
"""

from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType

    def _axis_kwargs(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}

except ImportError:  # jax < 0.5: Auto is the only (implicit) behaviour

    def _axis_kwargs(n: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds the cross-DCI "pod" axis
    (2 pods = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_debug_mesh(n_data: int = 2, n_model: int = 2, pods: int = 0):
    """Small mesh for CI-scale sharding tests (requires
    xla_force_host_platform_device_count >= n_data*n_model*max(pods,1))."""
    if pods:
        return jax.make_mesh(
            (pods, n_data, n_model),
            ("pod", "data", "model"),
            **_axis_kwargs(3),
        )
    return jax.make_mesh((n_data, n_model), ("data", "model"), **_axis_kwargs(2))
