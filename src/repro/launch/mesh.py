"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — required because the dry-run
must set XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds the cross-DCI "pod" axis
    (2 pods = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


def make_debug_mesh(n_data: int = 2, n_model: int = 2, pods: int = 0):
    """Small mesh for CI-scale sharding tests (requires
    xla_force_host_platform_device_count >= n_data*n_model*max(pods,1))."""
    if pods:
        return jax.make_mesh(
            (pods, n_data, n_model),
            ("pod", "data", "model"),
            axis_types=(AxisType.Auto,) * 3,
        )
    return jax.make_mesh(
        (n_data, n_model), ("data", "model"), axis_types=(AxisType.Auto,) * 2
    )
