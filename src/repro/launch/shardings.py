"""Per-(arch x shape x mesh) sharding assignments (DESIGN.md Sec. 4).

Parameters: FSDP over "data" on the input dim, TP over "model" on the
output dim; MoE experts EP-sharded over "model" with FSDP over "data" on
d_model; embeddings vocab-sharded over "model".  Caches: the *sequence*
axis shards over "model" (GQA kv-head counts of 4-8 cannot fill a
16-wide axis; sequence always can), batch over ("pod","data").
Non-divisible dims (15/25 heads, 1601 patches) rely on GSPMD padding.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import ShardingRules
from repro.models import ModelConfig


def param_rules(cfg: ModelConfig) -> ShardingRules:
    rules = [
        # --- MoE expert stacks: (L, E, din, dout) ---
        (r"moe.*w_gate|moe.*w_up", P(None, "model", "data", None)),
        (r"moe.*w_down", P(None, "model", None, "data")),
        (r"moe.*router", P(None, None, None)),
        # --- embeddings / heads ---
        (r"tok_embed", P("model", "data")),
        (r"lm_head", P(None, "data", "model") if cfg.n_codebooks > 1
         else P("data", "model")),
        # --- rwkv6 ---
        (r"cm_v", P(None, "model", "data")),
        (r"cm_k|cm_r", P(None, "data", "model")),
        (r"w_r\b|w_k\b|w_v\b|w_g\b", P(None, "data", "model")),
        (r"w_o\b", P(None, "model", "data")),
        (r"decay_a|decay_b|decay_base|mix_|bonus_u|ln_x", P()),
        # --- ssm ---
        (r"ssm.*in_x|ssm.*in_z|ssm.*w_dt", P(None, "data", "model")),
        (r"ssm.*w_bc", P(None, "data", None)),
        (r"ssm.*a_log|ssm.*d_skip|ssm.*dt_bias", P()),
        (r"ssm.*out", P(None, "model", "data")),
        # --- attention / dense mlp stacks: (L, din, dout) ---
        (r"wq|wk\b|wv\b|w_gate|w_up", P(None, "data", "model")),
        (r"wo\b|w_down", P(None, "model", "data")),
        # norms, gates, scalars: replicated
    ]
    return ShardingRules(rules=rules, default=P())


def _sanitize(mesh: Mesh, spec: P, shape) -> P:
    """jit in_shardings require exact divisibility on ARGUMENT dims (GSPMD
    padding only applies to internal values).  Drop any axis assignment
    whose mesh extent does not divide the dim (e.g. vocab 32001, 1601
    image patches) — that dim is stored replicated instead."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(None if i >= len(shape) else entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        extent = 1
        for a in axes:
            extent *= sizes.get(a, 1)
        out.append(entry if shape[i] % extent == 0 else None)
    return P(*out[: len(shape)])


def batch_axes(mesh: Mesh, global_batch: int):
    """Largest prefix of ("pod","data") that divides the batch."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    sizes = {a: dict(zip(mesh.axis_names, mesh.devices.shape))[a] for a in axes}
    total = 1
    chosen = []
    for a in axes:
        if global_batch % (total * sizes[a]) == 0:
            chosen.append(a)
            total *= sizes[a]
    return tuple(chosen) if chosen else None


def batch_sharding(mesh: Mesh, tree: Any, global_batch: int) -> Any:
    ba = batch_axes(mesh, global_batch)

    def spec(x):
        nd = len(x.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(ba, *([None] * (nd - 1))))

    return jax.tree.map(spec, tree)


def cache_sharding(mesh: Mesh, cache_tree: Any, cfg: ModelConfig, global_batch: int) -> Any:
    """KV caches (L, B, S, KV, hd): seq over "model", batch over data axes.
    RWKV/SSM states shard their widest feature dim over "model"."""
    ba = batch_axes(mesh, global_batch)
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        nd = len(leaf.shape)
        if "pos" in name:
            spec = P()
        elif "wkv" in name:           # (L, B, H, hd, hd)
            spec = P(None, ba, "model", None, None)
        elif "shift" in name:         # (L, B, D)
            spec = P(None, ba, "model")
        elif "ssm_h" in name:         # (L, B, d, n)
            spec = P(None, ba, "model", None)
        elif nd == 5:                  # (L, B, S, KV, hd)
            spec = P(None, ba, "model", None, None)
        else:
            spec = P(*([None] * nd))
        out.append(NamedSharding(mesh, _sanitize(mesh, spec, leaf.shape)))
    return jax.tree_util.tree_unflatten(treedef, out)


def decode_batch_sharding(mesh: Mesh, cache_tree: Any) -> Any:
    """Continuous-batching decode cache: ONLY the batch axis shards,
    over "data" (DESIGN.md Sec. 18).

    Deliberately NOT `cache_sharding`: that spec also shards the
    sequence axis over "model", which splits each attention softmax
    reduction across devices and re-associates the float accumulation —
    breaking the scheduler's bit-identity contract (a request's tokens
    must be identical in any shard layout).  Sharding only the batch
    axis keeps every per-slot reduction local to one device: decode
    rows are independent, so the math per row is untouched and tokens
    stay bitwise equal to the unsharded run, while the decode batch and
    cache memory scale with the "data" axis.  Model/TP parallelism
    composes orthogonally: CIM tile planes keep sharding their output
    channels over "model" (`cim_weight_specs`).

    Batch sizes not divisible by the "data" extent fall back to
    replicated via `_sanitize` (jit argument dims must divide exactly),
    and extent-1 mesh axes are dropped entirely (`_drop_trivial`): GSPMD
    canonicalizes them away in jit OUTPUT shardings, so keeping them in
    the committed input sharding would make the second decode call see
    a "different" layout and silently re-lower the whole step — a
    hidden post-warmup compile the trace-count contract cannot see.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        nd = len(leaf.shape)
        if "pos" in name:              # (B,)
            spec = P("data")
        elif nd >= 2:                  # stacked (L, B, ...) layouts
            spec = P(None, "data", *([None] * (nd - 2)))
        else:
            spec = P(*([None] * nd))
        spec = _drop_trivial(mesh, _sanitize(mesh, spec, leaf.shape))
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def decode_vec_sharding(mesh: Mesh, n_slots: int) -> NamedSharding:
    """Sharding for the scheduler's per-slot (B,) vectors (cur tokens,
    rids, gens): batch over "data", matching `decode_batch_sharding`."""
    return NamedSharding(
        mesh, _drop_trivial(mesh, _sanitize(mesh, P("data"), (n_slots,)))
    )


def _drop_trivial(mesh: Mesh, spec: P) -> P:
    """Remove mesh axes of extent 1 from a spec (partitioning over them
    is a no-op, and GSPMD strips them from jit output shardings)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
            continue
        axes = tuple(
            a for a in (entry if isinstance(entry, tuple) else (entry,))
            if sizes.get(a, 1) > 1
        )
        out.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    return P(*out)


def cim_weight_specs(mesh: Mesh, w: Any) -> dict[str, NamedSharding]:
    """Sharding for one `cim.CIMWeight`'s children (analog serving TP).

    Tile planes g_pos/g_neg ([L,] T, S, R, M) and the dequant scale
    ([L,] M) shard their output-channel axis M over "model" — the same
    TP assignment the dense (L, din, dout) projections use, so the
    analog forward's per-slice ADC readouts stay local to the shard
    that consumes them.  Noise keys and the per-layer `layer_id` index
    are replicated (a few bytes).  Non-divisible M falls back to
    replicated via `_sanitize`.
    """
    def out_spec(arr):
        spec = P(*([None] * (arr.ndim - 1)), "model")
        return NamedSharding(mesh, _sanitize(mesh, spec, arr.shape))

    specs = {
        "g_pos": out_spec(w.g_pos),
        "g_neg": out_spec(w.g_neg),
        "scale": out_spec(w.scale),
        "key": NamedSharding(mesh, P()),
    }
    if w.layer_id is not None:
        specs["layer_id"] = NamedSharding(mesh, P())
    return specs


def shard_cim_weight(mesh: Mesh, w: Any) -> Any:
    """device_put a `CIMWeight`'s children onto the mesh per the specs."""
    import dataclasses

    specs = cim_weight_specs(mesh, w)
    return dataclasses.replace(
        w, **{k: jax.device_put(getattr(w, k), s) for k, s in specs.items()}
    )


def state_sharding(mesh: Mesh, state_tree: Any, cfg: ModelConfig) -> Any:
    """TrainState sharding: params + AdamW m/v share the param rules."""
    from repro.distributed.sharding import shard_params_tree

    rules = param_rules(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_tree)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        if leaf.ndim == 0:
            out.append(NamedSharding(mesh, P()))
            continue
        spec = rules.spec(name)
        spec = P(*spec[: leaf.ndim]) if len(spec) > leaf.ndim else spec
        out.append(NamedSharding(mesh, _sanitize(mesh, spec, leaf.shape)))
    return jax.tree_util.tree_unflatten(treedef, out)
