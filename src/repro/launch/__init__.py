# Launchers: production mesh, multi-pod dry-run, roofline extraction,
# train/serve/program drivers.  Import modules directly (repro.launch.mesh,
# repro.launch.dryrun, ...) — dryrun must set XLA_FLAGS before jax init.
