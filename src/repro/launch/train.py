"""Production training launcher: mesh + sharded state + fault tolerance.

On real hardware this runs under `jax.distributed.initialize()`; in this
container it runs the same code path on a debug mesh:

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --smoke --steps 20
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data import SyntheticLM
from repro.distributed import FaultInjector, FaultTolerantRunner, StragglerMonitor
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.launch.shardings import batch_sharding, state_sharding
from repro.optim import AdamWConfig
from repro.training import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/harp_launch_train")
    ap.add_argument("--inject-failure", type=int, nargs="*", default=())
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    n_dev = len(jax.devices())
    if args.production_mesh:
        mesh = make_production_mesh()
    elif n_dev >= 4:
        mesh = make_debug_mesh(2, 2)
    else:
        mesh = make_debug_mesh(1, 1)

    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch)
    opt_cfg = AdamWConfig(lr_peak=1e-3, state_dtype=cfg.opt_state_dtype)
    with jax.set_mesh(mesh):
        state = init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg)
        st_sh = state_sharding(mesh, state, cfg)
        state = jax.device_put(state, st_sh)
        b_sh = batch_sharding(mesh, data.global_batch_at(0)._asdict(), args.batch)
        step = jax.jit(
            make_train_step(cfg, opt_cfg, mesh, total_steps=args.steps),
            in_shardings=(st_sh, b_sh),
            out_shardings=(st_sh, None),
            donate_argnums=(0,),
        )

        monitor = StragglerMonitor()
        manager = CheckpointManager(args.ckpt_dir, keep=2)

        def step_fn(state, batch):
            t0 = time.perf_counter()
            state, metrics = step(state, batch)
            loss = float(metrics["loss"])
            monitor.observe(int(state.opt.step), time.perf_counter() - t0)
            return state, {"loss": loss}

        runner = FaultTolerantRunner(
            step_fn,
            lambda s: jax.device_put(data.global_batch_at(s)._asdict(), b_sh),
            manager,
            checkpoint_every=max(args.steps // 2, 10),
            injector=FaultInjector(fail_at_steps=tuple(args.inject_failure)),
        )
        state, logs = runner.run(state, 0, args.steps)
    print(
        f"{args.arch} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
        f"loss {logs[0]['loss']:.4f} -> {logs[-1]['loss']:.4f} "
        f"restarts={runner.restarts}"
    )


if __name__ == "__main__":
    main()
