"""Verify-read noise model (paper eqs. 2-4).

For one verification sweep of a column read with patterns a_1..a_N:

    y_hat_i = a_i^T w  +  n_uc,i  +  mu_cm

* n_uc,i ~ N(0, sigma_uc^2) i.i.d. per measurement (TIA/ADC thermal noise) —
  independent across patterns AND across repeated reads (so multi-read
  averaging does average it down).
* mu_cm ~ N(0, sigma_cm^2) per column per sweep — constant across all N
  patterns of that sweep (shared TIA/ADC offset, reference drift, IR drop),
  independent across columns.  Because it is constant within the sweep,
  multi-read averaging does NOT remove it, while Hadamard decoding cancels
  it exactly for the N-1 balanced rows (eq. 7).

Units: cell-LSB throughout.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import rng
from .types import NoiseConfig

__all__ = ["sample_sweep_noise"]


def sample_sweep_noise(
    key: jax.Array,
    batch_shape: tuple[int, ...],
    n_meas: int,
    noise: NoiseConfig,
) -> jax.Array:
    """Noise for one verification sweep.

    Returns array of shape (*batch_shape, n_meas): i.i.d. uncorrelated
    noise plus a per-column common-mode offset broadcast across the
    measurement axis.  `key` may be a batch of per-column keys (one per
    `batch_shape[0]` column — the batched-pipeline RNG policy, DESIGN.md
    Sec. 10), in which case each column draws from its own sub-stream.
    """
    k_uc, k_cm = rng.split(key)
    n_uc = noise.sigma_uc_lsb * rng.normal(k_uc, (*batch_shape, n_meas))
    mu_cm = noise.sigma_cm_lsb * rng.normal(k_cm, (*batch_shape, 1))
    return n_uc + mu_cm
