"""Per-column PRNG sub-streams for batched programming (DESIGN.md Sec. 10).

The WV engine historically drew every stochastic field with the batch
shape baked into the call (``normal(key, (C, N))``), which welds the
noise stream to the exact column batch: programming a leaf alone and
programming it inside a concatenated multi-leaf bucket produce different
draws, so a bucketed deployment could never be bit-compared against the
per-leaf path.

The batched pipeline instead gives every physical column its own key,

    col_key[c] = fold_in(master_key, col_uid[c])

and draws each column's fields from its own stream (``vmap`` of the
per-column sampler).  A column's realization then depends only on
(master key, column uid) — not on which bucket it rode in, how much
padding sat next to it, or how many other leaves were batched along —
which is what makes `DeployedModel.materialize()` bit-identical between
the per-leaf and bucketed deployment paths.

These helpers mirror `jax.random.split` / `fold_in` / `normal` but
transparently accept either a single key or a 1-D batch of keys (both
classic ``uint32[2]`` keys and new-style typed key arrays).  All engine
sampling sites route through them, so `program_columns` supports both
RNG policies with one code path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["batch_ndim", "fold_col_keys", "split", "fold_in", "normal",
           "uniform"]


def batch_ndim(key: jax.Array) -> int:
    """Number of leading batch axes on a key (0 = single key)."""
    if jax.dtypes.issubdtype(key.dtype, jax.dtypes.prng_key):
        return key.ndim
    return key.ndim - 1


def fold_col_keys(key: jax.Array, col_ids: jax.Array) -> jax.Array:
    """Derive one key per column: ``fold_in(key, col_ids[c])``."""
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(col_ids)


def split(key: jax.Array, num: int = 2) -> tuple[jax.Array, ...]:
    """`jax.random.split`, element-wise over a key batch if present."""
    if batch_ndim(key):
        ks = jax.vmap(lambda k: jax.random.split(k, num))(key)
        return tuple(ks[:, j] for j in range(num))
    ks = jax.random.split(key, num)
    return tuple(ks[j] for j in range(num))


def fold_in(key: jax.Array, data) -> jax.Array:
    """`jax.random.fold_in` with the same scalar over a key batch."""
    if batch_ndim(key):
        return jax.vmap(lambda k: jax.random.fold_in(k, data))(key)
    return jax.random.fold_in(key, data)


def normal(key: jax.Array, shape, dtype=jnp.float32) -> jax.Array:
    """Normal draw of `shape`; a key batch owns the leading axis.

    With a single key this is exactly ``jax.random.normal(key, shape)``.
    With a batch of C keys, `shape` must lead with C and each column
    draws its ``shape[1:]`` tail from its own stream.
    """
    if batch_ndim(key):
        assert shape[0] == key.shape[0], (shape, key.shape)
        tail = tuple(shape[1:])
        return jax.vmap(lambda k: jax.random.normal(k, tail, dtype))(key)
    return jax.random.normal(key, shape, dtype)


def uniform(key: jax.Array, shape, dtype=jnp.float32) -> jax.Array:
    """U[0, 1) draw of `shape`; batch-transparent like :func:`normal`."""
    if batch_ndim(key):
        assert shape[0] == key.shape[0], (shape, key.shape)
        tail = tuple(shape[1:])
        return jax.vmap(lambda k: jax.random.uniform(k, tail, dtype))(key)
    return jax.random.uniform(key, shape, dtype)
