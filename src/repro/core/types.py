"""Configuration dataclasses for the HARP write-and-verify stack.

All conductances are expressed in *cell-LSB units*: LSB = G_max / (2^Bc - 1),
so a Bc-bit cell stores integer target levels in {0, ..., 2^Bc - 1} and
G_max == (2^Bc - 1) LSB.  sigma_map/G_max = 0.10 from the paper therefore
becomes sigma_map_lsb = 0.10 * (2^Bc - 1) = 0.7 LSB at Bc = 3.

Configs are plain frozen dataclasses: they are *static* under jit (closed
over, never traced).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class WVMethod(str, enum.Enum):
    """Write-and-verify scheme (paper Section 5 naming)."""

    CW_SC = "cw_sc"      # column-wise single-cell: one-hot reads + compare-only ADC
    MRA = "mra"          # multi-read averaging: M x one-hot reads, full SAR each
    HD_PV = "hd_pv"      # Hadamard reads + full SAR + inverse-Hadamard decode
    HARP = "harp"        # Hadamard reads + compare-only + ternary inverse decode


@dataclasses.dataclass(frozen=True)
class DeviceConfig:
    """RRAM cell behaviour (paper Table 1 + Fig. 3)."""

    bc: int = 3                      # bits per cell
    g_max_us: float = 13.0           # max conductance (microsiemens), LRS
    fine_step_lsb: float = 0.25      # fine SET/RESET pulse: ~0.25 LSB / pulse
    coarse_step_lsb: float = 1.25    # coarse SET pulse: 5 steps/pulse = 1.25 LSB
    sigma_map_frac: float = 0.10     # sigma_map / G_max per write event (eq. 1)
    # Nonlinearity / asymmetry (Fig. 3): effective step shrinks near the
    # rails; RESET is slightly weaker than SET (asymmetric switching).
    nonlinearity: float = 0.35       # 0 = linear; exponent of the rail taper
    reset_asymmetry: float = 0.85    # RESET step = asymmetry * SET step
    sigma_c2c_frac: float = 0.15     # cycle-to-cycle multiplicative step jitter
    sigma_d2d_frac: float = 0.10     # device-to-device static step spread
    # eq. (1) interpretation: "event" = additive sigma_map per write event
    # (one-shot mapping error); "pulse" = per-pulse noise proportional to
    # the pulse step (sigma_map is realized by a full-swing coarse write).
    map_noise_mode: str = "pulse"

    @property
    def levels(self) -> int:
        return 1 << self.bc

    @property
    def g_max_lsb(self) -> float:
        return float(self.levels - 1)

    @property
    def sigma_map_lsb(self) -> float:
        return self.sigma_map_frac * self.g_max_lsb


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Static cell-fault population + spatially correlated variation.

    Models the faulty-silicon regime real RRAM macros deploy into
    (DESIGN.md Sec. 15): a fraction of cells never switch (stuck-at),
    a fraction switch with collapsed efficiency (weak), and fault rates
    / step efficiency vary systematically per tile and per chip.  All
    probabilities are per-cell; the spatial geometry maps a physical
    column uid onto a (chip, tile) coordinate, so the same uid always
    lands on the same silicon — the fault map is a device property,
    sampled once per deployment from per-column RNG sub-streams
    (bucketed deploys stay bit-identical, DESIGN.md Sec. 10).

    The all-zero default is contractually inert: a `FaultConfig()` map
    pins no cell and multiplies every step by exactly 1.0, so the
    programmed conductances are bit-identical to a fault-free run.
    """

    p_stuck_hrs: float = 0.0        # SA0: filament never forms; g pinned at 0
    p_stuck_lrs: float = 0.0        # SA1: shorted filament; g pinned at G_max
    p_weak: float = 0.0             # step-efficiency collapse (still moves)
    weak_efficiency: float = 0.05   # weak cell step multiplier
    p_exhausted: float = 0.0        # endurance-dead: frozen at a random level
    # Physical geometry: column uid -> tile -> chip.
    columns_per_tile: int = 128
    tiles_per_chip: int = 64
    # Spatially correlated variation: lognormal per-tile fault-rate
    # multiplier (decades) and per-tile / per-chip systematic step-
    # efficiency spread (fractional).  Columns in one tile share a draw.
    sigma_tile_fault_dec: float = 0.0
    sigma_tile_eff_frac: float = 0.0
    sigma_chip_eff_frac: float = 0.0

    @property
    def any_faults(self) -> bool:
        return (
            max(self.p_stuck_hrs, self.p_stuck_lrs, self.p_weak,
                self.p_exhausted) > 0.0
            or max(self.sigma_tile_fault_dec, self.sigma_tile_eff_frac,
                   self.sigma_chip_eff_frac) > 0.0
        )

    def replace(self, **kw) -> "FaultConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ADCConfig:
    """Column TIA + SAR ADC (paper Table 1, Fig. 7)."""

    bits: int = 9                    # 9-bit for N=32, 10-bit for N=64
    # Full scale covers the whole column current range: N * (2^Bc - 1) LSB.
    # One-hot reads use the same hardware (same full scale) -> coarser
    # effective quantization for single-cell SAR reads; Hadamard reads use
    # the full dynamic range.  V_sam switching (Sec. 3.2) re-centres the
    # range for balanced rows without changing the bit budget.
    t_read_pulse_ns: float = 32.0
    t_sar_ns: float = 47.5           # TIA+ADC latency, full SAR conversion
    t_compare_ns: float = 30.0       # TIA+ADC latency, compare-only decision
    e_tia_pj: float = 1.44           # TIA energy per read
    e_sar_pj: float = 32.0           # full n-bit SAR conversion energy
    # one-shot compare: comparator + CDAC preset to the target code
    # (Table 1 ADC range 1.8-32 pJ; calibrated against the paper's
    # 9.5x HARP-vs-MRA energy ratio, see benchmarks/fig12)
    e_compare_pj: float = 3.6


@dataclasses.dataclass(frozen=True)
class NoiseConfig:
    """Verify-read noise (eqs. 2-4), in cell-LSB units."""

    sigma_read_lsb: float = 0.7      # total read-noise std: sqrt(uc^2 + cm^2)
    rho_cm: float = 0.0              # common-mode fraction: cm^2/(uc^2+cm^2)

    @property
    def sigma_uc_lsb(self) -> float:
        return self.sigma_read_lsb * (1.0 - self.rho_cm) ** 0.5

    @property
    def sigma_cm_lsb(self) -> float:
        return self.sigma_read_lsb * self.rho_cm ** 0.5


@dataclasses.dataclass(frozen=True)
class WVConfig:
    """End-to-end write-and-verify configuration."""

    method: WVMethod = WVMethod.HARP
    n_cells: int = 32                # column length N
    weight_bits: int = 6             # B
    k_streak: int = 2                # consecutive in-threshold reads to freeze
    # Streaks begin accumulating only after the open-loop coarse residual has
    # been worked off; freezing during the high-interference transient would
    # defeat the streak counter's stated purpose ("preventing premature
    # freezing from noisy observations", Sec. 3.1).  Magnitude methods
    # (MRA/HD-PV) clear the transient in 1-2 multi-pulse sweeps; ternary
    # methods (CW-SC/HARP) need ~residual/fine_step single-pulse sweeps.
    # See DESIGN.md Sec. 8.
    freeze_warmup_iters: int = 7
    freeze_warmup_ternary_extra: int = 4
    max_fine_iters: int = 50
    max_coarse_iters: int = 10
    decision_threshold_lsb: float = 0.5
    tau_w: float = 4.0               # HARP cell-domain threshold (unnormalized)
    mra_reads: int = 5               # M for multi-read averaging
    max_pulses_per_iter: int = 16    # magnitude methods: pulse burst cap
    # Bounded retry budget (DESIGN.md Sec. 15): a per-cell write-pulse
    # budget after which an unconverged cell is declared unprogrammable
    # and frozen (give-up).  None = legacy unbounded behaviour; the
    # give-up machinery then compiles to the exact current computation.
    give_up_pulses: Optional[int] = None
    device: DeviceConfig = dataclasses.field(default_factory=DeviceConfig)
    adc: ADCConfig = dataclasses.field(default_factory=ADCConfig)
    noise: NoiseConfig = dataclasses.field(default_factory=NoiseConfig)
    # Route the Hadamard decode through the Pallas FWHT kernel AND the
    # fine-WV cell update (threshold -> streak -> freeze -> pulse-size ->
    # device-step) through the fused Pallas wv_step kernel: one VMEM pass
    # instead of ~6 materialized (C, N) intermediates per iteration.
    # Bit-identical to the unfused path (write noise is pre-sampled from
    # the same key splits); kernels run interpreted off-TPU.
    use_pallas: bool = False

    @property
    def slices_per_weight(self) -> int:
        assert self.weight_bits % self.device.bc == 0
        return self.weight_bits // self.device.bc

    def replace(self, **kw) -> "WVConfig":
        return dataclasses.replace(self, **kw)


def default_config_for_array(n_cells: int) -> WVConfig:
    """Paper defaults: 9-bit ADC at N=32, 10-bit ADC at N=64 (Figs. 10/11).

    tau_w scales linearly with N: the unnormalized aggregate s_w = H^T s_y
    has signal gain ~N and noise ~sqrt(N), so the paper's tau_w = 4 at
    N = 32 corresponds to tau_w = 8 at N = 64 (validated: keeps HARP the
    energy-optimal mode at 64-cell columns, Fig. 13(c)-(d))."""
    bits = 9 if n_cells <= 32 else 10
    return WVConfig(
        n_cells=n_cells,
        adc=ADCConfig(bits=bits),
        tau_w=4.0 * n_cells / 32.0,
    )
