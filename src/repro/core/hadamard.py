"""Hadamard read-basis construction and fast Walsh-Hadamard transforms.

This module implements the measurement-basis machinery of the paper:

* Sylvester-Hadamard matrices ``H_N`` with entries in {-1, +1} and
  ``H^T H = N I`` (Prop. 2.1 optimality over +-1 read matrices).
* Forward encode ``y = H @ w``  — the *analog* column read, simulated.
* Inverse decode ``x = (1/N) H^T y`` — the *digital* periphery step.
* ``fwht``: the O(N log N) fast Walsh-Hadamard butterfly used by both
  (Sylvester H is symmetric, so encode and unnormalized decode are the
  same transform).  The Pallas TPU kernel in ``repro.kernels.fwht``
  implements the identical butterfly; this file is the pure-jnp oracle
  used across the WV engine and as the kernel reference.

Shapes follow the WV engine convention: the *last* axis is the N-cell
column axis; any leading axes are batch (columns, slices, ...).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "hadamard_matrix",
    "is_hadamard",
    "fwht",
    "encode",
    "decode",
    "decode_unnormalized",
]


@functools.lru_cache(maxsize=None)
def _hadamard_np(n: int) -> np.ndarray:
    """Sylvester construction of the n x n Hadamard matrix (n a power of 2)."""
    if n < 1 or (n & (n - 1)) != 0:
        raise ValueError(f"Sylvester-Hadamard order must be a power of 2, got {n}")
    h = np.array([[1.0]], dtype=np.float64)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return h


def hadamard_matrix(n: int, dtype=jnp.float32) -> jax.Array:
    """The N x N Sylvester-Hadamard read matrix (rows are read patterns).

    Row 0 is the all +1 pattern (the only unbalanced row: it alone
    carries the common-mode offset after decoding, eq. (7)).
    """
    return jnp.asarray(_hadamard_np(n), dtype=dtype)


def is_hadamard(a: np.ndarray) -> bool:
    """Check A in {-1,+1}^{NxN} with A^T A = N I (the Prop. 2.1 bound)."""
    a = np.asarray(a)
    n = a.shape[0]
    if a.shape != (n, n) or not np.all(np.isin(a, (-1.0, 1.0))):
        return False
    return np.array_equal(a.T @ a, n * np.eye(n))


def fwht(x: jax.Array, axis: int = -1) -> jax.Array:
    """Fast Walsh-Hadamard transform along ``axis`` (unnormalized).

    ``fwht(x) == x @ H_N`` for the Sylvester ``H_N`` (which is symmetric,
    so this also equals ``H_N @ x`` along that axis).  log2(N) butterfly
    stages, each a reshape + paired add/sub — this is the exact dataflow
    the Pallas kernel implements stage-by-stage in VMEM.
    """
    axis = axis % x.ndim
    if axis != x.ndim - 1:
        x = jnp.moveaxis(x, axis, -1)
    n = x.shape[-1]
    if n & (n - 1):
        raise ValueError(f"FWHT length must be a power of 2, got {n}")
    shape = x.shape
    stages = n.bit_length() - 1
    # Butterfly: at stage s, pair elements h = 2^s apart.
    for s in range(stages):
        h = 1 << s
        y = x.reshape(shape[:-1] + (n // (2 * h), 2, h))
        a = y[..., 0, :]
        b = y[..., 1, :]
        x = jnp.concatenate([a + b, a - b], axis=-1).reshape(shape)
    if axis != x.ndim - 1:
        x = jnp.moveaxis(x, -1, axis)
    return x


def encode(w: jax.Array, axis: int = -1) -> jax.Array:
    """Analog Hadamard column read (noiseless part): y = H w.

    ``w``: (..., N) cell conductances in LSB units.  Returns (..., N)
    Hadamard-domain measurements.  Row i of H is the i-th read pattern
    (+-1 BL drive of Fig. 6(a)).
    """
    return fwht(w, axis=axis)


def decode_unnormalized(y: jax.Array, axis: int = -1) -> jax.Array:
    """H^T y without the 1/N — used by HARP's ternary aggregation (eq. 10
    with the threshold tau_w applied to the unnormalized sum)."""
    return fwht(y, axis=axis)


def decode(y: jax.Array, axis: int = -1) -> jax.Array:
    """Inverse Hadamard decode: x = (1/N) H^T y (eq. 6)."""
    n = y.shape[axis % y.ndim]
    return fwht(y, axis=axis) / n
