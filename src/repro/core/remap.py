"""Spare-column remapping and fault-aware placement (DESIGN.md Sec. 15).

The right system response to unprogrammable cells is detection plus
redundancy, not infinite retry (Hirtzlin et al. 1904.03652, Bocquet
et al. 1902.02528).  This module owns the *placement* third of the
fault-model ownership contract: the device samples faults
(`core.device.sample_fault_map`), the WV engine decides give-up
(`core.wv` bounded retry budget), and remap decides where weight lives:

* **Spare-column remapping** — each leaf provisions
  ``ceil(spare_frac * C)`` spare physical columns; after the primary
  programming pass the worst columns (by `WVStats.gave_up`) are
  re-targeted onto spares, and a `RemapTable` permutation makes served
  traffic and scrubs see the repaired geometry.  Every decision is a
  device-side jnp op on the still-on-device stats — remapping adds ZERO
  host syncs to a deploy.
* **Fault-aware placement** — a pre-deploy "factory probe" of per-tile
  quality (the spatially correlated fault-rate field the device model
  exposes as `device.tile_quality`) ranks physical tiles, and sensitive
  leaves are steered onto the cleanest silicon.  The probe is one tiny
  host transfer BEFORE the dispatch stream starts (real fabs ship a
  known-bad-block map with the part), so the single-host-sync deploy
  contract is untouched.

The permutation invariant (property-tested): `RemapTable.perm` maps the
C logical columns onto C *distinct* physical rows of the (C + S)-row
physical array — no weight is lost or duplicated — and `active` marks
exactly the image of `perm`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import device as dev_mod
from .types import FaultConfig

__all__ = [
    "RemapConfig",
    "RemapTable",
    "n_spares",
    "spare_candidates",
    "build_table",
    "identity_table",
    "apply_remap",
    "plan_placement",
]


@dataclasses.dataclass(frozen=True)
class RemapConfig:
    """Spare provisioning + placement policy.

    `min_gave_up`: a primary column is remapped only when at least this
    many of its cells gave up AND its spare programmed no worse — a
    remap can repair, never regress.
    """

    spare_frac: float = 0.25        # spares per leaf as a fraction of C
    min_gave_up: int = 1
    placement: bool = False         # steer leaves away from bad tiles
    placement_provision: float = 2.0  # probed tiles / needed tiles

    def replace(self, **kw) -> "RemapConfig":
        return dataclasses.replace(self, **kw)


class RemapTable(NamedTuple):
    """Logical->physical column view of one leaf's (C + S)-row array.

    perm:   (C,) int32 — logical column c is served by physical row
            ``perm[c]``; identity where no remap happened, ``C + i`` for
            a column repaired onto spare i.
    active: (C + S,) bool — physical rows carrying live weight (exactly
            the image of `perm`); remapped-away primaries and unused
            spares are inactive, so scrubs skip them.
    """

    perm: jax.Array
    active: jax.Array


def n_spares(c: int, cfg: RemapConfig) -> int:
    """Spare columns provisioned for a C-column leaf (host-side)."""
    if cfg.spare_frac <= 0.0:
        return 0
    return max(1, min(c, math.ceil(cfg.spare_frac * c)))


def spare_candidates(gave_up: jax.Array, s: int) -> jax.Array:
    """The s worst primary columns by give-up count (device-side).

    Ties resolve by column index (stable argsort of the negated count),
    so the candidate set is deterministic.
    """
    order = jnp.argsort(-gave_up, stable=True)
    return order[:s].astype(jnp.int32)


def build_table(
    primary_gave_up: jax.Array,
    cand: jax.Array,
    spare_gave_up: jax.Array,
    min_gave_up: int = 1,
) -> RemapTable:
    """Decide the remap from programming evidence (device-side).

    Candidate i (primary column ``cand[i]``) is remapped onto spare i
    iff the primary had >= `min_gave_up` unprogrammable cells and the
    spare programmed no worse (fewer-or-equal gave-up cells) — a spare
    on equally bad silicon is not an improvement worth the swap.
    """
    c = primary_gave_up.shape[0]
    s = cand.shape[0]
    sidx = jnp.arange(s, dtype=jnp.int32)
    want = primary_gave_up[cand] >= float(min_gave_up)
    better = spare_gave_up <= primary_gave_up[cand]
    take = want & better
    perm = (
        jnp.arange(c, dtype=jnp.int32)
        .at[cand]
        .set(jnp.where(take, c + sidx, cand))
    )
    active = (
        jnp.ones((c + s,), bool)
        .at[cand].set(~take)
        .at[c + sidx].set(take)
    )
    return RemapTable(perm=perm, active=active)


def identity_table(c: int, s: int = 0) -> RemapTable:
    """No-op table: identity perm, spares (if any) inactive."""
    return RemapTable(
        perm=jnp.arange(c, dtype=jnp.int32),
        active=jnp.concatenate(
            [jnp.ones((c,), bool), jnp.zeros((s,), bool)]
        ),
    )


def apply_remap(x: jax.Array, table: RemapTable | None) -> jax.Array:
    """Physical (C + S, ...) array -> logical (C, ...) view."""
    if table is None:
        return x
    return x[table.perm]


def plan_placement(
    key: jax.Array,
    counts: Sequence[int],
    fault_cfg: FaultConfig,
    sensitivities: Sequence[float] | None = None,
    provision: float = 2.0,
) -> list[np.ndarray]:
    """Assign each leaf's physical column uids onto the cleanest tiles.

    Args:
      key: the deployment master key — `device.tile_quality` is a
        deterministic function of (key, tile id), so the probe sees
        exactly the silicon the deploy-time fault sampler will realize.
      counts: per-leaf physical column counts (primaries + spares).
      fault_cfg: fault population (geometry + correlated fields).
      sensitivities: per-leaf placement priority (higher = placed
        first, onto better tiles).  Default ``1 / count``: small leaves
        are cheap to place well and tend to be disproportionately
        load-bearing (heads, routers); big backbone leaves soak up the
        remaining tiles.
      provision: probed tiles / needed tiles (the fleet a part is
        binned from; > 1 gives placement real choices).

    Returns one int32 uid array per leaf (whole tiles, so a leaf's
    columns share tile-correlated fields with their own spares, not a
    neighbour's).  Leaves get disjoint uid ranges.  The probe is one
    small device->host transfer issued before any programming dispatch.
    """
    counts = [int(c) for c in counts]
    if sensitivities is None:
        sensitivities = [1.0 / max(c, 1) for c in counts]
    assert len(sensitivities) == len(counts)
    cpt = fault_cfg.columns_per_tile
    tiles_needed = [max(1, -(-c // cpt)) for c in counts]
    total = sum(tiles_needed)
    n_avail = max(total, math.ceil(total * max(provision, 1.0)))
    # Factory probe: the per-tile fault-rate multiplier, fetched once
    # before the dispatch stream (not via pipeline.host_fetch — it is
    # not a stream sync, and the single-host-sync contract counts those).
    q = np.asarray(
        jax.device_get(
            dev_mod.tile_quality(
                key, jnp.arange(n_avail, dtype=jnp.int32), fault_cfg
            )
        )
    )
    tile_order = np.argsort(q, kind="stable")  # cleanest first
    leaf_order = np.argsort(
        -np.asarray(sensitivities, dtype=np.float64), kind="stable"
    )
    uid_arrays: list[np.ndarray | None] = [None] * len(counts)
    t = 0
    for li in leaf_order:
        k = tiles_needed[li]
        tiles = np.sort(tile_order[t : t + k])
        t += k
        uids = np.concatenate(
            [tid * cpt + np.arange(cpt, dtype=np.int64) for tid in tiles]
        )[: counts[li]]
        uid_arrays[li] = uids.astype(np.int32)
    return uid_arrays  # type: ignore[return-value]
