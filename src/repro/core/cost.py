"""Circuit-level latency / energy cost model (paper Table 1, Sec. 5.3).

This module owns the Table-1 CONSTANTS (`CircuitCost`, plus `ADCConfig`
in core.types) and the write/inference phase pricing.  The verify READ
phase is priced by the shared readout subsystem from the same constants
(`repro.readout.cost.sweep_cost`, generalized over the basis x converter
matrix); `read_phase_cost` below is the WVConfig-facing wrapper kept for
the per-method call sites:

  CW-SC : N one-hot reads, compare-only ADC       (N x (t_pulse + t_cmp))
  MRA-M : M*N one-hot reads, full SAR each        (M*N x (t_pulse + t_sar))
  HD-PV : N Hadamard reads, full SAR each         (N x (t_pulse + t_sar))
          + inverse-Hadamard digital decode
  HARP  : N Hadamard reads, compare-only (1-2 cmp)(N x (t_pulse + t_cmp'))
          + ternary inverse-Hadamard aggregate

Write phase: SET and RESET pulses are applied column-parallel; the phase
latency is max(pulses) * t_write within each phase, and energy is
V^2 * G * t per pulse integrated over the actual conductances.

Units: ns and pJ.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .types import ADCConfig, DeviceConfig, WVConfig, WVMethod

__all__ = [
    "CircuitCost",
    "read_phase_cost",
    "write_phase_cost",
    "decode_cost",
    "inference_token_cost",
]


@dataclasses.dataclass(frozen=True)
class CircuitCost:
    """Extra Table-1 constants not owned by ADCConfig."""

    t_write_pulse_ns: float = 100.0
    v_set: float = 2.0
    v_reset: float = 2.0
    v_coarse: float = 4.0
    t_adder_ns: float = 5.0
    e_adder_hdpv_pj: float = 0.9   # multi-bit accumulate (0.8-1.0 pJ)
    e_adder_harp_pj: float = 0.2   # ternary accumulate
    g_lsb_us: float = 13.0 / 7.0   # conductance per LSB (G_max / (2^Bc - 1))
    # Inference phase (analog serving, DESIGN.md Sec. 11): bit-serial
    # input DAC row drivers — 1-bit pulse drivers, far cheaper than the
    # column ADCs they feed.
    t_dac_ns: float = 2.0          # row-driver settle per bit plane
    e_dac_pj: float = 0.05         # per driven row per plane


def read_phase_cost(
    cfg: WVConfig, cost: CircuitCost, n_compares: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """(latency_ns, energy_pj) of one verification sweep of one column.

    `n_compares`: (..., N) per-measurement comparison counts for
    compare-only modes (HARP's 1-or-2); the 1.5/read expectation if None.
    Returns scalars (or batched arrays if n_compares is batched).

    Thin wrapper: maps the WV method onto its readout config and prices
    the sweep with `repro.readout.cost.sweep_cost` (imported lazily —
    core.cost is a readout dependency, so the module level would cycle).
    """
    from repro.readout import config as ro_config
    from repro.readout import cost as ro_cost

    return ro_cost.sweep_cost(ro_config.for_wv_method(cfg), cost, n_compares)


def write_phase_cost(
    g_lsb: jax.Array,
    n_pulses: jax.Array,
    direction: jax.Array,
    dev: DeviceConfig,
    cost: CircuitCost,
    coarse: bool = False,
    column_axis: int = -1,
) -> tuple[jax.Array, jax.Array]:
    """(latency_ns, energy_pj) of one column-parallel write phase.

    SET and RESET are separate phases (Fig. 5): latency is
    t_write * (max SET pulses + max RESET pulses) over the column;
    energy integrates V^2 * G * t per pulse (G in siemens).
    """
    n_pulses = n_pulses.astype(jnp.float32)
    set_p = jnp.where(direction > 0, n_pulses, 0.0)
    rst_p = jnp.where(direction < 0, n_pulses, 0.0)
    lat = cost.t_write_pulse_ns * (
        jnp.max(set_p, axis=column_axis) + jnp.max(rst_p, axis=column_axis)
    )
    v = cost.v_coarse if coarse else cost.v_set
    g_us = jnp.clip(g_lsb, 0.0, dev.g_max_lsb) * cost.g_lsb_us
    # E = V^2 * G * t : us * ns * V^2 = 1e-6 S * 1e-9 s -> 1e-15 J = f J;
    # convert to pJ (1e-12 J) with * 1e-3.
    e_per_pulse_pj = (v * v) * g_us * cost.t_write_pulse_ns * 1e-3
    e = jnp.sum(n_pulses * e_per_pulse_pj, axis=column_axis)
    return lat, e


def inference_token_cost(
    n_conversions: int,
    n_row_drives: int,
    planes: int,
    adc: ADCConfig,
    cost: CircuitCost,
) -> tuple[float, float]:
    """(latency_ns, energy_pj) of serving ONE token through the arrays.

    The inference phase of the cost model (DESIGN.md Sec. 11): each of
    the `planes` bit-serial DAC phases drives every macro's rows and
    full-SAR-converts every sensed signed column pair (slices and tiles
    have their own converters, so a phase's latency is one
    drive+read+convert regardless of model size; phases are sequential).
    The shift-and-add recombination streams behind the reads (Sec. 3.2
    decode streaming) — one tail add on the critical path, accumulate
    energy per conversion.

    Args:
      n_conversions: ADC conversions per plane (sum over analog leaves
        of layers * tiles * slices * outputs).
      n_row_drives: DAC row drives per plane (layers * tiles * rows).
      planes: bit-serial phases per token (`cim.planes_per_token`).
    """
    lat = planes * (cost.t_dac_ns + adc.t_read_pulse_ns + adc.t_sar_ns)
    lat += cost.t_adder_ns
    e_plane = (
        n_row_drives * cost.e_dac_pj
        + n_conversions * (adc.e_tia_pj + adc.e_sar_pj + cost.e_adder_hdpv_pj)
    )
    return float(lat), float(planes * e_plane)


def decode_cost(cfg: WVConfig, cost: CircuitCost) -> tuple[float, float]:
    """Standalone decode-only cost (already folded into read_phase_cost)."""
    if cfg.method == WVMethod.HD_PV:
        return cost.t_adder_ns, cfg.n_cells * cost.e_adder_hdpv_pj
    if cfg.method == WVMethod.HARP:
        return cost.t_adder_ns, cfg.n_cells * cost.e_adder_harp_pj
    return 0.0, 0.0
