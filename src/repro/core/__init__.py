# The paper's primary contribution: Hadamard-domain write-and-verify for
# RRAM programming (HD-PV + HARP), as a composable JAX library.
from .types import (  # noqa: F401
    ADCConfig,
    DeviceConfig,
    NoiseConfig,
    WVConfig,
    WVMethod,
    default_config_for_array,
)
from .cost import CircuitCost  # noqa: F401
from .wv import WVStats, program_columns, verify_aggregate, verify_sweep  # noqa: F401
from . import hadamard  # noqa: F401
from . import pipeline  # noqa: F401
