"""Column TIA + SAR ADC behaviour: full conversion vs compare-only mode.

Paper Fig. 7: a standard n-bit SAR ADC either

* runs the full n-step binary search ("SAR logic"), producing a digital
  code — modelled as uniform quantization over the column's full-scale
  range; or
* is put in HARP's one-shot *compare* mode ("compare logic"): the
  capacitor array is preset to the target code and the comparator makes
  one (or two) decisions, yielding ternary {Low, Equal, High} — no code.

Full-scale convention (Sec. 3.2, V_sam reference switching):
the ADC always spans `N * (2^Bc - 1)` cell-LSB of column current.
* one-hot reads / first Hadamard row: range [0, FS]          (V_sam = GND)
* balanced Hadamard rows:            range [-FS/2, +FS/2]    (V_sam = Vcm/2)
Both use the same bit budget, so the ADC code width in cell-LSB is
FS / 2^bits regardless of mode — single-cell (one-hot) SAR reads therefore
use only 1/N of the converter's dynamic range, one of the structural
advantages of reading in the Hadamard basis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .types import ADCConfig

__all__ = ["full_scale_lsb", "code_width_lsb", "sar_read", "compare_read"]


def full_scale_lsb(n_cells: int, levels: int) -> float:
    return float(n_cells * (levels - 1))


def code_width_lsb(adc: ADCConfig, n_cells: int, levels: int) -> float:
    return full_scale_lsb(n_cells, levels) / float(1 << adc.bits)


def sar_read(
    y: jax.Array, adc: ADCConfig, n_cells: int, levels: int, centered: bool
) -> jax.Array:
    """Full SAR conversion: quantize analog y (cell-LSB) to the ADC grid.

    `centered` selects the balanced-row range [-FS/2, FS/2]; otherwise
    [0, FS].  Returns the *dequantized* value in cell-LSB (code * width),
    saturating at the rails.
    """
    fs = full_scale_lsb(n_cells, levels)
    w = code_width_lsb(adc, n_cells, levels)
    lo = -fs / 2.0 if centered else 0.0
    hi = lo + fs
    code = jnp.round((jnp.clip(y, lo, hi) - lo) / w)
    code = jnp.clip(code, 0, (1 << adc.bits) - 1)
    return lo + code * w


def compare_read(
    y: jax.Array, target: jax.Array, deadzone_lsb: float
) -> tuple[jax.Array, jax.Array]:
    """One-shot compare mode (eq. 9): ternary sign of (y - target).

    The comparator presets the capacitor array to the target code and
    compares; a second comparison against the adjacent code resolves the
    'Equal' band.  Returns (sign in {-1, 0, +1}, comparisons in {1, 2}).

    Comparison counting follows Fig. 7(c): the first comparison resolves
    "below target"; only a not-below outcome needs the second comparison
    against target+1 to separate Equal from High.
    """
    diff = y - target
    below = diff < -deadzone_lsb
    above = diff > deadzone_lsb
    sign = jnp.where(below, -1.0, jnp.where(above, 1.0, 0.0))
    n_cmp = jnp.where(below, 1, 2).astype(jnp.int32)
    return sign, n_cmp
