"""Bucketed whole-model programming pipeline (DESIGN.md Sec. 10).

Model deployment used to program one leaf at a time: every leaf shape
re-traced `program_columns`, and every leaf's report blocked on host
syncs — throwing away exactly the parallelism the paper buys (columns
are independent; the whole model is one giant column batch).

This module is the shared hot path for model-scale programming:

* `bucket_sizes` decomposes the total column count into a small menu of
  power-of-two buckets, so an arbitrary model compiles at most
  log2(max/min)+1 distinct dispatch shapes — and different models reuse
  the same compiled sizes.
* `get_program_fn` is the ONE jit cache for batched programming.  Both
  deployment (`core.programmer`) and scrubbing (`lifetime.refresh`)
  dispatch through it, so a refresh after a deploy hits warm compiles.
  Inputs are donated (targets/d2d buffers are bucket temporaries) and
  the column axis can be sharded over a device mesh.
* `program_packed_columns` runs many independently-packed column blocks
  (one per weight leaf) through the bucket dispatches and splits the
  results back per block.

Per-column RNG (see `core.rng`): every column draws from
``fold_in(key, uid)``, so a column's programmed value depends only on
(key, uid) — not on bucket boundaries or padding.  That is what makes
the bucketed path bit-identical to the per-leaf path.

The module also keeps two counters the benchmarks/tests assert on:
`compile_count()` (distinct traced dispatch shapes — must stay <= the
number of buckets) and `host_sync_count()` (`host_fetch` calls — a
batched deploy performs exactly one).  Since the obs refactor
(DESIGN.md Sec. 14) both live in the global telemetry registry
(`repro.obs.metrics.registry`, keys ``pipeline.compiles`` /
``pipeline.host_syncs``); the functions here are thin compatibility
wrappers over it.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import obs
from repro.obs import metrics as obs_metrics

from . import device as dev_mod
from . import rng
from .cost import CircuitCost
from .types import FaultConfig, WVConfig
from .wv import WVStats, program_columns

__all__ = [
    "bucket_sizes",
    "get_program_fn",
    "program_packed_columns",
    "sample_d2d_for",
    "host_fetch",
    "compile_count",
    "host_sync_count",
    "reset_counters",
]

DEFAULT_MIN_BUCKET = 256
DEFAULT_MAX_BUCKET = 1 << 18

_FN_CACHE: dict = {}
_TRACED: set = set()

# Registry keys for the pipeline's contract counters (obs.metrics).
COMPILE_COUNTER = "pipeline.compiles"
SYNC_COUNTER = "pipeline.host_syncs"


def compile_count() -> int:
    """Distinct (config, bucket-shape) dispatches traced so far."""
    return int(obs_metrics.value(COMPILE_COUNTER))


def host_sync_count() -> int:
    """`host_fetch` device->host synchronizations performed so far."""
    return int(obs_metrics.value(SYNC_COUNTER))


def reset_counters() -> None:
    """Zero the pipeline's registry counters (the jit cache survives)."""
    obs_metrics.reset("pipeline.")


def host_fetch(tree):
    """The pipeline's single device->host transfer point (counted)."""
    return obs_metrics.fetch(tree, counter=SYNC_COUNTER)


def donates() -> bool:
    """Whether `get_program_fn` donates its targets/d2d arguments.

    Donation is skipped on CPU (unsupported there; jax only warns).
    Callers that keep a dispatched buffer alive (persistent ArrayState)
    must pass a copy when this is True.
    """
    return jax.default_backend() != "cpu"


def bucket_sizes(
    c_total: int,
    min_bucket: int = DEFAULT_MIN_BUCKET,
    max_bucket: int = DEFAULT_MAX_BUCKET,
) -> list[int]:
    """Greedy power-of-two decomposition of a column count.

    Returns bucket sizes summing to >= c_total, each a power of two in
    [min_bucket, max_bucket].  Only the LAST bucket is padded (by at
    most min_bucket - 1 columns), and the menu of possible sizes has
    log2(max/min)+1 entries, which bounds the jit cache.
    """
    assert min_bucket > 0 and min_bucket & (min_bucket - 1) == 0, min_bucket
    assert max_bucket >= min_bucket and max_bucket & (max_bucket - 1) == 0, (
        max_bucket
    )
    sizes: list[int] = []
    rem = c_total
    while rem >= min_bucket:
        s = min(max_bucket, 1 << (rem.bit_length() - 1))
        sizes.append(s)
        rem -= s
    if rem > 0 or not sizes:
        sizes.append(min_bucket)
    return sizes


def get_program_fn(
    cfg: WVConfig,
    cost: CircuitCost,
    mesh: Mesh | None = None,
    mesh_axes: tuple | None = None,
    with_fault: bool = False,
):
    """The shared batched-programming dispatch: (key, targets, d2d, col_ids).

    Returns a jitted callable ``fn(key, (C, N) targets, (C, N) d2d,
    (C,) col_ids) -> (g, WVStats)`` cached per (cfg, cost, mesh).  The
    targets/d2d buffers are donated (they are bucket temporaries); when
    `mesh` is given the column axis is sharded over `mesh_axes`
    (default: all mesh axes) with zero cross-device traffic inside the
    WV loop.

    With `with_fault=True` the callable takes a trailing
    :class:`device.FaultMap` of (C, N) leaves (persistent silicon state
    — never donated) and programs under it.  Fault-free dispatches keep
    their own cache entry, so turning faults on never invalidates the
    warm zero-fault compile.
    """
    cache_key = (cfg, cost, mesh, mesh_axes, with_fault)
    entry = _FN_CACHE.get(cache_key)
    if entry is None:

        if with_fault:
            def raw(key, targets, d2d, col_ids, fault):
                return program_columns(
                    key, targets, cfg, cost=cost, d2d=d2d, col_ids=col_ids,
                    fault=fault,
                )
        else:
            def raw(key, targets, d2d, col_ids):
                return program_columns(
                    key, targets, cfg, cost=cost, d2d=d2d, col_ids=col_ids
                )

        kw: dict = {}
        if donates():
            kw["donate_argnums"] = (1, 2)
        if mesh is not None:
            ax = mesh_axes if mesh_axes is not None else tuple(mesh.axis_names)
            col2 = NamedSharding(mesh, P(ax, None))
            col1 = NamedSharding(mesh, P(ax))
            rep = NamedSharding(mesh, P())
            ins = (rep, col2, col2, col1)
            if with_fault:
                ins = ins + (dev_mod.FaultMap(col2, col2, col2),)
            kw["in_shardings"] = ins
            kw["out_shardings"] = (col2, col1)  # prefix: all WVStats leaves
        jfn = jax.jit(raw, **kw)

        def entry(key, targets, d2d, col_ids, *fault):
            tk = (cache_key, targets.shape)
            if tk not in _TRACED:
                _TRACED.add(tk)
                obs_metrics.inc(COMPILE_COUNTER)
                obs.instant(
                    "pipeline.compile", cat="pipeline",
                    bucket=int(targets.shape[0]), n_cells=int(targets.shape[1]),
                )
            return jfn(key, targets, d2d, col_ids, *fault)

        _FN_CACHE[cache_key] = entry
    return entry


def sample_d2d_for(key, col_ids, shape, dev_cfg):
    """Per-column-stream d2d sample, mirroring `program_columns`' own
    key schedule (`k_d2d` = first of the column key's 3-way split) so a
    caller-side sample equals what the engine would draw internally."""
    k_d2d = rng.split(rng.fold_col_keys(key, col_ids), 3)[0]
    return dev_mod.sample_d2d(k_d2d, shape, dev_cfg)


def program_packed_columns(
    key: jax.Array,
    blocks: Sequence[jax.Array],
    cfg: WVConfig,
    cost: CircuitCost | None = None,
    *,
    mesh: Mesh | None = None,
    mesh_axes: tuple | None = None,
    min_bucket: int = DEFAULT_MIN_BUCKET,
    max_bucket: int = DEFAULT_MAX_BUCKET,
    uid_base: int = 0,
    uids: jax.Array | None = None,
    pad_uid_base: int | None = None,
    fault_cfg: FaultConfig | None = None,
) -> tuple[
    list[jax.Array], list[WVStats], list[jax.Array],
    list[dev_mod.FaultMap] | list[None],
]:
    """Program many packed column blocks in a few bucketed dispatches.

    Args:
      key: master PRNG key (column sub-streams derive from it).
      blocks: list of (C_i, N) target-level arrays (e.g. one per leaf).
      cfg / cost: WV configuration and circuit constants.
      mesh / mesh_axes: optional device mesh to shard the column axis.
      min_bucket / max_bucket: power-of-two bucket bounds.
      uid_base: first column uid (block b's column j gets uid
        ``uid_base + sum(C_<b) + j``) — must match the per-leaf path's
        numbering for bit-identical results.  Filler uids for bucket
        padding start at ``uid_base + c_total``.
      uids: optional explicit (sum C_i,) int32 column uids overriding
        the contiguous numbering — the spare-column pass programs
        non-contiguous physical columns (`core.remap`).
      pad_uid_base: first filler uid (defaults to ``uid_base +
        c_total``); with explicit `uids` pass a value past the whole
        allocated uid range.
      fault_cfg: optional fault population; when set (and non-trivial),
        the silicon fault map is sampled per uid (same master key — a
        bucketed and a per-leaf deploy see the same silicon) and
        programming runs under it.  Returned per block so callers can
        persist it alongside d2d.

    Returns (g_blocks, stats_blocks, d2d_blocks, fault_blocks), all
    split back to the input block boundaries.  `fault_blocks` is a list
    of None when no fault config is given.  Everything stays on device;
    no host syncs.
    """
    if cost is None:
        cost = CircuitCost()
    sizes = [int(b.shape[0]) for b in blocks]
    c_total = sum(sizes)
    if c_total == 0:
        return [], [], [], []
    n = int(blocks[0].shape[1])
    targets = jnp.concatenate(blocks, axis=0) if len(blocks) > 1 else blocks[0]
    targets = targets.astype(jnp.float32)
    if uids is None:
        uids = uid_base + jnp.arange(c_total, dtype=jnp.int32)
    else:
        uids = jnp.asarray(uids, jnp.int32)
        assert uids.shape == (c_total,), (uids.shape, c_total)
    if pad_uid_base is None:
        pad_uid_base = uid_base + c_total
    # d2d is sampled OUTSIDE the donated dispatch: it is persistent array
    # state (ArrayState.d2d) while the padded bucket buffers are
    # temporaries.  Same sub-streams as the engine would use internally.
    d2d = sample_d2d_for(key, uids, (c_total, n), cfg.device)
    # The fault map is persistent silicon state like d2d: sampled here
    # (salted key domain — write-noise streams are untouched) and passed
    # through every dispatch, never resampled inside.
    with_fault = fault_cfg is not None and fault_cfg.any_faults
    fault = (
        dev_mod.sample_fault_map(key, uids, (c_total, n), fault_cfg, cfg.device)
        if with_fault
        else None
    )

    fn = get_program_fn(
        cfg, cost, mesh=mesh, mesh_axes=mesh_axes, with_fault=with_fault
    )
    sizes_plan = bucket_sizes(c_total, min_bucket, max_bucket)
    g_parts, stat_parts = [], []
    off = 0
    with obs.span(
        "deploy.program_columns", cat="pipeline",
        columns=c_total, buckets=len(sizes_plan), blocks=len(blocks),
    ):
        for size in sizes_plan:
            take = min(size, c_total - off)
            # Host-side shape bookkeeping (ints already on host): the
            # dispatch-size digest lets the dashboard show how well the
            # bucket menu fits real models — zero device work.
            obs.digests.observe(
                "pipeline.bucket_columns", float(take),
                lo=0.0, hi=float(DEFAULT_MAX_BUCKET), n_buckets=64,
            )
            tb = targets[off : off + take]
            db = d2d[off : off + take]
            ub = uids[off : off + take]
            fb = (
                jax.tree.map(lambda x: x[off : off + take], fault)
                if with_fault else None
            )
            pad = size - take
            if pad:
                # Filler columns: zero targets, fresh uids past the real
                # range (their streams never alias a real column's), unit
                # d2d, inert fault rows.  Their rows are sliced off below.
                tb = jnp.pad(tb, ((0, pad), (0, 0)))
                db = jnp.pad(db, ((0, pad), (0, 0)), constant_values=1.0)
                ub = jnp.concatenate(
                    [ub, pad_uid_base + jnp.arange(pad, dtype=jnp.int32)]
                )
                if with_fault:
                    filler = dev_mod.empty_fault_map((pad, n))
                    fb = jax.tree.map(
                        lambda x, f: jnp.concatenate([x, f]), fb, filler
                    )
            elif donates():
                # A full-range slice short-circuits to the SAME array, so a
                # single exact-size bucket would donate the caller's block
                # (persistent ArrayState.targets) / the returned d2d.  Copy
                # before donating in that case only.
                if tb is targets:
                    tb = jnp.copy(tb)
                if db is d2d:
                    db = jnp.copy(db)
            fargs = (fb,) if with_fault else ()
            g_b, st_b = fn(key, tb, db, ub, *fargs)
            g_parts.append(g_b[:take])
            stat_parts.append(jax.tree.map(lambda x: x[:take], st_b))
            off += take

    g_all = jnp.concatenate(g_parts) if len(g_parts) > 1 else g_parts[0]
    stats_all = (
        jax.tree.map(lambda *xs: jnp.concatenate(xs), *stat_parts)
        if len(stat_parts) > 1
        else stat_parts[0]
    )
    g_blocks, stats_blocks, d2d_blocks, fault_blocks = [], [], [], []
    off = 0
    for c_i in sizes:
        g_blocks.append(g_all[off : off + c_i])
        stats_blocks.append(jax.tree.map(lambda x: x[off : off + c_i], stats_all))
        d2d_blocks.append(d2d[off : off + c_i])
        fault_blocks.append(
            jax.tree.map(lambda x: x[off : off + c_i], fault)
            if with_fault else None
        )
        off += c_i
    return g_blocks, stats_blocks, d2d_blocks, fault_blocks
