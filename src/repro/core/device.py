"""RRAM device model: nonlinear, asymmetric, stochastic conductance updates.

Implements the programming physics of paper Sec. 2.2 / Fig. 3:

* SET increases conductance, RESET decreases it.
* The effective per-pulse step tapers near the rails (nonlinear switching):
  SET is weak near LRS (g -> g_max), RESET weak near HRS (g -> 0).
* Asymmetry: RESET transitions are weaker than SET by a fixed factor.
* D2D: a static per-cell step-efficiency drawn once per cell.
* C2C: multiplicative jitter per write event.
* Mapping noise (eq. 1): additive Gaussian per write event with
  sigma_map = 0.10 * G_max, then clip to [0 (HRS), G_max (LRS)].

All quantities are in cell-LSB units (see core.types).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import rng
from .types import DeviceConfig, FaultConfig

__all__ = [
    "sample_d2d",
    "apply_pulses",
    "initial_state",
    "write_noise_sigma",
    "sample_write_noise",
    "FaultMap",
    "sample_fault_map",
    "empty_fault_map",
    "clamp_stuck",
]


def sample_d2d(key: jax.Array, shape, dev: DeviceConfig) -> jax.Array:
    """Static device-to-device step-efficiency multiplier per cell.

    `key` may be a batch of per-column keys (leading axis == shape[0]).
    """
    return 1.0 + dev.sigma_d2d_frac * rng.normal(key, shape)


def write_noise_sigma(dev: DeviceConfig, step_lsb: float) -> float:
    """Per-single-pulse additive mapping-noise sigma for a pulse class.

    In "pulse" mode the per-pulse sigma is normalized so a full-swing
    coarse write accumulates ~sigma_map total (see `apply_pulses`); in
    "event" mode the whole write event draws sigma_map once.
    """
    if dev.map_noise_mode == "pulse":
        n_swing = dev.g_max_lsb / dev.coarse_step_lsb
        return float(
            dev.sigma_map_lsb / n_swing**0.5 * (step_lsb / dev.coarse_step_lsb)
        )
    return float(dev.sigma_map_lsb)


def sample_write_noise(
    key: jax.Array, shape, dev: DeviceConfig, step_lsb: float | None = None
) -> tuple[jax.Array, jax.Array]:
    """Pre-sample the stochastic fields of one write event: (c2c, nmap).

    Draws from exactly the key splits `apply_pulses` uses, so the fused
    Pallas cell-update path (which takes pre-sampled fields) is
    bit-identical to the unfused path.  `nmap` carries the single-pulse
    sigma; "pulse"-mode sqrt(n_pulses) scaling is applied downstream
    (the fused kernel's `nmap_sqrt_pulses` flag / `apply_pulses`).
    """
    if step_lsb is None:
        step_lsb = dev.fine_step_lsb
    k_c2c, k_map = rng.split(key)
    c2c = 1.0 + dev.sigma_c2c_frac * rng.normal(k_c2c, shape)
    nmap = write_noise_sigma(dev, step_lsb) * rng.normal(k_map, shape)
    return c2c, nmap


def initial_state(shape) -> jax.Array:
    """All cells start at HRS (zero conductance) before coarse SET."""
    return jnp.zeros(shape, jnp.float32)


class FaultMap(NamedTuple):
    """Static per-cell silicon fault state (DESIGN.md Sec. 15).

    The fault map is physical device state, like `d2d`: it is sampled
    once per deployment (caller side) and passed into every programming
    dispatch that touches the same cells — refresh re-programs under the
    *same* map, never a fresh draw.

    stuck:   (..., N) bool  — cell does not respond to pulses at all.
    stuck_g: (..., N) f32   — the conductance a stuck cell is pinned at
                              (0 for SA0/HRS, G_max for SA1/LRS, a random
                              level for endurance-exhausted cells).
    efficiency: (..., N) f32 — multiplicative step-efficiency factor
                              (1.0 healthy; `weak_efficiency` for weak
                              cells; x tile/chip systematic spread).
    """

    stuck: jax.Array
    stuck_g: jax.Array
    efficiency: jax.Array


def empty_fault_map(shape) -> FaultMap:
    """The inert map: nothing stuck, unit efficiency (used as pad)."""
    return FaultMap(
        stuck=jnp.zeros(shape, bool),
        stuck_g=jnp.zeros(shape, jnp.float32),
        efficiency=jnp.ones(shape, jnp.float32),
    )


# Salts carving fault sampling into its own key domain: the existing
# d2d/coarse/fine key schedule (DESIGN.md Sec. 10) is untouched, so a
# deployment that samples a fault map draws identical write noise to one
# that does not.
_FAULT_SALT = 0xFA0175
_TILE_SALT = 0x711E5
_CHIP_SALT = 0xC419


def tile_ids(col_ids: jax.Array, fault_cfg: FaultConfig) -> jax.Array:
    """Physical tile index of each column uid (geometry is static)."""
    return col_ids // fault_cfg.columns_per_tile


def chip_ids(col_ids: jax.Array, fault_cfg: FaultConfig) -> jax.Array:
    return tile_ids(col_ids, fault_cfg) // fault_cfg.tiles_per_chip


def tile_quality(
    key: jax.Array, tids: jax.Array, fault_cfg: FaultConfig
) -> jax.Array:
    """Per-tile fault-rate multiplier (lognormal, sigma in decades).

    Deterministic in (master key, tile id): the factory-probe pass and
    the deploy-time fault sampler both call this and see the same
    silicon.  1.0 everywhere when sigma_tile_fault_dec == 0.
    """
    fkey = jax.random.fold_in(key, _FAULT_SALT)
    tkey = rng.fold_col_keys(jax.random.fold_in(fkey, _TILE_SALT), tids)
    ln10 = 2.302585092994046
    z = jax.vmap(lambda k: jax.random.normal(k, ()))(tkey)
    return jnp.exp(fault_cfg.sigma_tile_fault_dec * ln10 * z)


def sample_fault_map(
    key: jax.Array,
    col_ids: jax.Array,
    shape,
    fault_cfg: FaultConfig,
    dev: DeviceConfig,
) -> FaultMap:
    """Sample the static fault state for a batch of physical columns.

    `key` is the deployment master key (a *single* key — per-column
    sub-streams are derived inside from `col_ids`, so a column's fault
    draw depends only on (master key, uid): bucketed and per-leaf
    deploys see identical silicon).  `shape` is (C, N) with
    C == col_ids.shape[0].

    Spatial correlation: per-tile lognormal fault-rate multiplier and
    per-tile / per-chip Gaussian step-efficiency offsets are derived by
    folding the (deterministic) tile/chip ids into salted sub-keys —
    columns sharing a tile share the draw, and the draw is independent
    of which columns ride in the batch.
    """
    assert shape[0] == col_ids.shape[0], (shape, col_ids.shape)
    fkey = jax.random.fold_in(key, _FAULT_SALT)
    ckeys = rng.fold_col_keys(fkey, col_ids)
    k_kind, k_level = rng.split(ckeys)

    tids = tile_ids(col_ids, fault_cfg)
    cids = chip_ids(col_ids, fault_cfg)
    rate_mult = tile_quality(key, tids, fault_cfg)[:, None]  # (C, 1)

    # One uniform per cell classifies it into {healthy, SA0, SA1, weak,
    # exhausted} by stacked thresholds; the tile multiplier scales all
    # fault probabilities together (bad tiles are bad in every mode).
    u = rng.uniform(k_kind, shape)
    p0 = jnp.float32(fault_cfg.p_stuck_hrs) * rate_mult
    p1 = p0 + jnp.float32(fault_cfg.p_stuck_lrs) * rate_mult
    p2 = p1 + jnp.float32(fault_cfg.p_weak) * rate_mult
    p3 = p2 + jnp.float32(fault_cfg.p_exhausted) * rate_mult
    sa0 = u < p0
    sa1 = (u >= p0) & (u < p1)
    weak = (u >= p1) & (u < p2)
    exhausted = (u >= p2) & (u < p3)

    # Endurance-exhausted cells are frozen wherever they last landed:
    # a uniform level in [0, G_max].
    level = rng.uniform(k_level, shape) * dev.g_max_lsb
    stuck = sa0 | sa1 | exhausted
    stuck_g = jnp.where(sa1, dev.g_max_lsb, jnp.where(exhausted, level, 0.0))

    # Systematic step-efficiency spread shared per tile / per chip.
    eff = jnp.where(weak, jnp.float32(fault_cfg.weak_efficiency), 1.0)
    if fault_cfg.sigma_tile_eff_frac > 0.0:
        tkeys = rng.fold_col_keys(
            jax.random.fold_in(fkey, _TILE_SALT + 1), tids)
        zt = jax.vmap(lambda k: jax.random.normal(k, ()))(tkeys)
        eff = eff * (1.0 + fault_cfg.sigma_tile_eff_frac * zt[:, None])
    if fault_cfg.sigma_chip_eff_frac > 0.0:
        qkeys = rng.fold_col_keys(jax.random.fold_in(fkey, _CHIP_SALT), cids)
        zc = jax.vmap(lambda k: jax.random.normal(k, ()))(qkeys)
        eff = eff * (1.0 + fault_cfg.sigma_chip_eff_frac * zc[:, None])
    eff = jnp.maximum(eff, 0.0)

    return FaultMap(stuck=stuck, stuck_g=stuck_g, efficiency=eff)


def clamp_stuck(g: jax.Array, fault: Optional[FaultMap]) -> jax.Array:
    """Pin stuck cells at their physical level (no-op without a map)."""
    if fault is None:
        return g
    return jnp.where(fault.stuck, fault.stuck_g, g)


def _effective_step(
    g: jax.Array, direction: jax.Array, dev: DeviceConfig, step_lsb: float
) -> jax.Array:
    """Direction-dependent nominal step at conductance g (Fig. 3 shape).

    direction: +1 (SET, conductance up), -1 (RESET, down), 0 (no pulse).
    """
    gmax = dev.g_max_lsb
    frac = jnp.clip(g / gmax, 0.0, 1.0)
    # Taper: SET slows approaching LRS, RESET slows approaching HRS.
    set_eff = (1.0 - frac) ** dev.nonlinearity
    reset_eff = frac**dev.nonlinearity * dev.reset_asymmetry
    eff = jnp.where(direction > 0, set_eff, reset_eff)
    return step_lsb * eff


def apply_pulses(
    key: jax.Array,
    g: jax.Array,
    direction: jax.Array,
    n_pulses: jax.Array,
    d2d: jax.Array,
    dev: DeviceConfig,
    step_lsb: float | None = None,
    noise_scale: float = 1.0,
    fault: Optional[FaultMap] = None,
) -> jax.Array:
    """Apply a burst of identical pulses to every cell (vectorized write phase).

    Args:
      key: PRNG key for this write event.
      g: (..., N) current conductances in LSB.
      direction: (..., N) in {-1, 0, +1}.
      n_pulses: (..., N) integer pulse counts (0 = skip; frozen cells pass 0).
      d2d: (..., N) static per-cell efficiency from :func:`sample_d2d`.
      dev: device config.
      step_lsb: nominal step per pulse (defaults to the fine step).
      noise_scale: multiplier on sigma_map (coarse pulses are noisier).
      fault: optional static :class:`FaultMap`; weak cells see collapsed
        step efficiency, stuck cells are re-pinned after the write.  The
        noise draw is unconditional, so `fault=None` and an inert map
        produce bit-identical conductances.

    Returns updated conductances, clipped to [0, G_max].
    """
    if step_lsb is None:
        step_lsb = dev.fine_step_lsb
    # eq. (1): additive mapping noise. "event" mode draws sigma_map once per
    # write event; "pulse" mode draws per-pulse noise proportional to the
    # step size (a random walk over the burst), normalized so a full-swing
    # coarse write realizes ~sigma_map total, matching the one-shot
    # characterization of eq. (1).
    c2c, nmap = sample_write_noise(key, g.shape, dev, step_lsb)
    n = n_pulses.astype(jnp.float32)
    pulsed = n > 0
    eff = d2d if fault is None else d2d * fault.efficiency
    step = _effective_step(g, direction, dev, step_lsb) * eff
    delta = direction.astype(jnp.float32) * step * n * c2c
    if dev.map_noise_mode == "pulse":
        nmap = nmap * jnp.sqrt(jnp.maximum(n, 1.0))
    g_new = g + delta + jnp.where(pulsed, nmap * noise_scale, 0.0)
    g_new = jnp.clip(g_new, 0.0, dev.g_max_lsb)
    return clamp_stuck(jnp.where(pulsed, g_new, g), fault)
