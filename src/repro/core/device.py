"""RRAM device model: nonlinear, asymmetric, stochastic conductance updates.

Implements the programming physics of paper Sec. 2.2 / Fig. 3:

* SET increases conductance, RESET decreases it.
* The effective per-pulse step tapers near the rails (nonlinear switching):
  SET is weak near LRS (g -> g_max), RESET weak near HRS (g -> 0).
* Asymmetry: RESET transitions are weaker than SET by a fixed factor.
* D2D: a static per-cell step-efficiency drawn once per cell.
* C2C: multiplicative jitter per write event.
* Mapping noise (eq. 1): additive Gaussian per write event with
  sigma_map = 0.10 * G_max, then clip to [0 (HRS), G_max (LRS)].

All quantities are in cell-LSB units (see core.types).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import rng
from .types import DeviceConfig

__all__ = [
    "sample_d2d",
    "apply_pulses",
    "initial_state",
    "write_noise_sigma",
    "sample_write_noise",
]


def sample_d2d(key: jax.Array, shape, dev: DeviceConfig) -> jax.Array:
    """Static device-to-device step-efficiency multiplier per cell.

    `key` may be a batch of per-column keys (leading axis == shape[0]).
    """
    return 1.0 + dev.sigma_d2d_frac * rng.normal(key, shape)


def write_noise_sigma(dev: DeviceConfig, step_lsb: float) -> float:
    """Per-single-pulse additive mapping-noise sigma for a pulse class.

    In "pulse" mode the per-pulse sigma is normalized so a full-swing
    coarse write accumulates ~sigma_map total (see `apply_pulses`); in
    "event" mode the whole write event draws sigma_map once.
    """
    if dev.map_noise_mode == "pulse":
        n_swing = dev.g_max_lsb / dev.coarse_step_lsb
        return float(
            dev.sigma_map_lsb / n_swing**0.5 * (step_lsb / dev.coarse_step_lsb)
        )
    return float(dev.sigma_map_lsb)


def sample_write_noise(
    key: jax.Array, shape, dev: DeviceConfig, step_lsb: float | None = None
) -> tuple[jax.Array, jax.Array]:
    """Pre-sample the stochastic fields of one write event: (c2c, nmap).

    Draws from exactly the key splits `apply_pulses` uses, so the fused
    Pallas cell-update path (which takes pre-sampled fields) is
    bit-identical to the unfused path.  `nmap` carries the single-pulse
    sigma; "pulse"-mode sqrt(n_pulses) scaling is applied downstream
    (the fused kernel's `nmap_sqrt_pulses` flag / `apply_pulses`).
    """
    if step_lsb is None:
        step_lsb = dev.fine_step_lsb
    k_c2c, k_map = rng.split(key)
    c2c = 1.0 + dev.sigma_c2c_frac * rng.normal(k_c2c, shape)
    nmap = write_noise_sigma(dev, step_lsb) * rng.normal(k_map, shape)
    return c2c, nmap


def initial_state(shape) -> jax.Array:
    """All cells start at HRS (zero conductance) before coarse SET."""
    return jnp.zeros(shape, jnp.float32)


def _effective_step(
    g: jax.Array, direction: jax.Array, dev: DeviceConfig, step_lsb: float
) -> jax.Array:
    """Direction-dependent nominal step at conductance g (Fig. 3 shape).

    direction: +1 (SET, conductance up), -1 (RESET, down), 0 (no pulse).
    """
    gmax = dev.g_max_lsb
    frac = jnp.clip(g / gmax, 0.0, 1.0)
    # Taper: SET slows approaching LRS, RESET slows approaching HRS.
    set_eff = (1.0 - frac) ** dev.nonlinearity
    reset_eff = frac**dev.nonlinearity * dev.reset_asymmetry
    eff = jnp.where(direction > 0, set_eff, reset_eff)
    return step_lsb * eff


def apply_pulses(
    key: jax.Array,
    g: jax.Array,
    direction: jax.Array,
    n_pulses: jax.Array,
    d2d: jax.Array,
    dev: DeviceConfig,
    step_lsb: float | None = None,
    noise_scale: float = 1.0,
) -> jax.Array:
    """Apply a burst of identical pulses to every cell (vectorized write phase).

    Args:
      key: PRNG key for this write event.
      g: (..., N) current conductances in LSB.
      direction: (..., N) in {-1, 0, +1}.
      n_pulses: (..., N) integer pulse counts (0 = skip; frozen cells pass 0).
      d2d: (..., N) static per-cell efficiency from :func:`sample_d2d`.
      dev: device config.
      step_lsb: nominal step per pulse (defaults to the fine step).
      noise_scale: multiplier on sigma_map (coarse pulses are noisier).

    Returns updated conductances, clipped to [0, G_max].
    """
    if step_lsb is None:
        step_lsb = dev.fine_step_lsb
    # eq. (1): additive mapping noise. "event" mode draws sigma_map once per
    # write event; "pulse" mode draws per-pulse noise proportional to the
    # step size (a random walk over the burst), normalized so a full-swing
    # coarse write realizes ~sigma_map total, matching the one-shot
    # characterization of eq. (1).
    c2c, nmap = sample_write_noise(key, g.shape, dev, step_lsb)
    n = n_pulses.astype(jnp.float32)
    pulsed = n > 0
    step = _effective_step(g, direction, dev, step_lsb) * d2d
    delta = direction.astype(jnp.float32) * step * n * c2c
    if dev.map_noise_mode == "pulse":
        nmap = nmap * jnp.sqrt(jnp.maximum(n, 1.0))
    g_new = g + delta + jnp.where(pulsed, nmap * noise_scale, 0.0)
    g_new = jnp.clip(g_new, 0.0, dev.g_max_lsb)
    return jnp.where(pulsed, g_new, g)
