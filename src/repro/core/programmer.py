"""Model-level RRAM deployment: quantize -> slice -> program -> read back.

This is the integration point between the paper's WV technique and the
training/serving framework: `deploy_params` takes any pytree of model
parameters, pushes every matmul weight through the
quantize -> bit-slice -> pack-to-columns -> write-and-verify pipeline,
and returns the *programmed* parameters (with real programming error)
plus aggregate WV statistics (latency / energy / iterations), so a
trained checkpoint can be "burned" onto simulated RRAM with CW-SC, MRA,
HD-PV, or HARP and then served to measure end-task robustness.

Two deployment paths share one programming core:

* `deploy_params` / `deploy_matrix` — the original "collapse to dense"
  path: program, read back, return an ordinary parameter pytree.  The
  array state is discarded; conductances are frozen forever.
* `deploy_arrays` — the persistent path (DESIGN.md Sec. 9): returns a
  `DeployedModel` that keeps per-leaf `ArrayState` (programmed
  conductances `g`, integer `targets`, static `d2d` efficiencies, quant
  `scale`, pack `layout`) alive, plus `materialize()` to rebuild dense
  params on demand.  This is what `repro.lifetime` ages, verifies, and
  refreshes: conductances are *state*, not a one-shot output.

By default both deploy the whole model through the bucketed programming
pipeline (`core.pipeline`, DESIGN.md Sec. 10): all leaves' packed
columns are concatenated into a few power-of-two column buckets, each
programmed by ONE jitted, donated `program_columns` dispatch (column
axis shardable over a device mesh), with `DeployReport` accumulated
device-side and a single host sync per deploy.  `batched=False` keeps
the per-leaf baseline path; per-column RNG sub-streams make the two
bit-identical.

Deployment policy (documented in DESIGN.md Sec. 3):
* >=2D weight leaves go to RRAM (flattened to (K, M) on the last axis);
* 1D leaves (norm scales, biases) stay digital — they are tiny and in
  real ACiM macros live in SRAM next to the shift-and-add periphery;
* embedding tables are RRAM-deployable but excluded by default
  (`deploy_embeddings=False`): token embedding lookups are row reads,
  not VMM columns.

Columns are independent; under jit the caller may shard the column axis
over the full mesh (launch/program.py does this for the dry-run mesh).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant import (
    QuantConfig,
    dequantize_weight,
    pack_columns,
    quantize_weight,
    unpack_columns,
)
from repro import obs
from repro.quant.pack import PackedLayout

from . import device as dev_mod
from . import pipeline
from . import remap as remap_mod
from .cost import CircuitCost
from .types import FaultConfig, WVConfig
from .wv import WVStats

__all__ = [
    "ArrayState",
    "DeployReport",
    "DeployedModel",
    "deploy_arrays",
    "deploy_params",
    "deploy_matrix",
]


@dataclasses.dataclass
class DeployReport:
    """Aggregate WV statistics for one deployment.

    The give-up/remap fields ride the SAME single host sync as the rest
    of the report (DESIGN.md Secs. 10/15): `total_gave_up_cells` counts
    cells the bounded-retry budget declared unprogrammable,
    `total_retry_pulses` the fine pulses burned on them before giving
    up, and `remapped_columns` the primary columns repaired onto spares.
    All three are zero on a fault-free / budget-less deploy.
    """

    num_columns: int = 0
    num_cells: int = 0
    mean_iterations: float = 0.0
    total_latency_ns: float = 0.0     # sum over arrays (columns in parallel)
    critical_latency_ns: float = 0.0  # max over columns = array wall-time
    total_energy_pj: float = 0.0
    rms_cell_error_lsb: float = 0.0
    total_reads: float = 0.0          # verify ADC conversions/comparisons
    total_write_pulses: float = 0.0
    total_gave_up_cells: float = 0.0  # cells declared unprogrammable
    total_retry_pulses: float = 0.0   # pulses burned on gave-up cells
    remapped_columns: int = 0         # primaries repaired onto spares
    leaves: dict[str, dict[str, float]] = dataclasses.field(default_factory=dict)
    # Fetched `extra` tree from collect() (per-tile health reductions,
    # deploy digests).  Deliberately NOT a dataclass field: it is a
    # transport slot for the fold in deploy_arrays, not part of the
    # report's stable scalar surface.
    extra = None

    @classmethod
    def collect(
        cls,
        leaf_stats: "dict[str, WVStats]",
        n_cells: int,
        remapped: "dict[str, jax.Array] | None" = None,
        extra: Any | None = None,
    ) -> "DeployReport":
        """Device-side report accumulation with exactly ONE host sync.

        All reductions (per-leaf and aggregate) are jnp ops over the
        still-on-device `WVStats` arrays; a single `pipeline.host_fetch`
        (device_get) at the end transfers the handful of scalars.  This
        is the batched-deployment stats contract (DESIGN.md Sec. 10):
        nothing in the deploy loop blocks on the device.
        """
        if not leaf_stats:
            return cls()
        stats = list(leaf_stats.values())
        its = jnp.concatenate([s.iterations for s in stats])
        lat = jnp.concatenate([s.latency_ns for s in stats])
        en = jnp.concatenate([s.energy_pj for s in stats])
        rms2 = jnp.concatenate([s.rms_error_lsb**2 for s in stats])
        agg = dict(
            mean_iterations=jnp.mean(its),
            total_latency_ns=jnp.sum(lat),
            critical_latency_ns=jnp.max(lat),
            total_energy_pj=jnp.sum(en),
            rms_cell_error_lsb=jnp.sqrt(jnp.mean(rms2)),
            # Telemetry sums (DESIGN.md Sec. 14) ride the same single
            # fetch: device-side reductions, zero extra syncs.
            total_reads=jnp.sum(
                jnp.concatenate([s.reads for s in stats])
            ),
            total_write_pulses=jnp.sum(
                jnp.concatenate([s.write_pulses for s in stats])
            ),
            # Give-up accounting (DESIGN.md Sec. 15) rides the same sync.
            total_gave_up_cells=jnp.sum(
                jnp.concatenate([s.gave_up for s in stats])
            ),
            total_retry_pulses=jnp.sum(
                jnp.concatenate([s.retry_pulses for s in stats])
            ),
        )
        per = {
            name: dict(
                mean_iterations=jnp.mean(s.iterations),
                critical_latency_ns=jnp.max(s.latency_ns),
                energy_pj=jnp.sum(s.energy_pj),
                rms_cell_error_lsb=jnp.sqrt(jnp.mean(s.rms_error_lsb**2)),
                gave_up_cells=jnp.sum(s.gave_up),
            )
            for name, s in leaf_stats.items()
        }
        # `extra` is an arbitrary device tree (per-tile health reductions,
        # deploy digests — DESIGN.md Sec. 16) riding the SAME single
        # fetch; the caller folds the fetched host copy afterwards.
        agg_h, per_h, rem_h, extra_h = pipeline.host_fetch(
            (agg, per, remapped or {}, extra)
        )
        report = cls(
            num_columns=sum(int(s.iterations.shape[0]) for s in stats),
            num_cells=sum(int(s.iterations.shape[0]) * n_cells for s in stats),
            remapped_columns=int(sum(float(v) for v in rem_h.values())),
            **{k: float(v) for k, v in agg_h.items()},
        )
        report.leaves = {
            name: dict(
                columns=int(leaf_stats[name].iterations.shape[0]),
                **{k: float(v) for k, v in d.items()},
            )
            for name, d in per_h.items()
        }
        for name, v in rem_h.items():
            report.leaves[name]["remapped_columns"] = float(v)
        report.extra = extra_h
        return report

    def merge(self, name: str, stats: WVStats, n_cells: int) -> None:
        c = int(stats.iterations.shape[0])
        lat = float(jnp.sum(stats.latency_ns))
        crit = float(jnp.max(stats.latency_ns))
        en = float(jnp.sum(stats.energy_pj))
        it = float(jnp.mean(stats.iterations))
        rms = float(jnp.sqrt(jnp.mean(stats.rms_error_lsb**2)))
        self.total_reads += float(jnp.sum(stats.reads))
        self.total_write_pulses += float(jnp.sum(stats.write_pulses))
        self.total_gave_up_cells += float(jnp.sum(stats.gave_up))
        self.total_retry_pulses += float(jnp.sum(stats.retry_pulses))
        self.leaves[name] = dict(
            columns=c, mean_iterations=it, critical_latency_ns=crit,
            energy_pj=en, rms_cell_error_lsb=rms,
        )
        tot_cells = self.num_cells + c * n_cells
        w_old = self.num_cells / max(tot_cells, 1)
        self.rms_cell_error_lsb = float(
            (self.rms_cell_error_lsb**2 * w_old + rms**2 * (1 - w_old)) ** 0.5
        )
        self.mean_iterations = (
            self.mean_iterations * self.num_columns + it * c
        ) / max(self.num_columns + c, 1)
        self.num_columns += c
        self.num_cells = tot_cells
        self.total_latency_ns += lat
        self.critical_latency_ns = max(self.critical_latency_ns, crit)
        self.total_energy_pj += en


@dataclasses.dataclass
class ArrayState:
    """Persistent programmed state of one weight leaf on RRAM.

    `g` is the *live* analog conductance of every cell (LSB units) — the
    lifetime subsystem mutates it (drift, refresh) by assigning a new
    array; everything else is fixed at deployment: `targets` are the
    intended integer levels (the refresh target), `d2d` the static
    per-cell step-efficiency (a device property, so re-programming the
    same physical array must reuse it), `scale`/`layout`/`shape`/`dtype`
    invert the quantize/pack transform.

    Faulty-silicon deploys (DESIGN.md Sec. 15) carry two extra pieces of
    physical state: `fault` — the sampled per-cell `FaultMap`, reused by
    every re-program of the same cells — and `remap` — the spare-column
    `RemapTable`.  With a remap the per-column arrays are PHYSICAL
    (C + S rows: C primaries then S spares) and the logical C-column
    view is ``x[remap.perm]``; `layout` always describes the logical
    geometry.
    """

    g: jax.Array              # (C[+S], N) programmed analog levels, LSB
    targets: jax.Array        # (C[+S], N) integer target levels, LSB
    d2d: jax.Array            # (C[+S], N) static per-cell step efficiency
    scale: jax.Array          # per-channel quantization scale
    layout: PackedLayout
    shape: tuple[int, ...]    # original leaf shape
    dtype: Any
    fault: dev_mod.FaultMap | None = None   # sampled silicon faults
    remap: remap_mod.RemapTable | None = None  # spare-column repair view
    # Physical column uids (host numpy, one per g row).  Pure address
    # metadata: uid // columns_per_tile is the tile a column lives on,
    # which is how scrub-time health maps (obs.health, DESIGN.md
    # Sec. 16) attribute drift to silicon without any device work.
    uids: np.ndarray | None = None

    def materialize(self, dtype: Any | None = None) -> jax.Array:
        """Programmed conductances -> effective dense weight leaf.

        `dtype` overrides the stored leaf dtype (deploy_matrix reads
        back in float32 regardless of the input dtype, so the analog
        error is not additionally rounded to a low-precision mantissa).
        """
        g = remap_mod.apply_remap(self.g, self.remap)
        q = unpack_columns(g, self.layout)
        w = dequantize_weight(q, self.scale).reshape(self.shape)
        return w.astype(self.dtype if dtype is None else dtype)


@dataclasses.dataclass
class DeployedModel:
    """A parameter pytree whose matmul leaves live on simulated RRAM.

    State-ownership contract (DESIGN.md Sec. 9): this object owns the
    analog array state.  Consumers (serving) never touch `g` directly —
    they call `materialize()` for a dense snapshot; producers (the
    lifetime simulator, refresh policies) advance `g` via
    `update_array`.  Digital leaves (norms, biases, embeddings) are kept
    verbatim and merged back at materialization.
    """

    treedef: Any
    leaves: list              # digital leaves verbatim; RRAM slots hold None
    slots: dict[str, int]     # leaf name -> index into `leaves`
    arrays: dict[str, ArrayState]
    wv_cfg: WVConfig
    cost: CircuitCost

    def materialize(self) -> Any:
        """Rebuild the full dense parameter pytree from current `g`."""
        leaves = list(self.leaves)
        for name, state in self.arrays.items():
            leaves[self.slots[name]] = state.materialize()
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def update_array(self, name: str, g: jax.Array) -> None:
        """Swap in aged/refreshed conductances for one leaf."""
        self.arrays[name] = dataclasses.replace(self.arrays[name], g=g)

    @property
    def num_columns(self) -> int:
        return sum(int(a.g.shape[0]) for a in self.arrays.values())


@dataclasses.dataclass
class _LeafPlan:
    """One eligible leaf, quantized and packed, awaiting programming."""

    name: str
    leaf: jax.Array
    cols: jax.Array           # (C, N) packed target levels
    layout: PackedLayout
    scale: jax.Array
    uid_base: int             # first global column uid of this leaf

    def state(
        self,
        g: jax.Array,
        d2d: jax.Array,
        targets: jax.Array | None = None,
        fault: dev_mod.FaultMap | None = None,
        remap: remap_mod.RemapTable | None = None,
        uids: np.ndarray | None = None,
    ) -> ArrayState:
        if uids is None:
            uids = self.uid_base + np.arange(
                int(self.cols.shape[0]), dtype=np.int64
            )
        return ArrayState(
            g=g, targets=self.cols if targets is None else targets, d2d=d2d,
            scale=self.scale, layout=self.layout, shape=self.leaf.shape,
            dtype=self.leaf.dtype, fault=fault, remap=remap,
            uids=np.asarray(uids, np.int64),
        )


# Deploy-wide digest configurations (static, so every deploy folds into
# the same bucket geometry): per-column verify write pulses and WV
# iterations.  Out-of-range columns clamp into the edge buckets.
_PULSE_DIGEST = ("deploy.write_pulses_per_column", 0.0, 4096.0, 64)
_ITER_DIGEST = ("deploy.iterations_per_column", 0.0, 128.0, 64)


def _deploy_health_tree(
    stats_map: "dict[str, WVStats]",
    uids_map: "dict[str, np.ndarray]",
    fault_cfg: FaultConfig | None,
    extra_columns: "dict[str, dict[str, jax.Array]] | None" = None,
) -> dict[str, Any]:
    """Device tree of per-tile health reductions + deploy digests.

    Everything here is a jnp reduction (or host uid bookkeeping) meant
    to ride the deploy's single `host_fetch` via `DeployReport.collect
    (extra=...)` — building it never synchronizes (DESIGN.md Sec. 16).
    """
    cpt = (fault_cfg or FaultConfig()).columns_per_tile
    tile_ids, tiles = obs.health.tile_deploy_stats(
        stats_map, uids_map, cpt, extra_columns=extra_columns
    )
    stats = list(stats_map.values())
    pulses = jnp.concatenate([s.write_pulses for s in stats])
    iters = jnp.concatenate([s.iterations for s in stats])
    digs = {}
    for (name, lo, hi, nb), vals in (
        (_PULSE_DIGEST, pulses), (_ITER_DIGEST, iters),
    ):
        digs[name] = obs.StreamingDigest.zeros(lo, hi, nb).add(vals)
    return {"tile_ids": tile_ids, "tiles": tiles, "digests": digs}


def _fold_deploy_health(extra_h: dict[str, Any] | None) -> None:
    """Fold the FETCHED health tree into the host registries."""
    if not extra_h:
        return
    tile_ids = extra_h["tile_ids"]
    for metric, vals in extra_h["tiles"].items():
        obs.health_registry.fold_tiles(f"deploy.{metric}", tile_ids, vals)
    for name, dig in extra_h["digests"].items():
        obs.digests.fold(name, dig)


def _plan_leaf(name, w, wv_cfg, q_cfg, uid_base) -> _LeafPlan:
    w2 = w.reshape((-1, w.shape[-1]))
    q, scale = quantize_weight(w2, q_cfg)
    cols, layout = pack_columns(q, wv_cfg.n_cells, q_cfg.cell_bits, q_cfg.slices)
    return _LeafPlan(name, w, cols, layout, scale, uid_base)


def _program_plan(
    key: jax.Array, plan: _LeafPlan, wv_cfg: WVConfig, cost: CircuitCost | None
) -> tuple[ArrayState, WVStats]:
    """Program one planned leaf on its own (the per-leaf baseline path).

    Columns draw from per-column sub-streams ``fold_in(key, uid)``
    (DESIGN.md Sec. 10), with d2d sampled from the same split the engine
    would use — so the result is bit-identical to programming the same
    uids inside a bucketed multi-leaf dispatch.
    """
    cols = plan.cols
    col_ids = plan.uid_base + jnp.arange(cols.shape[0], dtype=jnp.int32)
    d2d = pipeline.sample_d2d_for(key, col_ids, cols.shape, wv_cfg.device)
    # Dispatch through the shared jitted entry so the math is compiled
    # identically to the bucketed path (jit-vs-eager rounding differs at
    # the ulp level); the per-leaf cost profile — one trace per leaf
    # shape, per-leaf host syncs in the caller — is unchanged.  The
    # entry donates its targets/d2d buffers off-CPU, and both must
    # survive as ArrayState, so pass copies there.
    fn = pipeline.get_program_fn(wv_cfg, cost if cost is not None else CircuitCost())
    if pipeline.donates():
        g, stats = fn(key, jnp.copy(cols), jnp.copy(d2d), col_ids)
    else:
        g, stats = fn(key, cols, d2d, col_ids)
    return plan.state(g, d2d), stats


def _program_leaf(
    key: jax.Array,
    w: jax.Array,
    wv_cfg: WVConfig,
    q_cfg: QuantConfig,
    cost: CircuitCost | None,
) -> tuple[ArrayState, WVStats]:
    """Quantize, pack, and program one weight leaf; keep the array state."""
    return _program_plan(key, _plan_leaf("", w, wv_cfg, q_cfg, 0), wv_cfg, cost)


def deploy_matrix(
    key: jax.Array,
    w: jax.Array,
    wv_cfg: WVConfig,
    q_cfg: QuantConfig | None = None,
    cost: CircuitCost | None = None,
) -> tuple[jax.Array, WVStats]:
    """Program one weight matrix onto RRAM; returns (w_programmed, stats)."""
    if q_cfg is None:
        q_cfg = QuantConfig(
            weight_bits=wv_cfg.weight_bits, cell_bits=wv_cfg.device.bc
        )
    state, stats = _program_leaf(key, w, wv_cfg, q_cfg, cost)
    return state.materialize(dtype=jnp.float32), stats


def _eligible_leaves(
    params: Any,
    deploy_embeddings: bool,
    predicate: Callable[[str, jax.Array], bool] | None,
):
    """Flatten params and yield (index, name, leaf, eligible)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    records = []
    for i, (path, leaf) in enumerate(flat):
        name = jax.tree_util.keystr(path)
        eligible = hasattr(leaf, "ndim") and leaf.ndim >= 2
        if eligible and not deploy_embeddings and "embed" in name.lower():
            eligible = False
        if eligible and predicate is not None:
            eligible = predicate(name, leaf)
        records.append((i, name, leaf, eligible))
    return records, treedef


def deploy_arrays(
    key: jax.Array,
    params: Any,
    wv_cfg: WVConfig,
    q_cfg: QuantConfig | None = None,
    cost: CircuitCost | None = None,
    *,
    deploy_embeddings: bool = False,
    predicate: Callable[[str, jax.Array], bool] | None = None,
    batched: bool = True,
    mesh: Any | None = None,
    min_bucket: int = pipeline.DEFAULT_MIN_BUCKET,
    max_bucket: int = pipeline.DEFAULT_MAX_BUCKET,
    fault_cfg: FaultConfig | None = None,
    remap_cfg: remap_mod.RemapConfig | None = None,
    sensitivity: Callable[[str, jax.Array], float] | None = None,
) -> tuple[DeployedModel, DeployReport]:
    """Program every eligible weight leaf, keeping persistent array state.

    Returns (DeployedModel, DeployReport).  Same eligibility policy as
    `deploy_params`; `DeployedModel.materialize()` reproduces exactly
    what `deploy_params` would have returned for the same key.

    `batched=True` (default) routes ALL leaves' packed columns through
    the bucketed pipeline (`core.pipeline`): one jitted, donated
    `program_columns` dispatch per shape bucket, stats accumulated
    device-side with a single host sync, and the column axis optionally
    sharded over `mesh`.  `batched=False` is the per-leaf baseline path
    (one dispatch + per-leaf host syncs); both paths draw per-column RNG
    sub-streams, so their results are bit-identical.

    Faulty silicon (DESIGN.md Sec. 15, batched path only):
    `fault_cfg` samples a per-cell `FaultMap` (persisted in each
    `ArrayState`) and programs under it; `remap_cfg` provisions spare
    columns per leaf and — after the primary pass — repairs the worst
    columns (by `WVStats.gave_up`, so set `wv_cfg.give_up_pulses`) onto
    them, with optional fault-aware placement steering leaves ranked by
    `sensitivity(name, leaf)` onto the cleanest probed tiles.  All remap
    decisions are device-side; the deploy still performs exactly one
    host sync, with give-up/remap accounting riding it.
    """
    if q_cfg is None:
        q_cfg = QuantConfig(
            weight_bits=wv_cfg.weight_bits, cell_bits=wv_cfg.device.bc
        )
    if cost is None:
        cost = CircuitCost()
    use_fault = fault_cfg is not None and fault_cfg.any_faults
    use_remap = remap_cfg is not None and remap_cfg.spare_frac > 0.0
    if (use_fault or use_remap) and not batched:
        raise ValueError(
            "fault_cfg/remap_cfg require the batched deployment path"
        )
    records, treedef = _eligible_leaves(params, deploy_embeddings, predicate)
    leaves: list = []
    slots: dict[str, int] = {}
    plans: list[_LeafPlan] = []
    uid = 0
    for i, name, leaf, eligible in records:
        if not eligible:
            leaves.append(leaf)
            continue
        plan = _plan_leaf(name, leaf, wv_cfg, q_cfg, uid)
        uid += int(plan.cols.shape[0])
        slots[name] = len(leaves)
        plans.append(plan)
        leaves.append(None)

    arrays: dict[str, ArrayState] = {}
    with obs.span(
        "deploy", cat="deploy", method=wv_cfg.method.value,
        leaves=len(plans), batched=batched,
    ) as sp:
        if batched and not use_remap:
            g_blocks, stats_blocks, d2d_blocks, fault_blocks = (
                pipeline.program_packed_columns(
                    key, [p.cols for p in plans], wv_cfg, cost,
                    mesh=mesh, min_bucket=min_bucket, max_bucket=max_bucket,
                    fault_cfg=fault_cfg if use_fault else None,
                )
            )
            for plan, g, st, d2d, fb in zip(
                plans, g_blocks, stats_blocks, d2d_blocks, fault_blocks
            ):
                arrays[plan.name] = plan.state(g, d2d, fault=fb)
            stats_map = {p.name: s for p, s in zip(plans, stats_blocks)}
            uids_map = {p.name: arrays[p.name].uids for p in plans}
            report = DeployReport.collect(
                stats_map, wv_cfg.n_cells,
                extra=_deploy_health_tree(stats_map, uids_map, fault_cfg),
            )
        elif batched:
            # Two-pass spare-column deploy (DESIGN.md Sec. 15).  Pass A
            # programs every leaf's primary columns; the worst columns
            # (by give-up count) pick spare candidates DEVICE-SIDE; pass
            # B programs the spares at their own physical uids; the
            # remap table is decided device-side from both passes'
            # stats.  One host sync total, in the report collect below.
            c_counts = [int(p.cols.shape[0]) for p in plans]
            s_counts = [remap_mod.n_spares(c, remap_cfg) for c in c_counts]
            phys_counts = [c + s for c, s in zip(c_counts, s_counts)]
            if remap_cfg.placement and use_fault:
                sens = [
                    sensitivity(p.name, p.leaf) if sensitivity is not None
                    else 1.0 / max(pc, 1)
                    for p, pc in zip(plans, phys_counts)
                ]
                uid_arrays = remap_mod.plan_placement(
                    key, phys_counts, fault_cfg, sens,
                    provision=remap_cfg.placement_provision,
                )
                uid_end = max(
                    (int(u.max()) + 1 for u in uid_arrays if u.size), default=0
                )
            else:
                uid_arrays, base = [], 0
                for pc in phys_counts:
                    uid_arrays.append(base + np.arange(pc, dtype=np.int32))
                    base += pc
                uid_end = base
            prim_uids = np.concatenate(
                [ua[:c] for ua, c in zip(uid_arrays, c_counts)]
            )
            spare_uids = np.concatenate(
                [ua[c:] for ua, c in zip(uid_arrays, c_counts)]
            )
            fc = fault_cfg if use_fault else None
            g_blocks, stats_blocks, d2d_blocks, fault_blocks = (
                pipeline.program_packed_columns(
                    key, [p.cols for p in plans], wv_cfg, cost,
                    mesh=mesh, min_bucket=min_bucket, max_bucket=max_bucket,
                    uids=prim_uids, pad_uid_base=uid_end, fault_cfg=fc,
                )
            )
            cands = [
                remap_mod.spare_candidates(st.gave_up, s)
                for st, s in zip(stats_blocks, s_counts)
            ]
            sg_blocks, sstats_blocks, sd2d_blocks, sfault_blocks = (
                pipeline.program_packed_columns(
                    key,
                    [p.cols[cand] for p, cand in zip(plans, cands)],
                    wv_cfg, cost,
                    mesh=mesh, min_bucket=min_bucket, max_bucket=max_bucket,
                    uids=spare_uids, pad_uid_base=uid_end, fault_cfg=fc,
                )
            )
            remapped: dict[str, jax.Array] = {}
            combined: dict[str, WVStats] = {}
            remap_flags: dict[str, jax.Array] = {}
            cat = lambda a, b: jnp.concatenate([a, b])  # noqa: E731
            for plan, ua, c, cand, g, st, d2d, fb, sg, sst, sd2d, sfb in zip(
                plans, uid_arrays, c_counts, cands, g_blocks, stats_blocks,
                d2d_blocks, fault_blocks, sg_blocks, sstats_blocks,
                sd2d_blocks, sfault_blocks,
            ):
                table = remap_mod.build_table(
                    st.gave_up, cand, sst.gave_up, remap_cfg.min_gave_up
                )
                arrays[plan.name] = plan.state(
                    cat(g, sg),
                    cat(d2d, sd2d),
                    targets=cat(plan.cols, plan.cols[cand]),
                    fault=(
                        jax.tree.map(cat, fb, sfb) if fb is not None else None
                    ),
                    remap=table,
                    uids=ua,
                )
                combined[plan.name] = jax.tree.map(cat, st, sst)
                not_active = (~table.active[:c]).astype(jnp.float32)
                remapped[plan.name] = jnp.sum(not_active)
                # Per-column remap flags (physical order: primaries then
                # spares) for the per-tile health map.
                remap_flags[plan.name] = jnp.concatenate(
                    [not_active, jnp.zeros((len(ua) - c,), jnp.float32)]
                )
            uids_map = {p.name: arrays[p.name].uids for p in plans}
            report = DeployReport.collect(
                combined, wv_cfg.n_cells, remapped=remapped,
                extra=_deploy_health_tree(
                    combined, uids_map, fault_cfg,
                    extra_columns={"remapped_columns": remap_flags},
                ),
            )
        else:
            report = DeployReport()
            for plan in plans:
                state, stats = _program_plan(key, plan, wv_cfg, cost)
                report.merge(plan.name, stats, wv_cfg.n_cells)
                arrays[plan.name] = state
        sp["columns"] = report.num_columns
        sp["rms_cell_error_lsb"] = report.rms_cell_error_lsb
    # Health/digest fold (DESIGN.md Sec. 16): the per-tile reductions
    # and deploy digests were fetched BY the report's single host sync;
    # folding them here is pure host work.
    _fold_deploy_health(report.extra)
    # Telemetry attribution (DESIGN.md Sec. 14): all values above were
    # already fetched by the report's host sync(s) — pure host floats.
    obs.registry.fold(
        {
            "columns": report.num_columns,
            "verify_reads": report.total_reads,
            "write_pulses": report.total_write_pulses,
            # Contract-bearing give-up/remap counters (DESIGN.md Sec. 15).
            "gave_up_cells": report.total_gave_up_cells,
            "retry_pulses": report.total_retry_pulses,
            "remapped_columns": report.remapped_columns,
        },
        prefix="deploy.",
    )
    obs.charge(
        "deploy",
        energy_pj=report.total_energy_pj,
        latency_ns=report.critical_latency_ns,
        reads=report.total_reads,
        method=wv_cfg.method.value,
        columns=report.num_columns,
    )
    if report.total_gave_up_cells or report.remapped_columns:
        # Ledger attribution of the bounded-retry waste: energy of the
        # pulses burned on cells that were ultimately given up on,
        # estimated at mid-scale conductance (the per-pulse energy model
        # of cost.write_phase_cost, g = G_max/2).
        e_pulse_pj = (
            cost.v_set**2
            * (wv_cfg.device.g_max_lsb / 2.0 * cost.g_lsb_us)
            * cost.t_write_pulse_ns * 1e-3
        )
        obs.charge(
            "deploy.give_up",
            energy_pj=report.total_retry_pulses * e_pulse_pj,
            gave_up_cells=report.total_gave_up_cells,
            retry_pulses=report.total_retry_pulses,
            remapped_columns=report.remapped_columns,
        )
    return (
        DeployedModel(
            treedef=treedef, leaves=leaves, slots=slots, arrays=arrays,
            wv_cfg=wv_cfg, cost=cost,
        ),
        report,
    )


def deploy_params(
    key: jax.Array,
    params: Any,
    wv_cfg: WVConfig,
    q_cfg: QuantConfig | None = None,
    cost: CircuitCost | None = None,
    *,
    deploy_embeddings: bool = False,
    predicate: Callable[[str, jax.Array], bool] | None = None,
    batched: bool = True,
    mesh: Any | None = None,
) -> tuple[Any, DeployReport]:
    """Program every eligible weight leaf of a parameter pytree.

    Returns (programmed_params, DeployReport).  Eligibility: ndim >= 2,
    plus the optional `predicate(path, leaf)`; embedding-like leaves
    (path contains 'embed') follow `deploy_embeddings`.

    This is the dense one-shot path: array state is collapsed to weights
    immediately.  Use `deploy_arrays` when the conductances must stay
    live (lifetime simulation, refresh).  Programming itself is shared
    with `deploy_arrays` (bucketed pipeline by default).
    """
    deployed, report = deploy_arrays(
        key, params, wv_cfg, q_cfg, cost,
        deploy_embeddings=deploy_embeddings, predicate=predicate,
        batched=batched, mesh=mesh,
    )
    return deployed.materialize(), report
