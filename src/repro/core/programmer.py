"""Model-level RRAM deployment: quantize -> slice -> program -> read back.

This is the integration point between the paper's WV technique and the
training/serving framework: `deploy_params` takes any pytree of model
parameters, pushes every matmul weight through the
quantize -> bit-slice -> pack-to-columns -> write-and-verify pipeline,
and returns the *programmed* parameters (with real programming error)
plus aggregate WV statistics (latency / energy / iterations), so a
trained checkpoint can be "burned" onto simulated RRAM with CW-SC, MRA,
HD-PV, or HARP and then served to measure end-task robustness.

Deployment policy (documented in DESIGN.md):
* >=2D weight leaves go to RRAM (flattened to (K, M) on the last axis);
* 1D leaves (norm scales, biases) stay digital — they are tiny and in
  real ACiM macros live in SRAM next to the shift-and-add periphery;
* embedding tables are RRAM-deployable but excluded by default
  (`deploy_embeddings=False`): token embedding lookups are row reads,
  not VMM columns.

Columns are independent; under jit the caller may shard the column axis
over the full mesh (launch/program.py does this for the dry-run mesh).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.quant import (
    QuantConfig,
    dequantize_weight,
    pack_columns,
    quantize_weight,
    unpack_columns,
)

from .cost import CircuitCost
from .types import WVConfig
from .wv import WVStats, program_columns

__all__ = ["DeployReport", "deploy_params", "deploy_matrix"]


@dataclasses.dataclass
class DeployReport:
    """Aggregate WV statistics for one deployment."""

    num_columns: int = 0
    num_cells: int = 0
    mean_iterations: float = 0.0
    total_latency_ns: float = 0.0     # sum over arrays (columns in parallel)
    critical_latency_ns: float = 0.0  # max over columns = array wall-time
    total_energy_pj: float = 0.0
    rms_cell_error_lsb: float = 0.0
    leaves: dict[str, dict[str, float]] = dataclasses.field(default_factory=dict)

    def merge(self, name: str, stats: WVStats, n_cells: int) -> None:
        c = int(stats.iterations.shape[0])
        lat = float(jnp.sum(stats.latency_ns))
        crit = float(jnp.max(stats.latency_ns))
        en = float(jnp.sum(stats.energy_pj))
        it = float(jnp.mean(stats.iterations))
        rms = float(jnp.sqrt(jnp.mean(stats.rms_error_lsb**2)))
        self.leaves[name] = dict(
            columns=c, mean_iterations=it, critical_latency_ns=crit,
            energy_pj=en, rms_cell_error_lsb=rms,
        )
        tot_cells = self.num_cells + c * n_cells
        w_old = self.num_cells / max(tot_cells, 1)
        self.rms_cell_error_lsb = float(
            (self.rms_cell_error_lsb**2 * w_old + rms**2 * (1 - w_old)) ** 0.5
        )
        self.mean_iterations = (
            self.mean_iterations * self.num_columns + it * c
        ) / max(self.num_columns + c, 1)
        self.num_columns += c
        self.num_cells = tot_cells
        self.total_latency_ns += lat
        self.critical_latency_ns = max(self.critical_latency_ns, crit)
        self.total_energy_pj += en


def deploy_matrix(
    key: jax.Array,
    w: jax.Array,
    wv_cfg: WVConfig,
    q_cfg: QuantConfig | None = None,
    cost: CircuitCost | None = None,
) -> tuple[jax.Array, WVStats]:
    """Program one weight matrix onto RRAM; returns (w_programmed, stats)."""
    if q_cfg is None:
        q_cfg = QuantConfig(
            weight_bits=wv_cfg.weight_bits, cell_bits=wv_cfg.device.bc
        )
    shape = w.shape
    w2 = w.reshape((-1, shape[-1]))
    q, scale = quantize_weight(w2, q_cfg)
    cols, layout = pack_columns(q, wv_cfg.n_cells, q_cfg.cell_bits, q_cfg.slices)
    g, stats = program_columns(key, cols, wv_cfg, cost=cost)
    q_prog = unpack_columns(g, layout)  # analog effective levels
    w_prog = dequantize_weight(q_prog, scale).reshape(shape)
    return w_prog, stats


def deploy_params(
    key: jax.Array,
    params: Any,
    wv_cfg: WVConfig,
    q_cfg: QuantConfig | None = None,
    cost: CircuitCost | None = None,
    *,
    deploy_embeddings: bool = False,
    predicate: Callable[[str, jax.Array], bool] | None = None,
) -> tuple[Any, DeployReport]:
    """Program every eligible weight leaf of a parameter pytree.

    Returns (programmed_params, DeployReport).  Eligibility: ndim >= 2,
    plus the optional `predicate(path, leaf)`; embedding-like leaves
    (path contains 'embed') follow `deploy_embeddings`.
    """
    report = DeployReport()
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for i, (path, leaf) in enumerate(flat):
        name = jax.tree_util.keystr(path)
        eligible = hasattr(leaf, "ndim") and leaf.ndim >= 2
        if eligible and not deploy_embeddings and "embed" in name.lower():
            eligible = False
        if eligible and predicate is not None:
            eligible = predicate(name, leaf)
        if not eligible:
            out.append(leaf)
            continue
        w_prog, stats = deploy_matrix(
            jax.random.fold_in(key, i), leaf, wv_cfg, q_cfg, cost
        )
        report.merge(name, stats, wv_cfg.n_cells)
        out.append(w_prog.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), report
