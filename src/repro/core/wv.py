"""Column-wise write-and-verify engine (paper Secs. 3-4).

Implements all four WV schemes behind one vectorized loop:

  CW-SC  - column-wise single-cell baseline: one-hot verify reads with the
           compare-only ADC mode (ternary decision per cell, 1 fine
           pulse/iteration).  The paper's primary baseline.
  MRA-M  - multi-read averaging: M full-SAR one-hot reads per cell,
           averaged; magnitude estimate -> multi-pulse update.
  HD-PV  - Hadamard-encoded parallel verify: N Hadamard reads, full SAR,
           inverse-Hadamard (FWHT) decode; magnitude -> multi-pulse update.
  HARP   - Hadamard reads, compare-only vs the Hadamard-domain target
           (eq. 9), ternary aggregate s_w = H^T s_y (eq. 10), threshold
           tau_w (eq. 11); 1 fine pulse/iteration.

The verify READ itself — basis encode, noise sampling, converter
quantization, per-sweep cost — is owned by the shared readout subsystem
(`repro.readout`, DESIGN.md Sec. 12): each method is one point of the
basis x converter x averaging matrix (`readout.for_wv_method`), and this
module only owns the key schedule, the decision logic on the returned
measurements, and the write phase.

The engine runs ONE `lax.while_loop` over WV iterations for an arbitrary
batch of columns simultaneously, with per-cell freeze masks (streak
counter, Sec. 3.1) and per-column active masks — the idiomatic way to
batch heterogeneous convergence on SPMD hardware (no vmap-of-while).

Physical modelling notes:
* Verify reads always sense the WHOLE column (frozen cells keep
  contributing current); frozen cells merely ignore their decisions.
* mu_cm is redrawn per column per sweep and shared by every measurement
  in that sweep (incl. all M reads of MRA) — see readout.noise.
* Compare-mode targets are first quantized onto the ADC code grid (the
  comparator's DAC can only produce code levels) — readout owns that.
* Costs follow readout.cost / core.cost; per-column latency/energy
  accumulate only while the column is still active.
* An optional static per-column converter offset (`col_offset`,
  reference drift — readout.calibrate) biases every verify read.

Shapes: targets (C, N) float32 integer levels; returns g (C, N) and a
`WVStats` pytree of per-column diagnostics.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.readout import config as ro_config
from repro.readout import cost as ro_cost
from repro.readout import readout as ro

from . import device as dev_mod
from . import rng
from .cost import CircuitCost, write_phase_cost
from .types import WVConfig, WVMethod

__all__ = ["WVStats", "program_columns", "verify_aggregate", "verify_sweep"]


class WVStats(NamedTuple):
    """Per-column WV diagnostics (all shape (C,)).

    The two give-up fields are appended LAST so positional consumers of
    the original seven fields keep working; both are identically zero
    unless `cfg.give_up_pulses` is set (DESIGN.md Sec. 15).
    """

    iterations: jax.Array      # fine WV sweeps executed while column active
    latency_ns: jax.Array      # verify + write critical-path latency
    energy_pj: jax.Array       # verify + write + decode energy
    reads: jax.Array           # ADC conversions / comparisons issued
    write_pulses: jax.Array    # total write pulses applied
    rms_error_lsb: jax.Array   # final per-column RMS |g - w*|
    frozen_frac: jax.Array     # fraction of cells frozen at termination
    gave_up: jax.Array         # cells declared unprogrammable (count)
    retry_pulses: jax.Array    # fine pulses burned on cells that gave up


def verify_aggregate(
    key: jax.Array,
    g: jax.Array,
    targets: jax.Array,
    cfg: WVConfig,
    col_offset: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, float]:
    """One verification sweep, stopping BEFORE the ternary threshold.

    The pre-threshold aggregate is what the fused Pallas cell-update
    kernel consumes (it applies the threshold in VMEM); `verify_sweep`
    applies it in jnp for the unfused path.  `key` may be a batch of
    per-column keys (batched-pipeline RNG policy).  The physical read is
    one `readout.read_columns` sweep under the method's readout config.

    Returns:
      agg:      (C, N) decision aggregate — the decoded deviation for
        magnitude methods, the comparator sign for CW-SC, the
        unnormalized s_w = H^T s_y for HARP.
      dev_mag:  (C, N) |deviation| estimate in LSB for magnitude methods
        (pulse sizing); 1.0 placeholder for ternary methods.
      n_compares: (C, N) comparator operations (compare modes) else zeros.
      threshold: static decision threshold such that
        decision = sign(agg) * (|agg| > threshold).
    """
    rcfg = ro_config.for_wv_method(cfg)
    thr = cfg.decision_threshold_lsb

    if cfg.method == WVMethod.CW_SC:
        res = ro.read_columns(key, g, rcfg, targets=targets, col_offset=col_offset)
        # The comparator already made the ternary call; 0.5 re-thresholds
        # its {-1, 0, +1} output to itself.
        return res.values, jnp.ones_like(g), res.n_compares, 0.5

    if cfg.method in (WVMethod.MRA, WVMethod.HD_PV):
        res = ro.read_columns(key, g, rcfg, col_offset=col_offset)
        w_hat = ro.decode_magnitude(res.values, rcfg)  # eq. 6 digital adders
        dev = w_hat - targets
        return dev, jnp.abs(dev), jnp.zeros_like(g), thr

    if cfg.method == WVMethod.HARP:
        res = ro.read_columns(key, g, rcfg, targets=targets, col_offset=col_offset)
        s_w = ro.decode_ternary(res.values, rcfg)  # unnormalized H^T s_y
        return s_w, jnp.ones_like(g), res.n_compares, cfg.tau_w

    raise ValueError(cfg.method)


def _threshold(agg: jax.Array, thr: float) -> jax.Array:
    return jnp.where(agg > thr, 1.0, jnp.where(agg < -thr, -1.0, 0.0))


def verify_sweep(
    key: jax.Array,
    g: jax.Array,
    targets: jax.Array,
    cfg: WVConfig,
    col_offset: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One verification sweep for a batch of columns.

    Returns:
      decision: (C, N) in {-1, 0, +1} = sign of estimated (g - w*) beyond
        the threshold; +1 means conductance too HIGH (needs RESET).
      dev_mag:  (C, N) |deviation| estimate in LSB for magnitude methods
        (pulse sizing); 1.0 placeholder for ternary methods.
      n_compares: (C, N) comparator operations (compare modes) else zeros.
    """
    agg, dev_mag, n_cmp, thr = verify_aggregate(key, g, targets, cfg, col_offset)
    return _threshold(agg, thr), dev_mag, n_cmp


def _characterized_coarse_pulses(
    targets: jax.Array, dev_cfg, max_pulses: int
) -> jax.Array:
    """Coarse pulse counts from the characterized (nominal) device response.

    Real WV controllers derive open-loop pulse counts from the device's
    programming look-up table (NeuroSim-style cumulative SET curve), not
    from target/step — otherwise the nonlinear taper near LRS leaves a
    large systematic undershoot at high levels.  The nominal curve starts
    from g = 0 for EVERY cell, so one scalar (P+1,) landing trajectory
    characterizes the whole batch; the per-cell argmin is a broadcast
    against the targets, not a (P, C, N) scan.
    """
    from .device import _effective_step

    def body(g_nom, _):
        g_next = jnp.clip(
            g_nom + _effective_step(g_nom, 1.0, dev_cfg, dev_cfg.coarse_step_lsb),
            0.0,
            dev_cfg.g_max_lsb,
        )
        return g_next, g_next

    g0 = jnp.zeros((), jnp.float32)
    _, traj = jax.lax.scan(body, g0, None, length=max_pulses)
    # landings[p] = nominal conductance after p pulses, shape (P+1,).
    landings = jnp.concatenate([g0[None], traj], axis=0)
    err = jnp.abs(landings.reshape((-1,) + (1,) * targets.ndim) - targets[None])
    return jnp.argmin(err, axis=0).astype(jnp.float32)


class _LoopState(NamedTuple):
    g: jax.Array
    streak: jax.Array
    frozen: jax.Array
    it: jax.Array
    iters: jax.Array
    lat: jax.Array
    en: jax.Array
    reads: jax.Array
    pulses: jax.Array
    cell_pulses: jax.Array   # (C, N) fine pulses per cell (give-up budget)
    gave_up: jax.Array       # (C, N) cells frozen by budget exhaustion


def program_columns(
    key: jax.Array,
    targets: jax.Array,
    cfg: WVConfig,
    cost: CircuitCost | None = None,
    d2d: jax.Array | None = None,
    col_ids: jax.Array | None = None,
    col_offset: jax.Array | None = None,
    fault: dev_mod.FaultMap | None = None,
) -> tuple[jax.Array, WVStats]:
    """Program a batch of columns from HRS to integer target levels.

    Args:
      key: PRNG key.
      targets: (C, N) float32 target levels in [0, 2^Bc - 1].
      cfg: WV configuration (method, noise, ADC, device).
      cost: circuit cost constants (Table 1 defaults if None).
      d2d: optional pre-sampled (C, N) device-to-device efficiency.
      col_ids: optional (C,) int32 per-column stream ids.  When given,
        every column draws its noise from its own sub-stream
        ``fold_in(key, col_ids[c])`` (DESIGN.md Sec. 10), making the
        result per-column independent of batch composition/padding —
        the contract the bucketed deployment pipeline relies on.  When
        None, the legacy batch-shaped draws are used (same key schedule
        as pre-pipeline behaviour; the write-noise multiply was
        reassociated, so results match to the ulp, not bit-exactly).
      col_offset: optional (C,) static per-column converter reference
        offset biasing every verify read (readout.calibrate scenario).
      fault: optional static per-cell :class:`device.FaultMap` — weak
        cells see collapsed step efficiency, stuck cells never move.
        Sampled caller-side (like `d2d`) so refresh re-programs under
        the same silicon.  The verify key schedule is unconditional, so
        `fault=None` and an inert map are bit-identical.

    Give-up (DESIGN.md Sec. 15): with `cfg.give_up_pulses` set, a cell
    whose cumulative fine-pulse count reaches the budget at the start of
    a sweep is declared unprogrammable and folded into the frozen mask
    (same treatment the fused kernel already gives converged cells); the
    per-column count and the pulses burned on such cells are reported in
    `WVStats.gave_up` / `WVStats.retry_pulses`.  Magnitude methods may
    overshoot the budget by up to one burst (`max_pulses_per_iter - 1`)
    because the check runs at sweep granularity.  Cells still unfrozen
    at `max_fine_iters` also count as gave-up.  With the budget unset
    the decision logic is untouched and both stats are exactly zero.

    Returns (g_final, WVStats).
    """
    if cost is None:
        cost = CircuitCost()
    targets = targets.astype(jnp.float32)
    c, n = targets.shape
    assert n == cfg.n_cells, (n, cfg.n_cells)
    dev_cfg = cfg.device
    rcfg = ro_config.for_wv_method(cfg)

    if col_ids is None:
        k_d2d, k_coarse, k_loop = jax.random.split(key, 3)
    else:
        col_keys = rng.fold_col_keys(key, col_ids)
        k_d2d, k_coarse, k_loop = rng.split(col_keys, 3)
    if d2d is None:
        d2d = dev_mod.sample_d2d(k_d2d, targets.shape, dev_cfg)

    # ---- coarse OPEN-LOOP SET from HRS (Table 1: 4V, 5 steps/pulse, up to
    # max_coarse_iters pulses).  Fig. 8 shows coarse SET as a distinct
    # initialization before the WV loop: pulse counts come from the target
    # (no verify reads — coarse pays write cost only).  Per-pulse noise
    # accumulates as a random walk (device.map_noise_mode="pulse"), so the
    # residual entering the fine loop is ~ +-coarse_step/2 quantization plus
    # ~sigma_map of accumulated programming noise — the working point at
    # which HARP's tau_w=4 corresponds to the 0.5-LSB cell threshold.
    g = dev_mod.initial_state(targets.shape)
    n_coarse = _characterized_coarse_pulses(targets, dev_cfg, cfg.max_coarse_iters)
    direction0 = jnp.where(n_coarse > 0, 1.0, 0.0)
    g = dev_mod.apply_pulses(
        k_coarse, g, direction0, n_coarse, d2d, dev_cfg,
        step_lsb=dev_cfg.coarse_step_lsb, fault=fault,
    )
    lat0, en0 = write_phase_cost(g, n_coarse, direction0, dev_cfg, cost, coarse=True)
    pulses0 = jnp.sum(n_coarse, axis=-1)

    ternary = cfg.method in (WVMethod.CW_SC, WVMethod.HARP)
    reads_per_sweep = rcfg.reads_per_sweep
    # Freeze warmup (Sec. 3.1): streaks don't bite during the coarse-
    # residual transient; see types.WVConfig.freeze_warmup_iters.
    warmup = cfg.freeze_warmup_iters + (
        cfg.freeze_warmup_ternary_extra if ternary else 0
    )

    # Give-up budget: Python-level gate, so with the budget unset the
    # frozen mask fed to the decision logic is *literally* st.frozen and
    # the compiled decision stream is unchanged.
    budget = cfg.give_up_pulses

    def body(st: _LoopState) -> _LoopState:
        k_it = rng.fold_in(k_loop, st.it)
        k_v, k_w = rng.split(k_it)

        if budget is not None:
            # Budget check at sweep start: unconverged cells that spent
            # their pulse budget are declared unprogrammable and treated
            # exactly like converged-frozen cells from here on.
            exhausted = (~st.frozen) & (st.cell_pulses >= float(budget))
            frozen_in = st.frozen | exhausted
            gave_up = st.gave_up | exhausted
        else:
            frozen_in = st.frozen
            gave_up = st.gave_up
        col_active = ~jnp.all(frozen_in, axis=-1)  # (C,)

        agg, dev_mag, n_cmp, thr = verify_aggregate(
            k_v, st.g, targets, cfg, col_offset
        )
        can_freeze = st.it >= warmup

        if cfg.use_pallas:
            # Fused verify-tail + write: threshold -> streak -> freeze ->
            # pulse-size -> device-step -> clip in ONE VMEM pass (the
            # kernel is deterministic: write noise is pre-sampled here
            # from the same key splits `apply_pulses` uses, so fused and
            # unfused paths are bit-identical).  `can_freeze` is static
            # inside the kernel; the warmup boundary picks between two
            # kernel instances via lax.cond.
            from repro.kernels.wv_step import ops as wv_ops
            from repro.kernels.wv_step.ref import WVCellParams

            c2c, nmap = dev_mod.sample_write_noise(k_w, st.g.shape, dev_cfg)
            # The kernel consumes a pre-multiplied efficiency field, so
            # weak/tile-degraded cells need no kernel change; stuck cells
            # are re-pinned after the update (same association as the
            # unfused apply_pulses path -> still bit-identical).
            d2d_eff = d2d if fault is None else d2d * fault.efficiency

            def upd(cf: bool):
                p = WVCellParams(
                    threshold=thr,
                    k_streak=cfg.k_streak,
                    can_freeze=cf,
                    ternary=ternary,
                    fine_step=dev_cfg.fine_step_lsb,
                    max_pulses=float(cfg.max_pulses_per_iter),
                    g_max=dev_cfg.g_max_lsb,
                    nonlinearity=dev_cfg.nonlinearity,
                    reset_asymmetry=dev_cfg.reset_asymmetry,
                    nmap_sqrt_pulses=dev_cfg.map_noise_mode == "pulse",
                )
                return wv_ops.wv_cell_update(
                    agg, dev_mag, st.g, st.streak, frozen_in, c2c, nmap,
                    d2d_eff, p
                )

            g, streak, frozen, n_p, direction = jax.lax.cond(
                can_freeze, lambda: upd(True), lambda: upd(False)
            )
            g = dev_mod.clamp_stuck(g, fault)
        else:
            decision = _threshold(agg, thr)
            # Streak / freeze (Sec. 3.1): K consecutive in-threshold
            # verifies freeze a cell, gated behind the warmup.
            in_thr = decision == 0.0
            streak = jnp.where(in_thr, st.streak + 1, 0)
            frozen = frozen_in | (can_freeze & (streak >= cfg.k_streak))

            # Pulse sizing: ternary methods use single fine pulses;
            # magnitude methods apply round(|dev| / step) pulses (capped).
            if ternary:
                n_p = jnp.ones_like(st.g)
            else:
                n_p = jnp.clip(
                    jnp.round(dev_mag / dev_cfg.fine_step_lsb),
                    1.0,
                    float(cfg.max_pulses_per_iter),
                )
            act_cell = (~frozen_in) & (decision != 0.0) & col_active[:, None]
            n_p = jnp.where(act_cell, n_p, 0.0)
            direction = jnp.where(act_cell, -decision, 0.0)  # too high -> RESET

            g_new = dev_mod.apply_pulses(
                k_w, st.g, direction, n_p, d2d, dev_cfg, fault=fault
            )
            g = jnp.where(col_active[:, None], g_new, st.g)

        # Cost accounting (active columns only).
        lat_r, en_r = ro_cost.sweep_cost(
            rcfg, cost, n_compares=n_cmp if ternary else None
        )
        lat_w, en_w = write_phase_cost(st.g, n_p, direction, dev_cfg, cost)
        actf = col_active.astype(jnp.float32)
        return _LoopState(
            g=g,
            streak=streak,
            frozen=frozen,
            it=st.it + 1,
            iters=st.iters + actf,
            lat=st.lat + actf * (lat_r + lat_w),
            en=st.en + actf * (en_r + en_w),
            reads=st.reads + actf * reads_per_sweep,
            pulses=st.pulses + jnp.sum(n_p, axis=-1),
            cell_pulses=st.cell_pulses + n_p,
            gave_up=gave_up,
        )

    def cond(st: _LoopState) -> jax.Array:
        return (st.it < cfg.max_fine_iters) & jnp.any(~st.frozen)

    zero = jnp.zeros((c,), jnp.float32)
    init = _LoopState(
        g=g,
        streak=jnp.zeros(targets.shape, jnp.int32),
        frozen=jnp.zeros(targets.shape, bool),
        it=jnp.asarray(0, jnp.int32),
        iters=zero,
        lat=lat0,
        en=en0,
        reads=zero,
        pulses=pulses0,
        cell_pulses=jnp.zeros(targets.shape, jnp.float32),
        gave_up=jnp.zeros(targets.shape, bool),
    )
    st = jax.lax.while_loop(cond, body, init)

    if budget is not None:
        # Cells still unfrozen at max_fine_iters never converged either.
        gave_up_cells = st.gave_up | ~st.frozen
        retry_pulses = jnp.sum(
            jnp.where(gave_up_cells, st.cell_pulses, 0.0), axis=-1
        )
        gave_up_count = jnp.sum(gave_up_cells.astype(jnp.float32), axis=-1)
    else:
        gave_up_count = zero
        retry_pulses = zero

    err = st.g - targets
    stats = WVStats(
        iterations=st.iters,
        latency_ns=st.lat,
        energy_pj=st.en,
        reads=st.reads,
        write_pulses=st.pulses,
        rms_error_lsb=jnp.sqrt(jnp.mean(err * err, axis=-1)),
        frozen_frac=jnp.mean(st.frozen.astype(jnp.float32), axis=-1),
        gave_up=gave_up_count,
        retry_pulses=retry_pulses,
    )
    return st.g, stats
