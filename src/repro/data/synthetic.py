"""Deterministic synthetic LM data pipeline.

Offline-friendly substitute for a tokenized corpus: sequences are drawn
from a fixed random bigram process (per-seed transition structure), so
models *can* learn it (loss decreases well below the unigram entropy)
and runs are exactly reproducible from (seed, step) — no filesystem
state, no host synchronization.  The pipeline is stateless: any host can
materialize any step's global batch and slice out its own shard, which
is what makes elastic restarts and straggler backfill trivial.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Batch(NamedTuple):
    tokens: jax.Array   # (B, S) int32 inputs
    targets: jax.Array  # (B, S) int32 next-token labels
    mask: jax.Array     # (B, S) float32 loss weights


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 16  # successors per token: entropy ~= log2(branching) bits

    def _succ_table(self) -> np.ndarray:
        """(vocab, branching) fixed successor table defining the bigram chain."""
        rng = np.random.RandomState(self.seed ^ 0x5EED)
        return rng.randint(
            0, self.vocab_size, size=(self.vocab_size, self.branching)
        ).astype(np.int32)

    def global_batch_at(self, step: int) -> Batch:
        """Materialize the full global batch for `step` (host-agnostic)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        table = jnp.asarray(self._succ_table())
        k0, k1 = jax.random.split(key)
        first = jax.random.randint(k0, (self.global_batch,), 0, self.vocab_size)
        choices = jax.random.randint(
            k1, (self.global_batch, self.seq_len), 0, self.branching
        )

        def walk(tok, choice):
            nxt = table[tok, choice]
            return nxt, nxt

        _, seq = jax.lax.scan(
            walk, first, jnp.moveaxis(choices, 1, 0)
        )
        seq = jnp.moveaxis(seq, 0, 1)  # (B, S)
        tokens = jnp.concatenate([first[:, None], seq[:, :-1]], axis=1)
        return Batch(
            tokens=tokens.astype(jnp.int32),
            targets=seq.astype(jnp.int32),
            mask=jnp.ones(seq.shape, jnp.float32),
        )

    def host_batch_at(self, step: int, host_id: int, num_hosts: int) -> Batch:
        """This host's slice of the step's global batch."""
        assert self.global_batch % num_hosts == 0
        per = self.global_batch // num_hosts
        full = self.global_batch_at(step)
        sl = slice(host_id * per, (host_id + 1) * per)
        return Batch(full.tokens[sl], full.targets[sl], full.mask[sl])

    def iterate(self, start_step: int = 0) -> Iterator[Batch]:
        step = start_step
        while True:
            yield self.global_batch_at(step)
            step += 1
