from .synthetic import SyntheticLM, Batch  # noqa: F401
