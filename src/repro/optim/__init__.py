from .adamw import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from .schedule import cosine_schedule  # noqa: F401
from .compression import (  # noqa: F401
    CompressionState,
    compress_int8,
    decompress_int8,
    compressed_psum,
    init_compression_state,
)
