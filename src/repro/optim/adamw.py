"""AdamW on raw pytrees, with global-norm clipping and dtype policies.

The optimizer-state dtype is configurable per config: the 235B MoE
config stores m/v in bf16 so (params + grads + m + v) fits a v5e pod's
HBM (see DESIGN.md Sec. 4); small configs keep f32 states.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    state_dtype: Any = jnp.float32  # bf16 for the largest configs


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params: Any, cfg: AdamWConfig) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=cfg.state_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads: Any, state: AdamWState, params: Any, cfg: AdamWConfig, lr: jax.Array
) -> tuple[Any, AdamWState, dict[str, jax.Array]]:
    """One AdamW step; returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
    b1, b2 = cfg.betas
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_new = b1 * m32 + (1.0 - b1) * g
        v_new = b2 * v32 + (1.0 - b2) * g * g
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        p_new = p.astype(jnp.float32) - lr * (
            update + cfg.weight_decay * p.astype(jnp.float32)
        )
        return (
            p_new.astype(p.dtype),
            m_new.astype(cfg.state_dtype),
            v_new.astype(cfg.state_dtype),
        )

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "clip_scale": scale}
    return new_params, AdamWState(step, new_m, new_v), metrics
