"""Int8 gradient compression with error feedback for cross-pod all-reduce.

On a multi-pod mesh the "pod" axis crosses the slower inter-pod links
(DCI), while "data"/"model" stay on intra-pod ICI.  The standard trick
(1-bit Adam / PowerSGD lineage) is to reduce-scatter in full precision
inside the pod and compress only the cross-pod hop.  We implement the
int8 variant with error feedback:

    q = quantize_int8(g + e);   e' = (g + e) - dequant(q)
    g_synced = psum_over_pod(dequant(q)) / pods

Error feedback makes the quantization bias vanish over steps (the
residual e is re-injected next step), preserving convergence.

`compressed_psum` is written with `shard_map` collectives so it can be
dropped into a train step over the "pod" axis; quantization is
per-leading-row (block) scaled.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: Any  # pytree of residuals, same shapes as grads


def init_compression_state(grads: Any) -> CompressionState:
    return CompressionState(error=jax.tree.map(jnp.zeros_like, grads))


def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Row-block scaled int8 quantization: returns (q, scale)."""
    flat = x.reshape((x.shape[0], -1)) if x.ndim > 1 else x.reshape((1, -1))
    scale = jnp.max(jnp.abs(flat), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    return (q.astype(jnp.float32) * scale).reshape(shape)


def _compress_leaf(g, e):
    """One error-feedback compression round for a leaf; returns (q, scale, e')."""
    corrected = g.astype(jnp.float32) + e
    q, scale = compress_int8(corrected)
    deq = decompress_int8(q, scale, g.shape)
    return q, scale, corrected - deq


def compressed_psum(
    grads: Any, state: CompressionState, axis_name: str = "pod"
) -> tuple[Any, CompressionState]:
    """Cross-axis mean of grads in int8 with error feedback.

    Must run inside a `shard_map` (or other context) where `axis_name`
    is bound.  Full-precision leaves go over the wire as int8 + one f32
    scale per row block: a 3.98x wire-byte reduction on the slow hop.
    """
    size = jax.lax.psum(1, axis_name)

    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        flat = (
            corrected.reshape(corrected.shape[0], -1)
            if corrected.ndim > 1
            else corrected.reshape(1, -1)
        )
        # All pods must quantize against the SAME scale: summing integer
        # codes quantized with per-pod scales biases the mean (caught by
        # tests/test_compression_multipod.py).  The shared scale costs one
        # tiny pmax of the per-row absmax.
        local_max = jnp.max(jnp.abs(flat), axis=-1, keepdims=True)
        shared_max = jax.lax.pmax(local_max, axis_name)
        scale = shared_max / 127.0 + 1e-12
        q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
        e_new = corrected - (q.astype(jnp.float32) * scale).reshape(g.shape)
        # int8 payload summed in int32 to avoid overflow across pods.
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        deq = (q_sum.astype(jnp.float32) * scale / size).reshape(g.shape)
        return deq.astype(g.dtype), e_new

    out = jax.tree.map(leaf, grads, state.error)
    synced = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return synced, CompressionState(error=err)
