"""Fleet dashboard: join exported traces into one health report.

    python -m repro.obs.dashboard benchmarks/TRACE_serving.json \
        benchmarks/TRACE_fleet.json --fleet benchmarks/fleet_status.json \
        --out fleet.html

Reads one or more Chrome/Perfetto trace-event files written by
`repro.obs.trace` (phase spans + ledger charges + cat="digest"/
"health"/"slo" instants) plus an optional machine-readable fleet
status JSON (`repro.obs.fleet_status()` output written by a benchmark)
and renders a single self-contained report: per-replica phase/ledger
tables, latency-digest percentiles, per-tile health worst lists, and
SLO breach rolls.  `--format text` prints the same content as aligned
tables; the default HTML output embeds all styling inline (one file,
no assets, safe to upload as a CI artifact).

The dashboard only READS files — it never imports jax, touches
devices, or recomputes metrics (DESIGN.md Sec. 16: digests accumulate
in-jit, health maps reduce device-side, SLO rules evaluate host-side,
the dashboard joins artifacts).  Exits non-zero when any input is
malformed or when the joined inputs contain no events at all, so the
CI render step fails loudly instead of publishing an empty page.
"""

from __future__ import annotations

import argparse
import html as _html
import json
import sys
from typing import Any

from . import report as _report

__all__ = ["collect", "render_text", "render_html", "main"]


def _health_rows(doc: dict[str, Any]) -> list[dict[str, Any]]:
    """One row per health metric / gauge from cat="health" instants.

    Health emits are snapshots of cumulative maps, so the last instant
    per name wins (same rule as digest emits).
    """
    rows: dict[str, dict[str, Any]] = {}
    for ev in doc["traceEvents"]:
        if not isinstance(ev, dict) or ev.get("cat") != "health":
            continue
        name = str(ev.get("name", ""))
        args = ev.get("args") or {}
        if name.startswith("health.gauge."):
            rows[name] = {
                "metric": name[len("health.gauge."):],
                "kind": "gauge",
                "value": args.get("value"),
            }
        elif name.startswith("health."):
            rows[name] = {
                "metric": name[len("health."):],
                "kind": "tiles",
                "n_tiles": args.get("n_tiles"),
                "total": args.get("total"),
                "max": args.get("max"),
                "worst": args.get("worst") or {},
            }
    return [rows[k] for k in sorted(rows)]


def collect(trace_paths: list[str], fleet_path: str | None = None) -> dict:
    """Load and join every input into one plain-data report model.

    Raises ValueError on any malformed input (propagated from
    `report.load` / json) so `main` can turn it into a non-zero exit.
    """
    replicas = []
    for path in trace_paths:
        doc = _report.load(path)
        replicas.append(
            {
                "path": path,
                "n_events": len(doc["traceEvents"]),
                "phases": _report.summarize(doc),
                "digests": _report.digest_rows(doc),
                "slo": _report.slo_rows(doc),
                "health": _health_rows(doc),
            }
        )
    fleet = None
    if fleet_path is not None:
        try:
            with open(fleet_path) as f:
                fleet = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise ValueError(f"cannot read fleet status {fleet_path!r}: {e}")
        if not isinstance(fleet, dict):
            raise ValueError(f"{fleet_path!r} is not a fleet-status object")
    return {"replicas": replicas, "fleet": fleet}


# ------------------------------------------------------------- text view
def _fmt(v: Any) -> str:
    if v is None:
        return "-"
    if isinstance(v, (int, float)):
        return _report._fmt(float(v)) if v != 0 else "0"
    return str(v)


def _worst_str(worst: dict) -> str:
    items = sorted(worst.items(), key=lambda kv: -float(kv[1]))[:4]
    return ", ".join(f"{t}:{float(v):g}" for t, v in items) or "-"


def _health_table(rows: list[dict[str, Any]]) -> str:
    table = [["metric", "kind", "n_tiles", "total", "max", "worst tiles"]]
    for r in rows:
        if r["kind"] == "gauge":
            table.append(
                [r["metric"], "gauge", "-", _fmt(r["value"]), "-", "-"]
            )
        else:
            table.append(
                [r["metric"], "tiles", _fmt(r["n_tiles"]), _fmt(r["total"]),
                 _fmt(r["max"]), _worst_str(r["worst"])]
            )
    return _report._render_table(table)


def render_text(model: dict) -> str:
    out: list[str] = []
    for rep in model["replicas"]:
        out.append(f"## {rep['path']} ({rep['n_events']} events)")
        if rep["phases"]:
            out.append(_report.render(rep["phases"]))
        if rep["digests"]:
            out.append("# digests")
            out.append(_report.render_digests(rep["digests"]))
        if rep["health"]:
            out.append("# health")
            out.append(_health_table(rep["health"]))
        if rep["slo"]:
            out.append("# slo breaches")
            out.append(_report.render_slo(rep["slo"]))
        out.append("")
    fleet = model["fleet"]
    if fleet:
        out.append("## fleet status")
        out.append(json.dumps(fleet, indent=2, sort_keys=True, default=str))
    return "\n".join(out)


# ------------------------------------------------------------- html view
_CSS = """
body { font: 13px/1.5 -apple-system, 'Segoe UI', sans-serif; margin: 2em;
       color: #1a1a2e; background: #fafafa; max-width: 72em; }
h1 { font-size: 1.4em; } h2 { font-size: 1.1em; margin-top: 2em;
     border-bottom: 2px solid #d0d0e0; padding-bottom: .2em; }
h3 { font-size: .95em; color: #444; margin-bottom: .3em; }
table { border-collapse: collapse; margin: .5em 0 1.2em; }
th, td { padding: .25em .7em; border: 1px solid #e0e0e8; text-align: right; }
th { background: #eef; } td:first-child, th:first-child { text-align: left; }
.breach td { background: #ffe8e8; }
.ok { color: #2a7; } .bad { color: #c22; font-weight: 600; }
pre { background: #f0f0f5; padding: .8em; overflow-x: auto; }
"""


def _h(v: Any) -> str:
    return _html.escape(_fmt(v))


def _html_table(header: list[str], rows: list[list[Any]],
                row_classes: list[str] | None = None) -> str:
    parts = ["<table><tr>" + "".join(f"<th>{_html.escape(h)}</th>" for h in header) + "</tr>"]
    for i, row in enumerate(rows):
        cls = f' class="{row_classes[i]}"' if row_classes and row_classes[i] else ""
        parts.append(
            f"<tr{cls}>" + "".join(f"<td>{_h(c)}</td>" for c in row) + "</tr>"
        )
    parts.append("</table>")
    return "".join(parts)


def render_html(model: dict) -> str:
    body: list[str] = ["<h1>Fleet health dashboard</h1>"]
    total_breaches = sum(
        r["breaches"] for rep in model["replicas"] for r in rep["slo"]
    )
    cls = "bad" if total_breaches else "ok"
    body.append(
        f'<p>{len(model["replicas"])} trace(s) joined &middot; '
        f'<span class="{cls}">{total_breaches} SLO breach instant(s)</span></p>'
    )
    for rep in model["replicas"]:
        body.append(f"<h2>{_html.escape(rep['path'])} "
                    f"({rep['n_events']} events)</h2>")
        if rep["phases"]:
            body.append("<h3>Phases &amp; ledger</h3>")
            body.append(_html_table(
                ["phase", "count", "total_ms", "mean_ms", "energy_pj",
                 "latency_ns", "reads", "tokens"],
                [[r["phase"], r["count"], r["total_ms"], r["mean_ms"],
                  r["energy_pj"], r["latency_ns"], r["reads"], r["tokens"]]
                 for r in rep["phases"]],
            ))
        if rep["digests"]:
            body.append("<h3>Latency / pulse digests</h3>")
            body.append(_html_table(
                ["digest", "count", "mean", "p50", "p95", "p99", "max",
                 "under", "over"],
                [[r["digest"], r["count"], r["mean"], r["p50"], r["p95"],
                  r["p99"], r["max"], r.get("n_under", 0.0),
                  r.get("n_over", 0.0)] for r in rep["digests"]],
            ))
        if rep["health"]:
            body.append("<h3>Tile health</h3>")
            body.append(_html_table(
                ["metric", "kind", "n_tiles", "total", "max", "worst tiles"],
                [[r["metric"], r["kind"],
                  r.get("n_tiles"), r.get("total") if r["kind"] == "tiles"
                  else r.get("value"),
                  r.get("max"), _worst_str(r.get("worst") or {})]
                 for r in rep["health"]],
            ))
        if rep["slo"]:
            body.append("<h3>SLO breaches</h3>")
            body.append(_html_table(
                ["rule", "metric", "ceiling", "breaches", "last_value"],
                [[r["rule"], r["metric"], r["ceiling"], r["breaches"],
                  r["last_value"]] for r in rep["slo"]],
                row_classes=["breach" if r["breaches"] else "" for r in rep["slo"]],
            ))
    if model["fleet"]:
        body.append("<h2>Fleet status</h2>")
        body.append(
            "<pre>"
            + _html.escape(json.dumps(
                model["fleet"], indent=2, sort_keys=True, default=str))
            + "</pre>"
        )
    return (
        "<!doctype html><html><head><meta charset='utf-8'>"
        "<title>Fleet health dashboard</title>"
        f"<style>{_CSS}</style></head><body>"
        + "".join(body)
        + "</body></html>"
    )


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.obs.dashboard",
        description="Join obs trace files into one fleet health report.",
    )
    ap.add_argument("traces", nargs="+",
                    help="TRACE_*.json trace-event files (one per replica/run)")
    ap.add_argument("--fleet", default=None,
                    help="fleet-status JSON (repro.obs.fleet_status() output)")
    ap.add_argument("--out", default=None,
                    help="output path (default: stdout)")
    ap.add_argument("--format", choices=("html", "text"), default=None,
                    help="output format (default: html when --out ends in "
                         ".html, else text)")
    args = ap.parse_args(argv)

    try:
        model = collect(args.traces, args.fleet)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    if sum(rep["n_events"] for rep in model["replicas"]) == 0:
        print("error: joined traces contain no events", file=sys.stderr)
        return 1

    fmt = args.format or (
        "html" if args.out and args.out.endswith(".html") else "text"
    )
    text = render_html(model) if fmt == "html" else render_text(model)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        n_rules = sum(len(rep["slo"]) for rep in model["replicas"])
        print(f"wrote {args.out} ({len(text):,} bytes, "
              f"{len(model['replicas'])} trace(s), {n_rules} SLO rule(s))")
    else:
        print(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
