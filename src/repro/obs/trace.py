"""Host-side phase tracing: Chrome/Perfetto trace-event spans.

The repo's phases — deploy buckets, prefill admissions, decode steps,
refresh scrubs, calibration, benchmark timing loops — are recorded as
*spans* on one global `Tracer` and exported as Chrome trace-event JSON
(`{"traceEvents": [...]}`), the format Perfetto / `chrome://tracing`
load directly.  Every span is a host-side wall-clock interval; nothing
here touches the device, so tracing can never add a host sync or a
retrace to an instrumented hot path (the zero-extra-sync contract,
DESIGN.md Sec. 14).

Usage:

    from repro.obs import trace
    with trace.span("serve.decode", cat="serve", step=i) as args:
        ...                      # args is mutable: fill in results
        args["tokens"] = 4

    trace.export("TRACE_run.json")

Span events are "ph": "X" (complete) events with `ts`/`dur` in
microseconds; `instant` emits "ph": "i" markers (compiles, swaps);
ledger charges ride along as "cat": "ledger" instants (`obs.ledger`).
`repro.obs.report` summarizes an exported file per phase name.

Recording honours the global obs enable flag (`obs.disabled()`); the
`span` context manager itself keeps timing (benchmarks' `timed()` is
built on it) even when event recording is off.
"""

from __future__ import annotations

import contextlib
import json
import os
import time
from typing import Any, Iterator

__all__ = [
    "Tracer",
    "tracer",
    "span",
    "instant",
    "export",
    "reset",
    "events",
]

# Global obs enable flag, shared by the tracer and the ledger.  Contract
# counters (obs.metrics registry) are NOT gated on it: they are cheap
# and tests assert on them regardless of instrumentation verbosity.
_ENABLED = True


def _set_enabled(flag: bool) -> bool:
    global _ENABLED
    old = _ENABLED
    _ENABLED = bool(flag)
    return old


def is_enabled() -> bool:
    return _ENABLED


class Tracer:
    """An append-only list of Chrome trace events on one wall clock."""

    def __init__(self, pid: int | None = None):
        self.pid = os.getpid() if pid is None else pid
        self.t0_ns = time.perf_counter_ns()
        self._events: list[dict] = []

    # ------------------------------------------------------------ clock
    def now_us(self) -> float:
        """Microseconds since the tracer's epoch (reset rebases it)."""
        return (time.perf_counter_ns() - self.t0_ns) / 1e3

    # ----------------------------------------------------------- record
    def _append(self, ev: dict) -> None:
        if _ENABLED:
            self._events.append(ev)

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "phase", **args: Any) -> Iterator[dict]:
        """Record one complete ("ph": "X") event around the body.

        Yields the (mutable) args dict so the body can attach results —
        values filled in before exit land in the exported event.
        """
        ts = self.now_us()
        mutable = dict(args)
        try:
            yield mutable
        finally:
            self._append(
                {
                    "name": name,
                    "cat": cat,
                    "ph": "X",
                    "ts": ts,
                    "dur": self.now_us() - ts,
                    "pid": self.pid,
                    "tid": 1,
                    "args": mutable,
                }
            )

    def instant(self, name: str, cat: str = "phase", **args: Any) -> None:
        """Record a zero-duration marker event ("ph": "i")."""
        self._append(
            {
                "name": name,
                "cat": cat,
                "ph": "i",
                "s": "t",
                "ts": self.now_us(),
                "pid": self.pid,
                "tid": 1,
                "args": dict(args),
            }
        )

    def counter(self, name: str, cat: str = "metric", **values: float) -> None:
        """Record a counter sample ("ph": "C") — renders as a track."""
        self._append(
            {
                "name": name,
                "cat": cat,
                "ph": "C",
                "ts": self.now_us(),
                "pid": self.pid,
                "tid": 1,
                "args": {k: float(v) for k, v in values.items()},
            }
        )

    # ------------------------------------------------------- export/reset
    def events(self) -> list[dict]:
        return list(self._events)

    def export(self, path: str | os.PathLike) -> str:
        """Write the Chrome/Perfetto trace-event JSON; returns the path."""
        doc = {
            "traceEvents": self._events,
            "displayTimeUnit": "ms",
        }
        path = os.fspath(path)
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        return path

    def reset(self) -> None:
        """Drop all events and rebase the clock (fresh run in-process)."""
        self._events = []
        self.t0_ns = time.perf_counter_ns()


# The global tracer every subsystem records onto.  One process = one
# timeline; `benchmarks/run.py` resets it between registered benchmarks
# so each exported trace is self-contained.
tracer = Tracer()


def span(name: str, cat: str = "phase", **args: Any):
    return tracer.span(name, cat=cat, **args)


def instant(name: str, cat: str = "phase", **args: Any) -> None:
    tracer.instant(name, cat=cat, **args)


def counter(name: str, cat: str = "metric", **values: float) -> None:
    tracer.counter(name, cat=cat, **values)


def events() -> list[dict]:
    return tracer.events()


def export(path: str | os.PathLike) -> str:
    return tracer.export(path)


def reset() -> None:
    tracer.reset()
