"""Device-side metric accumulation + the host-side counter registry.

Two halves, one rule — *instrumentation may not add host syncs or
retraces* (DESIGN.md Sec. 14):

* `MetricAccumulator` is a pytree of named device scalars that rides
  *inside* jitted hot paths.  A step function takes it as an operand,
  `inc()`s it with traced values, and returns it; shapes are static so
  it can never retrace a warmed dispatch.  Its values come back to the
  host only on a fetch the hot path was already paying — the serving
  scheduler folds its per-step accumulator into the same
  `jax.device_get` that fetches the decoded tokens, and the deploy
  pipeline derives its totals from the `WVStats` arrays fetched by the
  deploy's single `host_fetch`.

* `MetricRegistry` is the host-side sum of everything fetched: named
  float counters (`pipeline.compiles`, `pipeline.host_syncs`,
  `serve.decode_tokens`, `cim.tokens`, ...).  `core.pipeline`'s
  compile/host-sync counters live here now (the old
  `pipeline.compile_count()` / `host_sync_count()` / `reset_counters()`
  are thin wrappers).  Registry counters are contract-bearing
  (benchmarks hard-assert on them), so they are NOT gated on the obs
  enable flag — only trace/ledger verbosity is.

`fetch(tree, counter=...)` is the counted device->host transfer
chokepoint: one call = one sync = one bump of its counter.
"""

from __future__ import annotations

from typing import Any, Iterable, Mapping

import jax
import jax.numpy as jnp

__all__ = [
    "MetricAccumulator",
    "MetricRegistry",
    "registry",
    "fetch",
    "inc",
    "value",
    "snapshot",
    "reset",
]


@jax.tree_util.register_pytree_node_class
class MetricAccumulator:
    """An immutable pytree of named device-side metric scalars.

    Functional by design: `inc` returns a NEW accumulator, so it
    composes with jit/scan/while carries.  Names are static pytree aux
    data — two accumulators with the same names have the same treedef,
    which is what keeps a warmed dispatch from retracing.
    """

    def __init__(self, values: Mapping[str, jax.Array]):
        self._values = dict(values)

    @classmethod
    def zeros(cls, names: Iterable[str]) -> "MetricAccumulator":
        return cls({n: jnp.zeros((), jnp.float32) for n in names})

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self._values))

    def __getitem__(self, name: str) -> jax.Array:
        return self._values[name]

    def inc(self, name: str, delta) -> "MetricAccumulator":
        """New accumulator with `delta` added to `name` (traced-safe)."""
        vals = dict(self._values)
        vals[name] = vals[name] + jnp.asarray(delta, jnp.float32)
        return MetricAccumulator(vals)

    def merge(self, other: "MetricAccumulator") -> "MetricAccumulator":
        assert self.names == other.names, (self.names, other.names)
        return MetricAccumulator(
            {n: self._values[n] + other._values[n] for n in self._values}
        )

    def as_dict(self) -> dict[str, jax.Array]:
        return dict(self._values)

    # ------------------------------------------------------------ pytree
    def tree_flatten(self):
        names = self.names
        return tuple(self._values[n] for n in names), names

    @classmethod
    def tree_unflatten(cls, names, children):
        return cls(dict(zip(names, children)))

    def __repr__(self) -> str:
        return f"MetricAccumulator({self._values!r})"


class MetricRegistry:
    """Host-side named counters: the sum of everything ever fetched."""

    def __init__(self):
        self._counts: dict[str, float] = {}

    def inc(self, name: str, delta: float = 1.0) -> None:
        self._counts[name] = self._counts.get(name, 0.0) + float(delta)

    def fold(self, values: Mapping[str, Any], prefix: str = "") -> None:
        """Add a mapping of fetched metric values (numpy/python scalars)."""
        for k, v in values.items():
            self.inc(prefix + k, float(v))

    def value(self, name: str) -> float:
        return self._counts.get(name, 0.0)

    def snapshot(self) -> dict[str, float]:
        return dict(self._counts)

    def reset(self, prefix: str | None = None) -> None:
        """Zero all counters, or only those under `prefix`."""
        if prefix is None:
            self._counts = {}
        else:
            for k in [k for k in self._counts if k.startswith(prefix)]:
                del self._counts[k]


# The global registry (one process = one counter namespace).
registry = MetricRegistry()


def fetch(tree: Any, counter: str | None = None) -> Any:
    """The counted device->host transfer point.

    One `fetch` call is exactly one host synchronization; `counter`
    names the registry counter that bumps (e.g. the deploy pipeline's
    `pipeline.host_syncs`).  Hot paths piggyback metric values on a
    fetch they already perform — never add a `fetch` just for metrics.
    """
    if counter is not None:
        registry.inc(counter)
    return jax.device_get(tree)


def inc(name: str, delta: float = 1.0) -> None:
    registry.inc(name, delta)


def value(name: str) -> float:
    return registry.value(name)


def snapshot() -> dict[str, float]:
    return registry.snapshot()


def reset(prefix: str | None = None) -> None:
    registry.reset(prefix)
