"""Fixed-bucket streaming histograms for hot-path percentiles.

`StreamingDigest` is a registered pytree holding a fixed-bucket
histogram (counts + sum + min/max) over a declared value range.  Two
accumulation paths, one rule — *instrumentation may not add host syncs
or retraces* (DESIGN.md Sec. 14/16):

* `add(x)` is the traced path: jnp ops only, safe inside jit/scan.
  The bucket edges (`lo`, `hi`) and bucket count are static aux data,
  so two digests with the same configuration share a treedef and a
  warmed dispatch never retraces.  Device digests come back to the
  host only on a fetch the hot path already performs (the deploy
  `host_fetch`, the scheduler's per-step token `device_get`).
* `observe(x)` is the host path: pure numpy, mutating in place.  It is
  for host-born quantities (wall-clock step latency, TTFT) where no
  device round-trip exists in the first place.

Quantiles are rank-based over the bucket midpoints: for n observed
values the q-quantile estimate is the midpoint of the bucket holding
the rank-``floor(q*(n-1))`` value, which is within half a bucket width
of the exact order statistic for any in-range input distribution
(tests/test_digest_properties.py holds this as a property).  Merging
is elementwise count addition — commutative and associative — so
per-replica digests fold into fleet digests without per-request
arrays.
"""

from __future__ import annotations

from typing import Any, Iterable, Mapping

import numpy as np

__all__ = [
    "StreamingDigest",
    "DigestRegistry",
    "digests",
    "observe",
    "rank_quantile",
    "snapshot",
    "reset",
]

_QUANTILES = (0.50, 0.95, 0.99)


def rank_quantile(values, q: float) -> float:
    """THE repo-wide quantile definition: the exact order statistic at
    rank ``floor(q * (n - 1))`` (== ``np.quantile(..., method="lower")``).

    `StreamingDigest.quantile` estimates the same rank (to bucket
    resolution), so digest percentiles and array percentiles computed
    with this function agree within one bucket width — asserted by
    tests/test_serving_scheduler.py.  Interpolating percentiles
    (np.percentile's default) disagree with rank-based ones on small
    samples, which is exactly the serving-p99 regime.
    """
    x = np.sort(np.asarray(values, np.float64).ravel())
    if x.size == 0:
        raise ValueError("rank_quantile of empty input")
    return float(x[int(np.floor(float(q) * (x.size - 1)))])


def _register():
    """Register the pytree node lazily so importing the digest module
    (e.g. from the stdlib-only dashboard) does not require jax."""
    try:
        import jax
    except Exception:  # pragma: no cover - jax-less dashboard path
        return
    try:
        jax.tree_util.register_pytree_node_class(StreamingDigest)
    except ValueError:  # pragma: no cover - already registered
        pass


class StreamingDigest:
    """A fixed-bucket histogram over ``[lo, hi)`` with ``n`` buckets.

    Values below ``lo`` clamp into the first bucket, values at or above
    ``hi`` into the last, so the count never leaks; the one-bucket
    quantile guarantee holds for in-range values.  Out-of-range values
    are additionally COUNTED in ``n_under`` / ``n_over`` — clamping is
    silent about how much of the mass it distorted, and a digest whose
    top bucket is secretly an overflow bin reports a fake p99.  The
    counters are pytree children (the static aux stays ``(lo, hi)``),
    so existing jit carries keep their treedef configuration and never
    retrace.
    """

    def __init__(self, lo: float, hi: float, counts, total, vmin, vmax,
                 n_under=None, n_over=None):
        self.lo = float(lo)
        self.hi = float(hi)
        self.counts = counts
        self.total = total
        self.vmin = vmin
        self.vmax = vmax
        self.n_under = np.float32(0.0) if n_under is None else n_under
        self.n_over = np.float32(0.0) if n_over is None else n_over

    # ------------------------------------------------------------ ctor
    @classmethod
    def zeros(cls, lo: float, hi: float, n_buckets: int) -> "StreamingDigest":
        """Device-side (jnp) zero digest for use inside jitted paths."""
        import jax.numpy as jnp

        assert hi > lo and n_buckets >= 1, (lo, hi, n_buckets)
        return cls(
            lo, hi,
            jnp.zeros((n_buckets,), jnp.float32),
            jnp.zeros((), jnp.float32),
            jnp.full((), jnp.inf, jnp.float32),
            jnp.full((), -jnp.inf, jnp.float32),
            jnp.zeros((), jnp.float32),
            jnp.zeros((), jnp.float32),
        )

    @classmethod
    def host(cls, lo: float, hi: float, n_buckets: int) -> "StreamingDigest":
        """Host-side (numpy) zero digest — never touches the device."""
        assert hi > lo and n_buckets >= 1, (lo, hi, n_buckets)
        return cls(
            lo, hi,
            np.zeros((n_buckets,), np.float32),
            np.zeros((), np.float32),
            np.float32(np.inf),
            np.float32(-np.inf),
            np.float32(0.0),
            np.float32(0.0),
        )

    # ------------------------------------------------------- properties
    @property
    def n_buckets(self) -> int:
        return int(self.counts.shape[0])

    @property
    def width(self) -> float:
        return (self.hi - self.lo) / self.n_buckets

    @property
    def count(self) -> float:
        return float(np.sum(np.asarray(self.counts)))

    # ------------------------------------------------------ accumulate
    def add(self, x) -> "StreamingDigest":
        """Traced-safe accumulation: returns a NEW digest (jnp ops)."""
        import jax.numpy as jnp

        x = jnp.asarray(x, jnp.float32).ravel()
        idx = jnp.clip(
            jnp.floor((x - self.lo) / self.width).astype(jnp.int32),
            0, self.n_buckets - 1,
        )
        return StreamingDigest(
            self.lo, self.hi,
            self.counts.at[idx].add(1.0),
            self.total + jnp.sum(x),
            jnp.minimum(self.vmin, jnp.min(x, initial=jnp.inf)),
            jnp.maximum(self.vmax, jnp.max(x, initial=-jnp.inf)),
            self.n_under + jnp.sum(x < self.lo).astype(jnp.float32),
            self.n_over + jnp.sum(x >= self.hi).astype(jnp.float32),
        )

    def add_weighted(self, x, weights) -> "StreamingDigest":
        """Traced-safe accumulation with per-value weights (counts).

        Used for device-side histograms where each value carries a
        multiplicity (e.g. "this tile contributed w cells at this
        drift level"); zero-weight entries contribute nothing,
        including to min/max.
        """
        import jax.numpy as jnp

        x = jnp.asarray(x, jnp.float32).ravel()
        w = jnp.asarray(weights, jnp.float32).ravel()
        idx = jnp.clip(
            jnp.floor((x - self.lo) / self.width).astype(jnp.int32),
            0, self.n_buckets - 1,
        )
        live = w > 0
        return StreamingDigest(
            self.lo, self.hi,
            self.counts.at[idx].add(w),
            self.total + jnp.sum(x * w),
            jnp.minimum(
                self.vmin, jnp.min(jnp.where(live, x, jnp.inf), initial=jnp.inf)
            ),
            jnp.maximum(
                self.vmax,
                jnp.max(jnp.where(live, x, -jnp.inf), initial=-jnp.inf),
            ),
            self.n_under + jnp.sum(jnp.where(x < self.lo, w, 0.0)),
            self.n_over + jnp.sum(jnp.where(x >= self.hi, w, 0.0)),
        )

    def observe(self, x) -> None:
        """Host-side accumulation (numpy, in place) — zero device work."""
        x = np.asarray(x, np.float32).ravel()
        if x.size == 0:
            return
        idx = np.clip(
            np.floor((x - self.lo) / self.width).astype(np.int64),
            0, self.n_buckets - 1,
        )
        np.add.at(self.counts, idx, 1.0)
        self.total = np.float32(self.total + np.sum(x))
        self.vmin = np.float32(min(float(self.vmin), float(np.min(x))))
        self.vmax = np.float32(max(float(self.vmax), float(np.max(x))))
        self.n_under = np.float32(self.n_under + np.sum(x < self.lo))
        self.n_over = np.float32(self.n_over + np.sum(x >= self.hi))

    def merge(self, other: "StreamingDigest") -> "StreamingDigest":
        """Elementwise merge — requires identical bucket configuration."""
        assert (self.lo, self.hi, self.n_buckets) == (
            other.lo, other.hi, other.n_buckets,
        ), "digest merge requires identical bucket configuration"
        return StreamingDigest(
            self.lo, self.hi,
            np.asarray(self.counts) + np.asarray(other.counts),
            np.asarray(self.total) + np.asarray(other.total),
            np.minimum(np.asarray(self.vmin), np.asarray(other.vmin)),
            np.maximum(np.asarray(self.vmax), np.asarray(other.vmax)),
            np.asarray(self.n_under) + np.asarray(other.n_under),
            np.asarray(self.n_over) + np.asarray(other.n_over),
        )

    # -------------------------------------------------------- quantiles
    def quantile(self, q: float) -> float | None:
        """Rank-based quantile estimate (bucket midpoint); None if empty."""
        counts = np.asarray(self.counts, np.float64)
        n = counts.sum()
        if n <= 0:
            return None
        rank = int(np.floor(float(q) * (n - 1)))
        cum = np.cumsum(counts)
        b = int(np.searchsorted(cum, rank + 1, side="left"))
        b = min(b, self.n_buckets - 1)
        return float(self.lo + (b + 0.5) * self.width)

    def summary(self) -> dict[str, Any]:
        """JSON-safe summary: count/mean/min/max + p50/p95/p99.

        Empty digests report ``count: 0`` with null percentiles — the
        report/dashboard layers render that corner explicitly rather
        than inventing numbers.
        """
        n = self.count
        out: dict[str, Any] = {
            "lo": self.lo, "hi": self.hi, "n_buckets": self.n_buckets,
            "count": n,
        }
        if n > 0:
            out["mean"] = float(np.asarray(self.total)) / n
            out["min"] = float(np.asarray(self.vmin))
            out["max"] = float(np.asarray(self.vmax))
        else:
            out["mean"] = None
            out["min"] = None
            out["max"] = None
        out["n_under"] = float(np.asarray(self.n_under))
        out["n_over"] = float(np.asarray(self.n_over))
        for q in _QUANTILES:
            out[f"p{int(q * 100)}"] = self.quantile(q)
        return out

    # ------------------------------------------------------------ pytree
    def tree_flatten(self):
        return (
            (self.counts, self.total, self.vmin, self.vmax,
             self.n_under, self.n_over),
            (self.lo, self.hi),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        lo, hi = aux
        return cls(lo, hi, *children)

    def __repr__(self) -> str:
        return (
            f"StreamingDigest(lo={self.lo}, hi={self.hi}, "
            f"n_buckets={self.n_buckets}, count={self.count})"
        )


def _host_copy(d: StreamingDigest) -> StreamingDigest:
    """Deep-copy a fetched digest onto host numpy leaves."""
    return StreamingDigest(
        d.lo, d.hi,
        np.asarray(d.counts, np.float32).copy(),
        np.float32(np.asarray(d.total)),
        np.float32(np.asarray(d.vmin)),
        np.float32(np.asarray(d.vmax)),
        np.float32(np.asarray(d.n_under)),
        np.float32(np.asarray(d.n_over)),
    )


class DigestRegistry:
    """Host-side named digests: the fold target for everything fetched.

    `observe` is for host-born values; `fold` merges an already-fetched
    device digest (numpy leaves — folding a live jnp digest would be a
    hidden sync, so callers fetch first on an existing chokepoint).
    """

    def __init__(self):
        self._digests: dict[str, StreamingDigest] = {}

    def ensure(self, name: str, lo: float, hi: float,
               n_buckets: int = 64) -> StreamingDigest:
        d = self._digests.get(name)
        if d is None:
            d = StreamingDigest.host(lo, hi, n_buckets)
            self._digests[name] = d
        return d

    def observe(self, name: str, x, *, lo: float, hi: float,
                n_buckets: int = 64) -> None:
        self.ensure(name, lo, hi, n_buckets).observe(x)

    def put(self, name: str, fetched: StreamingDigest) -> None:
        """Replace the named slot with a fetched digest.

        For CUMULATIVE device digests (a jit carry that already holds
        the whole history): re-folding one of those every fetch would
        double-count, so the rider replaces instead of merging.
        """
        self._digests[name] = _host_copy(fetched)

    def fold(self, name: str, fetched: StreamingDigest) -> None:
        """Merge a fetched (numpy-leaved) digest into the named slot."""
        d = self._digests.get(name)
        if d is None:
            self._digests[name] = _host_copy(fetched)
        else:
            self._digests[name] = d.merge(fetched)

    def get(self, name: str) -> StreamingDigest | None:
        return self._digests.get(name)

    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self._digests))

    def snapshot(self) -> dict[str, dict[str, Any]]:
        return {n: d.summary() for n, d in sorted(self._digests.items())}

    def emit(self) -> None:
        """Mirror every digest summary into the trace as cat="digest"
        instants so report/dashboard can read percentiles from the
        exported TRACE json without access to process state."""
        from . import trace

        for name, d in sorted(self._digests.items()):
            trace.instant(f"digest.{name}", cat="digest", **d.summary())

    def reset(self, prefix: str | None = None) -> None:
        if prefix is None:
            self._digests = {}
        else:
            for k in [k for k in self._digests if k.startswith(prefix)]:
                del self._digests[k]


# The global registry (one process = one digest namespace).
digests = DigestRegistry()


def observe(name: str, x, *, lo: float, hi: float, n_buckets: int = 64) -> None:
    digests.observe(name, x, lo=lo, hi=hi, n_buckets=n_buckets)


def snapshot() -> dict[str, dict[str, Any]]:
    return digests.snapshot()


def reset(prefix: str | None = None) -> None:
    digests.reset(prefix)


_register()
