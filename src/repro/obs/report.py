"""Run-summary reporting over exported trace files.

    python -m repro.obs.report benchmarks/TRACE_serving.json

Loads a Chrome/Perfetto trace-event JSON written by `repro.obs.trace`
and renders one table row per phase name: span count, wall time
(total/mean), and the ledger attribution (energy, modeled latency,
reads, tokens) charged to that phase.  This is the "where did the
reads, joules, and milliseconds go" view of a run — the paper's
latency/energy headline numbers, per phase, from one artifact.

Pure stdlib (no jax import) so it runs anywhere, including the CI
smoke step, which fails the build when a freshly emitted trace cannot
be parsed or contains no spans.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any

__all__ = ["load", "summarize", "render", "main"]

_LEDGER_FIELDS = ("energy_pj", "latency_ns", "reads", "tokens")


def load(path: str) -> dict[str, Any]:
    """Read and validate a trace file; raises ValueError when malformed."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise ValueError(f"cannot read trace {path!r}: {e}") from e
    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"), list):
        raise ValueError(f"{path!r} is not a trace-event file (no traceEvents)")
    return doc


def summarize(doc: dict[str, Any]) -> list[dict[str, Any]]:
    """Aggregate events into one row per phase name.

    Span ("ph": "X") events contribute count and wall time; ledger
    instants ("cat": "ledger") contribute the charged energy/latency/
    reads/tokens.  Rows join on the event name and sort by total wall
    time (ledger-only phases last, by energy).
    """
    rows: dict[str, dict[str, Any]] = {}

    def row(name: str) -> dict[str, Any]:
        r = rows.get(name)
        if r is None:
            r = rows[name] = dict(
                phase=name, count=0, total_ms=0.0,
                **{f: 0.0 for f in _LEDGER_FIELDS},
            )
        return r

    for ev in doc["traceEvents"]:
        if not isinstance(ev, dict) or "name" not in ev:
            continue
        if ev.get("cat") == "ledger":
            r = row(ev["name"])
            args = ev.get("args") or {}
            for f in _LEDGER_FIELDS:
                r[f] += float(args.get(f, 0.0))
        elif ev.get("ph") == "X":
            r = row(ev["name"])
            r["count"] += 1
            r["total_ms"] += float(ev.get("dur", 0.0)) / 1e3
    out = list(rows.values())
    for r in out:
        r["mean_ms"] = r["total_ms"] / r["count"] if r["count"] else 0.0
    out.sort(key=lambda r: (-r["total_ms"], -r["energy_pj"], r["phase"]))
    return out


def _fmt(v: float) -> str:
    if v == 0.0:
        return "-"
    if abs(v) >= 1e6:
        return f"{v:.3e}"
    return f"{v:,.2f}" if abs(v) < 1e3 else f"{v:,.0f}"


def render(rows: list[dict[str, Any]]) -> str:
    """Plain-text summary table (grep-able, fixed column order)."""
    cols = ["phase", "count", "total_ms", "mean_ms", *_LEDGER_FIELDS]
    table = [[str(c) for c in cols]]
    for r in rows:
        table.append(
            [r["phase"], str(r["count"])]
            + [_fmt(r[c]) for c in cols[2:]]
        )
    widths = [max(len(line[i]) for line in table) for i in range(len(cols))]
    lines = []
    for j, line in enumerate(table):
        lines.append(
            line[0].ljust(widths[0])
            + "  "
            + "  ".join(c.rjust(w) for c, w in zip(line[1:], widths[1:]))
        )
        if j == 0:
            lines.append("-" * len(lines[0]))
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.obs.report",
        description="Summarize an obs trace file per phase.",
    )
    ap.add_argument("trace", help="path to a TRACE_*.json trace-event file")
    args = ap.parse_args(argv)

    try:
        doc = load(args.trace)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    rows = summarize(doc)
    n_spans = sum(r["count"] for r in rows)
    if n_spans == 0:
        print(
            f"error: {args.trace!r} contains no span events "
            f"({len(doc['traceEvents'])} events total)",
            file=sys.stderr,
        )
        return 1
    print(f"# {args.trace}: {len(doc['traceEvents'])} events, {n_spans} spans")
    print(render(rows))
    total_e = sum(r["energy_pj"] for r in rows)
    total_ms = sum(r["total_ms"] for r in rows)
    print(
        f"# total: {total_ms:,.1f} ms wall across spans, "
        f"{total_e:,.1f} pJ attributed"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
