"""Run-summary reporting over exported trace files.

    python -m repro.obs.report benchmarks/TRACE_serving.json

Loads a Chrome/Perfetto trace-event JSON written by `repro.obs.trace`
and renders one table row per phase name: span count, wall time
(total/mean), and the ledger attribution (energy, modeled latency,
reads, tokens) charged to that phase.  This is the "where did the
reads, joules, and milliseconds go" view of a run — the paper's
latency/energy headline numbers, per phase, from one artifact.

When the trace carries fleet-observability events, two extra sections
follow the phase table: digest percentiles (cat="digest" instants
written by `obs.digests.emit()` — p50/p95/p99 per named histogram,
with empty digests rendered explicitly as count 0) and SLO breaches
(cat="slo" instants written by `obs.SLOPolicy.evaluate` — one row per
rule with breach count and last observed value).

Pure stdlib (no jax import) so it runs anywhere, including the CI
smoke step, which fails the build when a freshly emitted trace cannot
be parsed or contains no spans.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any

__all__ = [
    "load", "summarize", "render",
    "digest_rows", "slo_rows", "render_digests", "render_slo", "main",
]

_LEDGER_FIELDS = ("energy_pj", "latency_ns", "reads", "tokens")


def load(path: str) -> dict[str, Any]:
    """Read and validate a trace file; raises ValueError when malformed."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise ValueError(f"cannot read trace {path!r}: {e}") from e
    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"), list):
        raise ValueError(f"{path!r} is not a trace-event file (no traceEvents)")
    return doc


def summarize(doc: dict[str, Any]) -> list[dict[str, Any]]:
    """Aggregate events into one row per phase name.

    Span ("ph": "X") events contribute count and wall time; ledger
    instants ("cat": "ledger") contribute the charged energy/latency/
    reads/tokens.  Rows join on the event name and sort by total wall
    time (ledger-only phases last, by energy).
    """
    rows: dict[str, dict[str, Any]] = {}

    def row(name: str) -> dict[str, Any]:
        r = rows.get(name)
        if r is None:
            r = rows[name] = dict(
                phase=name, count=0, total_ms=0.0,
                **{f: 0.0 for f in _LEDGER_FIELDS},
            )
        return r

    for ev in doc["traceEvents"]:
        if not isinstance(ev, dict) or "name" not in ev:
            continue
        if ev.get("cat") == "ledger":
            r = row(ev["name"])
            args = ev.get("args") or {}
            for f in _LEDGER_FIELDS:
                r[f] += float(args.get(f, 0.0))
        elif ev.get("ph") == "X":
            r = row(ev["name"])
            r["count"] += 1
            r["total_ms"] += float(ev.get("dur", 0.0)) / 1e3
    out = list(rows.values())
    for r in out:
        r["mean_ms"] = r["total_ms"] / r["count"] if r["count"] else 0.0
    out.sort(key=lambda r: (-r["total_ms"], -r["energy_pj"], r["phase"]))
    return out


def digest_rows(doc: dict[str, Any]) -> list[dict[str, Any]]:
    """One row per digest name from cat="digest" instants.

    Digests are cumulative at emit time, so when a trace carries
    several emits of the same name the LAST one wins (it already
    contains the earlier counts).  Empty digests (count 0, null
    percentiles) are kept — the table renders them as "-" rather than
    dropping the row, so a silent zero-sample digest is visible.
    """
    rows: dict[str, dict[str, Any]] = {}
    for ev in doc["traceEvents"]:
        if not isinstance(ev, dict) or ev.get("cat") != "digest":
            continue
        name = str(ev.get("name", ""))
        if name.startswith("digest."):
            name = name[len("digest."):]
        args = ev.get("args") or {}
        rows[name] = {
            "digest": name,
            "count": float(args.get("count") or 0.0),
            **{k: args.get(k) for k in ("mean", "p50", "p95", "p99", "max")},
            # Out-of-range counts (0.0 for traces emitted before digests
            # tracked them): a digest clamping mass into its edge
            # buckets reports fake percentiles, so the table shows it.
            "n_under": float(args.get("n_under") or 0.0),
            "n_over": float(args.get("n_over") or 0.0),
        }
    return [rows[k] for k in sorted(rows)]


def slo_rows(doc: dict[str, Any]) -> list[dict[str, Any]]:
    """One row per SLO rule from cat="slo" breach instants."""
    rows: dict[str, dict[str, Any]] = {}
    for ev in doc["traceEvents"]:
        if not isinstance(ev, dict) or ev.get("cat") != "slo":
            continue
        args = ev.get("args") or {}
        name = str(ev.get("name", ""))
        if name.startswith("slo.breach."):
            name = name[len("slo.breach."):]
        r = rows.setdefault(
            name,
            {"rule": name, "metric": args.get("metric"),
             "ceiling": args.get("ceiling"), "breaches": 0,
             "last_value": None},
        )
        r["breaches"] += 1
        r["last_value"] = args.get("value")
    return [rows[k] for k in sorted(rows)]


def _fmt_opt(v: Any) -> str:
    return "-" if v is None else _fmt(float(v))


def render_digests(rows: list[dict[str, Any]]) -> str:
    cols = ["digest", "count", "mean", "p50", "p95", "p99", "max",
            "under", "over"]
    table = [cols[:]]
    for r in rows:
        table.append(
            [r["digest"], f"{r['count']:,.0f}"]
            + [_fmt_opt(r[c]) for c in ("mean", "p50", "p95", "p99", "max")]
            + [f"{r.get('n_under', 0.0):,.0f}", f"{r.get('n_over', 0.0):,.0f}"]
        )
    return _render_table(table)


def render_slo(rows: list[dict[str, Any]]) -> str:
    cols = ["rule", "metric", "ceiling", "breaches", "last_value"]
    table = [cols[:]]
    for r in rows:
        table.append(
            [r["rule"], str(r["metric"] or "-"), _fmt_opt(r["ceiling"]),
             str(r["breaches"]), _fmt_opt(r["last_value"])]
        )
    return _render_table(table)


def _fmt(v: float) -> str:
    if v == 0.0:
        return "-"
    if abs(v) >= 1e6:
        return f"{v:.3e}"
    return f"{v:,.2f}" if abs(v) < 1e3 else f"{v:,.0f}"


def _render_table(table: list[list[str]]) -> str:
    """Align a header + rows string table (first column left-justified)."""
    n = len(table[0])
    widths = [max(len(line[i]) for line in table) for i in range(n)]
    lines = []
    for j, line in enumerate(table):
        lines.append(
            line[0].ljust(widths[0])
            + "  "
            + "  ".join(c.rjust(w) for c, w in zip(line[1:], widths[1:]))
        )
        if j == 0:
            lines.append("-" * len(lines[0]))
    return "\n".join(lines)


def render(rows: list[dict[str, Any]]) -> str:
    """Plain-text summary table (grep-able, fixed column order)."""
    cols = ["phase", "count", "total_ms", "mean_ms", *_LEDGER_FIELDS]
    table = [[str(c) for c in cols]]
    for r in rows:
        table.append(
            [r["phase"], str(r["count"])]
            + [_fmt(r[c]) for c in cols[2:]]
        )
    return _render_table(table)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.obs.report",
        description="Summarize an obs trace file per phase.",
    )
    ap.add_argument("trace", help="path to a TRACE_*.json trace-event file")
    args = ap.parse_args(argv)

    try:
        doc = load(args.trace)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    rows = summarize(doc)
    n_spans = sum(r["count"] for r in rows)
    if n_spans == 0:
        print(
            f"error: {args.trace!r} contains no span events "
            f"({len(doc['traceEvents'])} events total)",
            file=sys.stderr,
        )
        return 1
    print(f"# {args.trace}: {len(doc['traceEvents'])} events, {n_spans} spans")
    print(render(rows))
    total_e = sum(r["energy_pj"] for r in rows)
    total_ms = sum(r["total_ms"] for r in rows)
    print(
        f"# total: {total_ms:,.1f} ms wall across spans, "
        f"{total_e:,.1f} pJ attributed"
    )
    drows = digest_rows(doc)
    if drows:
        print(f"\n# digests ({len(drows)})")
        print(render_digests(drows))
    srows = slo_rows(doc)
    if srows:
        total_breaches = sum(r["breaches"] for r in srows)
        print(f"\n# slo breaches ({total_breaches})")
        print(render_slo(srows))
    return 0


if __name__ == "__main__":
    sys.exit(main())
