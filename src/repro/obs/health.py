"""Per-tile health maps + declarative fleet SLO rules.

The health layer answers "which silicon is dying and does the fleet
still meet its objectives" from signals the stack already produces
(DESIGN.md Sec. 16).  Ownership is split exactly like the rest of obs:

* **Device-side reduction** — `tile_reduce` / `tile_deploy_stats` turn
  per-column WV statistics into per-tile sums with jnp segment sums.
  The tile axis is tiny (columns / columns_per_tile), so the per-tile
  arrays ride the host syncs the paths already perform: the deploy's
  single `host_fetch` (`DeployReport.collect`) and the scrub's drift
  fetch.  Column->tile assignment comes from the deploy's physical
  column uids (host numpy), so no device work is needed to route it.
* **Host-side registry** — `HealthRegistry` folds the fetched per-tile
  values into named maps (give-up density, retry pulses, drift RMS,
  remapped columns) plus scalar gauges (refresh debt, scrub backlog).
* **Host-side policy** — `SLORule`/`SLOPolicy` evaluate declarative
  ceilings against a machine-readable `fleet_status()` snapshot,
  emitting `cat="slo"` trace instants on breach and bumping
  `slo.breaches.*` registry counters (contract-bearing: benchmarks
  assert on them, so they are not gated on the obs enable flag).

The dashboard (`repro.obs.dashboard`) only ever reads exported files —
it never touches this module's live state.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import numpy as np

__all__ = [
    "tile_reduce",
    "tile_deploy_stats",
    "HealthRegistry",
    "health",
    "SLORule",
    "SLOPolicy",
    "fleet_status",
    "resolve_metric",
]


# ------------------------------------------------------- device-side
def tile_reduce(values, tile_inv, num_tiles: int):
    """Segment-sum per-column `values` into `num_tiles` tile bins.

    `tile_inv` is the host-computed (numpy) column->tile-slot index, so
    the only device work is one segment sum — traced-safe and fetchable
    alongside whatever the caller was already fetching.
    """
    import jax.numpy as jnp
    from jax import ops as jops

    return jops.segment_sum(
        jnp.asarray(values, jnp.float32),
        jnp.asarray(tile_inv, jnp.int32),
        num_segments=num_tiles,
    )


def tile_deploy_stats(
    stats_map: Mapping[str, Any],
    uids_map: Mapping[str, np.ndarray],
    columns_per_tile: int,
    extra_columns: Mapping[str, Mapping[str, Any]] | None = None,
) -> tuple[np.ndarray, dict[str, Any]]:
    """Per-tile deployment health reductions (device-side).

    Returns ``(tile_ids, device_tree)`` where `tile_ids` is the host
    numpy array of physical tile ids present in this deploy and
    `device_tree` maps metric name -> per-tile jnp array (same order).
    The caller appends `device_tree` to an existing fetch; nothing here
    synchronizes.  `stats_map` values are `WVStats`-shaped (duck-typed:
    gave_up / retry_pulses / write_pulses / reads / rms_error_lsb per
    column); `uids_map` holds each leaf's physical column uids.
    `extra_columns` adds caller-supplied per-column vectors (metric ->
    leaf name -> (C,) array) reduced with the same tile assignment —
    e.g. the spare-remap path's per-column remapped flags.
    """
    names = [n for n in stats_map if n in uids_map]
    if not names:
        return np.zeros((0,), np.int64), {}
    uids = np.concatenate(
        [np.asarray(uids_map[n], np.int64) for n in names]
    )
    tids = uids // int(columns_per_tile)
    tile_ids, inv = np.unique(tids, return_inverse=True)
    n_tiles = int(tile_ids.shape[0])

    import jax.numpy as jnp

    def cat(attr):
        return jnp.concatenate(
            [jnp.asarray(getattr(stats_map[n], attr)) for n in names]
        )

    tree = {
        "gave_up_cells": tile_reduce(cat("gave_up"), inv, n_tiles),
        "retry_pulses": tile_reduce(cat("retry_pulses"), inv, n_tiles),
        "write_pulses": tile_reduce(cat("write_pulses"), inv, n_tiles),
        "verify_reads": tile_reduce(cat("reads"), inv, n_tiles),
        "err2_sum": tile_reduce(cat("rms_error_lsb") ** 2, inv, n_tiles),
    }
    for metric, leaf_vecs in (extra_columns or {}).items():
        tree[metric] = tile_reduce(
            jnp.concatenate([jnp.asarray(leaf_vecs[n]) for n in names]),
            inv, n_tiles,
        )
    tree["columns"] = np.bincount(inv, minlength=n_tiles).astype(np.float64)
    return tile_ids, tree


# -------------------------------------------------------- host-side
class HealthRegistry:
    """Host-side per-tile health maps + scalar gauges.

    `fold_tiles` adds fetched per-tile values into a named map (one
    float per physical tile id); `set_gauge` overwrites a scalar.  All
    inputs are host scalars/arrays — folding a live device array here
    would be a hidden sync, so callers fetch first.
    """

    def __init__(self):
        self._tiles: dict[str, dict[int, float]] = {}
        self._gauges: dict[str, float] = {}

    # ------------------------------------------------------------ tiles
    def fold_tiles(self, metric: str, tile_ids, values,
                   mode: str = "sum") -> None:
        m = self._tiles.setdefault(metric, {})
        for tid, v in zip(np.asarray(tile_ids), np.asarray(values)):
            tid, v = int(tid), float(v)
            if mode == "sum":
                m[tid] = m.get(tid, 0.0) + v
            elif mode == "max":
                m[tid] = max(m.get(tid, float("-inf")), v)
            elif mode == "last":
                m[tid] = v
            else:
                raise ValueError(f"unknown fold mode {mode!r}")

    def tiles(self, metric: str) -> dict[int, float]:
        return dict(self._tiles.get(metric, {}))

    def worst(self, metric: str, k: int = 8) -> list[tuple[int, float]]:
        m = self._tiles.get(metric, {})
        return sorted(m.items(), key=lambda kv: -kv[1])[:k]

    # ----------------------------------------------------------- gauges
    def set_gauge(self, name: str, value: float) -> None:
        self._gauges[name] = float(value)

    def gauge(self, name: str, default: float = 0.0) -> float:
        return self._gauges.get(name, default)

    # -------------------------------------------------------- reporting
    def snapshot(self) -> dict[str, Any]:
        """JSON-safe snapshot: tile maps keyed by stringified tile id."""
        return {
            "tiles": {
                metric: {str(t): v for t, v in sorted(m.items())}
                for metric, m in sorted(self._tiles.items())
            },
            "gauges": dict(sorted(self._gauges.items())),
        }

    def emit(self) -> None:
        """Mirror the health maps into the trace as cat="health"
        instants (per-metric summary + worst tiles), so the dashboard
        can read them from the exported TRACE json."""
        from . import trace

        for metric, m in sorted(self._tiles.items()):
            vals = np.array(list(m.values()), np.float64)
            trace.instant(
                f"health.{metric}", cat="health",
                n_tiles=len(m),
                total=float(vals.sum()) if len(m) else 0.0,
                max=float(vals.max()) if len(m) else 0.0,
                worst={str(t): v for t, v in self.worst(metric)},
            )
        for name, v in sorted(self._gauges.items()):
            trace.instant(f"health.gauge.{name}", cat="health", value=v)

    def reset(self, prefix: str | None = None) -> None:
        if prefix is None:
            self._tiles = {}
            self._gauges = {}
        else:
            for d in (self._tiles, self._gauges):
                for k in [k for k in d if k.startswith(prefix)]:
                    del d[k]


# The global health registry (one process = one fleet view).
health = HealthRegistry()


# ------------------------------------------------------------- SLOs
def resolve_metric(status: Mapping[str, Any], path: str):
    """Resolve a dotted metric path against a nested status dict.

    Key names themselves contain dots ("serve.latency_steps"), so
    resolution tries the longest matching key prefix at every level;
    missing paths resolve to None (a rule on an absent metric does not
    breach — it reports value None).
    """
    if not path:
        return status
    if not isinstance(status, Mapping):
        return None
    if path in status:
        return status[path]
    parts = path.split(".")
    for i in range(len(parts) - 1, 0, -1):
        head = ".".join(parts[:i])
        if head in status:
            return resolve_metric(status[head], ".".join(parts[i:]))
    return None


@dataclasses.dataclass(frozen=True)
class SLORule:
    """One declarative service-level objective: `metric <= ceiling`.

    `metric` is a dotted path into the `fleet_status()` dict, e.g.
    ``digests.serve.latency_steps.p99`` or
    ``counters.deploy.gave_up_cells``.
    """

    name: str
    metric: str
    ceiling: float

    def evaluate(self, status: Mapping[str, Any]) -> dict[str, Any]:
        v = resolve_metric(status, self.metric)
        value = float(v) if isinstance(v, (int, float)) else None
        return {
            "name": self.name,
            "metric": self.metric,
            "ceiling": float(self.ceiling),
            "value": value,
            "breached": value is not None and value > self.ceiling,
        }


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """A set of SLO rules evaluated host-side against a status snapshot.

    Evaluation is pure host work on already-fetched floats; breaches
    emit `cat="slo"` trace instants (for the dashboard timeline) and
    bump `slo.breaches.<rule>` registry counters (contract-bearing, so
    benchmarks can hard-assert when a breach must/must not fire).
    """

    rules: tuple[SLORule, ...]

    def evaluate(self, status: Mapping[str, Any],
                 emit: bool = True, **context: Any) -> list[dict[str, Any]]:
        from . import metrics, trace

        results = []
        for rule in self.rules:
            res = rule.evaluate(status)
            res.update(context)
            results.append(res)
            if res["breached"]:
                metrics.registry.inc(f"slo.breaches.{rule.name}")
                if emit:
                    trace.instant(
                        f"slo.breach.{rule.name}", cat="slo",
                        **{k: v for k, v in res.items() if k != "name"},
                    )
        metrics.registry.inc("slo.evaluations")
        return results


def fleet_status(extra: Mapping[str, Any] | None = None) -> dict[str, Any]:
    """Machine-readable fleet snapshot joining every obs namespace.

    The canonical SLO evaluation input: digest percentile summaries,
    per-tile health maps, gauges, and the full counter registry — all
    host floats, JSON-safe, zero device work.
    """
    from . import digest, metrics

    status: dict[str, Any] = {
        "digests": digest.snapshot(),
        "health": health.snapshot(),
        "counters": metrics.snapshot(),
    }
    if extra:
        status.update(extra)
    return status
