"""Energy / latency ledger: where did the joules and milliseconds go.

The cost model prices individual operations — `CircuitCost` +
`readout.cost.sweep_cost` price a verify sweep, `write_phase_cost` a
write phase, `core.cost.inference_token_cost` a served token — but
until now nothing attributed those prices to the *run*: a benchmark's
deploy energy, a serving epoch's analog joules, a scrub's maintenance
bill all lived in per-module report objects with different shapes.

`EnergyLedger` is the one attribution sink.  Every subsystem charges
its modeled cost to a named phase:

    obs.charge("deploy",         energy_pj=..., latency_ns=..., reads=...)
    obs.charge("serve.analog",   tokens=n, energy_pj=..., reads=...)
    obs.charge("lifetime.scrub", energy_pj=..., latency_ns=...)

Charges aggregate per phase (energy_pj / latency_ns / reads / tokens /
n_charges) and — when tracing is enabled — mirror into the global
tracer as `cat: "ledger"` instant events, so an exported trace file
carries the full attribution and `repro.obs.report` can render
per-phase reads/energy/latency next to the span wall times.

Charging is pure host arithmetic on already-fetched floats: it can
never add a host sync to a hot path.  Phase names should match the
span names they annotate (e.g. the `lifetime.scrub` span and the
`lifetime.scrub` charge join in the report table).
"""

from __future__ import annotations

import dataclasses

from . import trace

__all__ = ["EnergyLedger", "ledger", "charge", "summary", "reset", "FIELDS"]

FIELDS = ("energy_pj", "latency_ns", "reads", "tokens")


@dataclasses.dataclass
class PhaseTotals:
    """Accumulated attribution for one named phase."""

    energy_pj: float = 0.0
    latency_ns: float = 0.0
    reads: float = 0.0
    tokens: float = 0.0
    n_charges: int = 0

    def as_dict(self) -> dict[str, float]:
        return dataclasses.asdict(self)


class EnergyLedger:
    """Per-phase accumulation of modeled energy/latency/reads/tokens."""

    def __init__(self):
        self._phases: dict[str, PhaseTotals] = {}

    def charge(
        self,
        phase: str,
        *,
        energy_pj: float = 0.0,
        latency_ns: float = 0.0,
        reads: float = 0.0,
        tokens: float = 0.0,
        **annotations,
    ) -> None:
        """Attribute modeled cost to `phase` (and mirror into the trace)."""
        if not trace.is_enabled():
            return
        tot = self._phases.get(phase)
        if tot is None:
            tot = self._phases[phase] = PhaseTotals()
        tot.energy_pj += float(energy_pj)
        tot.latency_ns += float(latency_ns)
        tot.reads += float(reads)
        tot.tokens += float(tokens)
        tot.n_charges += 1
        trace.instant(
            phase,
            cat="ledger",
            energy_pj=float(energy_pj),
            latency_ns=float(latency_ns),
            reads=float(reads),
            tokens=float(tokens),
            **annotations,
        )

    def summary(self) -> dict[str, dict[str, float]]:
        return {name: tot.as_dict() for name, tot in sorted(self._phases.items())}

    def total(self, field: str = "energy_pj") -> float:
        return sum(getattr(t, field) for t in self._phases.values())

    def reset(self) -> None:
        self._phases = {}


# The global ledger (one process = one attribution namespace); reset
# alongside the tracer/registry via `obs.reset_all()`.
ledger = EnergyLedger()


def charge(phase: str, **kw) -> None:
    ledger.charge(phase, **kw)


def summary() -> dict[str, dict[str, float]]:
    return ledger.summary()


def reset() -> None:
    ledger.reset()
