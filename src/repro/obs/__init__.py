"""Unified telemetry: metrics, phase tracing, and the energy ledger.

One observability substrate for the whole stack (DESIGN.md Sec. 14):

* `obs.metrics`  — device-side `MetricAccumulator` pytrees riding
  inside jitted hot paths + the host-side `MetricRegistry` of named
  counters (the deploy pipeline's compile/host-sync counters live
  here); `metrics.fetch` is the counted device->host chokepoint.
* `obs.trace`    — host-side phase spans exported as Chrome/Perfetto
  trace-event JSON (`trace.span` / `trace.instant` / `trace.export`).
* `obs.ledger`   — per-phase energy/latency/reads/tokens attribution
  from the circuit cost model (`obs.charge`), mirrored into the trace.
* `obs.report`   — `python -m repro.obs.report TRACE.json` renders the
  per-phase run summary table.

The zero-extra-sync rule: spans/charges are host-side only, and device
metrics are only fetched on host syncs the hot path already performs.
`disabled()` silences trace/ledger recording (contract counters in the
registry keep counting); `reset_all()` gives a fresh run in-process
(benchmarks/run.py calls it between registered benchmarks).
"""

from __future__ import annotations

import contextlib

from . import ledger, metrics, trace
from .ledger import charge
from .metrics import MetricAccumulator, registry
from .trace import instant, span, tracer

__all__ = [
    "ledger",
    "metrics",
    "trace",
    "charge",
    "MetricAccumulator",
    "registry",
    "instant",
    "span",
    "tracer",
    "disabled",
    "reset_all",
]


@contextlib.contextmanager
def disabled():
    """Silence span/ledger recording inside the block.

    Only *verbosity* is gated: registry counters (compile/host-sync
    contracts) keep counting, and device-side accumulators keep riding
    their dispatches — they are part of the compiled computation and
    toggling them would retrace.
    """
    old = trace._set_enabled(False)
    try:
        yield
    finally:
        trace._set_enabled(old)


def reset_all() -> None:
    """Fresh telemetry state: events, charges, and counters all zeroed."""
    trace.reset()
    ledger.reset()
    metrics.reset()
