"""Unified telemetry: metrics, phase tracing, and the energy ledger.

One observability substrate for the whole stack (DESIGN.md Sec. 14):

* `obs.metrics`  — device-side `MetricAccumulator` pytrees riding
  inside jitted hot paths + the host-side `MetricRegistry` of named
  counters (the deploy pipeline's compile/host-sync counters live
  here); `metrics.fetch` is the counted device->host chokepoint.
* `obs.trace`    — host-side phase spans exported as Chrome/Perfetto
  trace-event JSON (`trace.span` / `trace.instant` / `trace.export`).
* `obs.ledger`   — per-phase energy/latency/reads/tokens attribution
  from the circuit cost model (`obs.charge`), mirrored into the trace.
* `obs.digest`   — fixed-bucket streaming histograms (`StreamingDigest`
  pytrees accumulate in-jit / on host; the `digests` registry holds the
  folded percentile views) for p50/p95/p99 without per-request arrays.
* `obs.health`   — per-tile health maps reduced device-side on existing
  syncs + declarative `SLORule`/`SLOPolicy` ceilings evaluated
  host-side over `fleet_status()` (DESIGN.md Sec. 16).
* `obs.report`   — `python -m repro.obs.report TRACE.json` renders the
  per-phase run summary table (+ digest percentiles, SLO breaches).
* `obs.dashboard`— `python -m repro.obs.dashboard` joins TRACE files,
  ledger charges, and fleet-status snapshots into an HTML/text report.

The zero-extra-sync rule: spans/charges are host-side only, and device
metrics are only fetched on host syncs the hot path already performs.
`disabled()` silences trace/ledger recording (contract counters in the
registry keep counting); `reset_all()` gives a fresh run in-process
(benchmarks/run.py calls it between registered benchmarks).
"""

from __future__ import annotations

import contextlib

from . import digest, health, ledger, metrics, trace
from .digest import StreamingDigest, digests, rank_quantile
from .health import SLOPolicy, SLORule, fleet_status
from .health import health as health_registry
from .ledger import charge
from .metrics import MetricAccumulator, registry
from .trace import instant, span, tracer

__all__ = [
    "digest",
    "health",
    "ledger",
    "metrics",
    "trace",
    "charge",
    "MetricAccumulator",
    "StreamingDigest",
    "SLOPolicy",
    "SLORule",
    "digests",
    "rank_quantile",
    "fleet_status",
    "health_registry",
    "registry",
    "instant",
    "span",
    "tracer",
    "disabled",
    "reset_all",
]


@contextlib.contextmanager
def disabled():
    """Silence span/ledger recording inside the block.

    Only *verbosity* is gated: registry counters (compile/host-sync
    contracts) keep counting, and device-side accumulators keep riding
    their dispatches — they are part of the compiled computation and
    toggling them would retrace.
    """
    old = trace._set_enabled(False)
    try:
        yield
    finally:
        trace._set_enabled(old)


def reset_all() -> None:
    """Fresh telemetry state: events, charges, counters, digests, health."""
    trace.reset()
    ledger.reset()
    metrics.reset()
    digest.reset()
    health_registry.reset()
