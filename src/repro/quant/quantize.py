"""Symmetric per-output-channel quantization of weight matrices.

The paper stores B-bit signed weights on pos/neg RRAM column pairs
(Fig. 2): each polarity holds the magnitude across k = B/Bc cell slices,
so the integer magnitude range is [0, 2^B - 1] and signed weights live
in [-(2^B - 1), 2^B - 1] with a per-channel scale.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    weight_bits: int = 6         # B
    cell_bits: int = 3           # Bc
    channel_axis: int = -1       # per-output-channel scales
    clip_quantile: float = 1.0   # 1.0 = absmax scaling

    @property
    def q_max(self) -> int:
        return (1 << self.weight_bits) - 1

    @property
    def slices(self) -> int:
        assert self.weight_bits % self.cell_bits == 0
        return self.weight_bits // self.cell_bits


def quantize_weight(
    w: jax.Array, cfg: QuantConfig
) -> tuple[jax.Array, jax.Array]:
    """float weights -> (int levels in [-q_max, q_max], per-channel scale)."""
    axis = cfg.channel_axis % w.ndim
    red = tuple(i for i in range(w.ndim) if i != axis)
    if cfg.clip_quantile >= 1.0:
        amax = jnp.max(jnp.abs(w), axis=red, keepdims=True)
    else:
        amax = jnp.quantile(jnp.abs(w), cfg.clip_quantile, axis=red, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / cfg.q_max
    q = jnp.clip(jnp.round(w / scale), -cfg.q_max, cfg.q_max)
    return q.astype(jnp.int32), scale


def dequantize_weight(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Integer (or programmed analog) levels -> float weights."""
    return q.astype(jnp.float32) * scale
