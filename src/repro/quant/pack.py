"""Packing weight matrices into RRAM verify-columns and back.

A weight matrix W (K_in, M_out) deploys onto crossbar arrays whose
*physical columns* (the unit the WV engine programs: N cells sharing one
TIA/ADC) run along the input dimension.  Layout:

    (K, M) ->  pad K to multiple of N
           ->  (K/N, N, M) chunks
           ->  x2 polarities (pos/neg), x k slices
           ->  columns (K/N * M * 2 * k, N)

Columns are fully independent — at deployment scale they are sharded
over the entire device mesh (see launch/program.py).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .bitslice import pair_to_signed, signed_to_pair, slice_magnitudes, unslice_magnitudes


@dataclasses.dataclass(frozen=True)
class PackedLayout:
    """Static metadata needed to invert the packing."""

    k_in: int
    m_out: int
    n_cells: int
    slices: int
    bc: int

    @property
    def k_padded(self) -> int:
        return -(-self.k_in // self.n_cells) * self.n_cells

    @property
    def num_columns(self) -> int:
        return (self.k_padded // self.n_cells) * self.m_out * 2 * self.slices


def pack_columns(
    q: jax.Array, n_cells: int, bc: int, k_slices: int
) -> tuple[jax.Array, PackedLayout]:
    """Signed int weight matrix (K, M) -> target cell levels (C, N)."""
    k_in, m_out = q.shape
    layout = PackedLayout(k_in, m_out, n_cells, k_slices, bc)
    pad = layout.k_padded - k_in
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
    pos, neg = signed_to_pair(q)
    # (Kp, M, 2)
    pair = jnp.stack([pos, neg], axis=-1)
    # (Kp, M, 2, S)
    cells = slice_magnitudes(pair, bc, k_slices)
    # (Kp/N, N, M, 2, S) -> (Kp/N, M, 2, S, N) -> (C, N)
    kp = layout.k_padded
    cells = cells.reshape(kp // n_cells, n_cells, m_out, 2, k_slices)
    cells = jnp.moveaxis(cells, 1, -1)
    return cells.reshape(-1, n_cells).astype(jnp.float32), layout


def unpack_columns(columns: jax.Array, layout: PackedLayout) -> jax.Array:
    """Programmed cell levels (C, N) -> effective signed weights (K, M).

    Accepts continuous (analog read-back) levels: slices recombine with
    their binary weights and polarities subtract, so programming noise
    propagates to the effective weight exactly as in the macro.
    """
    kp, n = layout.k_padded, layout.n_cells
    cells = columns.reshape(kp // n, layout.m_out, 2, layout.slices, n)
    cells = jnp.moveaxis(cells, -1, 1).reshape(kp, layout.m_out, 2, layout.slices)
    mags = unslice_magnitudes(cells, layout.bc)  # (Kp, M, 2)
    signed = pair_to_signed(mags[..., 0], mags[..., 1])
    return signed[: layout.k_in]
