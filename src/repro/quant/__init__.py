from .quantize import QuantConfig, quantize_weight, dequantize_weight  # noqa: F401
from .bitslice import (  # noqa: F401
    slice_magnitudes,
    unslice_magnitudes,
    signed_to_pair,
    pair_to_signed,
)
from .pack import pack_columns, unpack_columns, PackedLayout  # noqa: F401
