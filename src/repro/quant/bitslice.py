"""Bit-slicing between B-bit integer magnitudes and Bc-bit cell levels.

Signed mapping (paper Fig. 5(d)): w = w+ - w-, with exactly one of the
pair nonzero (the other cell stays at HRS to encode zero).  Magnitudes
split base-2^Bc, LSB slice first:  mag = sum_l (2^Bc)^l * s_l.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def signed_to_pair(q: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Signed integers -> (positive, negative) magnitude planes."""
    return jnp.maximum(q, 0), jnp.maximum(-q, 0)


def pair_to_signed(pos: jax.Array, neg: jax.Array) -> jax.Array:
    """Inverse of signed_to_pair (works on analog read-back values too)."""
    return pos - neg


def slice_magnitudes(mag: jax.Array, bc: int, k: int) -> jax.Array:
    """(..., ) int magnitudes -> (..., k) cell levels, LSB slice first."""
    base = 1 << bc
    out = []
    rem = mag.astype(jnp.int32)
    for _ in range(k):
        out.append(rem % base)
        rem = rem // base
    return jnp.stack(out, axis=-1)


def unslice_magnitudes(slices: jax.Array, bc: int) -> jax.Array:
    """(..., k) cell levels (analog OK) -> (...,) magnitudes."""
    k = slices.shape[-1]
    weights = jnp.asarray([float(1 << (bc * l)) for l in range(k)], slices.dtype)
    return jnp.sum(slices * weights, axis=-1)
