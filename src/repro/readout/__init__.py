# The unified analog readout subsystem: ONE model of the read path
# (basis x converter x averaging x impairments) shared by WV verify
# (core.wv), lifetime refresh detection (lifetime.refresh), and CIM
# inference ADC readout (cim.mvm / kernels.acim_vmm).  DESIGN.md Sec. 12.
from .config import (  # noqa: F401
    Converter,
    ReadoutBasis,
    ReadoutConfig,
    for_wv_method,
)
from .converter import (  # noqa: F401
    code_width_lsb,
    compare_read,
    full_scale_lsb,
    sar_quantize,
    sar_read,
)
from .noise import (  # noqa: F401
    sample_read_fields,
    sample_token_read_noise,
)
from .readout import (  # noqa: F401
    ReadResult,
    decode_magnitude,
    decode_ternary,
    encode,
    read_columns,
    voted_signs,
)
from .cost import sweep_cost  # noqa: F401
from .calibrate import calibrate_offsets, sample_col_offsets  # noqa: F401
