"""`read_columns`: the single entry point onto the analog read path.

One call = one verification sweep of a batch of columns: basis encode
(the *physical* summation of cell currents under the drive patterns),
noise injection (owned by `readout.noise`), optional static per-column
converter offset, converter conversion (`readout.converter`), and
M-read averaging.  Everything the WV engine, the refresh detector, and
(via the shared converter primitive) the CIM inference epilogue know
about reading a column goes through here; cost accounting for the same
sweep lives in `readout.cost.sweep_cost`.

Ownership contract (DESIGN.md Sec. 12):

* the CALLER owns the key schedule (which sweep gets which key) and the
  decision logic applied to the returned measurements;
* READOUT owns what happens between conductances and digital numbers:
  noise sampling, offset injection, quantization, averaging;
* COST for a sweep is priced by `readout.cost.sweep_cost` from the same
  `ReadoutConfig` — consumers never hand-roll converter timing.

Decode helpers (`decode_magnitude` / `decode_ternary`) invert the basis
digitally — the shift-and-add periphery of Sec. 3.2 — and are gated on
`ReadoutConfig.use_pallas` to route through the fused FWHT kernel.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import rng
from repro.core import hadamard as hd

# Sibling modules are imported as modules (attributes resolved at call
# time): core.wv imports this package, so `from .config import X` here
# would break when the import cycle is entered via repro.readout.
from . import config as config_mod
from . import converter as conv_mod
from . import noise as noise_mod

if TYPE_CHECKING:
    from .config import ReadoutConfig

__all__ = [
    "ReadResult",
    "read_columns",
    "encode",
    "decode_magnitude",
    "decode_ternary",
    "voted_signs",
]


class ReadResult(NamedTuple):
    """What one sweep hands the digital periphery (all measurement-domain).

    values:     (C, N) converter output — dequantized codes for SAR,
                raw analog for IDEAL, ternary signs in {-1, 0, +1} for
                COMPARE.  M averaged reads are already collapsed.
    n_compares: (C, N) comparator operations issued (COMPARE: 1 or 2
                per Fig. 7(c)); zeros for code-producing converters.
    n_reads:    static physical column reads this sweep (M * N).
    """

    values: jax.Array
    n_compares: jax.Array
    n_reads: int


def _fwht(x: jax.Array, cfg: ReadoutConfig) -> jax.Array:
    if cfg.use_pallas:
        from repro.kernels.fwht import ops as fwht_ops

        return fwht_ops.fwht(x)
    return hd.fwht(x)


def encode(g: jax.Array, cfg: ReadoutConfig) -> jax.Array:
    """Noiseless physical read: cell conductances -> measurement domain."""
    if cfg.basis == config_mod.ReadoutBasis.HADAMARD:
        return _fwht(g, cfg)
    return g


def _centered_sar(y: jax.Array, cfg: ReadoutConfig) -> jax.Array:
    """SAR-convert measurements with the V_sam range convention.

    Hadamard row 0 (all-ones) reads over [0, FS]; the balanced rows are
    re-centred to [-FS/2, FS/2] (Sec. 3.2).  One-hot reads are all
    single-cell currents over [0, FS].
    """
    a, n, levels = cfg.adc, cfg.n_cells, cfg.levels
    if cfg.basis == config_mod.ReadoutBasis.HADAMARD:
        centered = jnp.arange(n) > 0
        return jnp.where(
            centered,
            conv_mod.sar_read(y, a, n, levels, centered=True),
            conv_mod.sar_read(y, a, n, levels, centered=False),
        )
    return conv_mod.sar_read(y, a, n, levels, centered=False)


def read_columns(
    key: jax.Array,
    g: jax.Array,
    cfg: ReadoutConfig,
    *,
    targets: jax.Array | None = None,
    col_offset: jax.Array | None = None,
) -> ReadResult:
    """One verification sweep of a batch of columns.

    Args:
      key: sweep key, or a batch of per-column keys (`core.rng`
        sub-streams; DESIGN.md Sec. 10).
      g: (C, N) true cell conductances in cell-LSB.
      cfg: the read path (basis / converter / averaging / impairments).
      targets: (C, N) intended integer levels — REQUIRED for the COMPARE
        converter (the comparator presets to the target code, quantized
        onto the ADC grid because its DAC can only produce code levels).
      col_offset: optional (C,) static per-column converter reference
        offset in cell-LSB, added to every measurement (see
        `readout.calibrate`; distinct from the per-sweep mu_cm).

    Returns a `ReadResult`; see the class docstring.
    """
    c, n = g.shape
    assert n == cfg.n_cells, (n, cfg.n_cells)
    m = cfg.avg_reads

    y_true = encode(g, cfg)
    n_uc, mu_cm = noise_mod.sample_read_fields(key, (c,), m, n, cfg.noise)
    # Summation order is part of the bit-compat contract with the
    # pre-refactor per-method implementations: single-read sweeps
    # materialize the combined noise field first; M-read sweeps add the
    # per-read field to the signal before the shared common mode.
    if m == 1:
        y = y_true + (n_uc + mu_cm).reshape(c, n)
    else:
        y = (y_true[:, None, :] + n_uc) + mu_cm
    if col_offset is not None:
        y = y + col_offset.reshape((c,) + (1,) * (y.ndim - 1))

    zeros = jnp.zeros((c, n), jnp.int32)
    if cfg.converter == config_mod.Converter.IDEAL:
        vals = y if m == 1 else jnp.mean(y, axis=1)
        return ReadResult(vals, zeros, m * n)

    if cfg.converter == config_mod.Converter.SAR:
        q = _centered_sar(y, cfg)
        vals = q if m == 1 else jnp.mean(q, axis=1)
        return ReadResult(vals, zeros, m * n)

    if cfg.converter == config_mod.Converter.COMPARE:
        # avg_reads == 1 is guaranteed by ReadoutConfig.__post_init__.
        if targets is None:
            raise ValueError("compare-mode readout needs targets")
        t_grid = _centered_sar(encode(targets, cfg), cfg)
        sign, n_cmp = conv_mod.compare_read(y, t_grid, cfg.deadzone_lsb)
        return ReadResult(sign, n_cmp, n)

    raise ValueError(cfg.converter)


def decode_magnitude(values: jax.Array, cfg: ReadoutConfig) -> jax.Array:
    """Digital basis inversion to a cell-domain estimate (eq. 6):
    (1/N) H^T y for Hadamard reads, identity for one-hot."""
    if cfg.basis == config_mod.ReadoutBasis.HADAMARD:
        return _fwht(values, cfg) / cfg.n_cells
    return values


def decode_ternary(signs: jax.Array, cfg: ReadoutConfig) -> jax.Array:
    """HARP's unnormalized ternary aggregate s_w = H^T s_y (eq. 10) for
    Hadamard reads; identity for one-hot (CW-SC's signs ARE per-cell)."""
    if cfg.basis == config_mod.ReadoutBasis.HADAMARD:
        return _fwht(signs, cfg)
    return signs


def voted_signs(
    key: jax.Array,
    sweeps: int,
    decision_fn: Callable[[jax.Array], jax.Array],
) -> tuple[jax.Array, jax.Array]:
    """Repeat a ternary readout decision over independent sub-streams.

    Runs `decision_fn(fold_in(key, r))` for r in [0, sweeps) and counts
    positive / negative decisions per cell — the repetition vote the
    refresh detector uses to crush single-sweep false alarms (a lone
    sweep at the programming threshold fires on nearly every healthy
    column).  Returns (pos_counts, neg_counts), float arrays shaped like
    one decision.
    """
    if sweeps < 1:
        raise ValueError(f"voted_signs needs at least one sweep, got {sweeps}")
    pos = neg = None
    for r in range(sweeps):
        d = decision_fn(rng.fold_in(key, r))
        if pos is None:
            pos = jnp.zeros_like(d)
            neg = jnp.zeros_like(d)
        pos = pos + (d > 0.0)
        neg = neg + (d < 0.0)
    return pos, neg
