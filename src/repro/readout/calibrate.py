"""Per-column converter offset drift and its calibration (reference tuning).

Scenario (a la ADC reference tuning for CIM readout, arXiv:2502.05948):
each column's converter carries a *static* reference/bias offset o_col,
sampled once per column (like d2d) from N(0, sigma_col_offset^2).
Unlike the per-sweep common mode mu_cm it never averages out across
sweeps — single-cell (one-hot) readouts eat it as a systematic level
error, which is exactly what reference tuning trims in hardware.
(Hadamard readouts cancel any measurement-constant offset on the N-1
balanced rows at decode — the same structural immunity as for mu_cm —
so calibration matters most for one-hot converter fleets.)

`calibrate_offsets` models the tuning procedure: read a reference
column programmed at a known mid-scale level K times through the SAR
converter, average the measurement-domain error, and subtract that
estimate from the true offset.  The residual after trimming is
~ sqrt(sigma_uc^2/(K*N) + sigma_cm^2/K) plus a quantization floor —
reads are cheap (K full-SAR sweeps per column, priced by
`readout.cost.sweep_cost`), so a handful of calibration reads turn
offset drift from a systematic error into a small random one.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from typing import TYPE_CHECKING

from repro.core import rng

from . import config as config_mod
from . import readout as ro

if TYPE_CHECKING:
    from .config import ReadoutConfig

__all__ = ["sample_col_offsets", "calibrate_offsets"]


def sample_col_offsets(
    key: jax.Array, n_columns: int, cfg: ReadoutConfig
) -> jax.Array:
    """Static per-column converter reference offsets: (C,) in cell-LSB."""
    return cfg.sigma_col_offset_lsb * jax.random.normal(key, (n_columns,))


def calibrate_offsets(
    key: jax.Array,
    col_offset: jax.Array,
    cfg: ReadoutConfig,
    k_reads: int = 8,
    ref_level: float | None = None,
) -> jax.Array:
    """Trim per-column offsets from K calibration reads of a reference.

    Every column reads a reference column whose cells all sit at the
    known `ref_level` (default mid-scale, which centres both the one-hot
    range and the unbalanced Hadamard row 0 so neither rail clips the
    offset).  The per-column mean measurement error over K independent
    SAR sweeps estimates o_col; the return value is the RESIDUAL offset
    ``col_offset - estimate`` to hand back to `read_columns` — i.e. the
    read path after reference tuning.
    """
    c = col_offset.shape[0]
    n = cfg.n_cells
    if ref_level is None:
        ref_level = 0.5 * (cfg.levels - 1)
    g_ref = jnp.full((c, n), ref_level, jnp.float32)
    cal_cfg = cfg.replace(converter=config_mod.Converter.SAR, avg_reads=1)
    y_ref = ro.encode(g_ref, cal_cfg)

    est = jnp.zeros((c,), jnp.float32)
    for k in range(k_reads):
        res = ro.read_columns(
            rng.fold_in(key, k), g_ref, cal_cfg, col_offset=col_offset
        )
        est = est + jnp.mean(res.values - y_ref, axis=-1)
    return col_offset - est / k_reads
