"""Readout-path configuration: basis x converter x averaging x impairments.

One `ReadoutConfig` describes everything between "cell conductances" and
"digital numbers the periphery sees" for ONE column readout:

* **basis** — which row-drive patterns sense the column.  `ONE_HOT`
  reads cells individually (rows of I); `HADAMARD` reads Sylvester
  +-1 patterns (rows of H_N), the paper's contribution (Sec. 3.2).
* **converter** — what the column TIA feeds.  `SAR` is a full n-bit
  binary search (uniform quantization over the column full scale);
  `COMPARE` is HARP's one-shot ternary compare against a preset target
  code (Fig. 7); `IDEAL` is an infinite-resolution converter (the
  algebraic limit used by equivalence contracts and what `adc_bits=None`
  means on the CIM side).
* **avg_reads** — M repeated reads averaged per measurement (MRA).
  Uncorrelated noise averages down ~1/sqrt(M); common-mode and static
  offsets do NOT (they are constant within the sweep).
* **noise** — per-read uncorrelated + per-sweep common-mode injection
  (`core.types.NoiseConfig`, eqs. 2-4).
* **sigma_col_offset_lsb** — *static* per-column ADC reference offset
  (reference/bias drift a la ADC reference tuning, arXiv:2502.05948).
  Unlike mu_cm it persists across sweeps, so it is sampled once per
  column (like d2d) and can be *calibrated out* from K reads of a known
  reference level (`readout.calibrate.calibrate_offsets`).

The four paper WV methods are points in this space
(`for_wv_method` / `ReadoutConfig.for_wv`):

    method | basis    | converter | avg_reads
    CW-SC  | one-hot  | compare   | 1
    MRA-M  | one-hot  | SAR       | M
    HD-PV  | Hadamard | SAR       | 1
    HARP   | Hadamard | compare   | 1

and new scenarios (reference-tuned converters, per-column offset drift,
mixed SAR/compare fleets) are configs, not code.
"""

from __future__ import annotations

import dataclasses
import enum

from repro.core.types import ADCConfig, NoiseConfig, WVConfig, WVMethod

__all__ = ["ReadoutBasis", "Converter", "ReadoutConfig", "for_wv_method"]


class ReadoutBasis(str, enum.Enum):
    ONE_HOT = "one_hot"      # identity read patterns (single-cell sensing)
    HADAMARD = "hadamard"    # Sylvester +-1 patterns (parallel sensing)


class Converter(str, enum.Enum):
    IDEAL = "ideal"          # infinite resolution (analysis/equivalence limit)
    SAR = "sar"              # full n-bit SAR conversion -> code on the ADC grid
    COMPARE = "compare"      # one-shot ternary compare vs a preset target code


@dataclasses.dataclass(frozen=True)
class ReadoutConfig:
    """Static description of one column read path (closed over under jit)."""

    basis: ReadoutBasis = ReadoutBasis.HADAMARD
    converter: Converter = Converter.SAR
    n_cells: int = 32                # column length N (Hadamard order)
    levels: int = 8                  # cell levels 2^Bc (full-scale units)
    avg_reads: int = 1               # M averaged reads per measurement
    deadzone_lsb: float = 0.5        # COMPARE 'Equal' band half-width
    adc: ADCConfig = dataclasses.field(default_factory=ADCConfig)
    noise: NoiseConfig = dataclasses.field(default_factory=NoiseConfig)
    sigma_col_offset_lsb: float = 0.0  # static per-column reference offset std
    use_pallas: bool = False         # route basis transforms via kernels.fwht

    def __post_init__(self):
        if self.avg_reads < 1:
            raise ValueError(f"avg_reads must be >= 1, got {self.avg_reads}")
        if self.converter == Converter.COMPARE and self.avg_reads != 1:
            # One-shot by construction (Fig. 7): the comparator makes a
            # decision, it produces no code that could be averaged.
            raise ValueError(
                f"compare-mode readout is one-shot; avg_reads={self.avg_reads}"
            )
        if self.basis == ReadoutBasis.HADAMARD:
            n = self.n_cells
            if n < 1 or n & (n - 1):
                raise ValueError(f"Hadamard order must be a power of 2: {n}")

    def replace(self, **kw) -> "ReadoutConfig":
        return dataclasses.replace(self, **kw)

    @property
    def reads_per_sweep(self) -> int:
        """Physical column reads per verification sweep."""
        return self.avg_reads * self.n_cells

    @classmethod
    def for_wv(cls, cfg: WVConfig) -> "ReadoutConfig":
        """The readout a WVConfig's verify phase uses (method matrix above)."""
        return for_wv_method(cfg)


def for_wv_method(cfg: WVConfig) -> ReadoutConfig:
    basis, converter, m = {
        WVMethod.CW_SC: (ReadoutBasis.ONE_HOT, Converter.COMPARE, 1),
        WVMethod.MRA: (ReadoutBasis.ONE_HOT, Converter.SAR, cfg.mra_reads),
        WVMethod.HD_PV: (ReadoutBasis.HADAMARD, Converter.SAR, 1),
        WVMethod.HARP: (ReadoutBasis.HADAMARD, Converter.COMPARE, 1),
    }[cfg.method]
    return ReadoutConfig(
        basis=basis,
        converter=converter,
        n_cells=cfg.n_cells,
        levels=cfg.device.levels,
        avg_reads=m,
        deadzone_lsb=cfg.decision_threshold_lsb,
        adc=cfg.adc,
        noise=cfg.noise,
        use_pallas=cfg.use_pallas,
    )
