"""The ONE read-noise sampler for every analog read path (eqs. 2-4).

For one verification sweep of a column read with patterns a_1..a_N:

    y_hat_i = a_i^T w  +  n_uc,i  +  mu_cm  +  o_col

* n_uc,i ~ N(0, sigma_uc^2) i.i.d. per measurement (TIA/ADC thermal
  noise) — independent across patterns AND across repeated reads, so
  multi-read averaging does average it down (~1/M in variance).
* mu_cm ~ N(0, sigma_cm^2) per column per sweep — constant across all N
  patterns AND all M averaged reads of that sweep (shared TIA/ADC
  offset, reference drift within the sweep, IR drop), independent
  across columns.  Multi-read averaging does NOT remove it; Hadamard
  decoding cancels it exactly for the N-1 balanced rows (eq. 7).
* o_col — *static* per-column converter reference offset (sampled once
  per column like d2d, constant across sweeps; see `readout.calibrate`).
  Injected by `readout.read_columns`, not sampled here.

RNG contract: callers hand a key that is either a single sweep key or a
batch of per-column keys (`core.rng` fold-in sub-streams, DESIGN.md
Sec. 10); both route through `core.rng`'s batch-transparent wrappers.

This module also owns the CIM inference read-noise policy (DESIGN.md
Sec. 17): per-(tile, plane) keys fan out to per-token sub-streams via
``fold_in(key, token_id)``, so a token's draw is independent of the
batch shape it rides in — and, with caller-supplied `token_ids`
(request ids in the serving scheduler), independent of WHICH slot the
token occupies.  `sample_token_read_noise` samples either one
(tile, plane)'s (S, T, M) field or — with `tiles`/`planes` — the whole
(tile, plane, token) lattice for a leaf in ONE batched threefry
dispatch, bit-identical to the per-(tile, plane) loop it replaces.

Units: cell-LSB throughout.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import rng
from repro.core.types import NoiseConfig

__all__ = ["sample_read_fields", "sample_token_read_noise"]


def sample_read_fields(
    key: jax.Array,
    batch_shape: tuple[int, ...],
    n_reads: int,
    n_meas: int,
    noise: NoiseConfig,
) -> tuple[jax.Array, jax.Array]:
    """Raw noise fields for one sweep of M averaged reads.

    Returns (n_uc, mu_cm): (*batch, M, n_meas) uncorrelated noise and a
    (*batch, 1, 1) per-column common-mode offset shared by every
    measurement of every averaged read in the sweep.  Kept separate so
    the caller controls the summation order against the true signal
    (the single-read and M-read paths historically associate
    differently; `read_columns` preserves both bit-exactly).
    """
    k_uc, k_cm = rng.split(key)
    n_uc = noise.sigma_uc_lsb * rng.normal(k_uc, (*batch_shape, n_reads, n_meas))
    mu_cm = noise.sigma_cm_lsb * rng.normal(
        k_cm, (*batch_shape,) + (1,) * 2
    )
    return n_uc, mu_cm


def sample_token_read_noise(
    key: jax.Array,
    n_tokens: int,
    n_slices: int,
    m: int,
    sigma_lsb: float,
    *,
    token_ids: jax.Array | None = None,
    tiles: int | None = None,
    planes: int | None = None,
) -> jax.Array | None:
    """Per-read CIM inference noise; one dispatch for a whole leaf.

    Without `tiles`/`planes`: `key` is one (tile, plane) sub-key and the
    result is (S, T, M) — token t draws from ``fold_in(key, ids[t])``.

    With `tiles`=Ti and `planes`=P: `key` is the LEAF key and the result
    is (Ti, S, P*T, M), the per-tile noise operand of the fused tiled
    kernel (`kernels.acim_vmm.acim_vmm_tiled`), where flattened row
    ``p*T + t`` of tile ti draws from

        fold_in(fold_in(fold_in(key, ti), p), ids[t])

    — the SAME stream the per-(tile, plane) loop produced, materialized
    by one batched threefry over the full (tile, plane, token) lattice.

    `token_ids` defaults to ``arange(T)`` (flattened batch index); the
    serving scheduler passes request ids so a token's draw is invariant
    to slot placement and batch composition.  Returns None when the path
    is clean (sigma <= 0) so callers can skip the noise operand.
    """
    if sigma_lsb <= 0.0:
        return None
    if token_ids is None:
        token_ids = jnp.arange(n_tokens, dtype=jnp.int32)
    token_ids = token_ids.astype(jnp.int32)
    if (tiles is None) != (planes is None):
        raise ValueError("tiles and planes must be given together")
    if tiles is None:
        tok_keys = rng.fold_col_keys(key, token_ids)
        nz = rng.normal(tok_keys, (n_tokens, n_slices, m))
        return sigma_lsb * jnp.transpose(nz, (1, 0, 2))
    # Whole-lattice path: build every (tile, plane, token) key, then one
    # batched per-key (S, M) draw — identical per-key tails to the
    # single-(tile, plane) path above, so the streams are bit-equal.
    tile_ids = jnp.arange(tiles, dtype=jnp.int32)
    plane_ids = jnp.arange(planes, dtype=jnp.int32)
    k_tile = rng.fold_col_keys(key, tile_ids)                    # (Ti, ...)
    k_tp = jax.vmap(lambda k: rng.fold_col_keys(k, plane_ids))(k_tile)
    k_tpt = jax.vmap(jax.vmap(lambda k: rng.fold_col_keys(k, token_ids)))(
        k_tp
    )                                                            # (Ti, P, T, ...)
    flat = k_tpt.reshape(tiles * planes * n_tokens, *k_tpt.shape[3:])
    nz = rng.normal(flat, (tiles * planes * n_tokens, n_slices, m))
    nz = nz.reshape(tiles, planes, n_tokens, n_slices, m)
    # (Ti, P, T, S, M) -> (Ti, S, P, T, M) -> (Ti, S, P*T, M): row p*T+t
    # matches the old concatenate-over-planes layout exactly.
    nz = jnp.transpose(nz, (0, 3, 1, 2, 4))
    return sigma_lsb * nz.reshape(tiles, n_slices, planes * n_tokens, m)
