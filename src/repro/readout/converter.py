"""Column converter models: the one place SAR/compare math lives.

Paper Fig. 7: a standard n-bit SAR ADC either

* runs the full n-step binary search ("SAR logic"), producing a digital
  code — modelled as uniform quantization over the converter's
  full-scale range; or
* is put in HARP's one-shot *compare* mode ("compare logic"): the
  capacitor array is preset to the target code and the comparator makes
  one (or two) decisions, yielding ternary {Low, Equal, High} — no code.

Every consumer of a quantizing read dispatches here: the WV verify path
(`core.wv` via `readout.read_columns`), refresh sweeps, and the CIM
inference ADC epilogue (`kernels.acim_vmm.ref` delegates its per-slice
`adc_quantize` to `sar_quantize`; the fused Pallas kernel implements the
identical expression in VMEM and is bit-identity-tested against it).

Full-scale convention (Sec. 3.2, V_sam reference switching): the verify
ADC always spans ``N * (2^Bc - 1)`` cell-LSB of column current.

* one-hot reads / first Hadamard row: range [0, FS]        (V_sam = GND)
* balanced Hadamard rows:            range [-FS/2, +FS/2]  (V_sam = Vcm/2)

Both use the same bit budget, so the ADC code width in cell-LSB is
FS / 2^bits regardless of mode — single-cell (one-hot) SAR reads
therefore use only 1/N of the converter's dynamic range, one of the
structural advantages of reading in the Hadamard basis.  The CIM
inference converter spans the signed macro range ``+-R * (2^Bc - 1)``
per slice — same primitive, different full scale.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import ADCConfig

__all__ = [
    "full_scale_lsb",
    "code_width_lsb",
    "sar_quantize",
    "sar_read",
    "compare_read",
]


def full_scale_lsb(n_cells: int, levels: int) -> float:
    return float(n_cells * (levels - 1))


def code_width_lsb(adc: ADCConfig, n_cells: int, levels: int) -> float:
    return full_scale_lsb(n_cells, levels) / float(1 << adc.bits)


def sar_quantize(
    y: jax.Array, bits: int, full_scale: float, centered: bool = True
) -> jax.Array:
    """n-bit uniform quantization over the full-scale range (dequantized).

    `centered` selects [-FS/2, +FS/2]; otherwise [0, FS].  Returns
    code * width + lo in the input units, saturating at the rails.  This
    is THE converter primitive: `sar_read` wraps it with the verify-path
    full-scale convention and the CIM ADC epilogue calls it per slice.
    """
    w = full_scale / float(1 << bits)
    lo = -full_scale / 2.0 if centered else 0.0
    code = jnp.clip(
        jnp.round((jnp.clip(y, lo, lo + full_scale) - lo) / w),
        0,
        (1 << bits) - 1,
    )
    return lo + code * w


def sar_read(
    y: jax.Array, adc: ADCConfig, n_cells: int, levels: int, centered: bool
) -> jax.Array:
    """Full SAR conversion of a verify read: quantize y (cell-LSB) to the
    ADC grid over the column full scale ``N * (2^Bc - 1)``."""
    return sar_quantize(y, adc.bits, full_scale_lsb(n_cells, levels), centered)


def compare_read(
    y: jax.Array, target: jax.Array, deadzone_lsb: float
) -> tuple[jax.Array, jax.Array]:
    """One-shot compare mode (eq. 9): ternary sign of (y - target).

    The comparator presets the capacitor array to the target code and
    compares; a second comparison against the adjacent code resolves the
    'Equal' band.  Returns (sign in {-1, 0, +1}, comparisons in {1, 2}).

    Comparison counting follows Fig. 7(c): the first comparison resolves
    "below target"; only a not-below outcome needs the second comparison
    against target+1 to separate Equal from High.
    """
    diff = y - target
    below = diff < -deadzone_lsb
    above = diff > deadzone_lsb
    sign = jnp.where(below, -1.0, jnp.where(above, 1.0, 0.0))
    n_cmp = jnp.where(below, 1, 2).astype(jnp.int32)
    return sign, n_cmp
