"""Latency / energy of one readout sweep (paper Table 1, Sec. 5.3).

`sweep_cost` prices exactly the sweep `readout.read_columns` performs,
from the same `ReadoutConfig` — the basis/converter matrix replaces the
old per-WV-method switch (the four methods are the four corners):

  one-hot  + COMPARE (CW-SC) : N x (t_pulse + t_cmp), rare 2nd compare
  one-hot  + SAR M=M (MRA-M) : M*N x (t_pulse + t_sar)
  Hadamard + SAR     (HD-PV) : N x (t_pulse + t_sar) + decode adder
  Hadamard + COMPARE (HARP)  : N x (t_pulse + t_cmp') + ternary adder

Decode streaming (Sec. 3.2 "digital decoding"): measurements stream
into the shift-and-add periphery, so adder latency pipelines behind the
next read (t_adder = 5 ns << t_pulse + t_adc); only a single tail add
lands on the critical path.  Adder *energy* is paid once per pattern
per column — at the multi-bit rate for code-producing (SAR) reads and
the cheaper ternary rate for compare reads.

The IDEAL converter is an analysis limit with no hardware realization;
it is priced as a full SAR conversion so idealized sweeps never read as
free in an energy comparison.

Units: ns and pJ.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from typing import TYPE_CHECKING

from repro.core.cost import CircuitCost

# Module-style sibling import: survives the core.wv <-> repro.readout
# import cycle regardless of entry point.
from . import config as config_mod

if TYPE_CHECKING:
    from .config import ReadoutConfig

__all__ = ["sweep_cost"]


def sweep_cost(
    cfg: ReadoutConfig,
    cost: CircuitCost,
    n_compares: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """(latency_ns, energy_pj) of one readout sweep of one column.

    `n_compares`: (..., N) per-measurement comparison counts for the
    COMPARE converter (1-or-2 per Fig. 7(c)); the 1.5/read expectation
    is assumed if None.  Returns scalars (or batched arrays if
    n_compares is batched).
    """
    adc, n = cfg.adc, cfg.n_cells
    hadamard = cfg.basis == config_mod.ReadoutBasis.HADAMARD

    if cfg.converter == config_mod.Converter.COMPARE:
        if n_compares is None:
            cmp_total = jnp.asarray(1.5 * n, jnp.float32)
        else:
            cmp_total = jnp.sum(n_compares.astype(jnp.float32), axis=-1)
        # Compare latency: the second comparison reuses the sampled
        # value; per-read critical path is t_pulse + t_cmp (first) and
        # the rare second compare adds t_cmp again.
        lat = (
            n * (adc.t_read_pulse_ns + adc.t_compare_ns)
            + (cmp_total - n) * adc.t_compare_ns
        )
        e = n * adc.e_tia_pj + cmp_total * adc.e_compare_pj
        if hadamard:
            lat = lat + cost.t_adder_ns
            e = e + n * cost.e_adder_harp_pj
        return jnp.asarray(lat, jnp.float32), jnp.asarray(e, jnp.float32)

    # Code-producing converters: SAR, and IDEAL priced as SAR.
    reads = cfg.avg_reads * n
    lat = reads * (adc.t_read_pulse_ns + adc.t_sar_ns)
    e = reads * (adc.e_tia_pj + adc.e_sar_pj)
    if hadamard:
        lat = lat + cost.t_adder_ns
        e = e + n * cost.e_adder_hdpv_pj
    return jnp.asarray(lat, jnp.float32), jnp.asarray(e, jnp.float32)
