"""Crossbar macro tiling: packed WV columns -> inference operand planes.

The WV engine programs *verify columns* — (C, N) rows of N cells sharing
one TIA/ADC (quant/pack layout).  Inference reads the same physical
cells along the orthogonal axis: a vector-matrix multiply drives the
array's K input rows and senses all signed column pairs in parallel.
This module re-views the programmed `ArrayState` conductances in the
inference layout without copying semantics:

    packed columns (C, N)
      -> per-slice signed planes  g_pos/g_neg : (S, K, M)   (slice_planes)
      -> macro tiles of <= `macro_rows` rows : (T, S, R, M) (tile_planes)

Pack padding rows (K..K_padded) are dropped exactly as `materialize()`
drops them; tile padding rows are zero conductance AND driven with zero
input, so they contribute nothing to any partial sum.

For stacked per-layer leaves (L, d, M) — the transformer's scanned layer
stacks — every tiled array carries a leading L axis on every *child*
array (tiles, scale, noise key), so the model's existing parameter
plumbing (``tree.map(lambda a: a[idx], layers)``, `lax.scan` over
stacked params) slices a `CIMWeight` exactly like it slices a dense
leaf.  That is what lets the analog forward drop into `models.layers`
without touching the scan bodies.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.quant.pack import PackedLayout

__all__ = ["CIMWeight", "slice_planes", "tile_planes", "build_weight", "rekey"]


@dataclasses.dataclass
class CIMWeight:
    """One weight leaf living on crossbar macro tiles (a pytree node).

    Children (sliced together by scan/tree.map — all lead with L for
    stacked leaves):
      g_pos/g_neg : ([L,] T, S, R, M) per-tile signed conductance planes
      scale       : ([L,] M) per-output-channel dequantization scale
      key         : ([L,] 2) per-access read-noise key — the SAME key
                    broadcast over L; the executor swaps it every access
                    with one fold + one broadcast (see mvm.py RNG policy)
      layer_id    : ([L,] ) int32 layer index for stacked leaves (folds
                    into the noise stream IN-JIT after slicing), None
                    for plain 2-D leaves
    Static aux:
      rows_in : real input rows per layer (pre tile padding)
      bc      : bits per cell (slice recombination weight base)
      levels  : cell levels (ADC full-scale in LSB units)
      cfg     : CIMConfig (opaque here; consumed by mvm.cim_matmul)
      name    : leaf name (diagnostics)
      uid     : executor leaf uid folded into the noise stream in-jit
                (None = no uid sub-stream: direct build_weight users)
    """

    g_pos: jax.Array
    g_neg: jax.Array
    scale: jax.Array
    key: jax.Array
    layer_id: jax.Array | None = None
    rows_in: int = 0
    bc: int = 0
    levels: int = 0
    cfg: Any = None
    name: str = ""
    uid: int | None = None

    @property
    def n_tiles(self) -> int:
        return self.g_pos.shape[-4]

    @property
    def n_slices(self) -> int:
        return self.g_pos.shape[-3]

    @property
    def tile_rows(self) -> int:
        return self.g_pos.shape[-2]

    @property
    def n_outputs(self) -> int:
        return self.g_pos.shape[-1]

    @property
    def stacked_layers(self) -> int:
        """Leading per-layer stack size (1 for a plain 2-D leaf)."""
        return self.g_pos.shape[0] if self.g_pos.ndim == 5 else 1


def _flatten(w: CIMWeight):
    return (
        (w.g_pos, w.g_neg, w.scale, w.key, w.layer_id),
        (w.rows_in, w.bc, w.levels, w.cfg, w.name, w.uid),
    )


def _unflatten(aux, children) -> CIMWeight:
    return CIMWeight(*children, *aux)


jax.tree_util.register_pytree_node(CIMWeight, _flatten, _unflatten)


def slice_planes(
    columns: jax.Array, layout: PackedLayout
) -> tuple[jax.Array, jax.Array]:
    """Packed verify columns (C, N) -> signed slice planes (S, K, M).

    The exact inverse view of `quant.pack.pack_columns` with polarity and
    slice axes kept separate (where `unpack_columns` recombines them):
    programming error on any cell lands on the same (slice, row, output)
    coordinate the inference VMM reads.  Pack padding rows are dropped.
    """
    kp, n = layout.k_padded, layout.n_cells
    cells = columns.reshape(kp // n, layout.m_out, 2, layout.slices, n)
    cells = jnp.moveaxis(cells, -1, 1).reshape(kp, layout.m_out, 2, layout.slices)
    planes = jnp.transpose(cells, (3, 0, 1, 2))  # (S, Kp, M, 2)
    planes = planes[:, : layout.k_in]
    return planes[..., 0], planes[..., 1]


def tile_planes(
    g_pos: jax.Array,
    g_neg: jax.Array,
    macro_rows: int,
    n_layers: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Row-partition slice planes (S, K, M) into <=`macro_rows` macro tiles.

    Returns ([L,] T, S, R, M) pairs.  With `n_layers` the K axis is first
    split into L per-layer row groups of d = K/L rows (the scanned-stack
    convention: layer idx owns rows [idx*d, (idx+1)*d)), each tiled
    independently so a sliced layer is a self-contained macro set.
    """
    s, k, m = g_pos.shape

    def _tile(gp, gn, rows):
        r = min(macro_rows, rows)
        n_t = -(-rows // r)
        pad = n_t * r - rows
        if pad:
            gp = jnp.pad(gp, ((0, 0), (0, pad), (0, 0)))
            gn = jnp.pad(gn, ((0, 0), (0, pad), (0, 0)))
        gp = gp.reshape(s, n_t, r, m)
        gn = gn.reshape(s, n_t, r, m)
        return jnp.moveaxis(gp, 1, 0), jnp.moveaxis(gn, 1, 0)  # (T, S, R, M)

    if n_layers is None:
        return _tile(g_pos, g_neg, k)
    if k % n_layers:
        raise ValueError(
            f"stacked tiling needs K divisible by the layer stack: "
            f"{k} rows over {n_layers} layers"
        )
    d = k // n_layers
    r = min(macro_rows, d)
    n_t = -(-d // r)
    pad = n_t * r - d

    def _tile_stacked(g):
        g = g.reshape(s, n_layers, d, m)
        if pad:
            g = jnp.pad(g, ((0, 0), (0, 0), (0, pad), (0, 0)))
        g = g.reshape(s, n_layers, n_t, r, m)
        return jnp.transpose(g, (1, 2, 0, 3, 4))  # (L, T, S, R, M)

    return _tile_stacked(g_pos), _tile_stacked(g_neg)


def broadcast_key(key: jax.Array, n_layers: int | None) -> jax.Array:
    """View one key per stacked layer (no fold — the layer sub-stream
    comes from the `layer_id` child folding in-jit).  `None` = 2-D leaf:
    the key passes through untouched."""
    if n_layers is None:
        return key
    return jnp.broadcast_to(key, (n_layers, *key.shape))


def build_weight(
    state,            # core.programmer.ArrayState (duck-typed: no import cycle)
    cfg: Any,
    key: jax.Array,
    name: str = "",
    uid: int | None = None,
) -> CIMWeight:
    """Re-view one programmed `ArrayState` as inference macro tiles.

    3-D leaves (L, d, M) — scanned layer stacks — get a leading L axis on
    every child: per-layer tiles, broadcast scale, the key broadcast per
    layer, and a `layer_id` arange whose sliced scalar folds the layer
    sub-stream in-jit (``fold_in(key, layer)`` — the same stream the old
    eager per-layer fold produced).  Other shapes tile the flattened
    (K, M) view directly.  The tiles alias the live `g`: rebuilding
    after lifetime drift re-views the aged conductances.  `uid` is the
    executor's per-leaf noise sub-stream id, also folded in-jit.

    Spare-column remap (DESIGN.md Sec. 15): a state carrying a
    `RemapTable` holds PHYSICAL (C + S) rows; served traffic must see
    the repaired logical geometry, so the perm gather is applied before
    the slice re-view (getattr: golden/duck-typed states predate the
    field).
    """
    layout: PackedLayout = state.layout
    g = state.g
    remap = getattr(state, "remap", None)
    if remap is not None:
        g = g[remap.perm]
    g_pos, g_neg = slice_planes(g, layout)
    stacked = len(state.shape) == 3
    if stacked:
        n_layers = int(state.shape[0])
        if g_pos.shape[1] % n_layers:
            raise ValueError(
                f"leaf {name!r}: {g_pos.shape[1]} packed input rows do not "
                f"split over a {n_layers}-layer stack (state shape "
                f"{tuple(state.shape)})"
            )
        g_pos, g_neg = tile_planes(g_pos, g_neg, cfg.macro_rows, n_layers)
        scale = jnp.broadcast_to(
            state.scale.reshape(1, -1).astype(jnp.float32),
            (n_layers, layout.m_out),
        )
        keys = broadcast_key(key, n_layers)
        layer_id = jnp.arange(n_layers, dtype=jnp.int32)
        rows_in = int(state.shape[1])
    else:
        g_pos, g_neg = tile_planes(g_pos, g_neg, cfg.macro_rows)
        scale = state.scale.reshape(-1).astype(jnp.float32)
        keys = key
        layer_id = None
        rows_in = layout.k_in
    return CIMWeight(
        g_pos=g_pos, g_neg=g_neg, scale=scale, key=keys, layer_id=layer_id,
        rows_in=rows_in, bc=layout.bc, levels=1 << layout.bc, cfg=cfg,
        name=name, uid=uid,
    )


def rekey(w: CIMWeight, key: jax.Array) -> CIMWeight:
    """Swap the read-noise key — one broadcast, no per-layer fold (the
    layer sub-stream folds in-jit from `layer_id`), so the executor's
    per-access rekey of every leaf is O(1) tiny host dispatches."""
    n_layers = w.g_pos.shape[0] if w.g_pos.ndim == 5 else None
    return dataclasses.replace(w, key=broadcast_key(key, n_layers))
