"""Crossbar macro tiling: packed WV columns -> inference operand planes.

The WV engine programs *verify columns* — (C, N) rows of N cells sharing
one TIA/ADC (quant/pack layout).  Inference reads the same physical
cells along the orthogonal axis: a vector-matrix multiply drives the
array's K input rows and senses all signed column pairs in parallel.
This module re-views the programmed `ArrayState` conductances in the
inference layout without copying semantics:

    packed columns (C, N)
      -> per-slice signed planes  g_pos/g_neg : (S, K, M)   (slice_planes)
      -> macro tiles of <= `macro_rows` rows : (T, S, R, M) (tile_planes)

Pack padding rows (K..K_padded) are dropped exactly as `materialize()`
drops them; tile padding rows are zero conductance AND driven with zero
input, so they contribute nothing to any partial sum.

For stacked per-layer leaves (L, d, M) — the transformer's scanned layer
stacks — every tiled array carries a leading L axis on every *child*
array (tiles, scale, noise key), so the model's existing parameter
plumbing (``tree.map(lambda a: a[idx], layers)``, `lax.scan` over
stacked params) slices a `CIMWeight` exactly like it slices a dense
leaf.  That is what lets the analog forward drop into `models.layers`
without touching the scan bodies.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import rng
from repro.quant.pack import PackedLayout

__all__ = ["CIMWeight", "slice_planes", "tile_planes", "build_weight", "rekey"]


@dataclasses.dataclass
class CIMWeight:
    """One weight leaf living on crossbar macro tiles (a pytree node).

    Children (sliced together by scan/tree.map — all lead with L for
    stacked leaves):
      g_pos/g_neg : ([L,] T, S, R, M) per-tile signed conductance planes
      scale       : ([L,] M) per-output-channel dequantization scale
      key         : ([L,] 2) per-access read-noise key (executor re-folds
                    it every access; see mvm.py RNG policy)
    Static aux:
      rows_in : real input rows per layer (pre tile padding)
      bc      : bits per cell (slice recombination weight base)
      levels  : cell levels (ADC full-scale in LSB units)
      cfg     : CIMConfig (opaque here; consumed by mvm.cim_matmul)
      name    : leaf name (diagnostics)
    """

    g_pos: jax.Array
    g_neg: jax.Array
    scale: jax.Array
    key: jax.Array
    rows_in: int
    bc: int
    levels: int
    cfg: Any
    name: str = ""

    @property
    def n_tiles(self) -> int:
        return self.g_pos.shape[-4]

    @property
    def n_slices(self) -> int:
        return self.g_pos.shape[-3]

    @property
    def tile_rows(self) -> int:
        return self.g_pos.shape[-2]

    @property
    def n_outputs(self) -> int:
        return self.g_pos.shape[-1]

    @property
    def stacked_layers(self) -> int:
        """Leading per-layer stack size (1 for a plain 2-D leaf)."""
        return self.g_pos.shape[0] if self.g_pos.ndim == 5 else 1


def _flatten(w: CIMWeight):
    return (
        (w.g_pos, w.g_neg, w.scale, w.key),
        (w.rows_in, w.bc, w.levels, w.cfg, w.name),
    )


def _unflatten(aux, children) -> CIMWeight:
    return CIMWeight(*children, *aux)


jax.tree_util.register_pytree_node(CIMWeight, _flatten, _unflatten)


def slice_planes(
    columns: jax.Array, layout: PackedLayout
) -> tuple[jax.Array, jax.Array]:
    """Packed verify columns (C, N) -> signed slice planes (S, K, M).

    The exact inverse view of `quant.pack.pack_columns` with polarity and
    slice axes kept separate (where `unpack_columns` recombines them):
    programming error on any cell lands on the same (slice, row, output)
    coordinate the inference VMM reads.  Pack padding rows are dropped.
    """
    kp, n = layout.k_padded, layout.n_cells
    cells = columns.reshape(kp // n, layout.m_out, 2, layout.slices, n)
    cells = jnp.moveaxis(cells, -1, 1).reshape(kp, layout.m_out, 2, layout.slices)
    planes = jnp.transpose(cells, (3, 0, 1, 2))  # (S, Kp, M, 2)
    planes = planes[:, : layout.k_in]
    return planes[..., 0], planes[..., 1]


def tile_planes(
    g_pos: jax.Array,
    g_neg: jax.Array,
    macro_rows: int,
    n_layers: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Row-partition slice planes (S, K, M) into <=`macro_rows` macro tiles.

    Returns ([L,] T, S, R, M) pairs.  With `n_layers` the K axis is first
    split into L per-layer row groups of d = K/L rows (the scanned-stack
    convention: layer idx owns rows [idx*d, (idx+1)*d)), each tiled
    independently so a sliced layer is a self-contained macro set.
    """
    s, k, m = g_pos.shape

    def _tile(gp, gn, rows):
        r = min(macro_rows, rows)
        n_t = -(-rows // r)
        pad = n_t * r - rows
        if pad:
            gp = jnp.pad(gp, ((0, 0), (0, pad), (0, 0)))
            gn = jnp.pad(gn, ((0, 0), (0, pad), (0, 0)))
        gp = gp.reshape(s, n_t, r, m)
        gn = gn.reshape(s, n_t, r, m)
        return jnp.moveaxis(gp, 1, 0), jnp.moveaxis(gn, 1, 0)  # (T, S, R, M)

    if n_layers is None:
        return _tile(g_pos, g_neg, k)
    assert k % n_layers == 0, (k, n_layers)
    d = k // n_layers
    r = min(macro_rows, d)
    n_t = -(-d // r)
    pad = n_t * r - d

    def _tile_stacked(g):
        g = g.reshape(s, n_layers, d, m)
        if pad:
            g = jnp.pad(g, ((0, 0), (0, 0), (0, pad), (0, 0)))
        g = g.reshape(s, n_layers, n_t, r, m)
        return jnp.transpose(g, (1, 2, 0, 3, 4))  # (L, T, S, R, M)

    return _tile_stacked(g_pos), _tile_stacked(g_neg)


def build_weight(
    state,            # core.programmer.ArrayState (duck-typed: no import cycle)
    cfg: Any,
    key: jax.Array,
    name: str = "",
) -> CIMWeight:
    """Re-view one programmed `ArrayState` as inference macro tiles.

    3-D leaves (L, d, M) — scanned layer stacks — get a leading L axis on
    every child (per-layer tiles, broadcast scale, per-layer noise keys
    ``fold_in(key, layer)``); other shapes tile the flattened (K, M) view
    directly.  The tiles alias the live `g`: rebuilding after lifetime
    drift re-views the aged conductances.

    Spare-column remap (DESIGN.md Sec. 15): a state carrying a
    `RemapTable` holds PHYSICAL (C + S) rows; served traffic must see
    the repaired logical geometry, so the perm gather is applied before
    the slice re-view (getattr: golden/duck-typed states predate the
    field).
    """
    layout: PackedLayout = state.layout
    g = state.g
    remap = getattr(state, "remap", None)
    if remap is not None:
        g = g[remap.perm]
    g_pos, g_neg = slice_planes(g, layout)
    stacked = len(state.shape) == 3
    if stacked:
        n_layers = int(state.shape[0])
        g_pos, g_neg = tile_planes(g_pos, g_neg, cfg.macro_rows, n_layers)
        scale = jnp.broadcast_to(
            state.scale.reshape(1, -1).astype(jnp.float32),
            (n_layers, layout.m_out),
        )
        keys = rng.fold_col_keys(key, jnp.arange(n_layers, dtype=jnp.int32))
        rows_in = int(state.shape[1])
    else:
        g_pos, g_neg = tile_planes(g_pos, g_neg, cfg.macro_rows)
        scale = state.scale.reshape(-1).astype(jnp.float32)
        keys = key
        rows_in = layout.k_in
    return CIMWeight(
        g_pos=g_pos, g_neg=g_neg, scale=scale, key=keys,
        rows_in=rows_in, bc=layout.bc, levels=1 << layout.bc, cfg=cfg,
        name=name,
    )


def rekey(w: CIMWeight, key: jax.Array) -> CIMWeight:
    """Swap the read-noise key (per-access re-fold; cheap, host-side)."""
    if w.g_pos.ndim == 5:  # stacked: one sub-stream per layer
        keys = rng.fold_col_keys(
            key, jnp.arange(w.g_pos.shape[0], dtype=jnp.int32)
        )
    else:
        keys = key
    return dataclasses.replace(w, key=keys)
