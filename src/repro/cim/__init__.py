# Analog compute-in-memory serving: inference computed *in* the
# programmed arrays (DESIGN.md Sec. 11) — macro tiling of live
# ArrayState conductances, the noisy bit-serial DAC -> VMM -> ADC
# forward, and the executor that swaps it into the serving engine.
from .tile import CIMWeight, build_weight, slice_planes, tile_planes  # noqa: F401
from .mvm import (  # noqa: F401
    CIMConfig,
    cim_matmul,
    cim_vmm,
    current_token_ids,
    planes_per_token,
    token_stream_ids,
)
from .executor import CIMExecutor, analog_eligible  # noqa: F401
