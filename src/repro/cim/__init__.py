# Analog compute-in-memory serving: inference computed *in* the
# programmed arrays (DESIGN.md Sec. 11) — macro tiling of live
# ArrayState conductances, the noisy bit-serial DAC -> VMM -> ADC
# forward, and the executor that swaps it into the serving engine.
from .tile import CIMWeight, build_weight, slice_planes, tile_planes  # noqa: F401
from .mvm import CIMConfig, cim_matmul, cim_vmm, planes_per_token  # noqa: F401
from .executor import CIMExecutor, analog_eligible  # noqa: F401
