"""Noisy analog matrix-vector multiply through programmed macro tiles.

The inference datapath of the paper's CBA macro (Fig. 2 / 6(b)), end to
end in cell-LSB units:

1. **Input DAC, bit-serial.**  Activations are scaled per token to a
   signed `dac_bits` code and streamed as binary row-drive planes —
   one plane per magnitude bit and polarity (positive and negative
   magnitudes drive separate phases; their ADC results subtract
   digitally).  ``dac_bits=None`` models an ideal analog driver: the
   raw activation drives the rows in a single plane.
2. **Analog column sums + per-slice ADC.**  Every plane multiplies into
   each tile's signed conductance pair per slice; per-read TIA/ADC
   thermal noise lands on the analog partial sum; the shared `cim_vmm`
   entry (`kernels/acim_vmm`, `use_pallas`-gated with a bit-identical
   unfused reference) applies the fused clamp+quantize ADC epilogue and
   the 2^(Bc*l) shift-and-add slice recombination.
3. **Digital recombination.**  Plane outputs recombine with their
   bit weights and the per-token DAC scale, tiles sum over the row
   partition, and the per-output-channel quantization scale dequantizes
   to model units.

Read-noise RNG policy (DESIGN.md Sec. 11): every read draws from

    fold_in(leaf_key, tile) -> fold_in(., plane) -> fold_in(., token)

where `leaf_key` is the executor's per-access key (re-folded every
engine step) and `token` is the flattened batch index of the call.  A
token's noise therefore depends only on (access key, tile, plane,
token index) — NOT on how many other tokens share the batch — so a
batched forward is bit-reproducible across batch shapes.  The sampler
itself (`readout.noise.sample_token_read_noise`) and the per-slice ADC
quantizer (`readout.converter.sar_quantize`, reached through the
`cim_vmm` epilogue) are the SAME models the WV verify path reads
through — one readout subsystem, DESIGN.md Sec. 12.

In the ideal limit (``dac_bits=None``, ``adc_bits=None``,
``sigma_read_lsb=0``) the whole pipeline collapses algebraically to
``x @ materialize(w)`` computed in f32 (reassociation-level error only)
— the materialize-vs-analog equivalence contract.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import rng
from repro.kernels.acim_vmm import ops as vmm_ops
from repro.readout import noise as ro_noise

from .tile import CIMWeight

__all__ = ["CIMConfig", "cim_vmm", "cim_matmul", "planes_per_token"]


@dataclasses.dataclass(frozen=True)
class CIMConfig:
    """Analog inference configuration (static under jit).

    `None` for dac_bits/adc_bits selects the ideal converter on that
    side — the knobs the equivalence contract turns to infinity.
    """

    macro_rows: int = 128            # max rows per crossbar macro tile
    dac_bits: int | None = 6         # input DAC resolution; None = ideal analog
    adc_bits: int | None = 10        # per-slice column ADC; None = ideal
    full_scale_frac: float = 1.0     # ADC range as fraction of +-R*(2^Bc-1)
    sigma_read_lsb: float = 0.0      # per-read TIA/ADC noise std (cell-LSB)
    use_pallas: bool = False         # fused Pallas kernel (interpret off-TPU)

    def __post_init__(self):
        # dac_bits counts sign + magnitude: >= 2 leaves >= 1 magnitude
        # bit; 1 would stream zero planes.
        assert self.dac_bits is None or self.dac_bits >= 2, self.dac_bits
        assert self.adc_bits is None or self.adc_bits >= 1, self.adc_bits
        assert self.macro_rows >= 1, self.macro_rows

    def replace(self, **kw) -> "CIMConfig":
        return dataclasses.replace(self, **kw)


def planes_per_token(cfg: CIMConfig) -> int:
    """Row-drive planes (= reads of every physical column) per token."""
    if cfg.dac_bits is None:
        return 1
    return 2 * (cfg.dac_bits - 1)  # magnitude bits x {pos, neg} phases


def cim_vmm(
    x: jax.Array,
    g_pos: jax.Array,
    g_neg: jax.Array,
    *,
    bc: int,
    adc_bits: int | None,
    full_scale: float,
    noise: jax.Array | None = None,
    use_pallas: bool = False,
) -> jax.Array:
    """One macro-tile readout: the shared serving/benchmark entry point.

    (B, R) row drives x (S, R, M) signed slice pairs -> (B, M) f32, with
    pre-ADC `noise` (S, B, M) and the fused ADC epilogue.  Dispatches to
    the Pallas kernel (interpret mode off-TPU) or the bit-identical
    unfused reference.
    """
    return vmm_ops.acim_vmm(
        x, g_pos, g_neg, bc=bc, adc_bits=adc_bits, full_scale=full_scale,
        noise=noise, use_pallas=use_pallas,
    )


def _dac_stream(xf: jax.Array, cfg: CIMConfig) -> tuple[jax.Array, jax.Array]:
    """(T, K) f32 activations -> (P, T, K) row-drive planes, (P, T) weights.

    Ideal driver: one plane, unit weight.  Bit-serial: per-token absmax
    scaling to a signed `dac_bits` code, positive and negative magnitudes
    split into binary planes LSB-first; plane p recombines with weight
    +-2^bit * token_scale.
    """
    if cfg.dac_bits is None:
        return xf[None], jnp.ones((1, xf.shape[0]), jnp.float32)
    n_mag = cfg.dac_bits - 1
    q_max = float((1 << n_mag) - 1)
    s_tok = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / q_max
    s_tok = jnp.maximum(s_tok, 1e-12)
    q = jnp.clip(jnp.round(xf / s_tok), -q_max, q_max).astype(jnp.int32)
    pos, neg = jnp.maximum(q, 0), jnp.maximum(-q, 0)
    planes, weights = [], []
    for sign, mag in ((1.0, pos), (-1.0, neg)):
        for b in range(n_mag):
            planes.append(((mag >> b) & 1).astype(jnp.float32))
            weights.append(sign * float(1 << b) * s_tok[:, 0])
    return jnp.stack(planes), jnp.stack(weights)


def cim_matmul(x: jax.Array, w: CIMWeight) -> jax.Array:
    """Analog forward for one weight leaf: x (..., K) -> (..., M).

    Drop-in for `models.layers.matmul` (f32 accumulation, result cast to
    x.dtype) computing through the live conductance tiles instead of a
    materialized dense weight.
    """
    cfg: CIMConfig = w.cfg
    assert w.g_pos.ndim == 4, (
        "stacked CIMWeight must be layer-sliced before matmul"
    )
    lead, k = x.shape[:-1], x.shape[-1]
    assert k == w.rows_in, (k, w.rows_in, w.name)
    xf = x.reshape(-1, k).astype(jnp.float32)
    t = xf.shape[0]

    planes, weights = _dac_stream(xf, cfg)        # (P, T, K), (P, T)
    p = planes.shape[0]
    n_tiles, s, r, m = w.g_pos.shape
    pad = n_tiles * r - k
    if pad:
        planes = jnp.pad(planes, ((0, 0), (0, 0), (0, pad)))
    xp = planes.reshape(p * t, n_tiles * r)
    full_scale = cfg.full_scale_frac * 2.0 * r * float(w.levels - 1)

    acc = jnp.zeros((p * t, m), jnp.float32)
    for ti in range(n_tiles):
        noise = None
        if cfg.sigma_read_lsb > 0.0:
            k_tile = rng.fold_in(w.key, ti)
            noise = jnp.concatenate(
                [
                    ro_noise.sample_token_read_noise(
                        rng.fold_in(k_tile, pi), t, s, m, cfg.sigma_read_lsb
                    )
                    for pi in range(p)
                ],
                axis=1,
            )  # (S, P*T, M)
        acc = acc + cim_vmm(
            xp[:, ti * r : (ti + 1) * r], w.g_pos[ti], w.g_neg[ti],
            bc=w.bc, adc_bits=cfg.adc_bits, full_scale=full_scale,
            noise=noise, use_pallas=cfg.use_pallas,
        )

    y = jnp.einsum("pt,ptm->tm", weights, acc.reshape(p, t, m))
    y = y * w.scale[None, :]
    return y.reshape(*lead, m).astype(x.dtype)
