"""Noisy analog matrix-vector multiply through programmed macro tiles.

The inference datapath of the paper's CBA macro (Fig. 2 / 6(b)), end to
end in cell-LSB units:

1. **Input DAC, bit-serial.**  Activations are scaled per token to a
   signed `dac_bits` code and streamed as binary row-drive planes —
   one plane per magnitude bit and polarity (positive and negative
   magnitudes drive separate phases; their ADC results subtract
   digitally).  ``dac_bits=None`` models an ideal analog driver: the
   raw activation drives the rows in a single plane.  The plane stack
   is built as one vectorized bit-extraction (no Python list append).
2. **Analog column sums + per-slice ADC, every tile at once.**  All
   planes multiply into EVERY macro tile's signed conductance pair in a
   single fused dispatch (`kernels/acim_vmm.acim_vmm_tiled`,
   `use_pallas`-gated with a bit-identical scanned reference): per-read
   TIA/ADC thermal noise lands on the analog partial sums, the fused
   clamp+quantize ADC epilogue and 2^(Bc*l) slice recombination run per
   tile, and tiles sum over the row partition — all inside the one
   kernel.  Noise for the whole (tile, plane, token) lattice is drawn
   by ONE batched `sample_token_read_noise` call.
3. **Digital recombination.**  Plane outputs recombine with their
   bit weights and the per-token DAC scale, and the per-output-channel
   quantization scale dequantizes to model units.

Read-noise RNG policy (DESIGN.md Sec. 17): every read draws from

    leaf key -> [uid] -> [layer] -> tile -> plane -> token_id

where the leaf `key` child is the executor's per-access key (swapped
every engine step), `uid`/`layer_id` ride the `CIMWeight` itself and
fold IN-JIT (so the executor's per-access rekey is one fold + a
broadcast, not a per-leaf vmap), and `token_id` defaults to the
flattened batch index but is overridden with the REQUEST id by the
serving scheduler (`token_stream_ids`).  A token's noise therefore
depends only on (access key, uid, layer, tile, plane, token id) — NOT
on which slot it occupies or how many other tokens share the batch —
so the analog forward is batch-composition-invariant.  The sampler
(`readout.noise.sample_token_read_noise`) and the per-slice ADC
quantizer (`readout.converter.sar_quantize`, reached through the
kernel epilogue) are the SAME models the WV verify path reads through
— one readout subsystem, DESIGN.md Sec. 12.

In the ideal limit (``dac_bits=None``, ``adc_bits=None``,
``sigma_read_lsb=0``) the whole pipeline collapses algebraically to
``x @ materialize(w)`` computed in f32 (reassociation-level error only)
— the materialize-vs-analog equivalence contract.
"""

from __future__ import annotations

import contextlib
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import rng
from repro.kernels.acim_vmm import ops as vmm_ops
from repro.readout import noise as ro_noise

from .tile import CIMWeight

__all__ = [
    "CIMConfig",
    "cim_vmm",
    "cim_matmul",
    "planes_per_token",
    "token_stream_ids",
    "current_token_ids",
]


@dataclasses.dataclass(frozen=True)
class CIMConfig:
    """Analog inference configuration (static under jit).

    `None` for dac_bits/adc_bits selects the ideal converter on that
    side — the knobs the equivalence contract turns to infinity.
    """

    macro_rows: int = 128            # max rows per crossbar macro tile
    dac_bits: int | None = 6         # input DAC resolution; None = ideal analog
    adc_bits: int | None = 10        # per-slice column ADC; None = ideal
    full_scale_frac: float = 1.0     # ADC range as fraction of +-R*(2^Bc-1)
    sigma_read_lsb: float = 0.0      # per-read TIA/ADC noise std (cell-LSB)
    use_pallas: bool = False         # fused Pallas kernel (interpret off-TPU)

    def __post_init__(self):
        # dac_bits counts sign + magnitude: >= 2 leaves >= 1 magnitude
        # bit; 1 would stream zero planes.
        if self.dac_bits is not None and self.dac_bits < 2:
            raise ValueError(f"dac_bits must be >= 2 or None: {self.dac_bits}")
        if self.adc_bits is not None and self.adc_bits < 1:
            raise ValueError(f"adc_bits must be >= 1 or None: {self.adc_bits}")
        if self.macro_rows < 1:
            raise ValueError(f"macro_rows must be >= 1: {self.macro_rows}")

    def replace(self, **kw) -> "CIMConfig":
        return dataclasses.replace(self, **kw)


def planes_per_token(cfg: CIMConfig) -> int:
    """Row-drive planes (= reads of every physical column) per token."""
    if cfg.dac_bits is None:
        return 1
    return 2 * (cfg.dac_bits - 1)  # magnitude bits x {pos, neg} phases


# --------------------------------------------------------------- token ids
# Ambient per-row token-id stream for the CIM noise sub-streams.  The
# serving scheduler wraps its jitted decode body in `token_stream_ids(
# rids)` so every analog leaf folds the REQUEST id (a traced argument of
# the compiled step — no retrace) instead of the flattened batch slot.
# Entered at trace time; the captured array is a tracer of the enclosing
# jit, which is exactly what makes the compiled step slot-invariant.
_TOKEN_IDS: list = []


@contextlib.contextmanager
def token_stream_ids(ids: jax.Array):
    """Route `ids` ((T,) int32) into every `cim_matmul` in the block."""
    _TOKEN_IDS.append(ids)
    try:
        yield
    finally:
        _TOKEN_IDS.pop()


def current_token_ids() -> jax.Array | None:
    """The ambient token-id stream, or None (= flattened batch index)."""
    return _TOKEN_IDS[-1] if _TOKEN_IDS else None


def cim_vmm(
    x: jax.Array,
    g_pos: jax.Array,
    g_neg: jax.Array,
    *,
    bc: int,
    adc_bits: int | None,
    full_scale: float,
    noise: jax.Array | None = None,
    use_pallas: bool = False,
) -> jax.Array:
    """One macro-tile readout: the shared serving/benchmark entry point.

    (B, R) row drives x (S, R, M) signed slice pairs -> (B, M) f32, with
    pre-ADC `noise` (S, B, M) and the fused ADC epilogue.  Dispatches to
    the Pallas kernel (interpret mode off-TPU) or the bit-identical
    unfused reference.
    """
    return vmm_ops.acim_vmm(
        x, g_pos, g_neg, bc=bc, adc_bits=adc_bits, full_scale=full_scale,
        noise=noise, use_pallas=use_pallas,
    )


def _dac_stream(xf: jax.Array, cfg: CIMConfig) -> tuple[jax.Array, jax.Array]:
    """(T, K) f32 activations -> (P, T, K) row-drive planes, (P, T) weights.

    Ideal driver: one plane, unit weight.  Bit-serial: per-token absmax
    scaling to a signed `dac_bits` code, positive and negative magnitudes
    split into binary planes LSB-first; plane p recombines with weight
    +-2^bit * token_scale.  The whole plane stack is one broadcast bit
    extraction — plane order [pos b0..b_{n-1}, neg b0..b_{n-1}], the same
    stream order the per-plane loop produced.
    """
    if cfg.dac_bits is None:
        return xf[None], jnp.ones((1, xf.shape[0]), jnp.float32)
    n_mag = cfg.dac_bits - 1
    q_max = float((1 << n_mag) - 1)
    s_tok = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / q_max
    s_tok = jnp.maximum(s_tok, 1e-12)
    q = jnp.clip(jnp.round(xf / s_tok), -q_max, q_max).astype(jnp.int32)
    mag = jnp.stack([jnp.maximum(q, 0), jnp.maximum(-q, 0)])   # (2, T, K)
    bits = jnp.arange(n_mag, dtype=jnp.int32)
    planes = ((mag[:, None] >> bits[None, :, None, None]) & 1).astype(
        jnp.float32
    )                                                          # (2, n_mag, T, K)
    signs = jnp.array([1.0, -1.0], jnp.float32)
    bit_w = signs[:, None] * (2.0 ** bits.astype(jnp.float32))[None, :]
    weights = bit_w.reshape(-1)[:, None] * s_tok[:, 0][None, :]  # (P, T)
    t, k = xf.shape
    return planes.reshape(2 * n_mag, t, k), weights


def cim_matmul(
    x: jax.Array, w: CIMWeight, *, token_ids: jax.Array | None = None
) -> jax.Array:
    """Analog forward for one weight leaf: x (..., K) -> (..., M).

    Drop-in for `models.layers.matmul` (f32 accumulation, result cast to
    x.dtype) computing through the live conductance tiles instead of a
    materialized dense weight — ONE fused kernel dispatch and (when
    noisy) ONE batched noise draw for the whole leaf.  `token_ids`
    overrides the per-row noise sub-stream ids (default: ambient
    `token_stream_ids` context, else the flattened batch index).
    """
    cfg: CIMConfig = w.cfg
    if w.g_pos.ndim != 4:
        raise ValueError(
            f"CIMWeight {w.name!r}: tile planes must be layer-sliced 4-D "
            f"(T, S, R, M) at matmul time, got shape {w.g_pos.shape} — "
            "slice stacked leaves (tree.map / lax.scan) before the forward"
        )
    lead, k = x.shape[:-1], x.shape[-1]
    if k != w.rows_in:
        raise ValueError(
            f"CIMWeight {w.name!r}: input features {k} do not match the "
            f"leaf's {w.rows_in} input rows (tile geometry "
            f"{w.g_pos.shape} = (tiles, slices, rows, outputs))"
        )
    xf = x.reshape(-1, k).astype(jnp.float32)
    t = xf.shape[0]
    if token_ids is None:
        token_ids = current_token_ids()
    if token_ids is not None and token_ids.shape != (t,):
        raise ValueError(
            f"CIMWeight {w.name!r}: token_ids shape {token_ids.shape} does "
            f"not match the {t} flattened input rows"
        )

    planes, weights = _dac_stream(xf, cfg)        # (P, T, K), (P, T)
    p = planes.shape[0]
    n_tiles, s, r, m = w.g_pos.shape
    pad = n_tiles * r - k
    if pad:
        planes = jnp.pad(planes, ((0, 0), (0, 0), (0, pad)))
    xp = planes.reshape(p * t, n_tiles * r)
    full_scale = cfg.full_scale_frac * 2.0 * r * float(w.levels - 1)

    noise = None
    if cfg.sigma_read_lsb > 0.0:
        key = w.key
        if w.uid is not None:
            key = rng.fold_in(key, w.uid)
        if w.layer_id is not None:
            key = rng.fold_in(key, w.layer_id)
        noise = ro_noise.sample_token_read_noise(
            key, t, s, m, cfg.sigma_read_lsb,
            token_ids=token_ids, tiles=n_tiles, planes=p,
        )  # (T_tiles, S, P*T, M)
    acc = vmm_ops.acim_vmm_tiled(
        xp, w.g_pos, w.g_neg, bc=w.bc, adc_bits=cfg.adc_bits,
        full_scale=full_scale, noise=noise, use_pallas=cfg.use_pallas,
    )

    y = jnp.einsum("pt,ptm->tm", weights, acc.reshape(p, t, m))
    y = y * w.scale[None, :]
    return y.reshape(*lead, m).astype(x.dtype)
