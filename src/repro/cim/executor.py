"""CIMExecutor: serve a `DeployedModel` straight off its live arrays.

The executor closes the loop the materialize() serving path leaves
open: instead of collapsing programmed conductances to dense digital
weights, it re-views every matmul-consumed RRAM leaf as crossbar macro
tiles (`tile.build_weight`) and hands the serving engine a parameter
pytree whose deployed leaves are `CIMWeight` nodes — `models.layers.
matmul` dispatches those through the noisy analog forward
(`mvm.cim_matmul`); everything else (norms, embeddings, leaves consumed
outside `matmul` such as MoE experts or cross-attention stacks) falls
back to the digital materialize() path transparently.

State-ownership: the `DeployedModel` still owns the conductances.  The
executor only *views* them — when the lifetime subsystem ages or
refreshes an array (``update_array`` swaps in a new `g`), the next
`params()` call notices the new array object and re-tiles it, so served
logits always read the live analog state.

Accounting: every served token drives `planes_per_token` read phases
through every analog macro, i.e. each physical verify column is read
`planes` times per token.  The executor accumulates per-array read
counts (`drain_reads` feeds them to `LifetimeSimulator` as real
read-disturb traffic) and per-token latency/energy through the
cost model's inference phase (`core.cost.inference_token_cost`).
"""

from __future__ import annotations

from typing import Any, Callable

import jax

from repro import obs
from repro.core.cost import inference_token_cost
from repro.core.programmer import DeployedModel

import dataclasses

from .mvm import CIMConfig, cim_matmul, planes_per_token
from .tile import CIMWeight, broadcast_key, build_weight

__all__ = ["CIMExecutor", "analog_eligible"]

# Leaves consumed by `models.layers.matmul` under the scanned-stack
# slicing convention.  Everything else deployed on RRAM (MoE experts,
# cross-attention projections, multi-codebook heads) is served through
# the digital materialize() fallback until it gets an analog mapping.
_LAYER_MATMUL_KEYS = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")


def analog_eligible(name: str, state) -> bool:
    """Default policy: which deployed leaves run through analog tiles.

    * stacked transformer projections ``['layers']['wq']`` etc. —
      3-D (L, d, M) leaves sliced per layer by the decode/prefill scans;
    * the 2-D LM head (untied embeddings).
    """
    if name == "['lm_head']":
        return len(state.shape) == 2
    return (
        len(state.shape) == 3
        and any(name == f"['layers']['{k}']" for k in _LAYER_MATMUL_KEYS)
    )


class CIMExecutor:
    """Builds and maintains the analog parameter pytree for serving.

    Args:
      deployed: `deploy_arrays` output (owns the live conductances).
      cfg: analog inference configuration.
      key: master read-noise key; every engine access folds a fresh
        sub-stream (`fold_in(key, access)`) swapped into the leaves'
        key child; each leaf's uid and each stacked layer's index fold
        in-jit from the `CIMWeight.uid` / `layer_id` fields.
      predicate: overrides `analog_eligible`.
      mesh: optional device mesh; tile planes shard their output-channel
        axis over "model" (`launch.shardings.cim_weight_specs`) so the
        analog TP layout matches the dense serving layout.
    """

    def __init__(
        self,
        deployed: DeployedModel,
        cfg: CIMConfig | None = None,
        key: jax.Array | None = None,
        predicate: Callable[[str, Any], bool] | None = None,
        mesh: Any = None,
    ):
        self.deployed = deployed
        self.cfg = cfg or CIMConfig()
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.mesh = mesh
        self.access = 0
        self.tokens_served = 0
        predicate = predicate or analog_eligible
        self._analog: dict[str, CIMWeight] = {}
        self._digital: dict[str, jax.Array] = {}
        self._g_seen: dict[str, Any] = {}
        self._uids = {
            name: i for i, name in enumerate(sorted(deployed.arrays))
        }
        self._token_cost: tuple[float, float] | None = None
        self._reads: dict[str, float] = {}
        for name, state in deployed.arrays.items():
            if predicate(name, state):
                self._analog[name] = self._tile(name, state)
                self._reads[name] = 0.0
            else:
                self._digital[name] = state.materialize()
            self._g_seen[name] = state.g

    # ----------------------------------------------------------- tiling
    def _access_key(self) -> jax.Array:
        """fold_in(master, access): ONE eager fold shared by every leaf.

        The per-leaf uid and per-layer sub-streams fold IN-JIT from the
        `CIMWeight.uid` / `layer_id` fields, so the stream chain
        master -> access -> uid -> layer -> tile -> plane -> token_id is
        unchanged while the host-side per-access work collapses from a
        per-leaf vmap fan-out to this single fold plus key broadcasts.
        """
        return jax.random.fold_in(self.key, self.access)

    def _tile(self, name: str, state) -> CIMWeight:
        w = build_weight(
            state, self.cfg, self._access_key(), name=name,
            uid=self._uids[name],
        )
        if self.mesh is not None:
            # Lazy import: launch sits above cim in the layering; the
            # executor only touches it when a mesh is actually supplied.
            from repro.launch.shardings import shard_cim_weight

            w = shard_cim_weight(self.mesh, w)
        return w

    def _refresh_views(self) -> None:
        """Re-view any array whose conductances were swapped (drift/refresh)."""
        for name, state in self.deployed.arrays.items():
            if state.g is self._g_seen[name]:
                continue
            if name in self._analog:
                self._analog[name] = self._tile(name, state)
            else:
                self._digital[name] = state.materialize()
            self._g_seen[name] = state.g

    # ---------------------------------------------------------- serving
    def params(self) -> Any:
        """Current served pytree: CIMWeight analog leaves + digital rest.

        Per-access rekey is one `fold_in` plus at most one broadcast per
        distinct layer-stack size — the leaves' uid/layer sub-streams
        fold inside the jitted forward, so refreshing noise streams for
        a whole model costs a couple of tiny dispatches, not a per-leaf
        vmap fan-out.
        """
        self._refresh_views()
        leaves = list(self.deployed.leaves)
        rekey_live = self.cfg.sigma_read_lsb > 0.0  # keys unread when clean
        if rekey_live:
            ak = self._access_key()
            bcast: dict[int | None, jax.Array] = {}
        for name in self.deployed.arrays:
            slot = self.deployed.slots[name]
            if name in self._analog:
                w = self._analog[name]
                if rekey_live:
                    n_layers = (
                        w.g_pos.shape[0] if w.g_pos.ndim == 5 else None
                    )
                    if n_layers not in bcast:
                        bcast[n_layers] = broadcast_key(ak, n_layers)
                    w = dataclasses.replace(w, key=bcast[n_layers])
                leaves[slot] = w
            else:
                leaves[slot] = self._digital[name]
        return jax.tree_util.tree_unflatten(self.deployed.treedef, leaves)

    def tick(self, n_tokens: int) -> Any:
        """One engine access: fresh noise sub-streams + read accounting.

        Every token reads every analog array's physical columns
        `planes_per_token` times (each DAC plane is one read phase of
        every macro the leaf spans).  Each tick also attributes the
        modeled per-token cost to the `serve.analog` ledger phase —
        pure host floats (the cached `token_cost`), never a sync.
        """
        self.access += 1
        self.tokens_served += n_tokens
        reads = float(n_tokens * self.planes)
        for name in self._reads:
            self._reads[name] += reads
        obs.registry.inc("cim.tokens", n_tokens)
        obs.registry.inc("cim.accesses")
        # Fleet health gauges (obs.health): served tokens and cumulative
        # read-disturb traffic per analog array — pure host floats the
        # tick already tracks, so no extra device work.
        obs.health_registry.set_gauge("cim.tokens_served", float(self.tokens_served))
        obs.health_registry.set_gauge(
            "cim.read_disturb_reads",
            float(self.tokens_served * self.planes * len(self._analog)),
        )
        lat_ns, en_pj = self.token_cost()
        obs.charge(
            "serve.analog",
            tokens=n_tokens,
            energy_pj=en_pj * n_tokens,
            latency_ns=lat_ns * n_tokens,
            reads=reads * len(self._analog),
        )
        return self.params()

    # ------------------------------------------------- traffic / costs
    @property
    def planes(self) -> int:
        return planes_per_token(self.cfg)

    def drain_reads(self) -> dict[str, float]:
        """Per-array column reads since the last drain (lifetime traffic)."""
        out = dict(self._reads)
        self._reads = {name: 0.0 for name in self._reads}
        return out

    def _conversion_counts(self) -> tuple[int, int]:
        """(ADC conversions, DAC row drives) per token per plane."""
        conv = drives = 0
        for w in self._analog.values():
            layers = w.stacked_layers
            conv += layers * w.n_tiles * w.n_slices * w.n_outputs
            drives += layers * w.n_tiles * w.tile_rows
        return conv, drives

    def token_cost(self) -> tuple[float, float]:
        """(latency_ns, energy_pj) per served token, from the cost model.

        Cached after the first call: tile geometry is fixed for the
        executor's lifetime (refresh re-tiles the same shapes), and
        `tick` charges the ledger with it on every engine access.
        """
        if self._token_cost is None:
            conv, drives = self._conversion_counts()
            self._token_cost = inference_token_cost(
                n_conversions=conv,
                n_row_drives=drives,
                planes=self.planes,
                adc=self.deployed.wv_cfg.adc,
                cost=self.deployed.cost,
            )
        return self._token_cost

    def summary(self) -> dict[str, float]:
        lat, en = self.token_cost()
        return dict(
            analog_leaves=len(self._analog),
            digital_fallback_leaves=len(self._digital),
            planes_per_token=self.planes,
            tokens_served=self.tokens_served,
            token_latency_ns=lat,
            token_energy_pj=en,
            total_energy_pj=en * self.tokens_served,
        )
