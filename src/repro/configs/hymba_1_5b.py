"""hymba-1.5b [hybrid] — parallel attn+mamba heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Sliding-window attention (1024) everywhere except 3 global layers
(first / middle / last).  Runs long_500k: global layers keep full caches
(3 x 500k), SWA layers keep 1024-slot ring buffers, SSM state is O(1).
The 25-head axis relies on GSPMD padding on the 16-wide model axis.
"""

import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    block="hymba",
    ssm_state=16,
    sliding_window=1024,
    global_layer_every=16,   # globals at 0, 16, 31 (first/middle/last)
    rope_theta=10000.0,
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, ssm_state=4, sliding_window=8,
    global_layer_every=2, attn_chunk_q=16, attn_chunk_kv=16,
    dtype=jnp.float32, remat=False,
)
