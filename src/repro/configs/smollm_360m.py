"""smollm-360m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M; hf].

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.  The 15-head axis is
not divisible by the 16-wide model mesh axis — GSPMD pads (DESIGN.md Sec. 4).
"""

import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    rope_theta=10000.0,
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=60, n_heads=3, n_kv_heads=1, head_dim=20,
    d_ff=128, vocab_size=256, attn_chunk_q=16, attn_chunk_kv=16,
    dtype=jnp.float32, remat=False,
)
