"""llama-3.2-vision-11b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256; a gated
cross-attention block every 5 decoder layers attends to image patch
embeddings.  The vision tower is a STUB per the task spec: input_specs()
supplies precomputed patch embeddings (B, 1601, d_cond).
"""

import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500000.0,
    cross_attn_every=5,
    cross_kv_len=1601,     # one 448x448 image -> 1601 patch embeddings
    cross_d_cond=4096,     # stub frontend projects to d_model width
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=4, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=128, vocab_size=256, cross_attn_every=2, cross_kv_len=17,
    cross_d_cond=64, attn_chunk_q=16, attn_chunk_kv=16,
    dtype=jnp.float32, remat=False,
)
