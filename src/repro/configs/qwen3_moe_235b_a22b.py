"""qwen3-moe-235b-a22b [moe] — 128 experts, top-8 [hf:Qwen/Qwen3-30B-A3B; hf].

94L d_model=4096 64H (GQA kv=4) per-expert d_ff=1536 vocab=151936, qk-norm.
Optimizer states ride in bf16 so params+grads+m+v fit the single-pod HBM
budget (DESIGN.md Sec. 4).
"""

import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    moe_experts=128,
    moe_top_k=8,
    moe_d_ff=1536,
    qk_norm=True,
    rope_theta=1000000.0,
    tie_embeddings=False,
    opt_state_dtype=jnp.bfloat16,
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    moe_experts=8, moe_top_k=2, moe_d_ff=32, d_ff=32, vocab_size=256,
    attn_chunk_q=16, attn_chunk_kv=16, dtype=jnp.float32,
    opt_state_dtype=jnp.float32, remat=False,
)
