"""llama3.2-1b [dense] — small llama3 [hf:meta-llama/Llama-3.2-1B].

16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.
"""

import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500000.0,
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=128, vocab_size=256, attn_chunk_q=16, attn_chunk_kv=16,
    dtype=jnp.float32, remat=False,
)
