"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf].

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048; 4 RVQ codebooks
decoded with the delay pattern -> 4 parallel output heads; sinusoidal
positions; text-conditioning cross-attention every layer.  The EnCodec
frontend is a STUB per the task spec: input_specs() supplies precomputed
frame embeddings (sum of codebook embeddings) and T5 text embeddings.
"""

import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    frontend="embed_stub",
    n_codebooks=4,
    pos_embedding="sinusoidal",
    cross_kv_len=64,       # T5 text-conditioning tokens
    cross_d_cond=1536,
    tie_embeddings=False,
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=64, cross_kv_len=9, cross_d_cond=64,
    attn_chunk_q=16, attn_chunk_kv=16, dtype=jnp.float32, remat=False,
)
