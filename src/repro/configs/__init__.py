from .registry import (  # noqa: F401
    ARCHS,
    SHAPES,
    ShapeSpec,
    get_config,
    get_smoke_config,
    input_specs,
    materialize_inputs,
    runnable_cells,
)
