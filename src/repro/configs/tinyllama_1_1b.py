"""tinyllama-1.1b [dense] — llama2-arch small [arXiv:2401.02385; hf].

22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
"""

import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=64,
    d_ff=5632,
    vocab_size=32000,
    rope_theta=10000.0,
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=128, vocab_size=256, attn_chunk_q=16, attn_chunk_kv=16,
    dtype=jnp.float32, remat=False,
)
