"""rwkv6-1.6b [ssm] — Finch, data-dependent decay [arXiv:2404.05892].

24L d_model=2048 (attention-free) d_ff=7168 vocab=65536; 32 wkv heads of 64.
Runs long_500k (O(1) recurrent state).
"""

import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    block="rwkv6",
    pos_embedding="none",
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, dtype=jnp.float32, remat=False,
)
