"""olmoe-1b-7b [moe] — 64 experts, top-8 [arXiv:2409.02060; hf].

16L d_model=2048 16H (MHA kv=16) per-expert d_ff=1024 vocab=50304.
"""

import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    moe_experts=64,
    moe_top_k=8,
    moe_d_ff=1024,
    rope_theta=10000.0,
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    moe_experts=8, moe_top_k=2, moe_d_ff=32, d_ff=32, vocab_size=256,
    attn_chunk_q=16, attn_chunk_kv=16, dtype=jnp.float32, remat=False,
)
