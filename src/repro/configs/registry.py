"""Architecture registry: 10 assigned archs x 4 input shapes.

`runnable_cells()` enumerates the dry-run matrix: every (arch x shape)
pair, minus long_500k for pure full-attention archs (spec'd skip —
recorded in DESIGN.md Sec. 5): only the SSM/hybrid archs (rwkv6, hymba)
run the 524288-context decode cell.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, init_cache

ARCHS: dict[str, str] = {
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "smollm-360m": "smollm_360m",
    "qwen3-0.6b": "qwen3_0_6b",
    "llama3.2-1b": "llama3_2_1b",
    "llama-3.2-vision-11b": "llama3_2_vision_11b",
    "hymba-1.5b": "hymba_1_5b",
    "musicgen-medium": "musicgen_medium",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# long_500k runs only for sub-quadratic (SSM / hybrid) archs.
LONG_CONTEXT_ARCHS = {"rwkv6-1.6b", "hymba-1.5b"}


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE_CONFIG


def runnable_cells() -> list[tuple[str, str]]:
    cells = []
    for arch in ARCHS:
        for shape in SHAPES:
            if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                continue  # full-attention arch: spec'd skip
            cells.append((arch, shape))
    return cells


# --------------------------------------------------------------------------
# Input construction (ShapeDtypeStructs for the dry-run; real arrays for
# smoke tests via materialize_inputs).
# --------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, spec: ShapeSpec) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step's inputs.

    train  -> {"batch": {...}}
    prefill-> {"batch": {...}}
    decode -> {"batch": {...}, "cache": {...}}  (cache sized to seq_len)
    """
    b, s = spec.global_batch, spec.seq_len
    i32, f32, dt = jnp.int32, jnp.float32, cfg.dtype

    def data_batch(seq):
        batch: dict[str, Any] = {}
        if cfg.frontend == "embed_stub":
            batch["embeds"] = _sds((b, seq, cfg.d_model), dt)
        else:
            batch["tokens"] = _sds((b, seq), i32)
        if cfg.cross_kv_len > 0:
            batch["cond"] = _sds((b, cfg.cross_kv_len, cfg.cross_d_cond), dt)
        return batch

    if spec.kind == "train":
        batch = data_batch(s)
        tshape = (b, s, cfg.n_codebooks) if cfg.n_codebooks > 1 else (b, s)
        batch["targets"] = _sds(tshape, i32)
        batch["mask"] = _sds((b, s), f32)
        return {"batch": batch}
    if spec.kind == "prefill":
        return {"batch": data_batch(s)}
    # decode: one new token against a seq_len cache
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
    cache = jax.tree.map(lambda x: _sds(x.shape, x.dtype), cache)
    return {"batch": data_batch(1), "cache": cache}


def materialize_inputs(cfg: ModelConfig, spec: ShapeSpec, seed: int = 0):
    """Small real arrays with the same structure (smoke tests)."""
    specs = input_specs(cfg, spec)
    key = jax.random.PRNGKey(seed)

    def fill(sds):
        nonlocal key
        key, sub = jax.random.split(key)
        if jnp.issubdtype(sds.dtype, jnp.integer):
            return jax.random.randint(sub, sds.shape, 0, max(cfg.vocab_size, 2)).astype(
                sds.dtype
            )
        return (0.01 * jax.random.normal(sub, sds.shape)).astype(sds.dtype)

    return jax.tree.map(fill, specs)
