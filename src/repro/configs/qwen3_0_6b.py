"""qwen3-0.6b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf].

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936; head_dim=128
(q-projection widens to 2048, Qwen3 style).
"""

import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
    dtype=jnp.bfloat16,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, attn_chunk_q=16, attn_chunk_kv=16,
    dtype=jnp.float32, remat=False,
)
