"""Write-and-verify engine behaviour (paper Secs. 3-5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DeviceConfig,
    NoiseConfig,
    WVConfig,
    WVMethod,
    program_columns,
)
from repro.core.wv import verify_sweep


@pytest.fixture(scope="module")
def targets():
    return jax.random.randint(jax.random.PRNGKey(0), (128, 32), 0, 8).astype(
        jnp.float32
    )


def _run(cfg, targets, seed=1):
    return jax.jit(lambda k, t: program_columns(k, t, cfg))(
        jax.random.PRNGKey(seed), targets
    )


@pytest.mark.parametrize("method", list(WVMethod))
def test_each_method_converges(method, targets):
    cfg = WVConfig(method=method)
    g, st = _run(cfg, targets)
    assert float(jnp.mean(st.rms_error_lsb)) < 1.2, method
    assert float(jnp.mean(st.frozen_frac)) > 0.9
    assert float(jnp.min(st.latency_ns)) > 0
    assert float(jnp.min(st.energy_pj)) > 0
    assert not bool(jnp.any(jnp.isnan(g)))


def test_paper_ordering(targets):
    """Fig. 9: HD-PV best error+iters; HARP between HD-PV and CW-SC;
    HARP lowest energy; MRA highest energy."""
    res = {
        m: _run(WVConfig(method=m), targets)[1]
        for m in WVMethod
    }
    err = {m: float(jnp.mean(s.rms_error_lsb)) for m, s in res.items()}
    its = {m: float(jnp.mean(s.iterations)) for m, s in res.items()}
    en = {m: float(jnp.mean(s.energy_pj)) for m, s in res.items()}
    assert err[WVMethod.HD_PV] < err[WVMethod.HARP] < err[WVMethod.CW_SC]
    assert its[WVMethod.HD_PV] < its[WVMethod.HARP] < its[WVMethod.CW_SC]
    assert en[WVMethod.HARP] < en[WVMethod.HD_PV] < en[WVMethod.MRA]


def test_low_noise_near_exact(targets):
    """With tiny read noise and a quiet device, every method lands within
    the 0.5 LSB decision band."""
    dev = DeviceConfig(sigma_map_frac=0.005, sigma_c2c_frac=0.01, sigma_d2d_frac=0.01)
    noise = NoiseConfig(sigma_read_lsb=0.01)
    for m in (WVMethod.CW_SC, WVMethod.HD_PV, WVMethod.HARP):
        g, st = _run(WVConfig(method=m, device=dev, noise=noise), targets)
        assert float(jnp.mean(st.rms_error_lsb)) < 0.45, m


def test_noise_hurts_cwsc_more_than_hdpv(targets):
    out = {}
    for sig in (0.1, 0.7):
        for m in (WVMethod.CW_SC, WVMethod.HD_PV):
            _, st = _run(WVConfig(method=m, noise=NoiseConfig(sigma_read_lsb=sig)), targets)
            out[(sig, m)] = float(jnp.mean(st.rms_error_lsb))
    degr_cw = out[(0.7, WVMethod.CW_SC)] / out[(0.1, WVMethod.CW_SC)]
    degr_hd = out[(0.7, WVMethod.HD_PV)] / out[(0.1, WVMethod.HD_PV)]
    assert degr_cw > degr_hd


def test_common_mode_immunity(targets):
    """rho = 0.5 at fixed total power: Hadamard decode cancels mu_cm for
    N-1 cells, so HD-PV degrades less than MRA."""
    res = {}
    for rho in (0.0, 0.5):
        for m in (WVMethod.MRA, WVMethod.HD_PV):
            _, st = _run(
                WVConfig(method=m, noise=NoiseConfig(0.7, rho)), targets, seed=3
            )
            res[(rho, m)] = float(jnp.mean(st.rms_error_lsb))
    assert res[(0.5, WVMethod.HD_PV)] <= res[(0.5, WVMethod.MRA)] * 1.05


def test_verify_sweep_detects_single_error():
    n = 32
    t = jnp.full((1, n), 3.0)
    g = t.at[0, 7].add(1.5)
    for m in (WVMethod.CW_SC, WVMethod.HD_PV, WVMethod.HARP):
        cfg = WVConfig(method=m, noise=NoiseConfig(sigma_read_lsb=0.0))
        d, mag, _ = verify_sweep(jax.random.PRNGKey(0), g, t, cfg)
        d = np.asarray(d[0])
        assert d[7] == 1.0, m                 # too high -> RESET indicated
        assert np.all(d[np.arange(n) != 7] == 0), m


def test_harp_tau_tradeoff(targets):
    """Paper Sec 5.1: larger tau freezes earlier (fewer iterations, more
    error); smaller tau improves error at iteration cost."""
    lo = _run(WVConfig(method=WVMethod.HARP, tau_w=2.0), targets)[1]
    hi = _run(WVConfig(method=WVMethod.HARP, tau_w=10.0), targets)[1]
    assert float(jnp.mean(hi.iterations)) < float(jnp.mean(lo.iterations))
    assert float(jnp.mean(hi.rms_error_lsb)) > float(jnp.mean(lo.rms_error_lsb))


def test_mra_reads_cost_scales():
    t = jax.random.randint(jax.random.PRNGKey(5), (64, 32), 0, 8).astype(jnp.float32)
    _, s3 = _run(WVConfig(method=WVMethod.MRA, mra_reads=3), t)
    _, s7 = _run(WVConfig(method=WVMethod.MRA, mra_reads=7), t)
    per3 = float(jnp.mean(s3.reads / jnp.maximum(s3.iterations, 1)))
    per7 = float(jnp.mean(s7.reads / jnp.maximum(s7.iterations, 1)))
    assert per3 == pytest.approx(3 * 32, rel=0.01)
    assert per7 == pytest.approx(7 * 32, rel=0.01)


def test_deterministic_given_key(targets):
    cfg = WVConfig(method=WVMethod.HARP)
    g1, s1 = _run(cfg, targets, seed=9)
    g2, s2 = _run(cfg, targets, seed=9)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
    g3, _ = _run(cfg, targets, seed=10)
    assert not np.array_equal(np.asarray(g1), np.asarray(g3))
