"""Telemetry contracts (DESIGN.md Sec. 14).

The obs subsystem's acceptance criteria live here:

* zero extra syncs / zero retraces — instrumentation rides existing
  fetches: a batched deploy still performs exactly ONE host sync, the
  scheduler still performs exactly one sync per decode step and stays
  retrace-free after warmup, with device metrics on;
* bit-neutrality — deployed conductances and served tokens are
  identical with instrumentation enabled and disabled;
* reset semantics — `obs.reset_all()` gives back-to-back benchmarks in
  one process independent counters/events/charges;
* the trace artifact round-trips: span/instant/ledger events export as
  Chrome/Perfetto trace-event JSON that `repro.obs.report` loads,
  summarizes, and renders (and rejects when empty or malformed);
* instrumentation overhead stays within budget on the decode hot path.
"""

import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import WVConfig, WVMethod, pipeline
from repro.core.programmer import deploy_arrays
from repro.models import ModelConfig, init_params
from repro.obs import ledger, metrics, report, trace
from repro.serving import ContinuousScheduler, ServeEngine, poisson_requests


@pytest.fixture(autouse=True)
def _fresh_obs():
    """Every test starts and ends with clean telemetry state."""
    obs.reset_all()
    yield
    obs.reset_all()


# ------------------------------------------------------- MetricAccumulator
def test_accumulator_rides_jit_without_retrace():
    acc = metrics.MetricAccumulator.zeros(["tokens", "reads"])
    traces = []

    @jax.jit
    def step(acc, x):
        traces.append(1)  # trace-time side effect
        y = x * 2.0
        return acc.inc("tokens", 1.0).inc("reads", jnp.sum(y)), y

    for i in range(4):
        acc, _ = step(acc, jnp.full((8,), float(i)))
    assert len(traces) == 1, "accumulator operand retraced a warmed dispatch"
    got = jax.device_get(acc.as_dict())
    assert got["tokens"] == 4.0
    assert got["reads"] == sum(2.0 * i * 8 for i in range(4))


def test_accumulator_treedef_stable_and_merge():
    a = metrics.MetricAccumulator.zeros(["x", "y"]).inc("x", 3.0)
    b = metrics.MetricAccumulator.zeros(["x", "y"]).inc("y", 4.0)
    ta = jax.tree_util.tree_structure(a)
    tb = jax.tree_util.tree_structure(b)
    assert ta == tb  # same names => same treedef (no-retrace invariant)
    m = jax.device_get(a.merge(b).as_dict())
    assert (m["x"], m["y"]) == (3.0, 4.0)


def test_registry_fold_prefix_and_scoped_reset():
    metrics.inc("pipeline.compiles", 2)
    metrics.registry.fold({"tokens": 5, "reads": 7.5}, prefix="serve.")
    assert metrics.value("serve.tokens") == 5.0
    metrics.reset("serve.")
    assert metrics.value("serve.tokens") == 0.0
    assert metrics.value("pipeline.compiles") == 2.0  # other prefix survives
    metrics.reset()
    assert metrics.snapshot() == {}


def test_pipeline_counters_are_registry_backed():
    pipeline.reset_counters()
    base = pipeline.host_sync_count()
    pipeline.host_fetch(jnp.ones((4,)))
    assert pipeline.host_sync_count() == base + 1
    assert metrics.value(pipeline.SYNC_COUNTER) == base + 1
    pipeline.reset_counters()
    assert pipeline.host_sync_count() == 0


# ------------------------------------------------------------ trace/ledger
def test_span_instant_counter_events_and_disabled():
    with trace.span("phase.a", cat="t", n=1) as sp:
        sp["result"] = 42
    trace.instant("marker", cat="t")
    trace.counter("load", slots=3)
    evs = trace.events()
    assert [e["ph"] for e in evs] == ["X", "i", "C"]
    assert evs[0]["args"] == {"n": 1, "result": 42}
    assert evs[0]["dur"] >= 0
    with obs.disabled():
        with trace.span("phase.hidden"):
            pass
        ledger.charge("hidden", energy_pj=1.0)
    assert len(trace.events()) == 3  # nothing recorded while disabled
    assert ledger.summary() == {}


def test_ledger_accumulates_and_mirrors_into_trace():
    ledger.charge("deploy", energy_pj=10.0, latency_ns=5.0, reads=3.0)
    ledger.charge("deploy", energy_pj=2.5, tokens=4.0)
    s = ledger.summary()["deploy"]
    assert s["energy_pj"] == 12.5
    assert s["latency_ns"] == 5.0
    assert s["reads"] == 3.0
    assert s["tokens"] == 4.0
    assert s["n_charges"] == 2
    assert ledger.ledger.total("energy_pj") == 12.5
    mirrored = [e for e in trace.events() if e.get("cat") == "ledger"]
    assert len(mirrored) == 2 and mirrored[0]["name"] == "deploy"


def test_reset_all_isolates_back_to_back_benchmarks():
    # benchmark 1
    with trace.span("bench.one"):
        metrics.inc("pipeline.compiles")
        ledger.charge("one", energy_pj=1.0)
    assert trace.events() and ledger.summary() and metrics.snapshot()
    obs.reset_all()  # what benchmarks/run.py does between benchmarks
    # benchmark 2 sees a clean slate
    assert trace.events() == []
    assert ledger.summary() == {}
    assert metrics.snapshot() == {}
    with trace.span("bench.two"):
        pass
    evs = trace.events()
    assert [e["name"] for e in evs] == ["bench.two"]
    assert evs[0]["ts"] < 10e6  # clock rebased: fresh epoch, not process age


# ------------------------------------------------------------- report CLI
def test_trace_export_report_roundtrip(tmp_path, capsys):
    with trace.span("serve.decode", cat="serve"):
        time.sleep(0.001)
    with trace.span("serve.decode", cat="serve"):
        pass
    ledger.charge("serve.analog", tokens=8.0, energy_pj=100.0)
    path = tmp_path / "TRACE_t.json"
    trace.export(path)
    doc = report.load(str(path))
    # Perfetto structure: a dict with a traceEvents list of ph-events
    assert isinstance(doc["traceEvents"], list)
    assert all("ph" in e and "ts" in e for e in doc["traceEvents"])
    rows = {r["phase"]: r for r in report.summarize(doc)}
    assert rows["serve.decode"]["count"] == 2
    assert rows["serve.decode"]["total_ms"] > 0
    assert rows["serve.analog"]["tokens"] == 8.0
    assert rows["serve.analog"]["energy_pj"] == 100.0
    assert report.main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "serve.decode" in out and "serve.analog" in out


def test_report_fails_on_empty_and_malformed(tmp_path):
    empty = tmp_path / "empty.json"
    empty.write_text(json.dumps({"traceEvents": []}))
    assert report.main([str(empty)]) == 1  # no spans -> CI smoke fails
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert report.main([str(bad)]) == 1
    missing = tmp_path / "missing.json"
    assert report.main([str(missing)]) == 1
    notrace = tmp_path / "notrace.json"
    notrace.write_text(json.dumps({"foo": 1}))
    assert report.main([str(notrace)]) == 1


# ------------------------------------------------- deploy instrumentation
def _tiny_params():
    k = jax.random.split(jax.random.PRNGKey(0), 2)
    return {
        "wa": jax.random.normal(k[0], (32, 48)) * 0.02,
        "wb": jax.random.normal(k[1], (48, 32)) * 0.02,
        "norm": jnp.ones((32,)),
    }


def test_deploy_bit_neutral_and_single_sync():
    """Instrumented vs uninstrumented deploys: identical conductances;
    the batched deploy still syncs exactly once and re-deploys with
    zero new compiles (the PR 5 contracts, with obs in the path)."""
    params = _tiny_params()
    wv = WVConfig(method=WVMethod.HARP, max_fine_iters=8, max_coarse_iters=3)

    d_on, rep_on = deploy_arrays(jax.random.PRNGKey(1), params, wv)
    with obs.disabled():
        d_off, rep_off = deploy_arrays(jax.random.PRNGKey(1), params, wv)
    for name in d_on.arrays:
        np.testing.assert_array_equal(
            np.asarray(d_on.arrays[name].g), np.asarray(d_off.arrays[name].g)
        )
    assert rep_on.total_reads == rep_off.total_reads > 0
    assert rep_on.total_write_pulses == rep_off.total_write_pulses > 0

    pipeline.reset_counters()
    c0 = pipeline.compile_count()
    deploy_arrays(jax.random.PRNGKey(2), params, wv)
    assert pipeline.host_sync_count() == 1  # ONE sync, metrics included
    assert pipeline.compile_count() == c0  # warm: zero retraces
    # deploy fold landed in the registry and the ledger
    assert metrics.value("deploy.verify_reads") > 0
    assert metrics.value("deploy.write_pulses") > 0
    assert ledger.summary()["deploy"]["energy_pj"] > 0
    spans = [e["name"] for e in trace.events() if e["ph"] == "X"]
    assert "deploy" in spans and "deploy.program_columns" in spans


# ----------------------------------------------- scheduler instrumentation
def _sched_cfg() -> ModelConfig:
    return ModelConfig(
        name="obs-test", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=64, dtype=jnp.float32,
        attn_chunk_q=16, attn_chunk_kv=16, remat=False, tie_embeddings=False,
    )


@pytest.fixture(scope="module")
def sched_model():
    cfg = _sched_cfg()
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def _run_stream(cfg, params, device_metrics):
    engine = ServeEngine(cfg, params, temperature=0.7)
    sched = ContinuousScheduler(
        engine, n_slots=3, max_len=48, key=jax.random.PRNGKey(5),
        device_metrics=device_metrics,
    )
    sched.warmup(prompt_range=(3, 10))
    warm = dict(sched.trace_counts)
    reqs = poisson_requests(
        3, 6, rate=0.5, vocab=cfg.vocab_size,
        prompt_lens=(3, 10), max_new=(2, 5),
    )
    recs = sched.run(reqs)
    return sched, warm, {r.rid: list(r.tokens) for r in recs}


def test_scheduler_device_metrics_bit_neutral(sched_model):
    """device_metrics on/off: identical served tokens, one sync per
    decode step, zero retraces after warmup — with spans recording."""
    cfg, params = sched_model
    s_on, warm_on, toks_on = _run_stream(cfg, params, device_metrics=True)
    s_off, _, toks_off = _run_stream(cfg, params, device_metrics=False)
    assert toks_on == toks_off  # bit-identical tokens
    for sched, warm in ((s_on, warm_on),):
        assert sched.host_syncs == sched.decode_steps  # ONE sync per step
        assert all(sched.trace_counts[k] == warm[k] for k in warm)
    # fetched step metrics landed in the registry (enabled run only)
    assert metrics.value("serve.decode_steps") >= s_on.decode_steps
    assert metrics.value("serve.decode_tokens") > 0
    assert metrics.value("serve.decode_active_slots") > 0
    names = {e["name"] for e in trace.events() if e["ph"] == "X"}
    assert {"serve.admit", "serve.decode", "serve.run"} <= names


def test_scheduler_instrumentation_overhead_budget(sched_model):
    """Tracing + device metrics must not blow up the decode step.

    Generous budget (CI wall clocks are noisy): the instrumented steady
    state stays within 1.5x + slack of the uninstrumented one.
    """
    cfg, params = sched_model

    def steady_wall(device_metrics, enabled):
        engine = ServeEngine(cfg, params, temperature=0.7)
        sched = ContinuousScheduler(
            engine, n_slots=3, max_len=48, key=jax.random.PRNGKey(5),
            device_metrics=device_metrics,
        )
        sched.warmup(prompt_range=(4, 4))
        sched.reset(keep_traces=True)
        reqs = [
            poisson_requests(
                7, 6, rate=10.0, vocab=cfg.vocab_size,
                prompt_lens=(4, 4), max_new=(30, 30),
            )[i] for i in range(3)
        ]
        if enabled:
            sched.run(reqs)
        else:
            with obs.disabled():
                sched.run(reqs)
        return sched.wall_s / max(sched.decode_steps, 1)

    steady_wall(True, True)  # warm everything once
    base = min(steady_wall(False, False) for _ in range(2))
    inst = min(steady_wall(True, True) for _ in range(2))
    assert inst <= base * 1.5 + 2e-3, (inst, base)


def test_span_overhead_microbenchmark():
    """Host-side span cost itself is tiny (a dict append + two clocks)."""
    n = 2000
    with obs.disabled():  # don't leak 2000 events into other asserts
        t0 = time.perf_counter()
        for _ in range(n):
            with trace.span("micro"):
                pass
        per_span = (time.perf_counter() - t0) / n
    assert per_span < 100e-6, per_span  # < 100 us/span, generously
