"""Report + dashboard rendering contracts (DESIGN.md Sec. 16).

The dashboard layer only reads exported files, so these tests build a
real trace through the live obs APIs (spans, digest/health emits, an
SLO breach), export it, and assert the file-readers reconstruct the
right rows — including the empty-digest corner, where every percentile
renders as "-" instead of crashing or inventing a number.
"""

import json

import pytest

from repro import obs
from repro.obs import dashboard, report


@pytest.fixture(autouse=True)
def _fresh_obs():
    obs.reset_all()
    yield
    obs.reset_all()


def _export_trace(tmp_path, with_breach=True):
    """Build a representative trace via the live obs APIs and export."""
    with obs.span("serve.generate", tokens=8):
        pass
    obs.digests.observe(
        "rep0.latency_steps", [4.0, 6.0, 6.0, 9.0], lo=0.0, hi=16.0,
        n_buckets=16,
    )
    obs.digests.ensure("rep0.empty", 0.0, 1.0, 8)  # never observed
    obs.health_registry.fold_tiles("deploy.gave_up_cells", [3, 7], [2.0, 5.0])
    obs.health_registry.set_gauge("fleet.give_up_rate", 2.5e-3)
    if with_breach:
        policy = obs.SLOPolicy(
            rules=(
                obs.SLORule(
                    "give_up_rate", "health.gauges.fleet.give_up_rate", 1e-3
                ),
            )
        )
        policy.evaluate(obs.fleet_status(), window=3)
    obs.digests.emit()
    obs.health_registry.emit()
    path = tmp_path / "TRACE_test.json"
    obs.trace.export(path)
    return str(path)


def test_report_digest_and_slo_rows(tmp_path):
    path = _export_trace(tmp_path)
    doc = report.load(path)

    rows = {r["digest"]: r for r in report.digest_rows(doc)}
    assert rows["rep0.latency_steps"]["count"] == 4.0
    assert rows["rep0.latency_steps"]["p50"] is not None
    # empty digest appears with every percentile None, not dropped
    assert rows["rep0.empty"]["count"] == 0.0
    assert rows["rep0.empty"]["p99"] is None
    rendered = report.render_digests(report.digest_rows(doc))
    empty_line = next(
        ln for ln in rendered.splitlines() if "rep0.empty" in ln
    )
    assert "-" in empty_line  # None percentiles render as "-"

    (slo,) = report.slo_rows(doc)
    assert slo["rule"] == "give_up_rate"
    assert slo["breaches"] == 1
    assert slo["last_value"] == pytest.approx(2.5e-3)


def test_report_main_prints_new_sections(tmp_path, capsys):
    path = _export_trace(tmp_path)
    assert report.main([path]) == 0
    out = capsys.readouterr().out
    assert "# digests" in out and "rep0.latency_steps" in out
    assert "# slo breaches" in out and "give_up_rate" in out


def test_dashboard_collect_and_renders(tmp_path):
    path = _export_trace(tmp_path)
    fleet_path = tmp_path / "fleet_status.json"
    fleet_path.write_text(json.dumps(obs.fleet_status()))

    model = dashboard.collect([path], str(fleet_path))
    (rep,) = model["replicas"]
    assert rep["n_events"] > 0 and rep["phases"]
    assert {r["digest"] for r in rep["digests"]} == {
        "rep0.latency_steps", "rep0.empty",
    }
    kinds = {r["metric"]: r for r in rep["health"]}
    assert kinds["deploy.gave_up_cells"]["kind"] == "tiles"
    assert kinds["deploy.gave_up_cells"]["total"] == 7.0
    assert kinds["fleet.give_up_rate"]["kind"] == "gauge"
    assert model["fleet"]["health"]["gauges"]["fleet.give_up_rate"] > 0

    text = dashboard.render_text(model)
    for needle in ("# digests", "# health", "# slo breaches",
                   "## fleet status"):
        assert needle in text

    html = dashboard.render_html(model)
    assert html.startswith("<!doctype html>")
    assert "1 SLO breach instant(s)" in html
    assert 'class="breach"' in html  # breached rule row is highlighted
    assert "rep0.latency_steps" in html


def test_dashboard_main_writes_html(tmp_path, capsys):
    path = _export_trace(tmp_path, with_breach=False)
    out = tmp_path / "fleet.html"
    assert dashboard.main([path, "--out", str(out)]) == 0
    assert out.exists() and out.read_text().startswith("<!doctype html>")
    assert "0 SLO breach instant(s)" in out.read_text()
    assert str(out) in capsys.readouterr().out


def test_dashboard_main_fails_loudly(tmp_path, capsys):
    # malformed trace json
    bad = tmp_path / "TRACE_bad.json"
    bad.write_text("{not json")
    assert dashboard.main([str(bad)]) == 1
    # structurally valid but zero events
    empty = tmp_path / "TRACE_empty.json"
    empty.write_text(json.dumps({"traceEvents": []}))
    assert dashboard.main([str(empty)]) == 1
    # malformed fleet status
    good = _export_trace(tmp_path)
    badfleet = tmp_path / "fleet_bad.json"
    badfleet.write_text("[1, 2]")
    assert dashboard.main([good, "--fleet", str(badfleet)]) == 1
    assert "error" in capsys.readouterr().err
