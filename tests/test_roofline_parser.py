"""Roofline extraction: collective-byte parser + term arithmetic."""

import pytest

from repro.launch.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    RooflineTerms,
    collective_bytes_from_hlo,
)

HLO = """
ENTRY %main {
  %p0 = bf16[16,512]{1,0} parameter(0)
  %ag = bf16[256,512]{1,0} all-gather(bf16[16,512]{1,0} %p0), dimensions={0}
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), to_apply=%add
  %rs = f32[64,32]{1,0} reduce-scatter(f32[1024,32]{1,0} %y), dimensions={0}
  %cp = bf16[8,128]{1,0} collective-permute(bf16[8,128]{1,0} %z)
  %a2a = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(f32[4,4]{1,0} %q, f32[4,4]{1,0} %r)
  %ags = bf16[32,16]{1,0} all-gather-start(bf16[2,16]{1,0} %w)
}
"""


def test_collective_parser_counts_and_bytes():
    res = collective_bytes_from_hlo(HLO)
    by = res["bytes_by_type"]
    assert by["all-gather"] == 256 * 512 * 2 + 32 * 16 * 2
    assert by["all-reduce"] == 1024 * 4
    assert by["reduce-scatter"] == 64 * 32 * 4
    assert by["collective-permute"] == 8 * 128 * 2
    assert by["all-to-all"] == 2 * 4 * 4 * 4
    assert res["counts_by_type"]["all-gather"] == 2
    assert res["total_bytes"] == sum(by.values())


def test_roofline_terms_and_bottleneck():
    t = RooflineTerms(
        arch="a", shape="s", mesh="m", chips=256,
        hlo_flops=256 * PEAK_FLOPS,          # exactly 1 s of compute
        hlo_bytes=256 * HBM_BW * 0.5,        # 0.5 s of HBM
        collective_bytes=ICI_BW * 0.25,      # 0.25 s of ICI
        model_flops=128 * PEAK_FLOPS,
    ).finalize()
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(0.5)
    assert t.collective_s == pytest.approx(0.25)
    assert t.bottleneck == "compute"
    assert t.useful_ratio == pytest.approx(0.5)
