import os

# Tests run with the real single CPU device; only dryrun-specific tests
# spawn subprocesses with XLA_FLAGS device-count overrides.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    """Compiled XLA:CPU executables accumulate across the suite (the full
    run was OOM-killed at 36 GB); dropping them per module keeps the
    single-process footprint bounded."""
    yield
    jax.clear_caches()
