"""End-to-end behaviour tests: the paper's full pipeline on the framework.

train (synthetic LM) -> quantize -> bit-slice -> program via WV ->
read back -> serve, comparing eval loss across WV methods — the Fig. 10
robustness experiment at test scale.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import NoiseConfig, WVConfig, WVMethod
from repro.core.programmer import deploy_matrix, deploy_params
from repro.data import SyntheticLM
from repro.models import ModelConfig
from repro.models.transformer import loss_fn
from repro.optim import AdamWConfig
from repro.training import init_train_state, make_train_step


@pytest.fixture(scope="module")
def trained_lm():
    cfg = ModelConfig(
        name="sys", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=64, dtype=jnp.float32,
        attn_chunk_q=32, attn_chunk_kv=32, remat=False,
    )
    data = SyntheticLM(vocab_size=64, seq_len=48, global_batch=16, seed=11)
    opt = AdamWConfig(lr_peak=1e-2)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt, total_steps=150))
    for i in range(150):
        state, _ = step(state, data.global_batch_at(i)._asdict())
    eval_batch = data.global_batch_at(50_000)._asdict()
    eval_fn = jax.jit(lambda p, b: loss_fn(p, b, cfg)[0])
    return cfg, state.params, eval_fn, eval_batch


def test_deploy_quality_ordering(trained_lm):
    """Under severe read noise, serving quality follows the paper:
    HD-PV ~ HARP >> CW-SC, all iso-footprint."""
    cfg, params, eval_fn, eval_batch = trained_lm
    clean = float(eval_fn(params, eval_batch))
    noise = NoiseConfig(sigma_read_lsb=0.7)
    dl = {}
    for m in (WVMethod.CW_SC, WVMethod.HD_PV, WVMethod.HARP):
        prog, _ = deploy_params(
            jax.random.PRNGKey(3), params, WVConfig(method=m, noise=noise)
        )
        dl[m] = float(eval_fn(prog, eval_batch)) - clean
    # small models tolerate some weight noise; compare with a tolerance
    # band and require the Hadamard deployments to stay usable.
    assert dl[WVMethod.HD_PV] <= dl[WVMethod.CW_SC] + 0.02
    assert dl[WVMethod.HARP] <= dl[WVMethod.CW_SC] + 0.05
    assert dl[WVMethod.HD_PV] < 0.25  # Hadamard deployment stays usable


def test_deploy_reports_costs(trained_lm):
    cfg, params, eval_fn, eval_batch = trained_lm
    _, report = deploy_params(
        jax.random.PRNGKey(4), params, WVConfig(method=WVMethod.HARP)
    )
    assert report.num_columns > 0 and report.num_cells > 0
    assert report.total_energy_pj > 0
    assert report.critical_latency_ns > 0
    assert 0 < report.mean_iterations <= 50
    # norm/bias/embedding leaves stay digital
    assert all("bias" not in k and "embed" not in k for k in report.leaves)


def test_deploy_matrix_improves_with_lower_noise():
    """CW-SC (single noisy reads) is read-noise sensitive: lower verify
    noise must improve the programmed weights."""
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32)) * 0.02
    errs = []
    for sig in (0.7, 0.05):
        cfg = WVConfig(method=WVMethod.CW_SC, noise=NoiseConfig(sigma_read_lsb=sig))
        wp, _ = deploy_matrix(jax.random.PRNGKey(1), w, cfg)
        errs.append(float(jnp.linalg.norm(wp - w) / jnp.linalg.norm(w)))
    assert errs[1] < errs[0]


def test_pallas_fwht_path_in_engine():
    """cfg.use_pallas routes the engine decode through the Pallas kernel;
    results must match the jnp path exactly (same RNG, same math)."""
    from repro.core import program_columns

    t = jax.random.randint(jax.random.PRNGKey(2), (64, 32), 0, 8).astype(jnp.float32)
    g1, s1 = program_columns(jax.random.PRNGKey(5), t, WVConfig(method=WVMethod.HD_PV))
    g2, s2 = program_columns(
        jax.random.PRNGKey(5), t, WVConfig(method=WVMethod.HD_PV, use_pallas=True)
    )
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4, atol=1e-3)
