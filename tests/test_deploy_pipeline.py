"""Bucketed whole-model programming pipeline (DESIGN.md Sec. 10).

Covers the ISSUE-2 contracts: bucketed-vs-per-leaf bit-identity, fused
Pallas wv_step-in-loop parity with the unfused engine and the ref
oracle, no-retrace bucketing (compiles <= buckets), the single-host-sync
stats path, the scalar coarse-pulse scan, and statistical equivalence of
the per-column RNG policy with the legacy batch-shaped draws.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import WVConfig, WVMethod, pipeline, program_columns
from repro.core.programmer import deploy_arrays, deploy_params
from repro.core.types import DeviceConfig


@pytest.fixture(scope="module")
def small_params():
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    return {
        "blk0": {
            "w": jax.random.normal(ks[0], (40, 24)) * 0.05,
            "scale": jnp.ones((24,)),  # 1D: stays digital
        },
        "blk1": {
            "w": jax.random.normal(ks[1], (64, 16)) * 0.05,
            "w2": jax.random.normal(ks[2], (33, 20)) * 0.05,
        },
        "embed": jax.random.normal(ks[3], (64, 8)) * 0.05,  # excluded
    }


@pytest.fixture(scope="module")
def fast_cfg():
    return WVConfig(method=WVMethod.HARP, max_fine_iters=14)


def test_bucket_sizes():
    assert pipeline.bucket_sizes(480, 64) == [256, 128, 64, 64]
    assert pipeline.bucket_sizes(512, 64) == [512]
    assert pipeline.bucket_sizes(40, 64) == [64]
    assert pipeline.bucket_sizes(5000, 256, 1024) == [1024] * 4 + [512, 256, 256]
    for c, lo, hi in [(480, 64, 1 << 18), (7, 4, 16), (4097, 256, 1024)]:
        sizes = pipeline.bucket_sizes(c, lo, hi)
        assert sum(sizes) >= c
        assert sum(sizes) - c < lo  # only the last bucket pads
        assert all(s & (s - 1) == 0 and lo <= s <= hi for s in sizes)


def test_bucketed_matches_per_leaf(small_params, fast_cfg):
    """The tentpole contract: bucketed multi-leaf programming is
    BIT-identical to programming each leaf alone (per-column RNG
    sub-streams make results independent of batch composition)."""
    key = jax.random.PRNGKey(7)
    dep_b, rep_b = deploy_arrays(
        key, small_params, fast_cfg, batched=True, min_bucket=64
    )
    dep_l, rep_l = deploy_arrays(key, small_params, fast_cfg, batched=False)
    for name in dep_l.arrays:
        np.testing.assert_array_equal(
            np.asarray(dep_b.arrays[name].g), np.asarray(dep_l.arrays[name].g), name
        )
        np.testing.assert_array_equal(
            np.asarray(dep_b.arrays[name].d2d),
            np.asarray(dep_l.arrays[name].d2d),
            name,
        )
    for a, b in zip(
        jax.tree_util.tree_leaves(dep_b.materialize()),
        jax.tree_util.tree_leaves(dep_l.materialize()),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # Device-side collect and host-side merge agree on the aggregates.
    assert rep_b.num_columns == rep_l.num_columns
    assert rep_b.num_cells == rep_l.num_cells
    assert rep_b.mean_iterations == pytest.approx(rep_l.mean_iterations, rel=1e-5)
    assert rep_b.total_energy_pj == pytest.approx(rep_l.total_energy_pj, rel=1e-5)
    assert rep_b.critical_latency_ns == pytest.approx(
        rep_l.critical_latency_ns, rel=1e-6
    )
    assert rep_b.rms_cell_error_lsb == pytest.approx(
        rep_l.rms_cell_error_lsb, rel=1e-4
    )
    assert set(rep_b.leaves) == set(rep_l.leaves)
    assert all("embed" not in k and "scale" not in k for k in rep_b.leaves)


def test_deploy_params_delegates_to_pipeline(small_params, fast_cfg):
    key = jax.random.PRNGKey(3)
    dense, _ = deploy_params(key, small_params, fast_cfg)
    dep, _ = deploy_arrays(key, small_params, fast_cfg)
    for a, b in zip(
        jax.tree_util.tree_leaves(dense),
        jax.tree_util.tree_leaves(dep.materialize()),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_columns_independent_of_batch_composition(fast_cfg):
    """A column's programmed value depends only on (key, uid) — not on
    which other columns rode in the same dispatch."""
    key = jax.random.PRNGKey(11)
    t = jax.random.randint(jax.random.PRNGKey(2), (96, 32), 0, 8).astype(
        jnp.float32
    )
    ids = jnp.arange(96, dtype=jnp.int32)
    g_all, _ = program_columns(key, t, fast_cfg, col_ids=ids)
    g_sub, _ = program_columns(key, t[32:64], fast_cfg, col_ids=ids[32:64])
    np.testing.assert_array_equal(np.asarray(g_all[32:64]), np.asarray(g_sub))


def test_mesh_sharded_dispatch_matches(small_params, fast_cfg):
    """The column axis can be sharded over a mesh; results are unchanged
    (columns are independent — no cross-device traffic in the WV loop)."""
    mesh = jax.make_mesh((1,), ("cols",))
    key = jax.random.PRNGKey(21)
    dep_m, _ = deploy_arrays(
        key, small_params, fast_cfg, batched=True, min_bucket=64, mesh=mesh
    )
    dep, _ = deploy_arrays(
        key, small_params, fast_cfg, batched=True, min_bucket=64
    )
    for name in dep.arrays:
        np.testing.assert_array_equal(
            np.asarray(dep_m.arrays[name].g), np.asarray(dep.arrays[name].g)
        )


def test_no_retrace_and_single_host_sync(small_params, fast_cfg):
    """Compile count <= number of buckets; redeploying the same shapes
    hits the warm cache; exactly one host sync per batched deploy."""
    dep, _ = deploy_arrays(
        jax.random.PRNGKey(0), small_params, fast_cfg, batched=True, min_bucket=64
    )
    n_buckets = len(pipeline.bucket_sizes(dep.num_columns, 64))
    # A config no other test dispatches -> its jit cache starts cold.
    cfg = fast_cfg.replace(max_fine_iters=9)
    pipeline.reset_counters()
    deploy_arrays(
        jax.random.PRNGKey(1), small_params, cfg, batched=True, min_bucket=64
    )
    assert 1 <= pipeline.compile_count() <= n_buckets
    assert pipeline.host_sync_count() == 1
    c0 = pipeline.compile_count()
    deploy_arrays(
        jax.random.PRNGKey(2), small_params, cfg, batched=True, min_bucket=64
    )
    assert pipeline.compile_count() == c0  # no retrace on redeploy
    assert pipeline.host_sync_count() == 2


@pytest.mark.parametrize(
    "method", [WVMethod.HARP, WVMethod.CW_SC, WVMethod.MRA, WVMethod.HD_PV]
)
def test_pallas_wv_step_in_loop_parity(method):
    """cfg.use_pallas routes the fine-WV cell update through the fused
    Pallas kernel; pre-sampled write noise makes it bit-identical to the
    unfused jnp path across ternary AND magnitude methods."""
    cfg = WVConfig(method=method, max_fine_iters=14)
    t = jax.random.randint(jax.random.PRNGKey(4), (64, 32), 0, 8).astype(
        jnp.float32
    )
    key = jax.random.PRNGKey(5)
    g0, s0 = jax.jit(lambda k, x: program_columns(k, x, cfg))(key, t)
    cfg_p = cfg.replace(use_pallas=True)
    g1, s1 = jax.jit(lambda k, x: program_columns(k, x, cfg_p))(key, t)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1), atol=1e-5)
    np.testing.assert_array_equal(
        np.asarray(s0.iterations), np.asarray(s1.iterations)
    )
    np.testing.assert_allclose(
        np.asarray(s0.energy_pj), np.asarray(s1.energy_pj), rtol=1e-5
    )


def test_event_mode_noise_parity():
    """map_noise_mode="event" disables the kernel's sqrt(n) nmap scaling;
    fused and unfused paths must still agree."""
    cfg = WVConfig(
        method=WVMethod.HD_PV,
        max_fine_iters=10,
        device=DeviceConfig(map_noise_mode="event"),
    )
    t = jax.random.randint(jax.random.PRNGKey(6), (32, 32), 0, 8).astype(
        jnp.float32
    )
    key = jax.random.PRNGKey(8)
    g0, _ = program_columns(key, t, cfg)
    g1, _ = program_columns(key, t, cfg.replace(use_pallas=True))
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1), atol=1e-5)


def test_per_column_rng_statistically_equivalent():
    """The RNG policy change (batch-shaped draws -> per-column
    sub-streams) preserves the programming statistics (DESIGN.md
    Sec. 10): mean RMS error and iteration count agree within CLT
    noise on a 512-column batch."""
    cfg = WVConfig(method=WVMethod.HARP)
    t = jax.random.randint(jax.random.PRNGKey(9), (512, 32), 0, 8).astype(
        jnp.float32
    )
    key = jax.random.PRNGKey(10)
    _, s_legacy = jax.jit(lambda k, x: program_columns(k, x, cfg))(key, t)
    ids = jnp.arange(512, dtype=jnp.int32)
    _, s_v2 = jax.jit(lambda k, x, i: program_columns(k, x, cfg, col_ids=i))(
        key, t, ids
    )
    rms_a = float(jnp.mean(s_legacy.rms_error_lsb))
    rms_b = float(jnp.mean(s_v2.rms_error_lsb))
    assert rms_b == pytest.approx(rms_a, rel=0.15), (rms_a, rms_b)
    it_a = float(jnp.mean(s_legacy.iterations))
    it_b = float(jnp.mean(s_v2.iterations))
    assert it_b == pytest.approx(it_a, rel=0.15), (it_a, it_b)


def test_scalar_coarse_scan_matches_per_cell_reference():
    """The coarse look-up now scans ONE scalar nominal trajectory; it
    must reproduce the old per-cell (P, C, N) scan exactly."""
    from repro.core.device import _effective_step
    from repro.core.wv import _characterized_coarse_pulses

    dev = DeviceConfig()
    targets = jax.random.uniform(
        jax.random.PRNGKey(12), (37, 32), minval=0.0, maxval=7.0
    )

    def reference(targets, dev_cfg, max_pulses):  # the pre-PR per-cell scan
        def body(g_nom, _):
            g_next = jnp.clip(
                g_nom
                + _effective_step(
                    g_nom, jnp.ones_like(g_nom), dev_cfg, dev_cfg.coarse_step_lsb
                ),
                0.0,
                dev_cfg.g_max_lsb,
            )
            return g_next, g_next

        g0 = jnp.zeros_like(targets)
        _, traj = jax.lax.scan(body, g0, None, length=max_pulses)
        landings = jnp.concatenate([g0[None], traj], axis=0)
        err = jnp.abs(landings - targets[None])
        return jnp.argmin(err, axis=0).astype(jnp.float32)

    np.testing.assert_array_equal(
        np.asarray(_characterized_coarse_pulses(targets, dev, 10)),
        np.asarray(reference(targets, dev, 10)),
    )


def test_refresh_shares_pipeline_cache():
    """lifetime.refresh dispatches re-programming through the pipeline's
    shared entry point (same jit cache as deployment)."""
    from repro.core.cost import CircuitCost
    from repro.lifetime.drift import DriftConfig, init_cell_state
    from repro.lifetime.refresh import RefreshConfig, RefreshPolicy, apply_refresh

    cfg = WVConfig(method=WVMethod.HARP, max_fine_iters=12)
    cost = CircuitCost()
    targets = jax.random.randint(jax.random.PRNGKey(13), (64, 32), 0, 8).astype(
        jnp.float32
    )
    key = jax.random.PRNGKey(14)
    ids = jnp.arange(64, dtype=jnp.int32)
    d2d = pipeline.sample_d2d_for(key, ids, targets.shape, cfg.device)
    fn = pipeline.get_program_fn(cfg, cost)
    g, _ = fn(key, targets, d2d, ids)
    state = init_cell_state(
        jax.random.PRNGKey(15), g, d2d, cfg.device, DriftConfig()
    )
    pipeline.reset_counters()
    state, out = apply_refresh(
        jax.random.PRNGKey(16), state, targets, cfg, cost, DriftConfig(),
        RefreshConfig(policy=RefreshPolicy.PERIODIC, period_epochs=1), epoch=0,
    )
    assert out.n_reprogrammed == 64
    # (64, 32) was already traced by the deploy-style dispatch above:
    # the refresh re-program hit the warm cache.
    assert pipeline.compile_count() == 0
