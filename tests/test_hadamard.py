"""Properties of the Hadamard read basis (paper Prop. 2.1 + eq. 7)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import hadamard as hd


@pytest.mark.parametrize("n", [2, 4, 8, 16, 32, 64, 128])
def test_sylvester_is_hadamard(n):
    h = np.asarray(hd.hadamard_matrix(n))
    assert hd.is_hadamard(h)
    # row 0 all ones; every other row balanced (sums to zero) -> eq. (7)
    assert np.all(h[0] == 1)
    assert np.all(h[1:].sum(axis=1) == 0)


@pytest.mark.parametrize("n", [8, 32, 64])
def test_prop21_variance_bound(n):
    """tr((A^T A)^-1) is minimized by Hadamard: identity gives N, H gives 1."""
    h = np.asarray(hd.hadamard_matrix(n), dtype=np.float64)
    tr_h = np.trace(np.linalg.inv(h.T @ h))
    tr_i = np.trace(np.linalg.inv(np.eye(n)))
    assert tr_h == pytest.approx(1.0, rel=1e-9)
    assert tr_i == pytest.approx(n)
    # a random +-1 matrix is never better than Hadamard
    rng = np.random.RandomState(0)
    for _ in range(5):
        a = rng.choice([-1.0, 1.0], size=(n, n))
        if abs(np.linalg.det(a)) < 1e-6:
            continue
        assert np.trace(np.linalg.inv(a.T @ a)) >= 1.0 - 1e-9


@settings(max_examples=25, deadline=None)
@given(
    logn=st.integers(1, 7),
    batch=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_fwht_matches_matmul(logn, batch, seed):
    n = 1 << logn
    x = np.random.RandomState(seed).randn(batch, n).astype(np.float32)
    h = np.asarray(hd.hadamard_matrix(n))
    np.testing.assert_allclose(
        np.asarray(hd.fwht(jnp.asarray(x))), x @ h, rtol=1e-4, atol=1e-4
    )


@settings(max_examples=20, deadline=None)
@given(logn=st.integers(1, 7), seed=st.integers(0, 2**31 - 1))
def test_encode_decode_roundtrip(logn, seed):
    n = 1 << logn
    x = jax.random.normal(jax.random.PRNGKey(seed), (3, n))
    np.testing.assert_allclose(
        np.asarray(hd.decode(hd.encode(x))), np.asarray(x), rtol=1e-4, atol=1e-4
    )


def test_uncorrelated_noise_variance_reduced_by_n():
    """Decoded uncorrelated-noise variance ~ sigma^2/N (Prop. 2.1)."""
    n, trials = 32, 20000
    key = jax.random.PRNGKey(0)
    noise = jax.random.normal(key, (trials, n))  # sigma = 1
    decoded = hd.decode(noise)
    var = float(jnp.var(decoded))
    assert var == pytest.approx(1.0 / n, rel=0.1)


def test_common_mode_cancellation_exact():
    """mu_cm maps to cell 0 only: (1/N) H^T (mu * 1) = mu * e1 (eq. 7)."""
    n = 32
    mu = 3.7
    decoded = np.asarray(hd.decode(jnp.full((1, n), mu)))
    assert decoded[0, 0] == pytest.approx(mu, rel=1e-6)
    np.testing.assert_allclose(decoded[0, 1:], 0.0, atol=1e-5)


def test_identity_passes_common_mode_everywhere():
    """Contrast: one-hot reads hand mu_cm to every cell unchanged."""
    n = 32
    mu = 3.7
    # identity read: y = w + mu ; "decode" is identity
    w = np.zeros(n)
    y = w + mu
    np.testing.assert_allclose(y, mu)  # all cells polluted
