"""Model-family correctness: decode path == training path, training works."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import SyntheticLM
from repro.models import ModelConfig, forward, init_params, prefill, decode_step
from repro.optim import AdamWConfig
from repro.training import init_train_state, make_train_step

V = 64
FAMILIES = {
    "dense": ModelConfig(
        name="d", n_layers=3, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=V, qk_norm=True, dtype=jnp.float32,
        attn_chunk_q=8, attn_chunk_kv=8, remat=False),
    "rwkv6": ModelConfig(
        name="r", n_layers=3, d_model=32, n_heads=4, n_kv_heads=4, head_dim=8,
        d_ff=64, vocab_size=V, block="rwkv6", pos_embedding="none",
        dtype=jnp.float32, remat=False),
    "hymba": ModelConfig(
        name="h", n_layers=4, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=V, block="hymba", ssm_state=4, sliding_window=8,
        global_layer_every=2, dtype=jnp.float32, attn_chunk_q=8,
        attn_chunk_kv=8, remat=False),
    "vlm": ModelConfig(
        name="v", n_layers=4, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=V, cross_attn_every=2, cross_kv_len=6,
        cross_d_cond=16, dtype=jnp.float32, attn_chunk_q=8, attn_chunk_kv=8,
        remat=False),
    "musicgen": ModelConfig(
        name="m", n_layers=3, d_model=32, n_heads=4, n_kv_heads=4, head_dim=8,
        d_ff=64, vocab_size=V, frontend="embed_stub", n_codebooks=4,
        pos_embedding="sinusoidal", cross_kv_len=6, cross_d_cond=16,
        tie_embeddings=False, dtype=jnp.float32, attn_chunk_q=8,
        attn_chunk_kv=8, remat=False),
}


def _batch(cfg, B=2, S=17):
    full = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, V)}
    if cfg.frontend == "embed_stub":
        full = {"embeds": jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))}
    if cfg.cross_kv_len:
        full["cond"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.cross_kv_len, cfg.cross_d_cond)
        )
    return full


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_decode_matches_forward(family):
    cfg = FAMILIES[family]
    params = init_params(jax.random.PRNGKey(0), cfg)
    S = 17
    full = _batch(cfg, S=S)
    logits_full, _, _ = forward(params, full, cfg)
    pre = {k: (v[:, : S - 1] if k in ("tokens", "embeds") else v) for k, v in full.items()}
    _, cache = prefill(params, pre, cfg, max_len=S + 4)
    step = {k: (v[:, S - 1 : S] if k in ("tokens", "embeds") else v) for k, v in full.items()}
    lg, cache = decode_step(params, cache, step, cfg)
    a, b = np.asarray(logits_full[:, -1]), np.asarray(lg[:, 0])
    rel = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert rel < 2e-3, (family, rel)
    assert int(cache["pos"][0]) == S - 1


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_multi_step_decode_no_nan(family):
    cfg = FAMILIES[family]
    params = init_params(jax.random.PRNGKey(0), cfg)
    full = _batch(cfg, S=9)
    _, cache = prefill(params, full, cfg, max_len=16)
    jit_step = jax.jit(lambda p, c, b: decode_step(p, c, b, cfg))
    step = {
        k: (v[:, -1:] if k in ("tokens", "embeds") else v)
        for k, v in full.items()
    }
    for _ in range(5):
        lg, cache = jit_step(params, cache, step)
        assert not bool(jnp.any(jnp.isnan(lg)))


def test_training_reduces_loss_dense():
    """Converges toward the bigram entropy floor: uniform ln(64)=4.16,
    optimal ln(16)=2.77."""
    cfg = ModelConfig(
        name="d2", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=64, dtype=jnp.float32, attn_chunk_q=16,
        attn_chunk_kv=16, remat=False)
    data = SyntheticLM(vocab_size=64, seq_len=32, global_batch=16, seed=2)
    opt = AdamWConfig(lr_peak=1e-2)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt, total_steps=150))
    losses = []
    for i in range(150):
        state, m = step(state, data.global_batch_at(i)._asdict())
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < 3.4, (losses[0], losses[-1])  # well below uniform 4.16


def test_gradients_flow_everywhere_rwkv():
    """No dead parameters: every leaf receives nonzero gradient."""
    cfg = FAMILIES["rwkv6"]
    params = init_params(jax.random.PRNGKey(0), cfg)
    from repro.models.transformer import loss_fn

    batch = {
        **_batch(cfg, S=16),
        "targets": jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0, V),
        "mask": jnp.ones((2, 16), jnp.float32),
    }
    grads = jax.grad(lambda p: loss_fn(p, batch, cfg)[0])(params)
    flat, _ = jax.tree_util.tree_flatten_with_path(grads)
    dead = [
        jax.tree_util.keystr(path)
        for path, g in flat
        if float(jnp.max(jnp.abs(g))) == 0.0
    ]
    # bonus_u may legitimately be near-zero early; everything else must live
    assert all("bonus_u" in d or "decay" in d for d in dead), dead
