"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles.

Kernels execute in interpret mode on CPU (same kernel body, Python
evaluation) — the sweep validates BlockSpec/grid logic and numerics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.acim_vmm import ops as vmm_ops, ref as vmm_ref
from repro.kernels.fwht import ops as fwht_ops, ref as fwht_ref
from repro.kernels.fwht.fwht import fwht_pallas
from repro.kernels.wv_step import ops as wv_ops, ref as wv_ref
from repro.kernels.wv_step.ref import WVCellParams


@pytest.mark.parametrize("n", [8, 16, 32, 64, 128])
@pytest.mark.parametrize("c", [1, 17, 512, 1000])
def test_fwht_shapes(n, c):
    x = jax.random.normal(jax.random.PRNGKey(c * 1000 + n), (c, n))
    np.testing.assert_allclose(
        np.asarray(fwht_ops.fwht(x)),
        np.asarray(fwht_ref.fwht(x)),
        rtol=1e-4,
        atol=1e-3,
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 32)).astype(dtype)
    out = fwht_ops.fwht(x)
    ref = fwht_ref.fwht(x.astype(jnp.float32))
    np.testing.assert_allclose(
        np.asarray(out, dtype=np.float32), np.asarray(ref), rtol=2e-2, atol=2e-1
    )


@pytest.mark.parametrize("block_c", [64, 256, 1024])
def test_fwht_block_sweep(block_c):
    x = jax.random.normal(jax.random.PRNGKey(1), (300, 32))
    out = fwht_pallas(x, block_c=block_c, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(fwht_ref.fwht(x)), rtol=1e-4, atol=1e-3
    )


def test_fwht_large_n_falls_back():
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 256))
    np.testing.assert_allclose(
        np.asarray(fwht_ops.fwht(x)), np.asarray(fwht_ref.fwht(x)), rtol=1e-4, atol=1e-3
    )


def _wv_args(c, n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    return (
        jax.random.normal(ks[0], (c, n)) * 8,
        jnp.abs(jax.random.normal(ks[1], (c, n))),
        jax.random.uniform(ks[2], (c, n), minval=0, maxval=7),
        jax.random.randint(ks[3], (c, n), 0, 3),
        jax.random.bernoulli(ks[4], 0.3, (c, n)),
        1 + 0.15 * jax.random.normal(ks[5], (c, n)),
        0.05 * jax.random.normal(ks[6], (c, n)),
        1 + 0.1 * jax.random.normal(ks[7], (c, n)),
    )


@pytest.mark.parametrize("c,n", [(16, 32), (300, 32), (128, 64), (64, 128)])
@pytest.mark.parametrize("ternary", [True, False])
@pytest.mark.parametrize("can_freeze", [True, False])
@pytest.mark.parametrize("nmap_sqrt", [True, False])
def test_wv_step_sweep(c, n, ternary, can_freeze, nmap_sqrt):
    p = WVCellParams(
        threshold=4.0 if ternary else 0.5, k_streak=2, can_freeze=can_freeze,
        ternary=ternary, fine_step=0.25, max_pulses=16.0, g_max=7.0,
        nonlinearity=0.35, reset_asymmetry=0.85, nmap_sqrt_pulses=nmap_sqrt,
    )
    args = _wv_args(c, n)
    outs_k = wv_ops.wv_cell_update(*args, p)
    outs_r = wv_ref.wv_cell_update(*args, p)
    for a, b in zip(outs_k, outs_r):
        np.testing.assert_allclose(
            np.asarray(a, dtype=np.float32),
            np.asarray(b, dtype=np.float32),
            rtol=1e-5,
            atol=1e-5,
        )


@pytest.mark.parametrize("b,k,m", [(8, 32, 64), (50, 32, 200), (128, 64, 128)])
@pytest.mark.parametrize("slices", [1, 2])
def test_acim_vmm_sweep(b, k, m, slices):
    x = jax.random.normal(jax.random.PRNGKey(b), (b, k))
    gp = jax.random.randint(jax.random.PRNGKey(k), (slices, k, m), 0, 8).astype(jnp.float32)
    gn = jax.random.randint(jax.random.PRNGKey(m), (slices, k, m), 0, 8).astype(jnp.float32)
    fs = float(k * 7)
    yk = vmm_ops.acim_vmm(x, gp, gn, bc=3, adc_bits=10, full_scale=fs)
    yr = vmm_ref.acim_vmm(x, gp, gn, 3, 10, fs)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=1e-4, atol=1e-2)


def test_acim_vmm_adc_saturates():
    """Columns beyond the ADC full scale clamp (macro behaviour)."""
    x = jnp.ones((1, 8)) * 100.0
    gp = jnp.full((1, 8, 4), 7.0)
    gn = jnp.zeros((1, 8, 4))
    y = vmm_ops.acim_vmm(x, gp, gn, bc=3, adc_bits=9, full_scale=56.0)
    assert float(jnp.max(y)) <= 28.0 + 1e-6
