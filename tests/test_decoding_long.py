"""Long-horizon decode correctness: ring-buffer wraparound + cache reuse.

The hymba SWA ring cache must stay exact after pos wraps past the window
(slots overwritten in ring order, RoPE applied at write time), and the
dense cache must support decoding well past the prefill length.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, forward, init_params, prefill, decode_step

V = 64


def _autoregress_reference(cfg, params, tokens):
    """Teacher-forced full forward at every step (O(S^2), exact)."""
    logits, _, _ = forward(params, {"tokens": tokens}, cfg)
    return logits


def test_hymba_ring_wraparound_exact():
    """Decode WINDOW+k steps: logits must match full forward at each pos."""
    cfg = ModelConfig(
        name="h", n_layers=4, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=V, block="hymba", ssm_state=4, sliding_window=6,
        global_layer_every=2, dtype=jnp.float32, attn_chunk_q=8,
        attn_chunk_kv=8, remat=False,
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    S_total = 21  # prefill 5 + 16 decode steps: wraps the 6-slot ring twice
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S_total), 0, V)

    ref = _autoregress_reference(cfg, params, toks)
    _, cache = prefill(params, {"tokens": toks[:, :5]}, cfg, max_len=S_total)
    step = jax.jit(lambda p, c, b: decode_step(p, c, b, cfg))
    for t in range(5, S_total):
        lg, cache = step(params, cache, {"tokens": toks[:, t : t + 1]})
        a, b = np.asarray(ref[:, t]), np.asarray(lg[:, 0])
        rel = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
        assert rel < 5e-3, (t, rel)


def test_dense_multi_decode_matches_forward():
    cfg = ModelConfig(
        name="d", n_layers=3, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=V, dtype=jnp.float32, attn_chunk_q=8,
        attn_chunk_kv=8, remat=False,
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    S_total = 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S_total), 0, V)
    ref = _autoregress_reference(cfg, params, toks)
    _, cache = prefill(params, {"tokens": toks[:, :4]}, cfg, max_len=S_total)
    step = jax.jit(lambda p, c, b: decode_step(p, c, b, cfg))
    for t in range(4, S_total):
        lg, cache = step(params, cache, {"tokens": toks[:, t : t + 1]})
        a, b = np.asarray(ref[:, t]), np.asarray(lg[:, 0])
        rel = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
        assert rel < 5e-3, (t, rel)


def test_rwkv_long_decode_state_stability():
    """RWKV state stays finite and logits sane over 50 decode steps."""
    cfg = ModelConfig(
        name="r", n_layers=2, d_model=32, n_heads=4, n_kv_heads=4, head_dim=8,
        d_ff=64, vocab_size=V, block="rwkv6", pos_embedding="none",
        dtype=jnp.float32, remat=False,
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, V)
    _, cache = prefill(params, {"tokens": toks}, cfg, max_len=8)
    step = jax.jit(lambda p, c, b: decode_step(p, c, b, cfg))
    cur = toks[:, -1:]
    for _ in range(50):
        lg, cache = step(params, cache, {"tokens": cur})
        assert bool(jnp.all(jnp.isfinite(lg)))
        cur = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)[:, None]
    assert bool(jnp.all(jnp.isfinite(cache["wkv"])))
