"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates its REDUCED config and runs one forward + one train step on
CPU, asserting output shapes and the absence of NaNs.  The FULL configs
are exercised by the dry-run only."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import init_params
from repro.models.transformer import forward, loss_fn
from repro.optim import AdamWConfig
from repro.training import init_train_state, make_train_step


def _smoke_batch(cfg, B=2, S=16, with_labels=True):
    key = jax.random.PRNGKey(0)
    batch = {}
    if cfg.frontend == "embed_stub":
        batch["embeds"] = 0.02 * jax.random.normal(key, (B, S, cfg.d_model))
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.cross_kv_len:
        batch["cond"] = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 1), (B, cfg.cross_kv_len, cfg.cross_d_cond)
        )
    if with_labels:
        tshape = (B, S, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, S)
        batch["targets"] = jax.random.randint(
            jax.random.fold_in(key, 2), tshape, 0, cfg.vocab_size
        )
        batch["mask"] = jnp.ones((B, S), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_well_formed(arch):
    cfg = get_config(arch)
    assert cfg.n_layers > 0 and cfg.vocab_size > 0
    assert cfg.param_count() > 1e8, f"{arch} param count suspiciously small"
    assert cfg.active_param_count() <= cfg.param_count()


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(cfg, with_labels=False)
    logits, aux, _ = jax.jit(lambda p, b: forward(p, b, cfg))(params, batch)
    if cfg.n_codebooks > 1:
        assert logits.shape == (2, 16, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits))), arch
    assert jnp.isfinite(aux), arch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    opt = AdamWConfig(lr_peak=1e-3)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt, total_steps=10))
    batch = _smoke_batch(cfg)
    state2, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"]), arch
    assert float(metrics["grad_norm"]) > 0, arch
    # parameters actually moved
    delta = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        state.params,
        state2.params,
    )
    assert max(jax.tree.leaves(delta)) > 0, arch
