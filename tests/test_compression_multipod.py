"""Cross-pod int8 gradient compression on a real "pod" mesh axis.

Runs in a subprocess with 8 forced host devices building a (2,2,2)
("pod","data","model") mesh; `compressed_psum` executes inside shard_map
over the pod axis and must (a) approximate the uncompressed cross-pod
mean within int8 tolerance, (b) drive the error-feedback residual's bias
to zero over repeated rounds, and (c) move ~4x fewer wire bytes (int8
payload + one f32 scale per row vs f32), which we assert structurally
from the compiled HLO's collective shapes.
"""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_debug_mesh
    from repro.optim import compressed_psum, init_compression_state
    from repro.optim.compression import CompressionState

    mesh = make_debug_mesh(2, 2, pods=2)

    def sync(grads, err):
        def body(g, e):
            out, st = compressed_psum({"g": g}, CompressionState(error={"g": e}),
                                      axis_name="pod")
            return out["g"] / 1.0, st.error["g"]
        return jax.shard_map(
            body, mesh=mesh,
            in_specs=(P("pod"), P("pod")),
            out_specs=(P("pod"), P("pod")),
            check_vma=False,
        )(grads, err)

    # per-pod gradients differ; the synced value must equal their mean.
    g = jnp.stack([jnp.linspace(-1, 1, 64), jnp.linspace(0, 2, 64)])  # (2 pods, 64)
    e = jnp.zeros_like(g)
    synced, e = jax.jit(sync)(g, e)
    true_mean = jnp.mean(g, axis=0)
    err0 = float(jnp.max(jnp.abs(synced[0] - true_mean)))
    assert err0 < 2e-2, err0
    print("COMPRESS-CORRECT-OK", err0)

    # error feedback: time-averaged synced gradient converges to the mean
    acc = jnp.zeros(64)
    e = jnp.zeros_like(g)
    jit_sync = jax.jit(sync)
    for _ in range(100):
        synced, e = jit_sync(g, e)
        acc = acc + synced[0]
    bias = float(jnp.max(jnp.abs(acc / 100 - true_mean)))
    assert bias < 2e-3, bias
    print("ERROR-FEEDBACK-OK", bias)

    # wire bytes: the cross-pod collective payload must be int (s32 sum of
    # int8 codes), not f32 gradients.
    txt = jax.jit(sync).lower(g, e).compile().as_text()
    lines = [l for l in txt.splitlines()
             if " all-reduce(" in l or " all-reduce-start(" in l]
    assert lines, "no all-reduce found"
    int_payload = [l for l in lines if "s32[" in l or "s8[" in l]
    assert int_payload, "cross-pod payload is not integer-compressed:" + lines[0]
    print("WIRE-INT8-OK", len(int_payload), "integer collectives")
    """
)


@pytest.mark.skipif(
    (os.cpu_count() or 1) < 4,
    reason="forced multi-device host simulation hangs XLA backend init on <4 cores",
)
def test_compressed_psum_on_pod_mesh():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd="/root/repo",
        timeout=420,
    )
    assert res.returncode == 0, res.stdout + res.stderr
    for tag in ("COMPRESS-CORRECT-OK", "ERROR-FEEDBACK-OK", "WIRE-INT8-OK"):
        assert tag in res.stdout, res.stdout + res.stderr
