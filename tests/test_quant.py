"""Quantization / bit-slicing / packing properties (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # dev-only dep, see requirements-dev.txt
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.quant import (
    QuantConfig,
    dequantize_weight,
    pack_columns,
    pair_to_signed,
    quantize_weight,
    signed_to_pair,
    slice_magnitudes,
    unpack_columns,
    unslice_magnitudes,
)


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    k=st.integers(1, 80),
    m=st.integers(1, 40),
)
def test_pack_unpack_roundtrip(seed, k, m):
    q = np.random.RandomState(seed).randint(-63, 64, size=(k, m))
    cols, layout = pack_columns(jnp.asarray(q), n_cells=32, bc=3, k_slices=2)
    assert cols.shape[1] == 32
    assert layout.num_columns == cols.shape[0]
    back = np.asarray(unpack_columns(cols, layout))
    np.testing.assert_array_equal(q, back)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bc=st.sampled_from([1, 2, 3]), kq=st.integers(1, 4))
def test_slice_unslice(seed, bc, kq):
    hi = (1 << (bc * kq)) - 1
    mag = np.random.RandomState(seed).randint(0, hi + 1, size=(37,))
    s = slice_magnitudes(jnp.asarray(mag), bc, kq)
    assert int(jnp.max(s)) < (1 << bc)
    back = np.asarray(unslice_magnitudes(s.astype(jnp.float32), bc))
    np.testing.assert_array_equal(mag, back)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_signed_pair_one_hot_hrs(seed):
    """Exactly one of (pos, neg) is nonzero per weight (HRS encodes zero)."""
    q = np.random.RandomState(seed).randint(-63, 64, size=(50,))
    pos, neg = signed_to_pair(jnp.asarray(q))
    assert bool(jnp.all((pos == 0) | (neg == 0)))
    np.testing.assert_array_equal(np.asarray(pair_to_signed(pos, neg)), q)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_quantize_error_bound(seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (32, 16)) * 0.05
    cfg = QuantConfig()
    q, scale = quantize_weight(w, cfg)
    wq = dequantize_weight(q, scale)
    # error bounded by half a quant step per channel
    assert bool(jnp.all(jnp.abs(wq - w) <= 0.5 * scale + 1e-9))
    assert int(jnp.max(jnp.abs(q))) <= cfg.q_max
