"""Faulty-silicon robustness layer (DESIGN.md Sec. 15).

Covers the three owners of the fault-model contract:

* device — fault sampling determinism (bucketing-independent per-column
  sub-streams), stuck-cell clamping, inert-map bit-identity;
* WV — bounded-retry give-up accounting rides `WVStats` without
  touching the zero-config decision stream;
* remap — spare-column table construction is a PERMUTATION onto
  distinct physical rows (hypothesis property), and the deploy path
  carries give-up/remap counts on its single host sync.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import default_config_for_array, pipeline, remap
from repro.core import device as dev_mod
from repro.core.programmer import deploy_arrays
from repro.core.types import FaultConfig, WVConfig, WVMethod
from repro.core.wv import program_columns

N = 16


def _cfg(**kw) -> WVConfig:
    return WVConfig(
        method=WVMethod.HARP, n_cells=N, max_fine_iters=20,
        max_coarse_iters=4, **kw,
    )


def _targets(c: int = 8, seed: int = 0) -> jax.Array:
    return jax.random.randint(
        jax.random.PRNGKey(seed), (c, N), 0, 8
    ).astype(jnp.float32)


_FAULTY = FaultConfig(
    p_stuck_hrs=0.05, p_stuck_lrs=0.03, p_weak=0.05,
    sigma_tile_fault_dec=0.5, columns_per_tile=4, tiles_per_chip=2,
)


# -------------------------------------------------------------- device
def test_fault_config_any_faults_gate():
    assert not FaultConfig().any_faults
    assert FaultConfig(p_weak=1e-4).any_faults
    assert FaultConfig(sigma_chip_eff_frac=0.1).any_faults


def test_fault_sampling_bucketing_independent():
    """A column's fault row depends only on (key, uid) — slicing the
    same uids out of a larger batch reproduces it bit-exactly."""
    key = jax.random.PRNGKey(3)
    dev = _cfg().device
    uids = jnp.arange(32, dtype=jnp.int32)
    full = dev_mod.sample_fault_map(key, uids, (32, N), _FAULTY, dev)
    sub = dev_mod.sample_fault_map(key, uids[5:9], (4, N), _FAULTY, dev)
    for a, b in zip(full, sub):
        np.testing.assert_array_equal(np.asarray(a[5:9]), np.asarray(b))


def test_stuck_cells_pinned_after_programming():
    t = _targets()
    fmap = dev_mod.sample_fault_map(
        jax.random.PRNGKey(1), jnp.arange(t.shape[0], dtype=jnp.int32),
        t.shape, _FAULTY, _cfg().device,
    )
    assert bool(jnp.any(fmap.stuck)), "fault rate too low to test clamping"
    g, _ = program_columns(
        jax.random.PRNGKey(2), t, _cfg(give_up_pulses=20), fault=fmap
    )
    np.testing.assert_array_equal(
        np.asarray(jnp.where(fmap.stuck, g, 0.0)),
        np.asarray(jnp.where(fmap.stuck, fmap.stuck_g, 0.0)),
    )


# ------------------------------------------------------------------ wv
def test_inert_fault_and_give_up_bit_identical():
    """fault=None, an all-empty map, and a generous give-up budget all
    produce the same conductances and zero give-up counters."""
    t = _targets()
    g0, s0 = program_columns(jax.random.PRNGKey(7), t, _cfg())
    g1, s1 = program_columns(
        jax.random.PRNGKey(7), t, _cfg(give_up_pulses=500),
        fault=dev_mod.empty_fault_map(t.shape),
    )
    np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))
    # legacy config: counters exist but stay zero without a budget
    assert float(jnp.sum(s0.gave_up)) == 0.0
    assert float(jnp.sum(s0.retry_pulses)) == 0.0
    # with a budget, gave_up counts never-converged cells (documented:
    # cells still unfrozen at max_fine_iters) even when nothing exhausts
    # the pulse budget — and each such cell carries its burned pulses
    gu, rp = np.asarray(s1.gave_up), np.asarray(s1.retry_pulses)
    assert (rp[gu > 0] > 0).all()
    assert (rp[gu == 0] == 0).all()


def test_give_up_fires_on_faulty_cells_and_counts_retries():
    t = _targets()
    fmap = dev_mod.sample_fault_map(
        jax.random.PRNGKey(1), jnp.arange(t.shape[0], dtype=jnp.int32),
        t.shape, _FAULTY, _cfg().device,
    )
    _, st = program_columns(
        jax.random.PRNGKey(2), t, _cfg(give_up_pulses=20), fault=fmap
    )
    assert float(jnp.sum(st.gave_up)) > 0
    assert float(jnp.sum(st.retry_pulses)) > 0
    # every stuck cell that needed pulses must eventually give up:
    # give-up count per column >= stuck-and-nonzero-target cells
    assert float(jnp.sum(st.gave_up)) >= 0.5 * float(jnp.sum(fmap.stuck))


# --------------------------------------------------------------- remap
@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(4, 48), st.integers(1, 12))
def test_remap_table_is_permutation(seed, c, s):
    """For ANY give-up profile and spare quality, the table maps the C
    logical columns onto C DISTINCT physical rows of the C+S array, and
    `active` is exactly the image of the permutation."""
    rng = np.random.default_rng(seed)
    s = min(s, c)
    prim = jnp.asarray(rng.integers(0, 5, c).astype(np.float32))
    spare = jnp.asarray(rng.integers(0, 5, s).astype(np.float32))
    cand = remap.spare_candidates(prim, s)
    tbl = remap.build_table(prim, cand, spare)
    perm = np.asarray(tbl.perm)
    active = np.asarray(tbl.active)
    assert perm.shape == (c,) and active.shape == (c + s,)
    assert len(np.unique(perm)) == c, "perm must be injective"
    assert perm.min() >= 0 and perm.max() < c + s
    image = np.zeros(c + s, bool)
    image[perm] = True
    np.testing.assert_array_equal(image, active)
    # a remap only happens toward a spare at least as good as its primary
    moved = perm >= c
    if moved.any():
        prim_np, spare_np = np.asarray(prim), np.asarray(spare)
        assert all(
            spare_np[perm[i] - c] <= prim_np[i] for i in np.nonzero(moved)[0]
        )


def test_identity_table_roundtrip():
    tbl = remap.identity_table(6, 2)
    x = jnp.arange(8.0)[:, None] * jnp.ones((1, 3))
    np.testing.assert_array_equal(
        np.asarray(remap.apply_remap(x, tbl)), np.asarray(x[:6])
    )
    assert remap.apply_remap(x, None) is x


def test_plan_placement_prefers_clean_tiles():
    fc = FaultConfig(p_stuck_hrs=0.01, sigma_tile_fault_dec=1.0,
                     columns_per_tile=8, tiles_per_chip=4)
    key = jax.random.PRNGKey(11)
    plans = remap.plan_placement(key, [16, 8], fc, sensitivities=[1.0, 2.0])
    assert [len(p) for p in plans] == [16, 8]
    all_uids = np.concatenate(plans)
    assert len(np.unique(all_uids)) == 24, "placement must not alias uids"
    # the most sensitive leaf (index 1) got the cleanest tiles
    q = np.asarray(dev_mod.tile_quality(
        key, jnp.arange(int(all_uids.max() // 8 + 1), dtype=jnp.int32), fc
    ))
    mean_q = [float(np.mean(q[np.unique(p // 8)])) for p in plans]
    assert mean_q[1] <= mean_q[0]


# -------------------------------------------------------------- deploy
def test_deploy_zero_fault_bit_identical_single_sync():
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (24, 12)) * 0.2}
    wv = default_config_for_array(N)
    dep0, _ = deploy_arrays(jax.random.PRNGKey(5), params, wv, min_bucket=16)
    before = pipeline.host_sync_count()
    dep1, rep1 = deploy_arrays(
        jax.random.PRNGKey(5), params, wv.replace(give_up_pulses=500),
        min_bucket=16, fault_cfg=FaultConfig(),
    )
    assert pipeline.host_sync_count() - before == 1
    m0, m1 = dep0.materialize(), dep1.materialize()
    np.testing.assert_array_equal(np.asarray(m0["w"]), np.asarray(m1["w"]))
    assert rep1.total_gave_up_cells == 0.0
    assert rep1.remapped_columns == 0


def test_deploy_fault_remap_reports_on_single_sync():
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (24, 12)) * 0.2}
    wv = default_config_for_array(N).replace(give_up_pulses=24)
    fc = FaultConfig(p_stuck_hrs=0.05, p_weak=0.05,
                     columns_per_tile=8, tiles_per_chip=2)
    before = pipeline.host_sync_count()
    dep, rep = deploy_arrays(
        jax.random.PRNGKey(5), params, wv, min_bucket=16,
        fault_cfg=fc, remap_cfg=remap.RemapConfig(spare_frac=0.25),
    )
    assert pipeline.host_sync_count() - before == 1, (
        "give-up/remap accounting must ride the existing single fetch"
    )
    assert rep.total_gave_up_cells > 0
    assert rep.remapped_columns > 0
    arr = dep.arrays["['w']"]
    assert arr.remap is not None and arr.fault is not None
    c = arr.remap.perm.shape[0]
    assert arr.g.shape[0] == arr.remap.active.shape[0] == c + (
        remap.n_spares(c, remap.RemapConfig(spare_frac=0.25))
    )
    assert arr.g.shape[0] > c, "remapped state must hold physical C+S rows"
    # materialize serves the repaired logical view
    assert dep.materialize()["w"].shape == params["w"].shape
