"""Analog compute-in-memory serving (repro.cim, DESIGN.md Sec. 11).

Covers the ISSUE-3 contracts:
* acim_vmm high-bit / zero-noise parity vs a float matmul across dtypes;
* tile pack -> unpack roundtrip vs the quant.pack layout;
* fused (Pallas) vs unfused reference bit-identity of the CIM forward;
* analog-served logits == digitally materialized logits in the ideal
  limit (DAC/ADC -> infinity, read noise -> 0);
* read-noise RNG policy: bit-reproducible across batch shapes, fresh
  per access;
* serving traffic -> real per-array read-disturb counts in lifetime;
* cost-model inference phase accounting.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cim import (
    CIMConfig,
    CIMExecutor,
    CIMWeight,
    build_weight,
    cim_matmul,
    cim_vmm,
    planes_per_token,
    slice_planes,
    token_stream_ids,
)
from repro.cim.tile import rekey
from repro.core import ADCConfig, CircuitCost, WVConfig, WVMethod
from repro.core.cost import inference_token_cost
from repro.core.programmer import ArrayState, deploy_arrays
from repro.lifetime import DriftConfig, LifetimeSimulator, RefreshConfig, RefreshPolicy
from repro.models import ModelConfig, init_params
from repro.models.transformer import forward
from repro.quant import pack_columns, unpack_columns
from repro.serving import ServeEngine

IDEAL = CIMConfig(dac_bits=None, adc_bits=None, sigma_read_lsb=0.0)


# ------------------------------------------------------------------ helpers
def _tiny_cfg() -> ModelConfig:
    return ModelConfig(
        name="cim-test", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=32, dtype=jnp.float32,
        attn_chunk_q=16, attn_chunk_kv=16, remat=False, tie_embeddings=False,
    )


@pytest.fixture(scope="module")
def deployed_tiny():
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    wv = WVConfig(method=WVMethod.HARP, max_fine_iters=12, max_coarse_iters=4)
    deployed, _ = deploy_arrays(jax.random.PRNGKey(1), params, wv)
    return cfg, deployed


def _synthetic_state(key, k_in=48, m_out=20, n_cells=32, bc=3, slices=2):
    """Perfectly programmed ArrayState for a random int weight matrix."""
    q_max = (1 << (bc * slices)) - 1
    q = jax.random.randint(key, (k_in, m_out), -q_max, q_max + 1)
    scale = 0.01 * (1.0 + jnp.arange(m_out, dtype=jnp.float32))[None, :]
    cols, layout = pack_columns(q, n_cells, bc, slices)
    return ArrayState(
        g=cols, targets=cols, d2d=jnp.ones_like(cols), scale=scale,
        layout=layout, shape=(k_in, m_out), dtype=jnp.float32,
    ), q


# ------------------------------------------------- kernel-level parity
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("adc_bits", [None, 24])
def test_acim_vmm_highbit_zero_noise_is_float_matmul(dtype, adc_bits):
    """ADC bits -> infinity + zero noise collapses to the f32 matmul."""
    x = jax.random.normal(jax.random.PRNGKey(0), (9, 32)).astype(dtype)
    gp = jax.random.randint(jax.random.PRNGKey(1), (2, 32, 40), 0, 8).astype(jnp.float32)
    gn = jax.random.randint(jax.random.PRNGKey(2), (2, 32, 40), 0, 8).astype(jnp.float32)
    w_eff = sum(
        float(1 << (3 * l)) * (gp[l] - gn[l]) for l in range(2)
    )
    want = x.astype(jnp.float32) @ w_eff
    for use_pallas in (False, True):
        got = cim_vmm(
            x, gp, gn, bc=3, adc_bits=adc_bits, full_scale=2.0 * 32 * 7,
            use_pallas=use_pallas,
        )
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=5e-3
        )


def test_acim_vmm_noise_enters_before_adc():
    """Noise shifts each slice's partial sum pre-quantization."""
    x = jnp.ones((1, 4))
    gp = jnp.array([[[2.0]] * 4])  # (1, 4, 1)
    gn = jnp.zeros((1, 4, 1))
    nz = jnp.full((1, 1, 1), 3.0)
    clean = cim_vmm(x, gp, gn, bc=3, adc_bits=None, full_scale=56.0,
                    use_pallas=False)
    noisy = cim_vmm(x, gp, gn, bc=3, adc_bits=None, full_scale=56.0,
                    noise=nz, use_pallas=False)
    np.testing.assert_allclose(np.asarray(noisy - clean), 3.0)


# ------------------------------------------------------ tile layout
def test_tile_roundtrip_matches_quant_pack():
    """slice_planes + slice recombination == quant.pack's unpack."""
    state, q = _synthetic_state(jax.random.PRNGKey(3))
    gp, gn = slice_planes(state.g, state.layout)
    w_signed = sum(
        float(1 << (state.layout.bc * l)) * (gp[l] - gn[l])
        for l in range(state.layout.slices)
    )
    np.testing.assert_allclose(
        np.asarray(w_signed),
        np.asarray(unpack_columns(state.g, state.layout)),
        rtol=0, atol=0,
    )
    np.testing.assert_array_equal(np.asarray(w_signed), np.asarray(q))


@pytest.mark.parametrize("macro_rows", [16, 32, 128])
def test_tiled_ideal_matmul_matches_materialize(macro_rows):
    """Ideal analog forward through tiles == x @ materialize()."""
    state, _ = _synthetic_state(jax.random.PRNGKey(4), k_in=70, m_out=12)
    cfg = dataclasses.replace(IDEAL, macro_rows=macro_rows)
    w = build_weight(state, cfg, jax.random.PRNGKey(5), name="t")
    assert w.tile_rows <= macro_rows
    x = jax.random.normal(jax.random.PRNGKey(6), (5, 70), jnp.float32)
    got = cim_matmul(x, w)
    want = x @ state.materialize(dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_stacked_weight_slices_like_dense_leaf():
    """A stacked CIMWeight sliced by tree.map equals per-layer tiling."""
    k_in, m = 64, 10
    state, q = _synthetic_state(jax.random.PRNGKey(7), k_in=k_in, m_out=m)
    stacked = dataclasses.replace(state, shape=(2, k_in // 2, m))
    w = build_weight(stacked, IDEAL, jax.random.PRNGKey(8), name="s")
    assert w.g_pos.ndim == 5 and w.g_pos.shape[0] == 2
    x = jax.random.normal(jax.random.PRNGKey(9), (3, k_in // 2), jnp.float32)
    dense = state.materialize(dtype=jnp.float32)  # (K, M)
    for idx in range(2):
        wl = jax.tree.map(lambda a: a[idx], w)
        assert isinstance(wl, CIMWeight)
        got = cim_matmul(x, wl)
        want = x @ dense[idx * (k_in // 2) : (idx + 1) * (k_in // 2)]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


# --------------------------------------------- fused vs unfused forward
def test_cim_forward_fused_vs_reference_bit_identical():
    """The full noisy bit-serial forward: Pallas == reference, bitwise."""
    state, _ = _synthetic_state(jax.random.PRNGKey(10), k_in=48, m_out=24)
    base = CIMConfig(dac_bits=5, adc_bits=9, sigma_read_lsb=0.4, macro_rows=32)
    key = jax.random.PRNGKey(11)
    w_ref = rekey(build_weight(state, base, key, name="b"), key)
    w_pal = rekey(
        build_weight(state, base.replace(use_pallas=True), key, name="b"), key
    )
    x = jax.random.normal(jax.random.PRNGKey(12), (6, 48), jnp.float32)
    y_ref = cim_matmul(x, w_ref)
    y_pal = cim_matmul(x, w_pal)
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_pal))
    # and under jit
    y_ref_j = jax.jit(cim_matmul)(x, w_ref)
    y_pal_j = jax.jit(cim_matmul)(x, w_pal)
    np.testing.assert_array_equal(np.asarray(y_ref_j), np.asarray(y_pal_j))


def test_request_id_stream_batch_composition_invariant():
    """ISSUE-9 tentpole: request ids (not batch slots) key the CIM noise
    sub-streams, so a row's analog output depends only on its own id —
    bit-identical alone, in any slot, and under the ambient
    `token_stream_ids` context the serving scheduler installs."""
    state, _ = _synthetic_state(jax.random.PRNGKey(20), k_in=48, m_out=16)
    cfg = CIMConfig(dac_bits=4, adc_bits=9, sigma_read_lsb=0.4)
    key = jax.random.PRNGKey(21)
    w = rekey(build_weight(state, cfg, key, name="inv"), key)
    x = jax.random.normal(jax.random.PRNGKey(22), (5, 48), jnp.float32)
    ids = jnp.array([11, 3, 7, 5, 2], jnp.int32)
    y = cim_matmul(x, w, token_ids=ids)
    for row in (0, 2, 4):  # alone (batch of 1) vs inside the full batch
        y1 = cim_matmul(x[row : row + 1], w, token_ids=ids[row : row + 1])
        np.testing.assert_array_equal(np.asarray(y1[0]), np.asarray(y[row]))
    perm = jnp.array([4, 0, 3, 1, 2])  # same requests, shuffled slots
    y_shuf = cim_matmul(x[perm], w, token_ids=ids[perm])
    np.testing.assert_array_equal(np.asarray(y_shuf), np.asarray(y[perm]))
    with token_stream_ids(ids):  # scheduler-style ambient stream
        y_ctx = cim_matmul(x, w)
    np.testing.assert_array_equal(np.asarray(y_ctx), np.asarray(y))


# ------------------------------------------------ RNG policy / noise
def test_read_noise_reproducible_across_batch_shapes():
    state, _ = _synthetic_state(jax.random.PRNGKey(13))
    cfg = CIMConfig(dac_bits=5, adc_bits=10, sigma_read_lsb=0.5)
    w = rekey(build_weight(state, cfg, jax.random.PRNGKey(14)),
              jax.random.PRNGKey(14))
    x2 = jax.random.normal(jax.random.PRNGKey(15), (2, 48), jnp.float32)
    x5 = jnp.concatenate(
        [x2, jax.random.normal(jax.random.PRNGKey(16), (3, 48), jnp.float32)]
    )
    y2 = cim_matmul(x2, w)
    y5 = cim_matmul(x5, w)
    np.testing.assert_array_equal(np.asarray(y2), np.asarray(y5[:2]))


def test_read_noise_fresh_per_access(deployed_tiny):
    cfg, deployed = deployed_tiny
    noisy = CIMConfig(dac_bits=5, adc_bits=10, sigma_read_lsb=0.5)
    toks = jax.random.randint(jax.random.PRNGKey(17), (2, 4), 0, cfg.vocab_size)
    ex = CIMExecutor(deployed, noisy, jax.random.PRNGKey(18))
    la, _, _ = forward(ex.tick(8), {"tokens": toks}, cfg)
    lb, _, _ = forward(ex.tick(8), {"tokens": toks}, cfg)
    assert float(jnp.max(jnp.abs(la - lb))) > 0.0
    # a fresh executor with the same master key replays access 1 exactly
    ex2 = CIMExecutor(deployed, noisy, jax.random.PRNGKey(18))
    lc, _, _ = forward(ex2.tick(8), {"tokens": toks}, cfg)
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lc))


# ------------------------------------- end-to-end equivalence contract
def test_analog_serving_matches_materialized_logits(deployed_tiny):
    """ADC -> infinity, DAC -> infinity, noise -> 0: analog == digital."""
    cfg, deployed = deployed_tiny
    ex = CIMExecutor(deployed, IDEAL, jax.random.PRNGKey(19))
    assert len(ex._analog) == 8  # 7 layer projections + lm_head
    toks = jax.random.randint(jax.random.PRNGKey(20), (2, 6), 0, cfg.vocab_size)
    la, _, _ = forward(ex.params(), {"tokens": toks}, cfg)
    ld, _, _ = forward(deployed.materialize(), {"tokens": toks}, cfg)
    np.testing.assert_allclose(np.asarray(la), np.asarray(ld),
                               rtol=1e-4, atol=1e-5)


def test_serve_engine_analog_generate(deployed_tiny):
    """ServeEngine drives the executor: params per access, reads counted."""
    cfg, deployed = deployed_tiny
    ex = CIMExecutor(deployed, IDEAL, jax.random.PRNGKey(21))
    engine = ServeEngine(cfg, executor=ex)
    toks = jax.random.randint(jax.random.PRNGKey(22), (2, 4), 0, cfg.vocab_size)
    out = engine.generate(toks, max_new=3)
    assert out.shape == (2, 3)
    # prefill (2*4 tokens) + 2 decode accesses (2 tokens each)
    assert ex.tokens_served == 12
    reads = ex.drain_reads()
    assert set(reads) == set(ex._analog)
    assert all(v == 12.0 * ex.planes for v in reads.values())
    assert all(v == 0.0 for v in ex.drain_reads().values())  # drained


# --------------------------------------------- lifetime traffic wiring
def test_cim_reads_drive_read_disturb_drift(deployed_tiny):
    """Served traffic -> real per-array read counts -> measurable drift."""
    cfg, deployed = deployed_tiny
    ex = CIMExecutor(
        deployed, CIMConfig(dac_bits=6, adc_bits=10), jax.random.PRNGKey(23)
    )
    ex.tick(500)  # 500 served tokens of traffic
    drift_cfg = DriftConfig(
        read_disturb_lsb=1e-3, nu_drift=0.0, relax_frac=0.0,
        sigma_relax_lsb=0.0,
    )
    quiet = RefreshConfig(policy=RefreshPolicy.NONE)
    sim = LifetimeSimulator(
        jax.random.PRNGKey(24), deployed, drift_cfg, quiet,
        traffic_fn=ex.drain_reads,
    )
    g_before = {n: st.g for n, st in sim.states.items()}
    rec = sim.step_epoch(dt_s=1.0)
    expect = 500.0 * ex.planes
    analog, digital = 0, 0
    for name, st in sim.states.items():
        if name in ex._analog:
            assert float(st.reads[0, 0]) == expect, name
            # SET-ward read disturb moved unsaturated cells up
            moved = jnp.mean(st.g - g_before[name])
            assert float(moved) > 0.0, name
            analog += 1
        else:
            assert float(st.reads[0, 0]) == 0.0, name
            np.testing.assert_array_equal(
                np.asarray(st.g), np.asarray(g_before[name])
            )
            digital += 1
    assert analog == 8 and digital > 0
    assert rec.reads_per_column > 0.0
    # next epoch with no new traffic: counts drained, no further disturb
    rec2 = sim.step_epoch(dt_s=1.0)
    assert rec2.reads_per_column == 0.0


def test_executor_reviews_aged_arrays(deployed_tiny):
    """update_array (drift/refresh) is visible at the next params()."""
    cfg, deployed = deployed_tiny
    ex = CIMExecutor(deployed, IDEAL, jax.random.PRNGKey(25))
    name = "['layers']['wq']"
    before = ex.params()
    old_g = deployed.arrays[name].g
    try:
        deployed.update_array(name, old_g + 0.5)
        after = ex.params()
        b = before["layers"]["wq"].g_pos
        a = after["layers"]["wq"].g_pos
        assert float(jnp.max(jnp.abs(a - b))) > 0.0
    finally:
        deployed.update_array(name, old_g)
        ex.params()


# ------------------------------------------------------ cost accounting
def test_inference_token_cost_scales_with_planes():
    adc, cost = ADCConfig(), CircuitCost()
    l1, e1 = inference_token_cost(100, 50, planes=1, adc=adc, cost=cost)
    l8, e8 = inference_token_cost(100, 50, planes=8, adc=adc, cost=cost)
    assert l8 > l1 and e8 == pytest.approx(8 * e1)
    assert e1 > 0 and l1 > 0


def test_executor_token_cost(deployed_tiny):
    cfg, deployed = deployed_tiny
    ex = CIMExecutor(
        deployed, CIMConfig(dac_bits=6, adc_bits=10), jax.random.PRNGKey(26)
    )
    assert ex.planes == planes_per_token(ex.cfg) == 10
    lat, en = ex.token_cost()
    assert lat > 0 and en > 0
    ideal = CIMExecutor(deployed, IDEAL, jax.random.PRNGKey(27))
    lat1, en1 = ideal.token_cost()
    assert ideal.planes == 1 and lat1 < lat and en1 < en
    s = ex.summary()
    assert s["analog_leaves"] == 8 and s["planes_per_token"] == 10
