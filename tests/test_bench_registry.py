"""Tier-1 smoke for the benchmarks/run.py registry + repo hygiene.

The registry is LAZY (no jax import for --list / bad names), so the
listing and error paths are cheap subprocesses; one genuinely tiny
quick-mode benchmark runs end-to-end to prove the dispatch path works.
Hygiene: compiled-bytecode artifacts must never be tracked.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(*args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", *args],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=timeout,
    )


def test_list_names_without_importing_jax():
    r = _run("--list", timeout=120)
    assert r.returncode == 0, r.stderr
    names = [ln.split()[0] for ln in r.stdout.splitlines() if ln.strip()]
    for expected in (
        "fig9.convergence", "serving.traffic", "readout.sweep",
        "fault.tolerance",
    ):
        assert expected in names
    assert "[quick]" in r.stdout  # quick-capable entries are tagged


def test_unknown_benchmark_exits_nonzero():
    r = _run("definitely.not.a.benchmark", timeout=120)
    assert r.returncode != 0
    assert "unknown benchmark" in r.stderr
    # non-quick-capable selection under --quick is also an error
    r2 = _run("fig9.convergence", "--quick", timeout=120)
    assert r2.returncode != 0
    assert "not quick-capable" in r2.stderr


def test_tiny_quick_benchmark_runs():
    """One real quick-mode benchmark through the registry dispatch."""
    r = _run("readout.sweep", "--quick")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "all-passed" in r.stdout


def test_baseline_check_key_resolution():
    """_resolve_key resolves dotted paths longest-prefix-first so
    literal dotted key names (e.g. "sigma0.7__logit_rmse") work, and
    every declared baseline check targets a key the committed full
    BENCH json actually has (a renamed key must fail here, not silently
    SKIP in CI)."""
    import json

    sys.path.insert(0, REPO)
    try:
        from benchmarks.run import BASELINE_CHECKS, _resolve_key
    finally:
        sys.path.remove(REPO)

    doc = {"a": {"b.c": {"d": 1.0}}, "x.y": 2.0, "x": {"y": 3.0}}
    assert _resolve_key(doc, "a.b.c.d") == 1.0
    assert _resolve_key(doc, "x.y") == 2.0  # literal dotted key wins
    assert _resolve_key(doc, "a.nope") is None
    assert _resolve_key(doc, "") == doc

    for bench, (full_file, _, checks) in BASELINE_CHECKS.items():
        path = os.path.join(REPO, "benchmarks", full_file)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            full = json.load(f)
        for key, mode, _ in checks:
            assert mode in ("eq", "min", "rel"), (bench, key, mode)
            assert _resolve_key(full, key) is not None, (
                f"{bench}: check key {key!r} missing from {full_file}"
            )


# ------------------------------------------------------------------ hygiene
def _git_ls_files():
    try:
        out = subprocess.run(
            ["git", "ls-files"], cwd=REPO, capture_output=True, text=True,
            timeout=60,
        )
    except (OSError, subprocess.TimeoutExpired):
        pytest.skip("git unavailable")
    if out.returncode != 0:
        pytest.skip("not a git checkout")
    return out.stdout.splitlines()

def test_committed_bench_json_parse_and_finite():
    """Every committed BENCH_*.json must parse and hold only finite
    numbers — a NaN/Infinity in a pinned trajectory means a benchmark
    silently diverged and its assertions let it through."""
    import json
    import math

    tracked = [
        f for f in _git_ls_files()
        if f.startswith("benchmarks/BENCH_") and f.endswith(".json")
    ]
    assert tracked, "no committed BENCH_*.json trajectories found"

    def walk(x, path):
        if isinstance(x, dict):
            for k, v in x.items():
                walk(v, f"{path}.{k}")
        elif isinstance(x, list):
            for i, v in enumerate(x):
                walk(v, f"{path}[{i}]")
        elif isinstance(x, float):
            assert math.isfinite(x), f"non-finite value at {path}: {x}"

    for f in tracked:
        with open(os.path.join(REPO, f)) as fh:
            walk(json.load(fh), f)


def test_no_bytecode_tracked_and_ignored():
    """No .pyc/__pycache__ may ever be committed; .gitignore blocks them."""
    tracked = _git_ls_files()
    offenders = [
        f for f in tracked if f.endswith(".pyc") or "__pycache__" in f
    ]
    assert offenders == [], offenders
    with open(os.path.join(REPO, ".gitignore")) as f:
        gitignore = f.read().splitlines()
    assert "__pycache__/" in gitignore
    assert "*.pyc" in gitignore


def test_no_quick_or_trace_artifacts_tracked():
    """Quick-mode BENCH json, trace exports, and fleet-status/dashboard
    files are per-run artifacts: regenerated by every CI smoke, never
    meaningful to diff.  Only the full-mode BENCH_*.json trajectories
    are committed; everything else must stay untracked, and .gitignore
    must carry the GLOBS (not an enumerated name list that silently
    rots as benchmarks are added)."""
    offenders = [
        f for f in _git_ls_files()
        if f.startswith("benchmarks/")
        and (
            f.endswith("_quick.json")
            or os.path.basename(f).startswith("TRACE_")
            or os.path.basename(f).startswith("fleet_status")
            or f.endswith(".html")
        )
    ]
    assert offenders == [], offenders
    with open(os.path.join(REPO, ".gitignore")) as f:
        gitignore = f.read().splitlines()
    for glob in (
        "benchmarks/*_quick.json",
        "benchmarks/TRACE_*.json",
        "benchmarks/fleet_status*.json",
    ):
        assert glob in gitignore, f"missing {glob!r} in .gitignore"
