"""Unified readout subsystem: converter edge cases, averaging physics,
offset calibration, and bit-identity of the refactored WV / refresh /
CIM read paths against pre-refactor goldens.

The golden archive (tests/golden/readout_golden.npz) was captured from
the tree BEFORE the read path was extracted into `repro.readout`
(generator: tests/golden/gen_readout_golden.py), so every
`assert_array_equal` below proves the refactor is a pure factoring of
the three previously-divergent read-path implementations.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cim import CIMConfig, cim_matmul, tile
from repro.core import ADCConfig, CircuitCost, NoiseConfig, WVConfig, WVMethod
from repro.core.cost import read_phase_cost
from repro.core.wv import program_columns, verify_aggregate
from repro.lifetime.refresh import flag_columns
from repro.quant import QuantConfig, pack_columns, quantize_weight
from repro.readout import (
    Converter,
    ReadoutBasis,
    ReadoutConfig,
    calibrate_offsets,
    compare_read,
    decode_magnitude,
    for_wv_method,
    full_scale_lsb,
    read_columns,
    sample_col_offsets,
    sar_quantize,
    sar_read,
    sweep_cost,
)

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "readout_golden.npz")
N = 16


@pytest.fixture(scope="module")
def golden():
    return np.load(GOLDEN)


def _cfg(method: WVMethod, **kw) -> WVConfig:
    # Must mirror tests/golden/gen_readout_golden.py exactly.
    return WVConfig(
        method=method,
        n_cells=N,
        adc=ADCConfig(bits=9),
        tau_w=4.0 * N / 32.0,
        noise=NoiseConfig(sigma_read_lsb=0.7, rho_cm=0.3),
        max_fine_iters=25,
        **kw,
    )


@pytest.fixture(scope="module")
def targets():
    return jax.random.randint(jax.random.PRNGKey(0), (12, N), 0, 8).astype(
        jnp.float32
    )


# ---------------------------------------------------- golden bit-identity
@pytest.mark.parametrize("method", list(WVMethod))
def test_programming_bit_identical_to_pre_refactor(golden, targets, method):
    cfg = _cfg(method)
    g, stats = jax.jit(lambda k, t: program_columns(k, t, cfg))(
        jax.random.PRNGKey(42), targets
    )
    np.testing.assert_array_equal(np.asarray(g), golden[f"prog_g_{method.value}"])
    np.testing.assert_array_equal(
        np.asarray(stats.energy_pj), golden[f"prog_energy_{method.value}"]
    )
    np.testing.assert_array_equal(
        np.asarray(stats.latency_ns), golden[f"prog_latency_{method.value}"]
    )
    np.testing.assert_array_equal(
        np.asarray(stats.reads), golden[f"prog_reads_{method.value}"]
    )


@pytest.mark.parametrize("method", list(WVMethod))
def test_colid_substream_bit_identical_to_pre_refactor(golden, targets, method):
    cfg = _cfg(method)
    col_ids = 100 + jnp.arange(targets.shape[0], dtype=jnp.int32)
    g, _ = jax.jit(lambda k, t, i: program_columns(k, t, cfg, col_ids=i))(
        jax.random.PRNGKey(42), targets, col_ids
    )
    np.testing.assert_array_equal(
        np.asarray(g), golden[f"prog_g_colids_{method.value}"]
    )


@pytest.mark.parametrize("method", list(WVMethod))
def test_verify_aggregate_bit_identical_to_pre_refactor(golden, targets, method):
    g_free = targets + 0.4 * jax.random.normal(
        jax.random.PRNGKey(1), targets.shape
    )
    agg, mag, ncmp, thr = verify_aggregate(
        jax.random.PRNGKey(5), g_free, targets, _cfg(method)
    )
    np.testing.assert_array_equal(np.asarray(agg), golden[f"agg_{method.value}"])
    np.testing.assert_array_equal(np.asarray(mag), golden[f"mag_{method.value}"])
    np.testing.assert_array_equal(np.asarray(ncmp), golden[f"ncmp_{method.value}"])
    assert np.float32(thr) == golden[f"thr_{method.value}"]


@pytest.mark.parametrize("method", [WVMethod.HARP, WVMethod.HD_PV])
def test_fused_pallas_loop_bit_identical_to_pre_refactor(golden, targets, method):
    cfg = _cfg(method, use_pallas=True)
    g, _ = jax.jit(lambda k, t: program_columns(k, t, cfg))(
        jax.random.PRNGKey(42), targets
    )
    np.testing.assert_array_equal(
        np.asarray(g), golden[f"prog_g_pallas_{method.value}"]
    )


@pytest.mark.parametrize(
    "method", [WVMethod.HARP, WVMethod.HD_PV, WVMethod.CW_SC]
)
def test_refresh_flagging_bit_identical_to_pre_refactor(golden, targets, method):
    drift = jnp.zeros_like(targets).at[2].add(1.6).at[7, 3].add(-2.0)
    flagged, sweeps = flag_columns(
        jax.random.PRNGKey(9), targets + drift, targets, _cfg(method)
    )
    np.testing.assert_array_equal(np.asarray(flagged), golden[f"flag_{method.value}"])
    assert sweeps == int(golden[f"flag_sweeps_{method.value}"])


@pytest.mark.parametrize("method", list(WVMethod))
def test_read_cost_bit_identical_to_pre_refactor(golden, method):
    lat, en = read_phase_cost(_cfg(method), CircuitCost())
    np.testing.assert_array_equal(np.asarray(lat), golden[f"cost_lat_{method.value}"])
    np.testing.assert_array_equal(np.asarray(en), golden[f"cost_en_{method.value}"])


def _cim_weight(cim_cfg):
    w = jax.random.normal(jax.random.PRNGKey(3), (24, 8), jnp.float32)
    q, scale = quantize_weight(w, QuantConfig(weight_bits=6, cell_bits=3))
    cols, layout = pack_columns(q, N, 3, 2)
    g_cells = cols.astype(jnp.float32) + 0.2 * jax.random.normal(
        jax.random.PRNGKey(4), cols.shape
    )

    class _State:
        pass

    st = _State()
    st.g, st.layout, st.shape, st.scale = g_cells, layout, w.shape, scale
    return tile.build_weight(st, cim_cfg, jax.random.PRNGKey(7), "leaf")


def test_cim_matmul_bit_identical_to_pre_refactor(golden):
    x = jax.random.normal(jax.random.PRNGKey(8), (5, 24), jnp.float32)
    cw = _cim_weight(
        CIMConfig(macro_rows=16, dac_bits=5, adc_bits=9, sigma_read_lsb=0.4)
    )
    np.testing.assert_array_equal(np.asarray(cim_matmul(x, cw)), golden["cim_y"])
    cw_ideal = _cim_weight(
        CIMConfig(macro_rows=16, dac_bits=None, adc_bits=None, sigma_read_lsb=0.0)
    )
    np.testing.assert_array_equal(
        np.asarray(cim_matmul(x, cw_ideal)), golden["cim_y_ideal"]
    )


# ------------------------------------------------- converter edge cases
def test_sar_clips_at_both_rails():
    adc = ADCConfig(bits=9)
    fs = full_scale_lsb(N, 8)
    # Uncentered range [0, FS]: rails at 0 and FS.
    y = jnp.asarray([-1e6, -0.1, 0.0, fs, fs + 0.1, 1e6])
    out = sar_read(y, adc, N, 8, centered=False)
    assert float(out[0]) == 0.0 and float(out[1]) == 0.0
    assert float(out[-1]) == pytest.approx(fs, abs=fs / (1 << 9))
    assert float(jnp.max(out)) <= fs
    # Centered range [-FS/2, FS/2].
    out_c = sar_read(jnp.asarray([-1e6, 1e6]), adc, N, 8, centered=True)
    assert float(out_c[0]) == -fs / 2.0
    assert float(out_c[1]) == pytest.approx(fs / 2.0, abs=fs / (1 << 9))
    assert float(out_c[1]) <= fs / 2.0


def test_sar_one_bit_converter():
    # bits=1 leaves exactly two codes: {lo, lo + FS/2}.
    out = sar_quantize(jnp.linspace(-60.0, 60.0, 101), 1, 112.0, centered=True)
    assert set(np.unique(np.asarray(out))) == {-56.0, 0.0}


def test_compare_deadzone_thresholds():
    t = jnp.zeros((5,))
    y = jnp.asarray([-0.51, -0.5, 0.0, 0.5, 0.51])
    sign, n_cmp = compare_read(y, t, deadzone_lsb=0.5)
    np.testing.assert_array_equal(np.asarray(sign), [-1.0, 0.0, 0.0, 0.0, 1.0])
    # Fig. 7(c): 'below' resolves in 1 comparison, everything else takes 2.
    np.testing.assert_array_equal(np.asarray(n_cmp), [1, 2, 2, 2, 2])


def test_mra_averaging_variance_scales_inverse_m():
    """Uncorrelated read noise averages ~1/M; common mode does not."""
    c = 4096
    g = jnp.zeros((c, 4))
    base = ReadoutConfig(
        basis=ReadoutBasis.ONE_HOT, converter=Converter.IDEAL, n_cells=4,
        noise=NoiseConfig(sigma_read_lsb=1.0, rho_cm=0.0),
    )
    key = jax.random.PRNGKey(11)
    var = {}
    for m in (1, 8):
        res = read_columns(key, g, base.replace(avg_reads=m))
        var[m] = float(jnp.var(res.values))
        assert res.n_reads == m * 4
    assert var[1] / var[8] == pytest.approx(8.0, rel=0.25)

    cm = base.replace(noise=NoiseConfig(sigma_read_lsb=1.0, rho_cm=1.0))
    v1 = float(jnp.var(read_columns(key, g, cm.replace(avg_reads=1)).values))
    v8 = float(jnp.var(read_columns(key, g, cm.replace(avg_reads=8)).values))
    assert v1 / v8 == pytest.approx(1.0, rel=0.1)


# ----------------------------------------- offset drift and calibration
def test_one_hot_reads_shift_by_col_offset_hadamard_decode_cancels():
    c = 8
    g = jnp.full((c, N), 3.0)
    offs = jnp.full((c,), 2.0)
    quiet = NoiseConfig(sigma_read_lsb=0.0)
    oh = ReadoutConfig(
        basis=ReadoutBasis.ONE_HOT, converter=Converter.SAR, n_cells=N,
        noise=quiet,
    )
    vals = read_columns(jax.random.PRNGKey(0), g, oh, col_offset=offs).values
    # Every one-hot measurement eats the offset as a systematic error.
    assert float(jnp.min(vals)) > 4.5
    hd_cfg = oh.replace(basis=ReadoutBasis.HADAMARD)
    res = read_columns(jax.random.PRNGKey(0), g, hd_cfg, col_offset=offs)
    w_hat = decode_magnitude(res.values, hd_cfg)
    # Balanced rows cancel a measurement-constant offset at decode
    # (eq. 7): cells 1..N-1 are clean, cell 0 absorbs it.
    np.testing.assert_allclose(np.asarray(w_hat[:, 1:]), 3.0, atol=0.25)


def test_calibration_trims_static_offsets():
    c = 512
    cfg = ReadoutConfig(
        basis=ReadoutBasis.ONE_HOT, converter=Converter.SAR, n_cells=N,
        noise=NoiseConfig(sigma_read_lsb=0.7, rho_cm=0.3),
        sigma_col_offset_lsb=1.5,
    )
    offs = sample_col_offsets(jax.random.PRNGKey(1), c, cfg)
    assert float(jnp.std(offs)) == pytest.approx(1.5, rel=0.15)
    residual = calibrate_offsets(jax.random.PRNGKey(2), offs, cfg, k_reads=16)
    assert float(jnp.std(residual)) < 0.35 * float(jnp.std(offs))
    # More calibration reads -> tighter trim.
    res_2 = calibrate_offsets(jax.random.PRNGKey(2), offs, cfg, k_reads=2)
    assert float(jnp.std(residual)) < float(jnp.std(res_2))


def test_offset_degrades_onehot_programming_and_calibration_recovers():
    """End-to-end reference-tuning scenario through the WV engine."""
    tgt = jax.random.randint(jax.random.PRNGKey(3), (48, N), 0, 8).astype(
        jnp.float32
    )
    cfg = _cfg(WVMethod.MRA)
    rcfg = for_wv_method(cfg).replace(sigma_col_offset_lsb=1.5)
    offs = sample_col_offsets(jax.random.PRNGKey(4), tgt.shape[0], rcfg)
    trimmed = calibrate_offsets(jax.random.PRNGKey(5), offs, rcfg, k_reads=8)

    def rms(col_offset):
        _, st = jax.jit(
            lambda k, t: program_columns(k, t, cfg, col_offset=col_offset)
        )(jax.random.PRNGKey(6), tgt)
        return float(jnp.mean(st.rms_error_lsb))

    clean, drifted, calibrated = rms(None), rms(offs), rms(trimmed)
    assert drifted > 1.5 * clean          # offsets poison one-hot verify
    assert calibrated < 0.5 * drifted     # reference tuning recovers it
    assert calibrated < 1.3 * clean


def test_compare_converter_rejects_averaging():
    with pytest.raises(ValueError, match="one-shot"):
        ReadoutConfig(converter=Converter.COMPARE, avg_reads=4)


def test_refresh_with_zero_sweeps_flags_nothing(targets):
    from repro.lifetime.refresh import RefreshConfig

    flagged, sweeps = flag_columns(
        jax.random.PRNGKey(0), targets + 2.0, targets, _cfg(WVMethod.HARP),
        RefreshConfig(verify_sweeps=0),
    )
    assert sweeps == 0 and not bool(jnp.any(flagged))


# ------------------------------------------------------- shared pricing
def test_sweep_cost_matrix_matches_method_wrappers():
    cfg = _cfg(WVMethod.MRA)
    rcfg = for_wv_method(cfg)
    assert rcfg.basis == ReadoutBasis.ONE_HOT
    assert rcfg.converter == Converter.SAR
    assert rcfg.avg_reads == cfg.mra_reads
    lat_w, en_w = read_phase_cost(cfg, CircuitCost())
    lat_r, en_r = sweep_cost(rcfg, CircuitCost())
    assert float(lat_w) == float(lat_r) and float(en_w) == float(en_r)
    # IDEAL is priced as SAR: idealized sweeps are never free.
    lat_i, en_i = sweep_cost(rcfg.replace(converter=Converter.IDEAL), CircuitCost())
    assert float(lat_i) == float(lat_r) and float(en_i) == float(en_r)
