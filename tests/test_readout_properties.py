"""Property-based + statistical contracts for the readout subsystem.

Fixed-seed goldens (tests/test_readout.py) pin *exact values*; this
module pins the *claims* — over random orders, random inputs and seed
ensembles — the way reference-tuning characterization (arXiv:2502.05948)
and bit-error-tolerance analyses (arXiv:1904.03652) test distributions
rather than point samples:

* algebra (hypothesis): FWHT involution + Parseval over N in {2..128},
  decode∘encode identity, SAR monotonicity + rail clipping, ternary
  compare deadzone correctness over random thresholds;
* statistics (plain seeds, chi-square-bounded): inverse-Hadamard decode
  cuts uncorrelated read-noise variance by ~N (eq. 6), cancels a
  constant common-mode disturbance exactly on the balanced rows
  (eq. 7), and M-read averaging lands on its analytic floor
  sigma_uc^2/M + sigma_cm^2 (MRA's common-mode wall).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import hadamard as hd
from repro.core.types import ADCConfig, NoiseConfig
from repro.readout import (
    Converter,
    ReadoutBasis,
    ReadoutConfig,
    read_columns,
)
from repro.readout.converter import compare_read, sar_quantize
from repro.readout.readout import decode_magnitude

ORDERS = [2, 4, 8, 16, 32, 64, 128]


def _rand(seed: int, *shape) -> np.ndarray:
    return np.random.default_rng(seed).standard_normal(shape).astype(np.float32)


# ----------------------------------------------------------- hypothesis
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from(ORDERS))
def test_fwht_involution_and_parseval(seed, n):
    """H is symmetric with H^T H = N I: applying the butterfly twice
    scales by N, and energy scales by N (Parseval)."""
    x = _rand(seed, 3, n) * 4.0
    y = np.asarray(hd.fwht(jnp.asarray(x)))
    np.testing.assert_allclose(
        np.asarray(hd.fwht(jnp.asarray(y))), n * x, rtol=1e-5, atol=1e-4
    )
    np.testing.assert_allclose(
        np.sum(y * y, -1), n * np.sum(x * x, -1), rtol=1e-5
    )


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from(ORDERS))
def test_decode_encode_identity(seed, n):
    """decode(encode(w)) == w both in core.hadamard and through a clean
    IDEAL-converter readout sweep."""
    w = _rand(seed, 4, n) * 3.0
    np.testing.assert_allclose(
        np.asarray(hd.decode(hd.encode(jnp.asarray(w)))), w,
        rtol=1e-5, atol=1e-5,
    )
    cfg = ReadoutConfig(
        basis=ReadoutBasis.HADAMARD, converter=Converter.IDEAL, n_cells=n,
        noise=NoiseConfig(sigma_read_lsb=0.0),
    )
    res = read_columns(jax.random.PRNGKey(seed % 997), jnp.asarray(w), cfg)
    np.testing.assert_allclose(
        np.asarray(decode_magnitude(res.values, cfg)), w, rtol=1e-5, atol=1e-5
    )
    assert res.n_reads == n


@settings(max_examples=40, deadline=None)
@given(
    st.integers(0, 2**31 - 1),
    st.integers(2, 12),
    st.booleans(),
    st.floats(4.0, 512.0),
)
def test_sar_monotone_and_rails(seed, bits, centered, full_scale):
    """SAR quantization is monotone and saturates at the converter rails."""
    y = np.sort(_rand(seed, 257)) * full_scale  # spans well past the rails
    q = np.asarray(sar_quantize(jnp.asarray(y), bits, full_scale, centered))
    assert np.all(np.diff(q) >= 0.0)  # monotone
    lo = -full_scale / 2.0 if centered else 0.0
    w = full_scale / (1 << bits)
    assert q.min() >= lo - 1e-4
    assert q.max() <= lo + full_scale - w + 1e-4  # top code, not lo+FS
    # deep saturation maps to the exact rail codes
    assert np.asarray(
        sar_quantize(jnp.asarray([lo - full_scale]), bits, full_scale, centered)
    )[0] == pytest.approx(lo)
    # in-range values land within half a code width
    inside = (y > lo) & (y < lo + full_scale - w)
    assert np.all(np.abs(q[inside] - y[inside]) <= 0.5 * w + 1e-5)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.0, 3.0))
def test_compare_ternary_deadzone(seed, deadzone):
    """Ternary compare: sign matches the deadzone definition exactly and
    the Fig. 7(c) comparison count is 1 below target, 2 otherwise."""
    g = np.random.default_rng(seed)
    y = g.normal(0.0, 4.0, size=(6, 16)).astype(np.float32)
    t = g.normal(0.0, 4.0, size=(6, 16)).astype(np.float32)
    sign, n_cmp = compare_read(jnp.asarray(y), jnp.asarray(t), deadzone)
    sign, n_cmp = np.asarray(sign), np.asarray(n_cmp)
    d = y - t
    np.testing.assert_array_equal(sign == -1.0, d < -deadzone)
    np.testing.assert_array_equal(sign == 1.0, d > deadzone)
    np.testing.assert_array_equal(sign == 0.0, np.abs(d) <= deadzone)
    np.testing.assert_array_equal(n_cmp == 1, d < -deadzone)
    assert set(np.unique(n_cmp)) <= {1, 2}


# ------------------------------------------------- statistical contracts
def _sweep_errors(basis, n, sigma, rho, m=1, seeds=4, c=64):
    """Decoded cell-domain errors over a seed ensemble: (seeds*C, N)."""
    cfg = ReadoutConfig(
        basis=basis, converter=Converter.IDEAL, n_cells=n, avg_reads=m,
        noise=NoiseConfig(sigma_read_lsb=sigma, rho_cm=rho),
    )
    g = jnp.asarray(_rand(123, c, n) * 2.0)
    errs = []
    for s in range(seeds):
        res = read_columns(jax.random.PRNGKey(1000 + s), g, cfg)
        errs.append(np.asarray(decode_magnitude(res.values, cfg)) - np.asarray(g))
    return np.concatenate(errs, 0)


def _chi2_bounds(dof: int, z: float = 4.5) -> tuple[float, float]:
    """Normal-approx chi-square band for a sample-variance / true-variance
    ratio with `dof` degrees of freedom (z=4.5 -> ~1e-5 false alarm)."""
    half = z * (2.0 / dof) ** 0.5
    return 1.0 - half, 1.0 + half


def test_hadamard_variance_reduction_is_n():
    """Headline claim (eq. 6): uncorrelated read noise of std sigma lands
    on the decoded estimate with variance sigma^2/N after inverse-
    Hadamard decoding, vs sigma^2 for one-hot reads."""
    n, sigma = 32, 0.5
    e_hd = _sweep_errors(ReadoutBasis.HADAMARD, n, sigma, rho=0.0)
    e_oh = _sweep_errors(ReadoutBasis.ONE_HOT, n, sigma, rho=0.0)
    dof = e_hd.size
    lo, hi = _chi2_bounds(dof)
    assert lo <= np.mean(e_hd**2) / (sigma**2 / n) <= hi
    assert lo <= np.mean(e_oh**2) / sigma**2 <= hi
    ratio = np.mean(e_oh**2) / np.mean(e_hd**2)
    assert n * lo / hi <= ratio <= n * hi / lo


def test_hadamard_cancels_common_mode_exactly():
    """Headline claim (eq. 7): a per-sweep constant disturbance mu lands
    entirely on cell 0 after decoding; the N-1 balanced rows cancel it.
    With zero signal the butterfly's cancellation is bitwise EXACT."""
    n, c = 32, 48
    cfg = ReadoutConfig(
        basis=ReadoutBasis.HADAMARD, converter=Converter.IDEAL, n_cells=n,
        noise=NoiseConfig(sigma_read_lsb=0.8, rho_cm=1.0),  # pure common mode
    )
    res = read_columns(jax.random.PRNGKey(3), jnp.zeros((c, n)), cfg)
    dec = np.asarray(decode_magnitude(res.values, cfg))
    assert np.all(dec[:, 1:] == 0.0)          # bitwise exact cancellation
    assert np.all(np.abs(dec[:, 0]) > 0.0)    # ... mu all lands on cell 0
    # one-hot reads eat the same disturbance on EVERY cell instead
    cfg_oh = cfg.replace(basis=ReadoutBasis.ONE_HOT)
    res_oh = read_columns(jax.random.PRNGKey(3), jnp.zeros((c, n)), cfg_oh)
    dec_oh = np.asarray(decode_magnitude(res_oh.values, cfg_oh))
    col_mu = dec_oh[:, :1]
    assert np.all(np.abs(col_mu) > 0.0)
    np.testing.assert_allclose(dec_oh, np.broadcast_to(col_mu, dec_oh.shape),
                               rtol=0, atol=1e-6)
    # nonzero signal: cancellation to rounding (not bitwise) still holds
    g = jnp.asarray(_rand(7, c, n) * 2.0)
    res2 = read_columns(jax.random.PRNGKey(3), g, cfg)
    err2 = np.asarray(decode_magnitude(res2.values, cfg)) - np.asarray(g)
    assert np.abs(err2[:, 1:]).max() < 1e-4


def test_mra_averaging_matches_analytic_floor():
    """Headline claim (Sec. 2.3): M-read averaging shrinks only the
    uncorrelated term — error variance tracks sigma_uc^2/M + sigma_cm^2,
    so MRA walls at the common-mode floor instead of reaching 0."""
    n, sigma, rho = 16, 0.6, 0.25
    noise = NoiseConfig(sigma_read_lsb=sigma, rho_cm=rho)
    var_uc, var_cm = noise.sigma_uc_lsb**2, noise.sigma_cm_lsb**2
    seeds, c = 6, 128
    for m in (1, 4, 16):
        errs = _sweep_errors(
            ReadoutBasis.ONE_HOT, n, sigma, rho, m=m, seeds=seeds, c=c
        )
        analytic = var_uc / m + var_cm
        # the shared per-column common mode shrinks the effective dof to
        # ~#sweeps when it dominates; bound with the smaller count
        lo, hi = _chi2_bounds(seeds * c)
        assert lo <= np.mean(errs**2) / analytic <= hi, m
    # and the M->inf floor is strictly the common-mode power: at M=16
    # the uncorrelated residue is down 16x
    e16 = _sweep_errors(ReadoutBasis.ONE_HOT, n, sigma, rho, m=16,
                        seeds=seeds, c=c)
    assert np.mean(e16**2) < var_cm * 1.35
    assert np.mean(e16**2) > var_cm * 0.75
