"""Multi-device sharding correctness: runs in a subprocess with 8 forced
host devices so the main test process keeps its single-device view.

Checks (on a 2x4 ("data","model") debug mesh):
  * MoE shard_map output == mesh-free dense reference;
  * sharded train step == single-device train step (bitwise-tolerant);
  * elastic checkpoint restore onto a different mesh shape.
"""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.shardings import state_sharding, batch_sharding
    from repro.models import ModelConfig, init_params
    from repro.models.moe import moe_block, init_moe_params
    from repro.optim import AdamWConfig
    from repro.training import init_train_state, make_train_step
    from repro.data import SyntheticLM

    mesh = make_debug_mesh(2, 4)

    # ---- MoE: sharded == dense reference
    cfg = ModelConfig(name="t", n_layers=1, d_model=32, n_heads=4, n_kv_heads=2,
                      head_dim=8, d_ff=64, vocab_size=64, moe_experts=8,
                      moe_top_k=2, moe_d_ff=16, dtype=jnp.float32,
                      capacity_factor=4.0, remat=False)
    p = jax.tree.map(lambda a: a[0], init_moe_params(jax.random.PRNGKey(0), cfg, 1))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))
    with jax.set_mesh(mesh):
        out_sh, aux_sh = jax.jit(lambda x, p: moe_block(x, p, cfg, mesh))(x, p)
    out_ref, aux_ref = moe_block(x, p, cfg, None)
    np.testing.assert_allclose(np.asarray(out_sh), np.asarray(out_ref), rtol=2e-3, atol=2e-3)
    print("MOE-EQUIV-OK")

    # ---- train step: sharded == single device
    mcfg = ModelConfig(name="d", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                       head_dim=8, d_ff=64, vocab_size=64, dtype=jnp.float32,
                       attn_chunk_q=8, attn_chunk_kv=8, remat=False)
    data = SyntheticLM(vocab_size=64, seq_len=16, global_batch=8, seed=0)
    opt = AdamWConfig(lr_peak=1e-3)
    batch = data.global_batch_at(0)._asdict()

    state0 = init_train_state(jax.random.PRNGKey(0), mcfg, opt)
    step_plain = jax.jit(make_train_step(mcfg, opt, total_steps=10))
    s_plain, m_plain = step_plain(state0, batch)

    with jax.set_mesh(mesh):
        st_sh = state_sharding(mesh, state0, mcfg)
        b_sh = batch_sharding(mesh, batch, 8)
        state_s = jax.device_put(state0, st_sh)
        batch_s = jax.device_put(batch, b_sh)
        step_sh = jax.jit(make_train_step(mcfg, opt, mesh, total_steps=10),
                          in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None))
        s_shard, m_shard = step_sh(state_s, batch_s)
    assert abs(float(m_plain["loss"]) - float(m_shard["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(s_plain.params), jax.tree.leaves(s_shard.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-4)
    print("TRAIN-EQUIV-OK")

    # ---- elastic restore onto a different mesh
    import tempfile
    from repro.checkpoint import CheckpointManager
    from repro.distributed.sharding import shard_params_tree
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, s_shard.params)
        mesh2 = make_debug_mesh(4, 2)  # different shape
        with jax.set_mesh(mesh2):
            sh2 = state_sharding(mesh2, s_shard.params, mcfg)
            step, rec = mgr.restore_latest(template=s_shard.params, sharding_tree=sh2)
        for a, b in zip(jax.tree.leaves(rec), jax.tree.leaves(s_shard.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    print("ELASTIC-OK")
    """
)


@pytest.mark.skipif(
    (os.cpu_count() or 1) < 4,
    reason="forced multi-device host simulation hangs XLA backend init on <4 cores",
)
def test_multidevice_sharding_subprocess():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd="/root/repo",
        timeout=560,
    )
    assert res.returncode == 0, res.stdout + res.stderr
    for tag in ("MOE-EQUIV-OK", "TRAIN-EQUIV-OK", "ELASTIC-OK"):
        assert tag in res.stdout, res.stdout + res.stderr
