"""Data pipeline, optimizer, compression, checkpointing, fault tolerance."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.checkpoint import CheckpointManager, latest_step, save_checkpoint, restore_checkpoint
from repro.data import SyntheticLM
from repro.distributed import FaultInjector, FaultTolerantRunner, StragglerMonitor
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    compress_int8,
    cosine_schedule,
    decompress_int8,
    init_compression_state,
)
from repro.optim.compression import _compress_leaf


# ------------------------------------------------------------------ data
def test_data_deterministic_and_host_sliced():
    ds = SyntheticLM(vocab_size=128, seq_len=32, global_batch=16, seed=5)
    a, b = ds.global_batch_at(7), ds.global_batch_at(7)
    np.testing.assert_array_equal(np.asarray(a.tokens), np.asarray(b.tokens))
    assert not np.array_equal(
        np.asarray(ds.global_batch_at(8).tokens), np.asarray(a.tokens)
    )
    # host shards tile the global batch exactly
    parts = [ds.host_batch_at(7, h, 4).tokens for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), np.asarray(a.tokens))
    # bigram structure: targets are deterministic successors of tokens
    assert a.tokens.shape == a.targets.shape == (16, 32)


def test_data_is_learnable_structure():
    """The bigram process has < log2(vocab) entropy (there IS signal)."""
    ds = SyntheticLM(vocab_size=64, seq_len=16, global_batch=64, seed=1)
    b = ds.global_batch_at(0)
    # successors per token limited to `branching` -> conditional support
    tok = np.asarray(b.tokens).ravel()
    tgt = np.asarray(b.targets).ravel()
    succ = {}
    for t, y in zip(tok, tgt):
        succ.setdefault(int(t), set()).add(int(y))
    max_succ = max(len(v) for v in succ.values())
    assert max_succ <= ds.branching


# ----------------------------------------------------------------- optim
def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr_peak=0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, state, _ = adamw_update(grads, state, params, cfg, 0.05)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_grad_clipping_metric():
    cfg = AdamWConfig(grad_clip_norm=1.0)
    params = {"w": jnp.ones(4)}
    state = adamw_init(params, cfg)
    _, _, m = adamw_update({"w": jnp.full(4, 100.0)}, state, params, cfg, 1e-3)
    assert float(m["grad_norm"]) == pytest.approx(200.0, rel=1e-3)
    assert float(m["clip_scale"]) < 0.01


def test_cosine_schedule_shape():
    s = [float(cosine_schedule(i, 1.0, 10, 100)) for i in (0, 5, 10, 55, 100)]
    assert s[0] == 0.0 and s[1] == pytest.approx(0.5)
    assert s[2] == pytest.approx(1.0)
    assert s[2] > s[3] > s[4]
    assert s[4] == pytest.approx(0.1, rel=1e-3)  # floor


# ----------------------------------------------------------- compression
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_int8_compression_bounded_error(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (8, 64)) * 3
    q, scale = compress_int8(x)
    back = decompress_int8(q, scale, x.shape)
    assert float(jnp.max(jnp.abs(back - x))) <= float(jnp.max(scale)) * 0.751


def test_error_feedback_removes_bias():
    """With error feedback, the time-averaged compressed gradient matches
    the true gradient (quantization bias cancels)."""
    g = {"w": jnp.linspace(-0.011, 0.013, 32)}  # constant true gradient
    state = init_compression_state(g)
    acc = jnp.zeros(32)
    steps = 200
    err = state.error["w"]
    for _ in range(steps):
        q, scale, err = _compress_leaf(g["w"], err)
        acc = acc + decompress_int8(q, scale, (32,))
    np.testing.assert_allclose(np.asarray(acc / steps), np.asarray(g["w"]),
                               rtol=1e-2, atol=1e-5)


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip_and_rotation():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.bfloat16)}}
        for s in (5, 10, 15):
            mgr.save(s, tree)
        assert latest_step(d) == 15
        steps = sorted(int(n.split("_")[1]) for n in os.listdir(d) if n.startswith("step_"))
        assert steps == [10, 15]  # keep=2 rotated
        step, rec = mgr.restore_latest(template=tree)
        assert step == 15
        np.testing.assert_array_equal(np.asarray(rec["a"]), np.asarray(tree["a"]))
        assert rec["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomicity_tmp_ignored():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"x": jnp.zeros(2)})
        os.makedirs(os.path.join(d, "step_00000002.tmp"))  # crashed save
        assert latest_step(d) == 1
        step, _ = restore_checkpoint(d, template={"x": jnp.zeros(2)})
        assert step == 1


def test_async_checkpoint_consistency():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        x = jnp.arange(8.0)
        mgr.save(1, {"x": x}, blocking=False)
        x = x + 100.0  # caller mutates after snapshot
        mgr.wait()
        _, rec = mgr.restore_latest(template={"x": x})
        np.testing.assert_array_equal(np.asarray(rec["x"]), np.arange(8.0))


# ------------------------------------------------------- fault tolerance
def test_fault_runner_replays_to_target():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        seen = []

        def step_fn(s, batch):
            seen.append(batch)
            return {"x": s["x"] + batch}, {}

        runner = FaultTolerantRunner(
            step_fn, lambda i: i, mgr, checkpoint_every=4,
            injector=FaultInjector(fail_at_steps=(6, 11)),
        )
        state, logs = runner.run({"x": jnp.zeros(())}, 0, 15)
        # final state = sum of 0..14 regardless of failures
        assert float(state["x"]) == sum(range(15))
        assert runner.restarts == 2


def test_fault_runner_gives_up_on_crash_loop():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)

        class AlwaysFail(FaultInjector):
            def maybe_fail(self, step):
                if step == 3:
                    from repro.distributed.fault import SimulatedFailure

                    raise SimulatedFailure("persistent")

        runner = FaultTolerantRunner(
            lambda s, b: (s, {}), lambda i: i, mgr,
            checkpoint_every=100, max_retries_per_step=2, injector=AlwaysFail(),
        )
        with pytest.raises(RuntimeError, match="giving up"):
            runner.run({"x": jnp.zeros(())}, 0, 10)


def test_fault_runner_retry_exhaustion_with_real_injector():
    """A zero-retry budget turns the FIRST real injection into give-up.

    Unlike the crash-loop test (which needs a subclass that refires
    forever), the stock `FaultInjector` exercises the exhaustion branch
    directly when `max_retries_per_step` is 0 — and the injection must
    land a `fault.injected` instant in the obs trace.
    """
    from repro.obs import trace as obs_trace

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        inj = FaultInjector(fail_at_steps=(3,))
        runner = FaultTolerantRunner(
            lambda s, b: (s, {}), lambda i: i, mgr,
            checkpoint_every=100, max_retries_per_step=0, injector=inj,
        )
        with pytest.raises(RuntimeError, match="giving up"):
            runner.run({"x": jnp.zeros(())}, 0, 10)
        assert runner.restarts == 1
        assert inj._fired == {3}
        assert any(
            e.get("name") == "fault.injected" and e["args"]["step"] == 3
            for e in obs_trace.events()
        )


def test_fault_injector_reset_rearms():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=3)
        inj = FaultInjector(fail_at_steps=(3,))
        runner = FaultTolerantRunner(
            lambda s, b: ({"x": s["x"] + b}, {}), lambda i: i, mgr,
            checkpoint_every=4, injector=inj,
        )
        state, _ = runner.run({"x": jnp.zeros(())}, 0, 10)
        assert runner.restarts == 1 and inj._fired == {3}
        assert float(state["x"]) == sum(range(10))
        inj.reset()
        assert inj._fired == set()
        # re-armed: the same planned failure fires again on a fresh run
        runner2 = FaultTolerantRunner(
            lambda s, b: ({"x": s["x"] + b}, {}), lambda i: i, mgr,
            checkpoint_every=100, injector=inj,
        )
        with tempfile.TemporaryDirectory() as d2:
            runner2.manager = CheckpointManager(d2, keep=3)
            state2, _ = runner2.run({"x": jnp.zeros(())}, 0, 10)
        assert runner2.restarts == 1
        assert float(state2["x"]) == sum(range(10))


def test_straggler_monitor_escalates():
    mon = StragglerMonitor(threshold=2.0, strikes_to_escalate=2, warmup_steps=3)
    events = []
    mon.on_straggler = lambda step, dur: events.append(step)
    for i in range(10):
        mon.observe(i, 0.1)
    assert not mon.flagged_steps
    mon.observe(10, 0.35)
    mon.observe(11, 0.4)
    assert len(mon.flagged_steps) == 2
    assert mon.escalations == 1 and events == [11]
    # healthy steps reset strikes
    mon.observe(12, 0.1)
    mon.observe(13, 0.5)
    assert mon.escalations == 1
