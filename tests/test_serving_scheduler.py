"""Continuous-batching scheduler contracts (DESIGN.md Sec. 13).

The ISSUE-5 acceptance criteria live here: a Poisson arrival stream of
variable-length requests is served with ZERO retraces after warmup, and
a request's decoded tokens are bit-identical when served alone vs
inside a full batch (per-request RNG sub-streams).  Plus: padded-prefill
equivalence against the fixed-batch engine, slot evict/refill, eos
stops, per-request latency accounting, analog executor traffic ticking
with interleaved lifetime maintenance, and CIM tile-plane sharding.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cim import CIMConfig, CIMExecutor
from repro.core import WVConfig, WVMethod
from repro.core.programmer import deploy_arrays
from repro.lifetime import LifetimeSimulator
from repro.lifetime.refresh import RefreshConfig, RefreshPolicy
from repro.models import ModelConfig, init_cache, init_params, prefill
from repro.models.decoding import write_cache_slot
from repro.serving import (
    ContinuousScheduler,
    Request,
    ServeEngine,
    poisson_requests,
)


def _tiny_cfg(**kw) -> ModelConfig:
    base = dict(
        name="sched-test", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=64, dtype=jnp.float32,
        attn_chunk_q=16, attn_chunk_kv=16, remat=False, tie_embeddings=False,
    )
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def digital():
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def deployed_tiny(digital):
    cfg, params = digital
    wv = WVConfig(method=WVMethod.HARP, max_fine_iters=12, max_coarse_iters=4)
    deployed, _ = deploy_arrays(jax.random.PRNGKey(1), params, wv)
    return cfg, deployed


def _scheduler(cfg, params, temperature=0.7, **kw):
    engine = ServeEngine(cfg, params, temperature=temperature)
    kw.setdefault("n_slots", 3)
    kw.setdefault("max_len", 64)
    kw.setdefault("key", jax.random.PRNGKey(5))
    return ContinuousScheduler(engine, **kw)


# ----------------------------------------------------------------- tentpole
def test_poisson_stream_zero_retrace(digital):
    """Acceptance: Poisson stream of variable-length requests, 0 retraces
    after warmup, one host sync per decode step, everyone completes."""
    cfg, params = digital
    sched = _scheduler(cfg, params)
    sched.warmup(prompt_range=(3, 20))
    warm = dict(sched.trace_counts)
    reqs = poisson_requests(
        0, 12, rate=0.5, vocab=cfg.vocab_size,
        prompt_lens=(3, 20), max_new=(3, 8),
    )
    recs = sched.run(reqs)
    assert len(recs) == 12
    assert {r.rid for r in recs} == {r.rid for r in reqs}
    assert sched.trace_counts == warm, "retrace after warmup"
    assert sched.host_syncs == sched.decode_steps
    for r in recs:
        req = next(q for q in reqs if q.rid == r.rid)
        assert r.n_generated == req.max_new  # no eos in this stream
        assert r.admit_step >= r.arrival
        assert r.latency_steps >= r.n_generated


def test_bit_identity_alone_vs_full_batch(digital):
    """Acceptance: a request's sampled tokens are bit-identical served
    alone vs inside a full batch (and in a different slot)."""
    cfg, params = digital
    sched = _scheduler(cfg, params, temperature=0.7)
    sched.warmup(prompt_range=(3, 16))
    reqs = poisson_requests(
        1, 9, rate=2.0, vocab=cfg.vocab_size,  # heavy load -> full batch
        prompt_lens=(3, 16), max_new=(4, 8),
    )
    busy = {r.rid: r.tokens for r in sched.run(reqs)}
    for probe in (reqs[4], reqs[7]):
        sched.reset(keep_traces=True)
        alone = sched.run([probe])[0]
        assert alone.tokens == busy[probe.rid], probe.rid
    assert sched.trace_counts["decode"] == 1  # still zero retraces


def test_padded_prefill_and_slot_decode_inert(digital):
    """The scheduler's building blocks are BIT-identical to the plain
    fixed-batch computation: right-padding a prompt to its bucket changes
    no prefill output, and decoding the request inside a 3-slot batch
    (idle neighbors) matches the single-sequence decode bitwise.

    (Token-level equality against `ServeEngine.generate` is NOT asserted:
    the engine's differently-fused jit graph rounds differently at the
    ulp level, which flips argmax on this random tiny model's near-tie
    logits.  The scheduler's own end-to-end determinism is pinned by
    `test_bit_identity_alone_vs_full_batch`.)"""
    from repro.models import decode_step

    cfg, params = digital
    prompt = jnp.asarray([[5, 9, 2, 40, 17]], jnp.int32)  # non-pow2 length
    last_u, cache_u = prefill(params, {"tokens": prompt}, cfg, max_len=64)
    pad = jnp.zeros((1, 8), jnp.int32).at[:, :5].set(prompt)
    last_p, cache_p = prefill(
        params, {"tokens": pad}, cfg, max_len=64,
        true_len=jnp.asarray([5], jnp.int32),
    )
    np.testing.assert_array_equal(np.asarray(last_u), np.asarray(last_p))
    assert cache_p["pos"].tolist() == [4]
    np.testing.assert_array_equal(  # real positions identical; rest junk
        np.asarray(cache_u["k"][:, :, :5]), np.asarray(cache_p["k"][:, :, :5])
    )

    shared = write_cache_slot(init_cache(cfg, 3, 64), cache_p, jnp.int32(1))
    cur_u = jnp.argmax(last_u, -1).astype(jnp.int32)[:, None]
    cur_b = jnp.zeros((3, 1), jnp.int32).at[1].set(cur_u[0])
    cu, cb = cache_u, shared
    for _ in range(4):
        lu, cu = decode_step(params, cu, {"tokens": cur_u}, cfg)
        lb, cb = decode_step(params, cb, {"tokens": cur_b}, cfg)
        np.testing.assert_array_equal(
            np.asarray(lu[0]), np.asarray(lb[1])
        )
        tok = jnp.argmax(lu[:, -1], -1).astype(jnp.int32)
        cur_u = tok[:, None]
        cur_b = jnp.zeros((3, 1), jnp.int32).at[1, 0].set(tok[0])


def test_evict_refill_and_latency_accounting(digital):
    """More requests than slots: slots are recycled, admission respects
    arrivals + capacity, queue delay shows up in the records."""
    cfg, params = digital
    sched = _scheduler(cfg, params, n_slots=2)
    sched.warmup(prompt_range=(4, 8))
    reqs = [
        Request(rid=i, prompt=[1 + i] * 5, max_new=4, arrival=0.0)
        for i in range(5)
    ]
    recs = sched.run(reqs)
    assert len(recs) == 5
    assert sched.admits == 5
    # 5 requests x 4 tokens through 2 slots needs >= 10 decode-ish steps.
    assert sched.tokens_generated == 20
    # only the very first admission is instant: each prefill occupies the
    # engine for a step, and the last three must also wait for a slot
    delayed = [r for r in recs if r.queue_delay_steps > 0]
    assert len(delayed) == 4
    assert all(r.done_step >= r.admit_step for r in recs)


def test_eos_stops_slot_early(digital):
    cfg, params = digital
    sched = _scheduler(cfg, params)
    sched.warmup(prompt_range=(4, 8))
    probe = Request(rid=3, prompt=[7, 8, 9, 10], max_new=8)
    full = sched.run([probe])[0]
    assert full.n_generated == 8
    eos = full.tokens[2]  # stop on the 3rd emitted token
    sched.reset(keep_traces=True)
    stopped = sched.run(
        [Request(rid=3, prompt=[7, 8, 9, 10], max_new=8, eos_id=eos)]
    )[0]
    assert stopped.tokens == full.tokens[:3]
    assert sched.active_slots() == 0


def test_rejects_recurrent_and_oversize(digital):
    cfg, params = digital
    rwkv = _tiny_cfg(block="rwkv6", name="rwkv-sched")
    engine = ServeEngine(rwkv, None)
    with pytest.raises(ValueError, match="attention"):
        ContinuousScheduler(engine, n_slots=2, max_len=32)
    with pytest.raises(ValueError, match="rwkv6|attention-only"):
        prefill(
            params, {"tokens": jnp.zeros((1, 8), jnp.int32)}, rwkv,
            max_len=16, true_len=jnp.asarray([4], jnp.int32),
        )
    sin = _tiny_cfg(pos_embedding="sinusoidal", name="sin-sched")
    with pytest.raises(ValueError, match="sinusoidal"):
        ContinuousScheduler(ServeEngine(sin, None), n_slots=2, max_len=32)
    sched = _scheduler(cfg, params, max_len=16)
    with pytest.raises(ValueError, match="exceeds max_len"):
        sched.admit(Request(rid=0, prompt=[1] * 10, max_new=8))


def test_write_cache_slot_unit():
    cfg = _tiny_cfg()
    shared = init_cache(cfg, 4, 32)
    single = init_cache(cfg, 1, 32)
    single["k"] = single["k"] + 1.5
    single["pos"] = single["pos"] + 7
    out = write_cache_slot(shared, single, jnp.int32(2))
    assert float(out["k"][:, 2].min()) == 1.5
    assert float(jnp.abs(out["k"][:, [0, 1, 3]]).max()) == 0.0
    assert out["pos"].tolist() == [0, 0, 7, 0]


# ------------------------------------------------------------------- analog
def test_analog_traffic_and_maintenance(deployed_tiny):
    """CIMExecutor ticks real read traffic per scheduled step; lifetime
    epochs interleave between decode steps without blocking the batch."""
    cfg, deployed = deployed_tiny
    ex = CIMExecutor(
        deployed, CIMConfig(dac_bits=4, adc_bits=10, sigma_read_lsb=0.2),
        jax.random.PRNGKey(7),
    )
    engine = ServeEngine(cfg, executor=ex, temperature=0.7)
    sim = LifetimeSimulator(
        jax.random.PRNGKey(3), deployed,
        refresh_cfg=RefreshConfig(policy=RefreshPolicy.VERIFY_TRIGGERED),
        traffic_fn=ex.drain_reads,
    )
    epochs = []
    sched = ContinuousScheduler(
        engine, n_slots=2, max_len=48, key=jax.random.PRNGKey(5),
        maintenance_fn=lambda: epochs.append(sim.step_epoch(1.0, max_leaves=2)),
        maintenance_every=4,
    )
    sched.warmup(prompt_range=(3, 8))
    warm = dict(sched.trace_counts)
    ex.drain_reads()
    tokens0 = ex.tokens_served
    reqs = poisson_requests(
        2, 5, rate=0.6, vocab=cfg.vocab_size,
        prompt_lens=(3, 8), max_new=(3, 6),
    )
    sched.run(reqs)
    assert sched.trace_counts == warm  # analog serving: still no retrace
    # every decode step ticks the full physical batch; every admit ticks
    # the padded bucket length
    expect_tokens = (
        sched.decode_steps * sched.n_slots + sched.prefill_tokens
    )
    assert ex.tokens_served - tokens0 == expect_tokens
    assert len(epochs) == sched.decode_steps // 4
    assert epochs[0].reads_per_column > 0  # drained traffic reached aging
    leftover = sum(ex.drain_reads().values())
    assert leftover >= 0.0


def test_analog_batch_composition_invariance(deployed_tiny):
    """ISSUE-9 tentpole: with request ids folded into the CIM noise
    stream (via `token_stream_ids`), a request's analog decode logits
    are bit-identical served alone vs inside a full batch, regardless
    of which slot it lands in or who its neighbors are."""
    from repro.cim import token_stream_ids
    from repro.models import decode_step

    cfg, deployed = deployed_tiny
    ex = CIMExecutor(
        deployed, CIMConfig(dac_bits=4, adc_bits=10, sigma_read_lsb=0.3),
        jax.random.PRNGKey(7),
    )
    params = ex.tick(1)  # one access: same leaf keys for every variant
    prompt = jnp.asarray([[5, 9, 2, 40, 17]], jnp.int32)
    rid = jnp.asarray([37], jnp.int32)
    last, cache1 = prefill(params, {"tokens": prompt}, cfg, max_len=48)
    cur = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
    with token_stream_ids(rid):
        la, _ = decode_step(params, cache1, {"tokens": cur}, cfg)
    for slot in (0, 2):
        cache_b = write_cache_slot(
            init_cache(cfg, 3, 48), cache1, jnp.int32(slot)
        )
        # neighbors: other live requests with their own ids and tokens
        rids_b = jnp.asarray([3, 11, 29], jnp.int32).at[slot].set(rid[0])
        cur_b = jnp.full((3, 1), 7, jnp.int32).at[slot].set(cur[0, 0])
        with token_stream_ids(rids_b):
            lb, _ = decode_step(params, cache_b, {"tokens": cur_b}, cfg)
        np.testing.assert_array_equal(np.asarray(la[0]), np.asarray(lb[slot]))


def test_incremental_scrub_rotates(deployed_tiny):
    """max_leaves bounds per-epoch scrub work and the cursor visits every
    leaf; aging still applies to all leaves each epoch."""
    cfg, deployed = deployed_tiny
    n_leaves = len(deployed.arrays)
    assert n_leaves >= 2
    ref = RefreshConfig(policy=RefreshPolicy.PERIODIC, period_epochs=1)
    full = LifetimeSimulator(jax.random.PRNGKey(3), deployed, refresh_cfg=ref)
    part = LifetimeSimulator(jax.random.PRNGKey(3), deployed, refresh_cfg=ref)
    e_full = full.step_epoch(1.0).program_energy_pj
    e1 = part.step_epoch(1.0, max_leaves=1).program_energy_pj
    assert 0.0 < e1 < e_full
    assert part._scrub_cursor == 1
    for _ in range(n_leaves - 1):
        part.step_epoch(1.0, max_leaves=1)
    assert part._scrub_cursor == 0  # wrapped: every leaf visited once


def test_cim_weight_sharding_single_device(deployed_tiny):
    """Tile planes shard their output axis over "model"; a 1x1 mesh is a
    placement no-op so served params stay bit-identical."""
    from repro.launch.shardings import cim_weight_specs
    from repro.launch.mesh import make_debug_mesh

    cfg, deployed = deployed_tiny
    mesh = make_debug_mesh(1, 1)
    cim_cfg = CIMConfig(dac_bits=4, adc_bits=10, sigma_read_lsb=0.0)
    ex_plain = CIMExecutor(deployed, cim_cfg, jax.random.PRNGKey(7))
    ex_mesh = CIMExecutor(deployed, cim_cfg, jax.random.PRNGKey(7), mesh=mesh)
    name = next(iter(ex_mesh._analog))
    w = ex_mesh._analog[name]
    specs = cim_weight_specs(mesh, w)
    # last-axis assignment is "model" whenever the extent divides M (1 here)
    assert specs["g_pos"].spec[-1] == "model"
    assert specs["scale"].spec[-1] == "model"
    assert tuple(specs["key"].spec) == ()
    for a, b in zip(
        jax.tree.leaves(ex_plain._analog[name]),
        jax.tree.leaves(w),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_request_record_dataclass_roundtrip():
    from repro.serving import RequestRecord

    r = RequestRecord(rid=1, arrival=2.0, prompt_len=4, bucket_len=8,
                      admit_step=3.0, first_token_step=4.0, done_step=9.0,
                      tokens=[1, 2, 3])
    assert r.queue_delay_steps == 1.0
    assert r.ttft_steps == 2.0
    assert r.latency_steps == 7.0
    assert r.n_generated == 3
    assert dataclasses.asdict(r)["tokens"] == [1, 2, 3]
