"""Continuous-batching scheduler contracts (DESIGN.md Sec. 13).

The ISSUE-5 acceptance criteria live here: a Poisson arrival stream of
variable-length requests is served with ZERO retraces after warmup, and
a request's decoded tokens are bit-identical when served alone vs
inside a full batch (per-request RNG sub-streams).  Plus: padded-prefill
equivalence against the fixed-batch engine, slot evict/refill, eos
stops, per-request latency accounting, analog executor traffic ticking
with interleaved lifetime maintenance, and CIM tile-plane sharding.
"""

import dataclasses
import math
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.cim import CIMConfig, CIMExecutor
from repro.core import WVConfig, WVMethod
from repro.core.programmer import deploy_arrays
from repro.lifetime import LifetimeSimulator
from repro.lifetime.refresh import RefreshConfig, RefreshPolicy
from repro.models import ModelConfig, init_cache, init_params, prefill
from repro.models.decoding import write_cache_slot
from repro.serving import (
    ADMISSION_POLICIES,
    ContinuousScheduler,
    Request,
    ServeEngine,
    admission_key,
    poisson_requests,
    select_next,
)

from hypothesis_compat import given, settings, st


def _tiny_cfg(**kw) -> ModelConfig:
    base = dict(
        name="sched-test", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, d_ff=64, vocab_size=64, dtype=jnp.float32,
        attn_chunk_q=16, attn_chunk_kv=16, remat=False, tie_embeddings=False,
    )
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def digital():
    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def deployed_tiny(digital):
    cfg, params = digital
    wv = WVConfig(method=WVMethod.HARP, max_fine_iters=12, max_coarse_iters=4)
    deployed, _ = deploy_arrays(jax.random.PRNGKey(1), params, wv)
    return cfg, deployed


def _scheduler(cfg, params, temperature=0.7, **kw):
    engine = ServeEngine(cfg, params, temperature=temperature)
    kw.setdefault("n_slots", 3)
    kw.setdefault("max_len", 64)
    kw.setdefault("key", jax.random.PRNGKey(5))
    return ContinuousScheduler(engine, **kw)


# ----------------------------------------------------------------- tentpole
def test_poisson_stream_zero_retrace(digital):
    """Acceptance: Poisson stream of variable-length requests, 0 retraces
    after warmup, one host sync per decode step, everyone completes."""
    cfg, params = digital
    sched = _scheduler(cfg, params)
    sched.warmup(prompt_range=(3, 20))
    warm = dict(sched.trace_counts)
    reqs = poisson_requests(
        0, 12, rate=0.5, vocab=cfg.vocab_size,
        prompt_lens=(3, 20), max_new=(3, 8),
    )
    recs = sched.run(reqs)
    assert len(recs) == 12
    assert {r.rid for r in recs} == {r.rid for r in reqs}
    assert sched.trace_counts == warm, "retrace after warmup"
    assert sched.host_syncs == sched.decode_steps
    for r in recs:
        req = next(q for q in reqs if q.rid == r.rid)
        assert r.n_generated == req.max_new  # no eos in this stream
        assert r.admit_step >= r.arrival
        assert r.latency_steps >= r.n_generated


def test_bit_identity_alone_vs_full_batch(digital):
    """Acceptance: a request's sampled tokens are bit-identical served
    alone vs inside a full batch (and in a different slot)."""
    cfg, params = digital
    sched = _scheduler(cfg, params, temperature=0.7)
    sched.warmup(prompt_range=(3, 16))
    reqs = poisson_requests(
        1, 9, rate=2.0, vocab=cfg.vocab_size,  # heavy load -> full batch
        prompt_lens=(3, 16), max_new=(4, 8),
    )
    busy = {r.rid: r.tokens for r in sched.run(reqs)}
    for probe in (reqs[4], reqs[7]):
        sched.reset(keep_traces=True)
        alone = sched.run([probe])[0]
        assert alone.tokens == busy[probe.rid], probe.rid
    assert sched.trace_counts["decode"] == 1  # still zero retraces


def test_padded_prefill_and_slot_decode_inert(digital):
    """The scheduler's building blocks are BIT-identical to the plain
    fixed-batch computation: right-padding a prompt to its bucket changes
    no prefill output, and decoding the request inside a 3-slot batch
    (idle neighbors) matches the single-sequence decode bitwise.

    (Token-level equality against `ServeEngine.generate` is NOT asserted:
    the engine's differently-fused jit graph rounds differently at the
    ulp level, which flips argmax on this random tiny model's near-tie
    logits.  The scheduler's own end-to-end determinism is pinned by
    `test_bit_identity_alone_vs_full_batch`.)"""
    from repro.models import decode_step

    cfg, params = digital
    prompt = jnp.asarray([[5, 9, 2, 40, 17]], jnp.int32)  # non-pow2 length
    last_u, cache_u = prefill(params, {"tokens": prompt}, cfg, max_len=64)
    pad = jnp.zeros((1, 8), jnp.int32).at[:, :5].set(prompt)
    last_p, cache_p = prefill(
        params, {"tokens": pad}, cfg, max_len=64,
        true_len=jnp.asarray([5], jnp.int32),
    )
    np.testing.assert_array_equal(np.asarray(last_u), np.asarray(last_p))
    assert cache_p["pos"].tolist() == [4]
    np.testing.assert_array_equal(  # real positions identical; rest junk
        np.asarray(cache_u["k"][:, :, :5]), np.asarray(cache_p["k"][:, :, :5])
    )

    shared = write_cache_slot(init_cache(cfg, 3, 64), cache_p, jnp.int32(1))
    cur_u = jnp.argmax(last_u, -1).astype(jnp.int32)[:, None]
    cur_b = jnp.zeros((3, 1), jnp.int32).at[1].set(cur_u[0])
    cu, cb = cache_u, shared
    for _ in range(4):
        lu, cu = decode_step(params, cu, {"tokens": cur_u}, cfg)
        lb, cb = decode_step(params, cb, {"tokens": cur_b}, cfg)
        np.testing.assert_array_equal(
            np.asarray(lu[0]), np.asarray(lb[1])
        )
        tok = jnp.argmax(lu[:, -1], -1).astype(jnp.int32)
        cur_u = tok[:, None]
        cur_b = jnp.zeros((3, 1), jnp.int32).at[1, 0].set(tok[0])


def test_evict_refill_and_latency_accounting(digital):
    """More requests than slots: slots are recycled, admission respects
    arrivals + capacity, queue delay shows up in the records."""
    cfg, params = digital
    sched = _scheduler(cfg, params, n_slots=2)
    sched.warmup(prompt_range=(4, 8))
    reqs = [
        Request(rid=i, prompt=[1 + i] * 5, max_new=4, arrival=0.0)
        for i in range(5)
    ]
    recs = sched.run(reqs)
    assert len(recs) == 5
    assert sched.admits == 5
    # 5 requests x 4 tokens through 2 slots needs >= 10 decode-ish steps.
    assert sched.tokens_generated == 20
    # only the very first admission is instant: each prefill occupies the
    # engine for a step, and the last three must also wait for a slot
    delayed = [r for r in recs if r.queue_delay_steps > 0]
    assert len(delayed) == 4
    assert all(r.done_step >= r.admit_step for r in recs)


def test_eos_stops_slot_early(digital):
    cfg, params = digital
    sched = _scheduler(cfg, params)
    sched.warmup(prompt_range=(4, 8))
    probe = Request(rid=3, prompt=[7, 8, 9, 10], max_new=8)
    full = sched.run([probe])[0]
    assert full.n_generated == 8
    eos = full.tokens[2]  # stop on the 3rd emitted token
    sched.reset(keep_traces=True)
    stopped = sched.run(
        [Request(rid=3, prompt=[7, 8, 9, 10], max_new=8, eos_id=eos)]
    )[0]
    assert stopped.tokens == full.tokens[:3]
    assert sched.active_slots() == 0


def test_rejects_recurrent_and_oversize(digital):
    cfg, params = digital
    rwkv = _tiny_cfg(block="rwkv6", name="rwkv-sched")
    engine = ServeEngine(rwkv, None)
    with pytest.raises(ValueError, match="attention"):
        ContinuousScheduler(engine, n_slots=2, max_len=32)
    with pytest.raises(ValueError, match="rwkv6|attention-only"):
        prefill(
            params, {"tokens": jnp.zeros((1, 8), jnp.int32)}, rwkv,
            max_len=16, true_len=jnp.asarray([4], jnp.int32),
        )
    sin = _tiny_cfg(pos_embedding="sinusoidal", name="sin-sched")
    with pytest.raises(ValueError, match="sinusoidal"):
        ContinuousScheduler(ServeEngine(sin, None), n_slots=2, max_len=32)
    sched = _scheduler(cfg, params, max_len=16)
    with pytest.raises(ValueError, match="exceeds max_len"):
        sched.admit(Request(rid=0, prompt=[1] * 10, max_new=8))


def test_write_cache_slot_unit():
    cfg = _tiny_cfg()
    shared = init_cache(cfg, 4, 32)
    single = init_cache(cfg, 1, 32)
    single["k"] = single["k"] + 1.5
    single["pos"] = single["pos"] + 7
    out = write_cache_slot(shared, single, jnp.int32(2))
    assert float(out["k"][:, 2].min()) == 1.5
    assert float(jnp.abs(out["k"][:, [0, 1, 3]]).max()) == 0.0
    assert out["pos"].tolist() == [0, 0, 7, 0]


# ------------------------------------------------------------------- analog
def test_analog_traffic_and_maintenance(deployed_tiny):
    """CIMExecutor ticks real read traffic per scheduled step; lifetime
    epochs interleave between decode steps without blocking the batch."""
    cfg, deployed = deployed_tiny
    ex = CIMExecutor(
        deployed, CIMConfig(dac_bits=4, adc_bits=10, sigma_read_lsb=0.2),
        jax.random.PRNGKey(7),
    )
    engine = ServeEngine(cfg, executor=ex, temperature=0.7)
    sim = LifetimeSimulator(
        jax.random.PRNGKey(3), deployed,
        refresh_cfg=RefreshConfig(policy=RefreshPolicy.VERIFY_TRIGGERED),
        traffic_fn=ex.drain_reads,
    )
    epochs = []
    sched = ContinuousScheduler(
        engine, n_slots=2, max_len=48, key=jax.random.PRNGKey(5),
        maintenance_fn=lambda: epochs.append(sim.step_epoch(1.0, max_leaves=2)),
        maintenance_every=4,
    )
    sched.warmup(prompt_range=(3, 8))
    warm = dict(sched.trace_counts)
    ex.drain_reads()
    tokens0 = ex.tokens_served
    reqs = poisson_requests(
        2, 5, rate=0.6, vocab=cfg.vocab_size,
        prompt_lens=(3, 8), max_new=(3, 6),
    )
    sched.run(reqs)
    assert sched.trace_counts == warm  # analog serving: still no retrace
    # every decode step ticks the full physical batch; every admit ticks
    # the padded bucket length
    expect_tokens = (
        sched.decode_steps * sched.n_slots + sched.prefill_tokens
    )
    assert ex.tokens_served - tokens0 == expect_tokens
    assert len(epochs) == sched.decode_steps // 4
    assert epochs[0].reads_per_column > 0  # drained traffic reached aging
    leftover = sum(ex.drain_reads().values())
    assert leftover >= 0.0


def test_analog_batch_composition_invariance(deployed_tiny):
    """ISSUE-9 tentpole: with request ids folded into the CIM noise
    stream (via `token_stream_ids`), a request's analog decode logits
    are bit-identical served alone vs inside a full batch, regardless
    of which slot it lands in or who its neighbors are."""
    from repro.cim import token_stream_ids
    from repro.models import decode_step

    cfg, deployed = deployed_tiny
    ex = CIMExecutor(
        deployed, CIMConfig(dac_bits=4, adc_bits=10, sigma_read_lsb=0.3),
        jax.random.PRNGKey(7),
    )
    params = ex.tick(1)  # one access: same leaf keys for every variant
    prompt = jnp.asarray([[5, 9, 2, 40, 17]], jnp.int32)
    rid = jnp.asarray([37], jnp.int32)
    last, cache1 = prefill(params, {"tokens": prompt}, cfg, max_len=48)
    cur = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
    with token_stream_ids(rid):
        la, _ = decode_step(params, cache1, {"tokens": cur}, cfg)
    for slot in (0, 2):
        cache_b = write_cache_slot(
            init_cache(cfg, 3, 48), cache1, jnp.int32(slot)
        )
        # neighbors: other live requests with their own ids and tokens
        rids_b = jnp.asarray([3, 11, 29], jnp.int32).at[slot].set(rid[0])
        cur_b = jnp.full((3, 1), 7, jnp.int32).at[slot].set(cur[0, 0])
        with token_stream_ids(rids_b):
            lb, _ = decode_step(params, cache_b, {"tokens": cur_b}, cfg)
        np.testing.assert_array_equal(np.asarray(la[0]), np.asarray(lb[slot]))


def test_incremental_scrub_rotates(deployed_tiny):
    """max_leaves bounds per-epoch scrub work and the cursor visits every
    leaf; aging still applies to all leaves each epoch."""
    cfg, deployed = deployed_tiny
    n_leaves = len(deployed.arrays)
    assert n_leaves >= 2
    ref = RefreshConfig(policy=RefreshPolicy.PERIODIC, period_epochs=1)
    full = LifetimeSimulator(jax.random.PRNGKey(3), deployed, refresh_cfg=ref)
    part = LifetimeSimulator(jax.random.PRNGKey(3), deployed, refresh_cfg=ref)
    e_full = full.step_epoch(1.0).program_energy_pj
    e1 = part.step_epoch(1.0, max_leaves=1).program_energy_pj
    assert 0.0 < e1 < e_full
    assert part._scrub_cursor == 1
    for _ in range(n_leaves - 1):
        part.step_epoch(1.0, max_leaves=1)
    assert part._scrub_cursor == 0  # wrapped: every leaf visited once


def test_cim_weight_sharding_single_device(deployed_tiny):
    """Tile planes shard their output axis over "model"; a 1x1 mesh is a
    placement no-op so served params stay bit-identical."""
    from repro.launch.shardings import cim_weight_specs
    from repro.launch.mesh import make_debug_mesh

    cfg, deployed = deployed_tiny
    mesh = make_debug_mesh(1, 1)
    cim_cfg = CIMConfig(dac_bits=4, adc_bits=10, sigma_read_lsb=0.0)
    ex_plain = CIMExecutor(deployed, cim_cfg, jax.random.PRNGKey(7))
    ex_mesh = CIMExecutor(deployed, cim_cfg, jax.random.PRNGKey(7), mesh=mesh)
    name = next(iter(ex_mesh._analog))
    w = ex_mesh._analog[name]
    specs = cim_weight_specs(mesh, w)
    # last-axis assignment is "model" whenever the extent divides M (1 here)
    assert specs["g_pos"].spec[-1] == "model"
    assert specs["scale"].spec[-1] == "model"
    assert tuple(specs["key"].spec) == ()
    for a, b in zip(
        jax.tree.leaves(ex_plain._analog[name]),
        jax.tree.leaves(w),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -------------------------------------------- chunked prefill + SLO (ISSUE-10)
def test_chunked_prefill_bit_identity(digital):
    """Tentpole acceptance: the SAME Poisson stream served with chunked
    prefill yields byte-for-byte the tokens of whole-prompt admission,
    with zero retraces after warmup and one sync per decode step."""
    cfg, params = digital
    reqs = poisson_requests(
        3, 10, rate=0.8, vocab=cfg.vocab_size,
        prompt_lens=(3, 40), max_new=(3, 6),
    )
    whole = _scheduler(cfg, params)
    whole.warmup(prompt_range=(3, 40))
    base = {r.rid: r.tokens for r in whole.run(reqs)}

    ch = _scheduler(cfg, params, prefill_chunk_tokens=16)
    ch.warmup(prompt_range=(3, 40))
    warm = dict(ch.trace_counts)
    recs = ch.run(reqs)
    assert {r.rid: r.tokens for r in recs} == base
    assert ch.trace_counts == warm, "chunk dispatch retraced after warmup"
    assert ch.host_syncs == ch.decode_steps
    assert max(r.n_chunks for r in recs) >= 2  # long prompts did chunk


def test_chunked_prefill_cache_matches_whole(digital):
    """Chunk-by-chunk prefill writes the SAME cache bits as one
    whole-bucket prefill over every real position, restores the slot's
    pos, and samples an identical first token."""
    cfg, params = digital
    plen = 37  # 3 chunks of 16; bucket 64
    prompt = [(7 * i) % cfg.vocab_size for i in range(plen)]
    whole = _scheduler(cfg, params)
    whole.admit(Request(rid=5, prompt=prompt, max_new=2))
    ch = _scheduler(cfg, params, prefill_chunk_tokens=16)
    ch.admit(Request(rid=5, prompt=prompt, max_new=2))
    assert 0 in ch._prefilling  # slot reserved, prefill in flight
    assert int(ch.cache["pos"][0]) == ch.max_len  # parked: decode writes drop
    while ch.prefill_tick():
        pass
    assert ch.records[5].n_chunks == 3
    for leaf in ("k", "v"):  # identical over REAL positions (rest is junk)
        np.testing.assert_array_equal(
            np.asarray(whole.cache[leaf][:, 0, :plen]),
            np.asarray(ch.cache[leaf][:, 0, :plen]),
        )
    assert int(ch.cache["pos"][0]) == plen - 1
    assert ch.records[5].tokens == whole.records[5].tokens


_REQ_ROWS = st.lists(
    st.tuples(
        st.integers(0, 1000),                                    # rid
        st.floats(0, 100, allow_nan=False, allow_infinity=False),  # arrival
        st.integers(1, 32),                                      # prompt len
        st.one_of(st.none(), st.floats(0, 200, allow_nan=False,
                                       allow_infinity=False)),   # deadline
    ),
    min_size=1, max_size=20, unique_by=lambda t: t[0],
)


@settings(max_examples=200, deadline=None)
@given(rows=_REQ_ROWS, policy=st.sampled_from(ADMISSION_POLICIES))
def test_select_next_is_policy_order(rows, policy):
    """Property: repeatedly admitting `select_next` drains the ready set
    in exactly `sorted(key=admission_key)` order — a strict total order
    (deterministic admission) for every policy; EDF is deadline-sorted
    with deadline-less requests last."""
    ready = [
        Request(rid=r, prompt=[0] * p, max_new=1, arrival=a, deadline=d)
        for r, a, p, d in rows
    ]
    pool, order = list(ready), []
    while pool:
        nxt = select_next(pool, policy)
        pool.remove(nxt)
        order.append(nxt)
    assert [r.rid for r in order] == [
        r.rid for r in sorted(ready, key=lambda r: admission_key(policy, r))
    ]
    if policy == "edf":
        ds = [r.deadline if r.deadline is not None else math.inf
              for r in order]
        assert ds == sorted(ds)
    if policy == "spf":
        ls = [len(r.prompt) for r in order]
        assert ls == sorted(ls)


def test_edf_admission_order_integration(digital):
    """A real EDF serve admits tight-deadline requests first (admit_step
    order follows deadlines, not rid/arrival), and latency_stats reports
    the deadline-miss accounting."""
    cfg, params = digital
    sched = _scheduler(cfg, params, n_slots=1, admission_policy="edf")
    sched.warmup(prompt_range=(4, 8))
    reqs = [
        Request(rid=0, prompt=[1] * 5, max_new=2, arrival=0.0, deadline=100.0),
        Request(rid=1, prompt=[2] * 5, max_new=2, arrival=0.0, deadline=5.0),
        Request(rid=2, prompt=[3] * 5, max_new=2, arrival=0.0, deadline=50.0),
    ]
    recs = sched.run(reqs)
    by_admit = sorted(recs, key=lambda r: (r.admit_step, r.rid))
    assert [r.rid for r in by_admit] == [1, 2, 0]
    stats = sched.latency_stats()
    assert stats["deadline_requests"] == 3.0
    assert stats["deadline_misses"] == sum(r.deadline_missed for r in recs)
    assert stats["deadline_miss_rate"] == stats["deadline_misses"] / 3.0


def test_proportional_prefill_pricing(digital):
    """ISSUE-10 bugfix: with `prefill_tokens_per_step` the admission
    clock charges proportionally to the physical tokens driven (a
    64-token bucket is 8x a costly as an 8-token one), while the legacy
    constant stays the default and chunk charges pro-rate."""
    cfg, params = digital
    sched = _scheduler(cfg, params, prefill_tokens_per_step=16.0)
    sched.warmup(prompt_range=(3, 40))
    sched.admit(Request(rid=1, prompt=[1] * 40, max_new=2, arrival=0.0))
    assert sched.records[1].first_token_step == pytest.approx(4.0)  # 64/16
    sched.reset(keep_traces=True)
    sched.admit(Request(rid=2, prompt=[1] * 5, max_new=2, arrival=0.0))
    assert sched.records[2].first_token_step == pytest.approx(0.5)  # 8/16
    legacy = _scheduler(cfg, params)
    assert legacy.prefill_cost(64, 64) == 1.0 == legacy.prefill_cost(8, 8)
    assert legacy.prefill_cost(16, 64) == pytest.approx(0.25)  # chunk share


def test_quantile_definition_consistent(digital):
    """latency_stats percentiles ARE obs.rank_quantile of the per-request
    arrays (an order statistic, present in the sample), and the streaming
    digest estimates the same rank within one bucket width."""
    cfg, params = digital
    sched = _scheduler(cfg, params)
    sched.warmup(prompt_range=(3, 12))
    reqs = poisson_requests(
        7, 14, rate=1.0, vocab=cfg.vocab_size,
        prompt_lens=(3, 12), max_new=(2, 6),
    )
    recs = sched.run(reqs)
    stats = sched.latency_stats()
    lats = np.array([r.latency_steps for r in recs])
    ttfts = np.array([r.ttft_steps for r in recs])
    assert stats["p99_latency_steps"] == obs.rank_quantile(lats, 0.99)
    assert stats["p50_latency_steps"] == obs.rank_quantile(lats, 0.50)
    assert stats["p99_ttft_steps"] == obs.rank_quantile(ttfts, 0.99)
    assert stats["p99_latency_steps"] in set(lats.tolist())
    dig = sched.digest_stats()["serve.latency_steps"]
    width = (dig["hi"] - dig["lo"]) / dig["n_buckets"]
    assert abs(dig["p99"] - stats["p99_latency_steps"]) <= width + 1e-6
    assert dig["n_under"] == 0.0 and dig["n_over"] == 0.0


def test_sharded_decode_bit_identity_single_device(digital):
    """batch_mesh placement (batch over "data", DESIGN.md Sec. 18) is
    bit-neutral: tokens identical to the meshless run, contracts hold."""
    from repro.launch.mesh import make_debug_mesh

    cfg, params = digital
    reqs = poisson_requests(
        11, 8, rate=0.7, vocab=cfg.vocab_size,
        prompt_lens=(3, 12), max_new=(3, 6),
    )
    plain = _scheduler(cfg, params)
    plain.warmup(prompt_range=(3, 12))
    base = {r.rid: r.tokens for r in plain.run(reqs)}
    sh = _scheduler(cfg, params, batch_mesh=make_debug_mesh(1, 1))
    sh.warmup(prompt_range=(3, 12))
    warm = dict(sh.trace_counts)
    recs = sh.run(reqs)
    assert {r.rid: r.tokens for r in recs} == base
    assert sh.trace_counts == warm
    assert sh.host_syncs == sh.decode_steps


_SHARD_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_debug_mesh
    from repro.models import ModelConfig, init_params
    from repro.serving import ContinuousScheduler, ServeEngine, poisson_requests

    cfg = ModelConfig(name="shard-serve", n_layers=2, d_model=32, n_heads=2,
                      n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64,
                      dtype=jnp.float32, attn_chunk_q=16, attn_chunk_kv=16,
                      remat=False, tie_embeddings=False)
    params = init_params(jax.random.PRNGKey(0), cfg)
    reqs = poisson_requests(3, 8, rate=0.8, vocab=cfg.vocab_size,
                            prompt_lens=(3, 24), max_new=(3, 6))

    def serve(batch_mesh):
        eng = ServeEngine(cfg, params, temperature=0.7)
        s = ContinuousScheduler(eng, n_slots=4, max_len=64,
                                key=jax.random.PRNGKey(5),
                                prefill_chunk_tokens=16,
                                batch_mesh=batch_mesh)
        s.warmup(prompt_range=(3, 24))
        warm = dict(s.trace_counts)
        recs = s.run(reqs)
        assert s.trace_counts == warm, (s.trace_counts, warm)
        assert s.host_syncs == s.decode_steps
        return {r.rid: r.tokens for r in recs}

    base = serve(None)
    shard = serve(make_debug_mesh(4, 2))  # 4-way "data" over the 4 slots
    assert base == shard, "sharded decode tokens differ from unsharded"
    print("SHARD-SERVE-OK")
    """
)


@pytest.mark.skipif(
    (os.cpu_count() or 1) < 4,
    reason="forced multi-device host simulation hangs XLA backend init on <4 cores",
)
def test_sharded_decode_multidevice_subprocess():
    """Acceptance: decode-batch "data" sharding on a REAL 4x2 device mesh
    (8 forced host devices) serves bit-identical tokens to the unsharded
    run, chunked prefill included, with contracts intact."""
    res = subprocess.run(
        [sys.executable, "-c", _SHARD_SCRIPT],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd="/root/repo",
        timeout=560,
    )
    assert res.returncode == 0, res.stdout + res.stderr
    assert "SHARD-SERVE-OK" in res.stdout, res.stdout + res.stderr


def test_request_record_dataclass_roundtrip():
    from repro.serving import RequestRecord

    r = RequestRecord(rid=1, arrival=2.0, prompt_len=4, bucket_len=8,
                      admit_step=3.0, first_token_step=4.0, done_step=9.0,
                      tokens=[1, 2, 3])
    assert r.queue_delay_steps == 1.0
    assert r.ttft_steps == 2.0
    assert r.latency_steps == 7.0
    assert r.n_generated == 3
    assert dataclasses.asdict(r)["tokens"] == [1, 2, 3]
