"""Circuit cost-model invariants (paper Table 1 / Sec. 5.3 structure)."""

import jax.numpy as jnp
import pytest

from repro.core import CircuitCost, WVConfig, WVMethod, default_config_for_array
from repro.core.cost import read_phase_cost, write_phase_cost


@pytest.fixture
def cost():
    return CircuitCost()


def test_default_config_scaling():
    c32, c64 = default_config_for_array(32), default_config_for_array(64)
    assert c32.adc.bits == 9 and c64.adc.bits == 10
    assert c64.tau_w == pytest.approx(2 * c32.tau_w)  # tau_w ~ N


def test_read_cost_per_method_ordering(cost):
    """Per verification sweep: compare-only < full-SAR latency; MRA pays
    M x the HD-PV read cost; HARP adds only the tiny adder tail."""
    lat, en = {}, {}
    for m in WVMethod:
        cfg = WVConfig(method=m)
        lat[m], en[m] = (
            float(x) for x in read_phase_cost(cfg, cost)
        )
    assert lat[WVMethod.CW_SC] < lat[WVMethod.HD_PV]
    assert lat[WVMethod.HARP] < lat[WVMethod.HD_PV]
    assert lat[WVMethod.MRA] == pytest.approx(5 * (lat[WVMethod.HD_PV] - cost.t_adder_ns))
    assert en[WVMethod.MRA] == pytest.approx(
        5 * (en[WVMethod.HD_PV] - 32 * cost.e_adder_hdpv_pj)
    )
    # ADC energy dominates (paper: >90% of WV energy is ADC activity)
    cfg = WVConfig(method=WVMethod.HD_PV)
    adc_only = cfg.n_cells * cfg.adc.e_sar_pj
    assert adc_only / en[WVMethod.HD_PV] > 0.9


def test_write_cost_column_parallel(cost):
    """Phase latency is max-pulses (column-parallel), not sum; energy sums."""
    cfg = WVConfig()
    g = jnp.full((1, 32), 3.0)
    n_p = jnp.zeros((1, 32)).at[0, 0].set(4.0).at[0, 1].set(2.0)
    direction = jnp.zeros((1, 32)).at[0, 0].set(1.0).at[0, 1].set(-1.0)
    lat, en = write_phase_cost(g, n_p, direction, cfg.device, cost)
    # 4 SET pulses + 2 RESET pulses, phases serialized
    assert float(lat[0]) == pytest.approx(cost.t_write_pulse_ns * (4 + 2))
    assert float(en[0]) > 0
    # doubling pulses doubles energy, latency follows the max
    lat2, en2 = write_phase_cost(g, 2 * n_p, direction, cfg.device, cost)
    assert float(en2[0]) == pytest.approx(2 * float(en[0]))
    assert float(lat2[0]) == pytest.approx(2 * float(lat[0]))


def test_harp_compare_count_affects_cost(cost):
    cfg = WVConfig(method=WVMethod.HARP)
    ones = jnp.ones((32,), jnp.int32)
    lat1, en1 = read_phase_cost(cfg, cost, n_compares=ones)
    lat2, en2 = read_phase_cost(cfg, cost, n_compares=2 * ones)
    assert float(en2) > float(en1)
    assert float(lat2) > float(lat1)
