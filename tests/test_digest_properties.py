"""Property-based contracts for obs.digest (DESIGN.md Sec. 16).

Two guarantees the fleet observability layer leans on:

* QUANTILE ACCURACY — for ANY in-range input distribution, the
  rank-based bucket-midpoint quantile is within one bucket width of
  the exact order statistic (np.quantile with method="lower", the same
  rank convention).  This is what makes fixed-bucket histograms a safe
  replacement for per-request latency arrays.
* MERGE ALGEBRA — merge is commutative and associative (elementwise
  float32 count addition), so per-replica digests fold into fleet
  digests in any order with identical results.

Pure numpy paths (`host` + `observe`): no jax required here; the
traced `add` path is covered by test_obs.py equivalence tests.
"""

import numpy as np

from repro.obs.digest import StreamingDigest

from hypothesis_compat import given, settings, st

_VALUES = st.lists(
    st.floats(
        min_value=-1e6, max_value=1e6,
        allow_nan=False, allow_infinity=False, width=32,
    ),
    min_size=1, max_size=200,
)


def _digest_for(values: np.ndarray, n_buckets: int) -> StreamingDigest:
    lo = float(values.min())
    hi = float(values.max())
    if hi <= lo:  # degenerate range: give the single bucket some width
        hi = lo + max(abs(lo) * 1e-6, 1e-6)
    d = StreamingDigest.host(lo, hi, n_buckets)
    d.observe(values)
    return d


@settings(max_examples=200, deadline=None)
@given(values=_VALUES, n_buckets=st.integers(1, 64), q=st.floats(0.0, 1.0))
def test_quantile_within_one_bucket_width(values, n_buckets, q):
    """digest.quantile(q) is within one bucket width of the exact
    rank-based order statistic, for arbitrary distributions."""
    x = np.asarray(values, np.float32)
    d = _digest_for(x, n_buckets)
    est = d.quantile(q)
    assert est is not None
    exact = float(np.quantile(x, q, method="lower"))
    assert abs(est - exact) <= d.width + 1e-6 * max(abs(exact), 1.0), (
        est, exact, d.width,
    )


@settings(max_examples=100, deadline=None)
@given(chunks=st.lists(_VALUES, min_size=2, max_size=5), seed=st.integers(0, 2**31 - 1))
def test_merge_commutative_and_associative(chunks, seed):
    """Folding per-replica digests in ANY order gives identical counts,
    totals and extrema — the fleet-fold contract."""
    flat = np.asarray([v for c in chunks for v in c], np.float32)
    lo, hi = float(flat.min()), float(flat.max())
    if hi <= lo:
        hi = lo + max(abs(lo) * 1e-6, 1e-6)
    parts = []
    for c in chunks:
        d = StreamingDigest.host(lo, hi, 16)
        d.observe(np.asarray(c, np.float32))
        parts.append(d)

    def fold(ds):
        acc = ds[0]
        for d in ds[1:]:
            acc = acc.merge(d)
        return acc

    rng = np.random.default_rng(seed)
    forward = fold(parts)
    shuffled = fold([parts[i] for i in rng.permutation(len(parts))])
    # associativity: right fold == left fold
    acc = parts[-1]
    for d in reversed(parts[:-1]):
        acc = d.merge(acc)
    for other in (shuffled, acc):
        # counts (small float32 integers) and extrema are EXACT under
        # reordering — quantiles depend only on these; the running sum
        # reorders float additions, so it is close, not bit-equal.
        np.testing.assert_array_equal(
            np.asarray(forward.counts), np.asarray(other.counts)
        )
        np.testing.assert_allclose(
            float(forward.total), float(other.total), rtol=1e-4, atol=1e-3
        )
        assert float(forward.vmin) == float(other.vmin)
        assert float(forward.vmax) == float(other.vmax)
    # the fold saw every observation exactly once
    assert forward.count == len(flat)


@settings(max_examples=100, deadline=None)
@given(a=_VALUES, b=_VALUES)
def test_pairwise_merge_commutes(a, b):
    """merge(a, b) == merge(b, a) exactly."""
    flat = np.asarray(list(a) + list(b), np.float32)
    lo, hi = float(flat.min()), float(flat.max())
    if hi <= lo:
        hi = lo + max(abs(lo) * 1e-6, 1e-6)
    da = StreamingDigest.host(lo, hi, 32)
    da.observe(np.asarray(a, np.float32))
    db = StreamingDigest.host(lo, hi, 32)
    db.observe(np.asarray(b, np.float32))
    ab, ba = da.merge(db), db.merge(da)
    np.testing.assert_array_equal(np.asarray(ab.counts), np.asarray(ba.counts))
    assert float(ab.total) == float(ba.total)
    assert (float(ab.vmin), float(ab.vmax)) == (float(ba.vmin), float(ba.vmax))


def test_empty_digest_quantiles_none():
    d = StreamingDigest.host(0.0, 1.0, 8)
    assert d.quantile(0.5) is None
    s = d.summary()
    assert s["count"] == 0 and s["p99"] is None and s["mean"] is None
    assert s["n_under"] == 0.0 and s["n_over"] == 0.0


def test_out_of_range_counts_observed():
    """Values outside [lo, hi) still clamp into the edge buckets (no
    count leaks) but are COUNTED, so a digest whose top bucket is
    secretly an overflow bin is visible in summaries (ISSUE-10: the
    step_latency_us hi=1e5 clip silently ate slow-step mass)."""
    d = StreamingDigest.host(0.0, 10.0, 10)
    d.observe(np.asarray([-3.0, 5.0, 5.0, 10.0, 12.0, 9.99], np.float32))
    assert d.count == 6.0  # clamped mass still counted in the histogram
    assert float(d.n_under) == 1.0
    assert float(d.n_over) == 2.0  # hi itself is out of [lo, hi)
    s = d.summary()
    assert (s["n_under"], s["n_over"]) == (1.0, 2.0)
    # in-range-only digests report zero — the common healthy case
    clean = StreamingDigest.host(0.0, 10.0, 10)
    clean.observe(np.linspace(0.0, 9.9, 50).astype(np.float32))
    assert float(clean.n_under) == 0.0 and float(clean.n_over) == 0.0
    # merge adds the counters like any other count
    m = d.merge(d)
    assert (float(m.n_under), float(m.n_over)) == (2.0, 4.0)


def test_overflow_counters_ride_jit_without_retrace():
    """The traced `add` path counts out-of-range values, the counters are
    pytree CHILDREN (aux stays (lo, hi)), and a warmed dispatch never
    retraces — the scheduler's in-jit occupancy digest relies on this."""
    import jax
    import jax.numpy as jnp

    d = StreamingDigest.zeros(0.0, 4.0, 4)
    traces = []

    @jax.jit
    def step(dig, x):
        traces.append(1)  # trace-time side effect
        return dig.add(x)

    for v in (1.0, -2.0, 7.0, 3.5):
        d = step(d, jnp.float32(v))
    assert len(traces) == 1, "digest operand retraced a warmed dispatch"
    host = jax.device_get(d)
    assert float(host.n_under) == 1.0
    assert float(host.n_over) == 1.0
    assert host.count == 4.0
    # weighted path: out-of-range mass carries its weight
    w = StreamingDigest.zeros(0.0, 4.0, 4).add_weighted(
        jnp.asarray([-1.0, 2.0, 9.0]), jnp.asarray([3.0, 1.0, 2.0])
    )
    w = jax.device_get(w)
    assert (float(w.n_under), float(w.n_over)) == (3.0, 2.0)
