"""Optional-hypothesis shim for property-based tests.

`hypothesis` is a dev-only dependency (requirements-dev.txt).  Modules that
are *entirely* property-based guard themselves with
``pytest.importorskip("hypothesis")``; modules where only a few tests use
hypothesis import ``given / settings / st`` from here instead, so the rest
of the module still collects and runs when hypothesis is absent — the
property tests alone report as skipped.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # exercised when hypothesis is not installed
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Stands in for `strategies`; tests using it are skipped anyway."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*a, **k):
        return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)

    def settings(*a, **k):
        return lambda f: f
