"""Lifetime subsystem: drift dynamics, refresh policies, deploy state."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.device as dev_mod
from repro.core import (
    CircuitCost,
    NoiseConfig,
    WVConfig,
    WVMethod,
    default_config_for_array,
)
from repro.core.programmer import deploy_arrays, deploy_params
from repro.lifetime import (
    CellState,
    DriftConfig,
    LifetimeSimulator,
    RefreshConfig,
    RefreshPolicy,
    advance,
    apply_refresh,
    flag_columns,
    init_cell_state,
    reset_programmed,
    wear_efficiency,
)

C, N = 24, 16


def _state(seed=0, drift_cfg=None, g=None):
    dev = WVConfig(n_cells=N).device
    dcfg = drift_cfg or DriftConfig()
    key = jax.random.PRNGKey(seed)
    k_t, k_d, k_s = jax.random.split(key, 3)
    if g is None:
        g = jax.random.randint(k_t, (C, N), 0, dev.levels).astype(jnp.float32)
    d2d = dev_mod.sample_d2d(k_d, g.shape, dev)
    return init_cell_state(k_s, g, d2d, dev, dcfg), g, dev, dcfg


# ------------------------------------------------------------- drift
def test_relaxation_settles_toward_equilibrium():
    # Isolate relaxation: no log drift, no disturb (nu is sampled into the
    # state at init, so the config must be drift-free *at init*).
    dcfg = DriftConfig(nu_drift=0.0, sigma_nu_frac=0.0, read_disturb_lsb=0.0)
    st, g0, dev, dcfg = _state(drift_cfg=dcfg)
    st1 = advance(jax.random.PRNGKey(1), st, 40.0, 0.0, dev, dcfg)
    st2 = advance(jax.random.PRNGKey(2), st1, 1e6, 0.0, dev, dcfg)
    d1 = float(jnp.mean(jnp.abs(st1.g - st.g_eq)))
    d0 = float(jnp.mean(jnp.abs(st.g - st.g_eq)))
    assert d1 < d0  # monotone approach...
    np.testing.assert_allclose(st2.g, st.g_eq, atol=1e-4)  # ...to equilibrium
    # Direction: cells relax toward mid-scale on average (rail pull).
    hi = np.asarray(g0) > 0.75 * dev.g_max_lsb
    assert float(jnp.mean((st2.g - g0)[hi])) < 0.0


def test_advance_deterministic_and_scannable():
    st, _, dev, dcfg = _state()
    key = jax.random.PRNGKey(3)
    a = advance(key, st, 600.0, 100.0, dev, dcfg)
    b = advance(key, st, 600.0, 100.0, dev, dcfg)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def body(carry, k):
        return advance(k, carry, 600.0, 100.0, dev, dcfg), None

    keys = jax.random.split(key, 4)
    scanned, _ = jax.lax.scan(body, st, keys)
    seq = st
    for k in keys:
        seq = advance(k, seq, 600.0, 100.0, dev, dcfg)
    np.testing.assert_allclose(np.asarray(scanned.g), np.asarray(seq.g), atol=1e-5)
    assert float(scanned.age_s[0, 0]) == pytest.approx(2400.0)
    assert float(scanned.reads[0, 0]) == pytest.approx(400.0)


def test_log_drift_decays_and_composes():
    st, g0, dev, _ = _state()
    dcfg = DriftConfig(
        tau_relax_s=1e-6, relax_frac=0.0, sigma_relax_lsb=0.0,
        nu_drift=0.05, sigma_nu_frac=0.0, read_disturb_lsb=0.0,
    )
    st, g0, dev, dcfg = _state(drift_cfg=dcfg)
    one = advance(jax.random.PRNGKey(0), st, 7200.0, 0.0, dev, dcfg)
    two = advance(
        jax.random.PRNGKey(1),
        advance(jax.random.PRNGKey(0), st, 3600.0, 0.0, dev, dcfg),
        3600.0, 0.0, dev, dcfg,
    )
    # Exact composition: two half steps == one full step.
    np.testing.assert_allclose(np.asarray(one.g), np.asarray(two.g), atol=1e-5)
    nz = np.asarray(g0) > 0
    assert np.all(np.asarray(one.g)[nz] < np.asarray(g0)[nz])


def test_read_disturb_accumulates_setward():
    dcfg = DriftConfig(
        tau_relax_s=1e9, nu_drift=0.0, sigma_nu_frac=0.0,
        read_disturb_lsb=1e-4,
    )
    st, g0, dev, dcfg = _state(drift_cfg=dcfg)
    aged = advance(jax.random.PRNGKey(0), st, 1.0, 1000.0, dev, dcfg)
    inner = ~((np.asarray(g0) <= 0) | (np.asarray(g0) >= dev.g_max_lsb))
    delta = np.asarray(aged.g - g0)[inner]
    np.testing.assert_allclose(delta, 0.1, atol=1e-5)


def test_wear_monotonically_degrades_step_efficiency():
    dcfg = DriftConfig()
    cycles = jnp.asarray([0.0, 1e4, 1e5, 1e6, 1e7])
    eff = np.asarray(wear_efficiency(cycles, dcfg))
    assert eff[0] == pytest.approx(1.0)
    assert np.all(np.diff(eff) < 0)
    assert np.all(eff > 0)


def test_stuck_cells_freeze():
    dcfg = DriftConfig(endurance_cycles=10.0, sigma_endurance_dec=0.0)
    st, g0, dev, dcfg = _state(drift_cfg=dcfg)
    pulses = jnp.full((C, N), 100.0)  # blow past every cell's limit
    refreshed = jnp.ones((C,), bool)
    st2 = reset_programmed(
        jax.random.PRNGKey(1), st, st.g, refreshed, pulses, dev, dcfg
    )
    assert bool(jnp.all(st2.stuck))
    aged = advance(jax.random.PRNGKey(2), st2, 1e6, 1e6, dev, dcfg)
    np.testing.assert_array_equal(np.asarray(aged.g), np.asarray(st2.g))


# ------------------------------------------------------------- refresh
def test_verify_triggered_flags_exactly_drifted_columns():
    cfg = WVConfig(
        method=WVMethod.HD_PV, n_cells=N,
        noise=NoiseConfig(sigma_read_lsb=0.0),
    )
    targets = jax.random.randint(
        jax.random.PRNGKey(0), (C, N), 0, cfg.device.levels
    ).astype(jnp.float32)
    g = targets  # perfectly programmed
    drifted = [3, 11, 17]
    for c in drifted:
        delta = jnp.where(targets[c, :4] > 3.0, -2.0, 2.0)  # stay in range
        g = g.at[c, :4].add(delta)
    flags, sweeps = flag_columns(
        jax.random.PRNGKey(1), g, targets, cfg, RefreshConfig()
    )
    assert sweeps >= 1
    np.testing.assert_array_equal(
        np.nonzero(np.asarray(flags))[0], np.asarray(drifted)
    )


def test_refresh_policies_reprogram_and_account_cost():
    # tau_w scales with N (default_config_for_array); plain tau_w=4 at
    # N=16 under-corrects and re-programming would not beat the drift.
    cfg = default_config_for_array(N).replace(method=WVMethod.HARP)
    dcfg = DriftConfig()
    cost = CircuitCost()
    st, targets, dev, _ = _state(seed=2)
    # Age hard so columns genuinely drift.
    st = advance(jax.random.PRNGKey(5), st, 3600.0, 1e5, dev,
                 dcfg.replace(nu_drift=0.05))
    rms_pre = float(jnp.sqrt(jnp.mean((st.g - targets) ** 2)))

    st_none, out_none = apply_refresh(
        jax.random.PRNGKey(6), st, targets, cfg, cost, dcfg,
        RefreshConfig(policy=RefreshPolicy.NONE), epoch=0,
    )
    assert out_none.n_reprogrammed == 0
    assert out_none.maintenance_energy_pj == 0.0
    np.testing.assert_array_equal(np.asarray(st_none.g), np.asarray(st.g))

    st_p, out_p = apply_refresh(
        jax.random.PRNGKey(6), st, targets, cfg, cost, dcfg,
        RefreshConfig(policy=RefreshPolicy.PERIODIC), epoch=0,
    )
    assert out_p.n_reprogrammed == C
    assert out_p.program_energy_pj > 0
    assert out_p.verify_energy_pj == 0.0
    rms_post = float(jnp.sqrt(jnp.mean((st_p.g - targets) ** 2)))
    assert rms_post < rms_pre
    # Refresh restarts the relaxation/drift clock and charges wear.
    assert float(jnp.max(st_p.age_s)) == 0.0
    assert float(jnp.sum(st_p.cycles)) > float(jnp.sum(st.cycles))

    st_v, out_v = apply_refresh(
        jax.random.PRNGKey(6), st, targets, cfg, cost, dcfg,
        RefreshConfig(policy=RefreshPolicy.VERIFY_TRIGGERED), epoch=0,
    )
    assert out_v.flagged is not None
    assert out_v.n_reprogrammed == int(out_v.flagged.sum())
    assert out_v.verify_energy_pj > 0
    # Only flagged columns were touched.
    untouched = ~out_v.flagged
    np.testing.assert_array_equal(
        np.asarray(st_v.g)[untouched], np.asarray(st.g)[untouched]
    )


def test_periodic_respects_period():
    cfg = WVConfig(method=WVMethod.HARP, n_cells=N)
    st, targets, dev, dcfg = _state(seed=3)
    rcfg = RefreshConfig(policy=RefreshPolicy.PERIODIC, period_epochs=3)
    _, out0 = apply_refresh(
        jax.random.PRNGKey(0), st, targets, cfg, CircuitCost(), dcfg, rcfg, 0
    )
    _, out2 = apply_refresh(
        jax.random.PRNGKey(0), st, targets, cfg, CircuitCost(), dcfg, rcfg, 2
    )
    assert out0.n_reprogrammed == 0       # epoch 0: not due yet
    assert out2.n_reprogrammed == C       # epoch 2: (2+1) % 3 == 0


# ------------------------------------------------------- deploy state
def test_deploy_arrays_rematerialize_matches_deploy_params():
    key = jax.random.PRNGKey(0)
    params = {
        "blk": {"w": jax.random.normal(key, (40, 24)) * 0.3},
        "norm": jnp.ones((24,)),
    }
    cfg = WVConfig(method=WVMethod.HARP)
    dense, rep_a = deploy_params(jax.random.PRNGKey(7), params, cfg)
    deployed, rep_b = deploy_arrays(jax.random.PRNGKey(7), params, cfg)
    mat = deployed.materialize()
    # Bit-identical round-trip: same keys, same WV trajectory.
    np.testing.assert_array_equal(
        np.asarray(dense["blk"]["w"]), np.asarray(mat["blk"]["w"])
    )
    np.testing.assert_array_equal(
        np.asarray(params["norm"]), np.asarray(mat["norm"])
    )
    assert rep_a.rms_cell_error_lsb == pytest.approx(rep_b.rms_cell_error_lsb)
    assert rep_a.num_columns == rep_b.num_columns == deployed.num_columns

    # update_array propagates into the next materialization.
    name = next(iter(deployed.arrays))
    arr = deployed.arrays[name]
    deployed.update_array(name, arr.targets.astype(jnp.float32))
    perfect = deployed.materialize()
    assert not np.array_equal(
        np.asarray(perfect["blk"]["w"]), np.asarray(mat["blk"]["w"])
    )


def test_lifetime_simulator_end_to_end():
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (32, 12)) * 0.3}
    cfg = WVConfig(method=WVMethod.HARP)
    deployed, _ = deploy_arrays(jax.random.PRNGKey(1), params, cfg)
    swaps = []
    sim = LifetimeSimulator(
        jax.random.PRNGKey(2),
        deployed,
        drift_cfg=DriftConfig(nu_drift=0.05),
        refresh_cfg=RefreshConfig(policy=RefreshPolicy.VERIFY_TRIGGERED),
        on_refresh=lambda p: swaps.append(p),
    )
    report = sim.run(
        epochs=3, dt_s=3600.0, reads_per_column=1e4,
        eval_fn=lambda p: float(jnp.mean(jnp.abs(p["w"] - params["w"]))),
    )
    assert len(report.records) == 3
    assert report.records[-1].t_s == pytest.approx(3 * 3600.0)
    assert all(r.eval_metric is not None for r in report.records)
    assert report.total_maintenance_energy_pj >= 0.0
    d = report.to_dict()
    assert d["policy"] == "verify_triggered" and len(d["records"]) == 3
    if any(r.columns_reprogrammed for r in report.records):
        assert swaps  # refresh hot-swapped params into the "engine"
