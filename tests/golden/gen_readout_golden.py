"""Regenerate tests/golden/readout_golden.npz.

The archive pins the exact numerical outputs of the verify / refresh /
CIM read paths under fixed PRNG keys.  It was captured from the
pre-readout-refactor tree (PR 3 head) and is asserted bit-exactly by
tests/test_readout.py, so the shared `repro.readout` subsystem is
provably a pure factoring — not a behaviour change.

Run from the repo root (only to re-pin after an INTENDED numerical
change, never to paper over an accidental one):

    PYTHONPATH=src python tests/golden/gen_readout_golden.py

``--check`` is the CI drift guard: it regenerates every array in memory
and fails (exit 1) unless each one is BIT-identical to the committed
archive — so the goldens can never silently go stale against the code,
and a numerical change can never ride in without re-pinning them.
(Array payloads are compared, not the npz container bytes: zip framing
is not reproducible across numpy versions.)

    PYTHONPATH=src python tests/golden/gen_readout_golden.py --check
"""

from __future__ import annotations

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.cim import CIMConfig, cim_matmul, tile
from repro.core import ADCConfig, CircuitCost, NoiseConfig, WVConfig, WVMethod
from repro.core import device as dev_mod
from repro.core import remap as remap_mod
from repro.core.cost import read_phase_cost
from repro.core.types import FaultConfig
from repro.core.wv import program_columns, verify_aggregate
from repro.lifetime.refresh import flag_columns
from repro.quant import QuantConfig, pack_columns, quantize_weight

OUT = os.path.join(os.path.dirname(__file__), "readout_golden.npz")

N = 16
METHODS = list(WVMethod)


def _cfg(method: WVMethod, **kw) -> WVConfig:
    return WVConfig(
        method=method,
        n_cells=N,
        adc=ADCConfig(bits=9),
        tau_w=4.0 * N / 32.0,
        noise=NoiseConfig(sigma_read_lsb=0.7, rho_cm=0.3),
        max_fine_iters=25,
        **kw,
    )


def generate() -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    tkey = jax.random.PRNGKey(0)
    targets = jax.random.randint(tkey, (12, N), 0, 8).astype(jnp.float32)
    g_free = targets + 0.4 * jax.random.normal(jax.random.PRNGKey(1), targets.shape)

    for m in METHODS:
        cfg = _cfg(m)
        # Full programming run (exercises the WV loop's whole key schedule).
        g, stats = jax.jit(lambda k, t: program_columns(k, t, cfg))(
            jax.random.PRNGKey(42), targets
        )
        out[f"prog_g_{m.value}"] = np.asarray(g)
        out[f"prog_energy_{m.value}"] = np.asarray(stats.energy_pj)
        out[f"prog_latency_{m.value}"] = np.asarray(stats.latency_ns)
        out[f"prog_reads_{m.value}"] = np.asarray(stats.reads)
        # One verify sweep on a free-floating state (pre-threshold outputs).
        agg, mag, ncmp, thr = verify_aggregate(
            jax.random.PRNGKey(5), g_free, targets, cfg
        )
        out[f"agg_{m.value}"] = np.asarray(agg)
        out[f"mag_{m.value}"] = np.asarray(mag)
        out[f"ncmp_{m.value}"] = np.asarray(ncmp)
        out[f"thr_{m.value}"] = np.asarray(thr, np.float32)
        # Per-column sub-stream (bucketed pipeline) RNG policy.
        col_ids = 100 + jnp.arange(targets.shape[0], dtype=jnp.int32)
        g_c, _ = jax.jit(
            lambda k, t, i: program_columns(k, t, cfg, col_ids=i)
        )(jax.random.PRNGKey(42), targets, col_ids)
        out[f"prog_g_colids_{m.value}"] = np.asarray(g_c)
        # Read-phase cost constants.
        lat, en = read_phase_cost(cfg, CircuitCost())
        out[f"cost_lat_{m.value}"] = np.asarray(lat)
        out[f"cost_en_{m.value}"] = np.asarray(en)

    # Fused Pallas in-loop path (HARP + HD-PV cover ternary & magnitude).
    for m in (WVMethod.HARP, WVMethod.HD_PV):
        cfg = _cfg(m, use_pallas=True)
        g, _ = jax.jit(lambda k, t: program_columns(k, t, cfg))(
            jax.random.PRNGKey(42), targets
        )
        out[f"prog_g_pallas_{m.value}"] = np.asarray(g)

    # Refresh: voted drift detection on a partially-drifted state.
    drift = jnp.zeros_like(targets).at[2].add(1.6).at[7, 3].add(-2.0)
    g_drift = targets + drift
    for m in (WVMethod.HARP, WVMethod.HD_PV, WVMethod.CW_SC):
        flagged, sweeps = flag_columns(
            jax.random.PRNGKey(9), g_drift, targets, _cfg(m)
        )
        out[f"flag_{m.value}"] = np.asarray(flagged)
        out[f"flag_sweeps_{m.value}"] = np.asarray(sweeps)

    # ---- robustness layer (DESIGN.md Sec. 15) -----------------------
    # Zero-fault invariance: the give-up/fault machinery enabled but
    # inert (generous budget, all-zero fault map) must regenerate the
    # PRE-robustness-layer programming arrays bit-exactly.  Asserted
    # here so the CI --check re-proves the invariance on every push.
    inert = dev_mod.empty_fault_map(targets.shape)
    for m in METHODS:
        cfg = _cfg(m).replace(give_up_pulses=500)
        g_z, _ = jax.jit(lambda k, t: program_columns(k, t, cfg, fault=inert))(
            jax.random.PRNGKey(42), targets
        )
        assert np.array_equal(np.asarray(g_z), out[f"prog_g_{m.value}"]), (
            f"zero-fault guarded programming drifted from prog_g_{m.value}"
        )

    # Pinned faulty-silicon path: one fault map (stuck/weak cells +
    # correlated per-tile rate field) and the bounded-retry outputs.
    fault_cfg = FaultConfig(
        p_stuck_hrs=0.06, p_stuck_lrs=0.03, p_weak=0.06,
        sigma_tile_fault_dec=0.5, columns_per_tile=4, tiles_per_chip=2,
    )
    col_ids = jnp.arange(targets.shape[0], dtype=jnp.int32)
    cfg_h = _cfg(WVMethod.HARP)
    fmap = dev_mod.sample_fault_map(
        jax.random.PRNGKey(42), col_ids, targets.shape, fault_cfg, cfg_h.device
    )
    out["fault_stuck"] = np.asarray(fmap.stuck)
    out["fault_stuck_g"] = np.asarray(fmap.stuck_g)
    out["fault_eff"] = np.asarray(fmap.efficiency)
    for m in (WVMethod.HARP, WVMethod.CW_SC):
        cfg = _cfg(m).replace(give_up_pulses=30)
        g_f, st_f = jax.jit(
            lambda k, t, c=cfg: program_columns(k, t, c, fault=fmap)
        )(jax.random.PRNGKey(42), targets)
        out[f"prog_g_fault_{m.value}"] = np.asarray(g_f)
        out[f"fault_gave_up_{m.value}"] = np.asarray(st_f.gave_up)
        out[f"fault_retry_{m.value}"] = np.asarray(st_f.retry_pulses)
    # Remap table built from the CW-SC give-up profile (2 spares,
    # fault-free spares so every wanted candidate is taken).
    cand = remap_mod.spare_candidates(st_f.gave_up, 2)
    tbl = remap_mod.build_table(st_f.gave_up, cand, jnp.zeros((2,)))
    out["remap_perm"] = np.asarray(tbl.perm)
    out["remap_active"] = np.asarray(tbl.active)

    # CIM analog matmul through macro tiles (noisy + quantized converters).
    w = jax.random.normal(jax.random.PRNGKey(3), (24, 8), jnp.float32)
    q, scale = quantize_weight(w, QuantConfig(weight_bits=6, cell_bits=3))
    cols, layout = pack_columns(q, N, 3, 2)
    g_cells = cols.astype(jnp.float32) + 0.2 * jax.random.normal(
        jax.random.PRNGKey(4), cols.shape
    )

    class _State:
        pass

    st = _State()
    st.g, st.layout, st.shape, st.scale = g_cells, layout, w.shape, scale
    cim_cfg = CIMConfig(
        macro_rows=16, dac_bits=5, adc_bits=9, sigma_read_lsb=0.4
    )
    cw = tile.build_weight(st, cim_cfg, jax.random.PRNGKey(7), "leaf")
    x = jax.random.normal(jax.random.PRNGKey(8), (5, 24), jnp.float32)
    out["cim_y"] = np.asarray(cim_matmul(x, cw))
    out["cim_y_ideal"] = np.asarray(
        cim_matmul(x, tile.build_weight(
            st, CIMConfig(dac_bits=None, adc_bits=None, sigma_read_lsb=0.0,
                          macro_rows=16),
            jax.random.PRNGKey(7), "leaf",
        ))
    )

    # ---- fused analog decode (DESIGN.md Sec. 17) --------------------
    # The fused single-dispatch forward must regenerate the pre-fusion
    # per-tile loop bit-exactly.  `_legacy_cim_matmul` below IS that
    # loop (kept verbatim as the oracle), so the CI --check re-proves
    # the fusion equivalence — noisy AND zero-noise — on every push.
    assert np.array_equal(
        np.asarray(_legacy_cim_matmul(x, cw)), out["cim_y"]
    ), "fused cim_matmul drifted from the pre-fusion per-tile loop (noisy)"
    cfg_clean = cim_cfg.replace(sigma_read_lsb=0.0)
    cw_clean = tile.build_weight(st, cfg_clean, jax.random.PRNGKey(7), "leaf")
    y_clean = cim_matmul(x, cw_clean)
    assert np.array_equal(
        np.asarray(_legacy_cim_matmul(x, cw_clean)), np.asarray(y_clean)
    ), "fused cim_matmul drifted from the pre-fusion per-tile loop (clean)"
    out["cim_y_zero_noise"] = np.asarray(y_clean)
    # Fused Pallas mega-kernel == scanned reference, bit for bit.
    for tag, base in (("", cim_cfg), ("_zero_noise", cfg_clean)):
        cw_p = tile.build_weight(
            st, base.replace(use_pallas=True), jax.random.PRNGKey(7), "leaf"
        )
        assert np.array_equal(
            np.asarray(cim_matmul(x, cw_p)), out[f"cim_y{tag}"]
        ), f"pallas tiled kernel diverged from reference (cim_y{tag})"
    # Request-id noise stream: rows keyed by request ids (not batch
    # slots) — the serving scheduler's batch-composition-invariant
    # stream, pinned with both executor-style uid and layer sub-streams.
    rids = jnp.array([11, 3, 7, 5, 2], jnp.int32)
    out["cim_y_rids"] = np.asarray(cim_matmul(x, cw, token_ids=rids))

    return out


def _legacy_cim_matmul(x, w):
    """The pre-fusion `cim_matmul` (PR 8 head), verbatim: Python-listed
    DAC planes, per-(tile, plane) noise draws concatenated per tile, and
    an eager per-tile accumulation loop.  The fused path must reproduce
    it bit-for-bit; kept here as the equivalence oracle for --check."""
    from repro.core import rng
    from repro.readout import noise as ro_noise
    from repro.cim.mvm import cim_vmm

    cfg = w.cfg
    lead, k = x.shape[:-1], x.shape[-1]
    xf = x.reshape(-1, k).astype(jnp.float32)
    t = xf.shape[0]
    n_mag = cfg.dac_bits - 1
    q_max = float((1 << n_mag) - 1)
    s_tok = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / q_max
    s_tok = jnp.maximum(s_tok, 1e-12)
    qq = jnp.clip(jnp.round(xf / s_tok), -q_max, q_max).astype(jnp.int32)
    pos, neg = jnp.maximum(qq, 0), jnp.maximum(-qq, 0)
    planes, weights = [], []
    for sign, mag in ((1.0, pos), (-1.0, neg)):
        for b in range(n_mag):
            planes.append(((mag >> b) & 1).astype(jnp.float32))
            weights.append(sign * float(1 << b) * s_tok[:, 0])
    planes, weights = jnp.stack(planes), jnp.stack(weights)
    p = planes.shape[0]
    n_tiles, s, r, m = w.g_pos.shape
    pad = n_tiles * r - k
    if pad:
        planes = jnp.pad(planes, ((0, 0), (0, 0), (0, pad)))
    xp = planes.reshape(p * t, n_tiles * r)
    full_scale = cfg.full_scale_frac * 2.0 * r * float(w.levels - 1)
    acc = jnp.zeros((p * t, m), jnp.float32)
    for ti in range(n_tiles):
        noise = None
        if cfg.sigma_read_lsb > 0.0:
            k_tile = rng.fold_in(w.key, ti)
            noise = jnp.concatenate(
                [
                    ro_noise.sample_token_read_noise(
                        rng.fold_in(k_tile, pi), t, s, m, cfg.sigma_read_lsb
                    )
                    for pi in range(p)
                ],
                axis=1,
            )
        acc = acc + cim_vmm(
            xp[:, ti * r : (ti + 1) * r], w.g_pos[ti], w.g_neg[ti],
            bc=w.bc, adc_bits=cfg.adc_bits, full_scale=full_scale,
            noise=noise, use_pallas=cfg.use_pallas,
        )
    y = jnp.einsum("pt,ptm->tm", weights, acc.reshape(p, t, m))
    y = y * w.scale[None, :]
    return y.reshape(*lead, m).astype(x.dtype)


def check() -> int:
    """Regenerate in memory; compare bit-exactly against the committed npz."""
    fresh = generate()
    with np.load(OUT) as committed:
        drift = []
        missing = sorted(set(fresh) ^ set(committed.files))
        for k in sorted(set(fresh) & set(committed.files)):
            a, b = fresh[k], committed[k]
            if a.shape != b.shape or a.dtype != b.dtype or not np.array_equal(
                a, b, equal_nan=True
            ):
                drift.append(k)
    if missing or drift:
        print(
            f"GOLDEN DRIFT vs {OUT}:\n"
            f"  key set mismatch: {missing or 'none'}\n"
            f"  diverged arrays:  {drift or 'none'}\n"
            "If the numerical change is INTENDED, re-pin with\n"
            "  PYTHONPATH=src python tests/golden/gen_readout_golden.py",
            file=sys.stderr,
        )
        return 1
    print(f"golden check OK: {len(fresh)} arrays bit-identical to {OUT}")
    return 0


def main() -> None:
    if "--check" in sys.argv:
        sys.exit(check())
    out = generate()
    np.savez_compressed(OUT, **out)
    print(f"wrote {OUT}: {len(out)} arrays")


if __name__ == "__main__":
    main()
