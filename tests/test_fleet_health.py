"""Fleet health & SLO contracts (DESIGN.md Sec. 16).

Tier-1 versions of what benchmarks/fleet_health.py asserts at scale:

* per-tile health maps reduce device-side and ride the deploy's single
  host sync (no extra fetch for the maps or the deploy digests);
* the lifetime scrub populates drift/give-up health state and the
  refresh-debt gauge on its existing epoch sync;
* declarative SLO rules resolve dotted metric paths (including literal
  dotted key names), treat missing metrics as non-breaching, and fire
  exactly when injected degradation crosses the ceiling — a sick chip's
  stuck-cell population surfaces give-ups only when ITS scrub runs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import WVConfig, WVMethod, pipeline
from repro.core.programmer import deploy_arrays
from repro.core.types import FaultConfig
from repro.lifetime import LifetimeSimulator
from repro.lifetime.refresh import RefreshConfig, RefreshPolicy
from repro.obs import metrics
from repro.obs.health import resolve_metric


@pytest.fixture(autouse=True)
def _fresh_obs():
    obs.reset_all()
    yield
    obs.reset_all()


def _tiny_params():
    k = jax.random.split(jax.random.PRNGKey(0), 2)
    return {
        "wa": jax.random.normal(k[0], (32, 48)) * 0.02,
        "wb": jax.random.normal(k[1], (48, 32)) * 0.02,
        "norm": jnp.ones((32,)),
    }


_WV = WVConfig(method=WVMethod.HARP, give_up_pulses=80)


# ------------------------------------------------------------- SLO rules
def test_slo_rule_resolution_and_missing_metric():
    status = {
        "digests": {"rep0.latency_steps": {"p99": 40.0, "count": 7.0}},
        "health": {"gauges": {"fleet.give_up_rate": 2e-3}},
        "counters": {"lifetime.gave_up_cells": 12.0},
    }
    # dotted digest name + summary field resolve longest-prefix-first
    assert resolve_metric(status, "digests.rep0.latency_steps.p99") == 40.0
    assert resolve_metric(status, "health.gauges.fleet.give_up_rate") == 2e-3
    assert resolve_metric(status, "digests.rep9.latency_steps.p99") is None

    hit = obs.SLORule("p99", "digests.rep0.latency_steps.p99", 30.0)
    ok = obs.SLORule("p99_ok", "digests.rep0.latency_steps.p99", 50.0)
    missing = obs.SLORule("gone", "digests.rep9.latency_steps.p99", 1.0)
    assert hit.evaluate(status)["breached"] is True
    assert ok.evaluate(status)["breached"] is False
    res = missing.evaluate(status)
    assert res["value"] is None and res["breached"] is False


def test_slo_policy_counters_and_trace_gating():
    status = {"digests": {}, "health": {"gauges": {"g": 3.0}}, "counters": {}}
    policy = obs.SLOPolicy(rules=(obs.SLORule("g_high", "health.gauges.g", 1.0),))
    policy.evaluate(status, window=0)
    with obs.disabled():
        policy.evaluate(status, window=1)
    # counters are contract-bearing: they count even while disabled
    assert metrics.value("slo.breaches.g_high") == 2.0
    assert metrics.value("slo.evaluations") == 2.0
    # trace instants are presentation: only the enabled evaluation emits
    slo_events = [
        e for e in obs.trace.events() if e.get("cat") == "slo"
    ]
    assert len(slo_events) == 1
    assert slo_events[0]["args"]["window"] == 0
    assert slo_events[0]["args"]["value"] == 3.0


def test_fleet_status_joins_namespaces():
    obs.digests.observe("d", 2.0, lo=0.0, hi=4.0, n_buckets=4)
    obs.health_registry.set_gauge("g", 1.0)
    metrics.registry.inc("c", 5.0)
    status = obs.fleet_status(extra={"fleet": {"inject_window": 2}})
    assert status["digests"]["d"]["count"] == 1.0
    assert status["health"]["gauges"]["g"] == 1.0
    assert status["counters"]["c"] == 5.0
    assert status["fleet"]["inject_window"] == 2


# ---------------------------------------------------- deploy health maps
def test_deploy_health_rides_single_sync():
    """Tile health maps + deploy digests populate on the batched
    deploy's ONE host sync — faulty silicon shows up as per-tile
    give-up mass without any extra fetch."""
    fc = FaultConfig(p_stuck_hrs=0.05, columns_per_tile=16, tiles_per_chip=4)
    pipeline.reset_counters()
    deploy_arrays(jax.random.PRNGKey(3), _tiny_params(), _WV, fault_cfg=fc)
    assert pipeline.host_sync_count() == 1
    tiles = obs.health_registry.tiles("deploy.gave_up_cells")
    assert tiles and sum(tiles.values()) > 0
    assert obs.health_registry.tiles("deploy.write_pulses")
    for name in ("deploy.write_pulses_per_column",
                 "deploy.iterations_per_column"):
        d = obs.digests.get(name)
        assert d is not None and d.count > 0


# ------------------------------------- injected degradation -> SLO epoch
def test_give_up_slo_fires_only_when_sick_scrub_runs():
    """Two chips, one sick (stuck cells), staggered scrubs: the
    give-up-rate rule stays green while only the healthy chip scrubs
    and breaches exactly when the sick chip's deferred scrub surfaces
    its bad silicon."""
    params = _tiny_params()
    dep_h, _ = deploy_arrays(jax.random.PRNGKey(1), params, _WV)
    fc = FaultConfig(p_stuck_hrs=0.05, columns_per_tile=16, tiles_per_chip=4)
    dep_s, rep_s = deploy_arrays(
        jax.random.PRNGKey(2), params, _WV, fault_cfg=fc
    )
    assert rep_s.total_gave_up_cells > 0  # the bad silicon is real
    n_cells = sum(
        int(np.prod(a.g.shape))
        for d in (dep_h, dep_s)
        for a in d.arrays.values()
    )
    sim_h = LifetimeSimulator(
        jax.random.PRNGKey(4), dep_h,
        refresh_cfg=RefreshConfig(policy=RefreshPolicy.VERIFY_TRIGGERED),
        columns_per_tile=16,
    )
    sim_s = LifetimeSimulator(
        jax.random.PRNGKey(5), dep_s,
        refresh_cfg=RefreshConfig(policy=RefreshPolicy.VERIFY_TRIGGERED),
        columns_per_tile=16,
    )
    policy = obs.SLOPolicy(
        rules=(
            obs.SLORule(
                "give_up_rate", "health.gauges.fleet.give_up_rate", 3e-4
            ),
        )
    )

    def window(sims):
        for sim in sims:
            sim.step_epoch(10.0)
        gave_up = metrics.snapshot().get("lifetime.gave_up_cells", 0.0)
        obs.health_registry.set_gauge("fleet.give_up_rate", gave_up / n_cells)
        (res,) = policy.evaluate(obs.fleet_status())
        return res

    # windows 0-1: only the healthy chip scrubs -> green
    assert window([sim_h])["breached"] is False
    assert window([sim_h])["breached"] is False
    # window 2: the sick chip's deferred scrub runs -> breach
    res = window([sim_h, sim_s])
    assert res["breached"] is True, res
    # the scrub also populated drift health + the refresh-debt gauge
    assert obs.health_registry.tiles("lifetime.drift_rms_lsb")
    gauges = obs.health_registry.snapshot()["gauges"]
    assert "lifetime.refresh_debt_epochs" in gauges
    d = obs.digests.get("lifetime.drift_lsb")
    assert d is not None and d.count > 0
